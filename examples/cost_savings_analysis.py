"""Reproduce the paper's headline numbers (§6.4 Figure 5): 48.8% average
cost saving and 27.6% carbon saving at provider scale, plus the per-case
study table.

    PYTHONPATH=src python examples/cost_savings_analysis.py
"""
import sys
sys.path.insert(0, "src")


def main():
    from repro.sim.provider_scale import (FIGURE5_CONTRIB, PAPER_CARBON_SAVING,
                                          PAPER_TOTAL_SAVING, evaluate)
    r = evaluate()
    print("=== Provider-scale savings (paper Figure 5) ===")
    print(f"  paper:        cost -{PAPER_TOTAL_SAVING:.1%}  "
          f"carbon -{PAPER_CARBON_SAVING:.1%}")
    print(f"  independence: cost -{r.saving_independence:.1%}  "
          f"carbon -{r.carbon_independence:.1%}")
    print(f"  calibrated:   cost -{r.saving_calibrated:.1%}  "
          f"carbon -{r.carbon_calibrated:.1%}  (rho={r.rho:.3f})")
    print("  per-optimization contributions (ours vs paper):")
    for o, v in sorted(r.contrib_independence.items(), key=lambda kv: -kv[1]):
        p = FIGURE5_CONTRIB.get(o)
        print(f"    {o:20s} {v:6.1%}" + (f"  (paper {p:.1%})" if p else ""))

    print("\n=== Case studies ===")
    from repro.sim.casestudies.bigdata import run_all
    b = run_all()
    print(f"  §6.1 big data: wi_deploy {b['wi_deploy']['slowdown_x']:.2f}x "
          f"-{b['wi_deploy']['cost_saving']:.1%} | wi_full "
          f"{b['wi_full']['slowdown_x']:.2f}x "
          f"-{b['wi_full']['cost_saving']:.1%} "
          f"(paper: 2.1x -92.6% | ~1.7x -93.5%)")
    from repro.sim.casestudies.microservices import run as ms
    m = ms()
    print(f"  §6.2 microservices: p99 {m['baseline']['p99_ms']:.0f}->"
          f"{m['wi']['p99_ms']:.0f} ms, cost "
          f"-{m['summary']['cost_saving']:.1%} (paper: 376->332, -44%)")
    from repro.sim.casestudies.videoconf import run as vc
    v = vc()["summary"]
    print(f"  §6.3 videoconf: cost -{v['cost_saving']:.1%}, carbon "
          f"-{v['carbon_saving']:.1%}, rate +{v['rate_improvement']:.1%}, "
          f"spikes +{v['spike_rate_improvement']:.1%} "
          f"(paper: -26.3%, -51%, +35.4%, +22%)")
    print("OK")


if __name__ == "__main__":
    main()
