"""Demo: the hint-aware platform scheduler end to end.

1. Build a two-region cluster and register workloads whose WI hints differ:
   a spread-hard frontend, a region-agnostic flexible service, and a spot
   pool with a generous hinted eviction-notice window.
2. Place everything (anti-affinity, cheapest region, p95 oversubscription).
3. Hit the platform with a power event and a capacity crunch and watch the
   eviction pipeline pay every hinted notice window before killing.

    PYTHONPATH=src python examples/sched_cluster_demo.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import hints as H
from repro.sched import Scheduler
from repro.sim.cluster import VM


def main():
    s = Scheduler()
    for r in ("region-0", "region-green"):
        for i in range(4):
            s.cluster.add_server(f"{r}/s{i}", 32, region=r)

    s.gm.register_workload("frontend", {"availability_nines": 4.0})
    s.gm.register_workload("flex", {
        "scale_out_in": True, "scale_up_down": True,
        "region_independent": True, "delay_tolerance_ms": 5_000.0,
        "availability_nines": 3.0})
    s.gm.register_workload("spotpool", {
        "preemptibility_pct": 90.0, "availability_nines": 1.0,
        "delay_tolerance_ms": 60_000.0, "x-eviction-notice-s": 120.0})

    for i in range(3):
        s.submit(VM(f"fe-{i}", "frontend", "", 8, util_p95=0.8))
    for i in range(4):
        s.submit(VM(f"fx-{i}", "flex", "", 8, util_p95=0.3))
    for i in range(6):
        s.submit(VM(f"sp-{i}", "spotpool", "", 4, util_p95=0.2, spot=True))

    print("placement decisions:")
    for d in s.schedule_pending():
        print(f"  {d.vm_id:6s} -> {d.server or '(pending)':18s} "
              f"region={d.region or '-':12s} oversub={d.oversubscribed}")
    fe = {d.server for d in s.decisions if d.workload == "frontend"}
    assert len(fe) == 3, "anti-affinity spread: one frontend per server"

    print("\npower event on a frontend server:")
    srv = sorted(fe)[-1]        # the server also hosting the spot pool
    r = s.power_event(srv, shed_frac=0.5)
    print(f"  throttles={r['throttles']} evictions={r['evictions']}")

    print("\ncapacity crunch in region-0 (spot reclaim, 120s notice):")
    r = s.capacity_crunch("region-0", cores_needed=8)
    print(f"  freed={r['freed_cores']} evictions={r['evictions']}")
    s.run_until(300.0)
    for t in s.evictor.log:
        print(f"  evicted {t.vm_id}: notice={t.notice_s}s "
              f"lead_time={t.lead_time_s}s")
    assert not s.evictor.violations(), "every notice window honored"

    print("\ntelemetry:", s.telemetry())
    print("OK")


if __name__ == "__main__":
    main()
