"""Quickstart: the WI hint loop end to end, in one minute on CPU.

1. Start a WI global manager (bus + durable store + coordinator).
2. Register a workload with deployment hints.
3. A VM publishes runtime hints through its local manager.
4. An optimization policy (Spot) picks eviction victims straight off the
   cluster state + hints and notifies the workload through the
   platform-hint channel.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys
sys.path.insert(0, "src")

from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.local_manager import LocalManager
from repro.core.optimizations import SpotPolicy
from repro.sim.cluster import VM, Cluster


def main():
    gm = GlobalManager(hint_rate_per_s=100, hint_burst=100)

    # deployment-time hints (the seven paper hints; anything omitted is
    # assumed most-conservative)
    gm.register_workload("batch-analytics", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 70.0, "delay_tolerance_ms": 30_000.0,
        "availability_nines": 3.0,
    })
    gm.register_workload("frontend", {"availability_nines": 4.0})

    # per-server local manager + guest endpoints
    lm = LocalManager("rack0/srv0", gm.bus, clock=gm.clock)
    vm_a = lm.attach_vm("vm-analytics", "batch-analytics")
    vm_f = lm.attach_vm("vm-frontend", "frontend")
    vm_a.on_event(lambda e: print(f"  [vm-analytics] got platform hint: "
                                  f"{e['event']} deadline={e['deadline_s']}s"))

    # runtime hint from inside the VM (Hyper-V KVP / XenStore analogue)
    vm_a.set_runtime_hints({"preemptibility_pct": 95.0})
    print("effective hints for batch-analytics VM:",
          gm.effective_hints("batch-analytics", "rack0/srv0/vm-analytics"))

    # the Spot optimization needs capacity: it consults hints, not guesses
    cluster = Cluster()
    cluster.add_server("rack0/srv0", 64)
    cluster.add_vm(VM("vm-analytics", "batch-analytics", "rack0/srv0", 16,
                      spot=True))
    cluster.add_vm(VM("vm-frontend", "frontend", "rack0/srv0", 16, spot=True))
    spot = SpotPolicy(gm)
    actions = spot.reclaim_cores(cluster, cores_needed=16)
    print("spot eviction decisions:", [(a.kind, a.vm) for a in actions])
    assert actions[0].vm == "vm-analytics"   # hints drove the choice
    print("aggregated per-rack view:", gm.aggregate("rack"))
    print("OK")


if __name__ == "__main__":
    main()
