"""End-to-end driver: train a ~100M-parameter LM under WI with live platform
events — eviction mid-run (elastic shrink), harvest offer (grow back),
throttle (microbatch switch) — and verify the loss keeps descending.

Run with 8 virtual devices so the mesh can actually resize:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/elastic_spot_training.py \
        [--steps 300] [--d-model 512]

(The default --steps 60 keeps CPU runtime modest; --steps 300+ shows a
clean loss curve.)
"""
import argparse
import os
import sys
import tempfile

if "--xla8" not in os.environ.get("_WI_SENTINEL", ""):
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
sys.path.insert(0, "src")

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=4096)
    args = ap.parse_args()

    import dataclasses
    import jax
    from repro.configs.archs import ARCHS
    from repro.configs.base import RunConfig
    from repro.core.global_manager import GlobalManager
    from repro.models.model import count_params
    from repro.runtime.faults import FaultInjector
    from repro.runtime.trainer import WITrainer

    cfg = dataclasses.replace(
        ARCHS["minitron-8b"], name="minitron-100m",
        n_layers=args.layers, d_model=args.d_model, n_heads=8, n_kv_heads=4,
        head_dim=args.d_model // 8, d_ff=4 * args.d_model,
        vocab_size=args.vocab, act_dtype="float32")
    print(f"model: {count_params(cfg)/1e6:.1f}M params, "
          f"{jax.device_count()} devices")

    rcfg = RunConfig(model=cfg, learning_rate=3e-3, warmup_steps=20,
                     total_steps=args.steps)
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    ckpt_dir = tempfile.mkdtemp(prefix="wi-elastic-")
    tr = WITrainer(rcfg, gm, ckpt_dir=ckpt_dir, model_axis=2, ckpt_every=10,
                   batch_override=16, seq_override=128)
    inj = FaultInjector(gm, "train-job")

    third = max(args.steps // 3, 5)

    def hooks(t):
        if t.step == third:
            print(f"  step {t.step}: PLATFORM EVENT eviction of 4 devices")
            inj.evict(n_devices=4)
        if t.step == 2 * third:
            print(f"  step {t.step}: PLATFORM EVENT harvest offer (+4)")
            inj.offer_capacity(n_devices=4)

    tr.run(args.steps, step_callback=hooks)
    losses = [m["loss"] for m in tr.metrics_log]
    dps = [m["dp"] for m in tr.metrics_log]
    for i in range(0, len(losses), max(1, len(losses) // 12)):
        print(f"  step {i+1:4d} loss={losses[i]:7.4f} dp={dps[i]}")
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"dp trace: {sorted(set(dps))}")
    print("events:", [e["kind"] for e in tr.events_log])
    assert losses[-1] < losses[0], "loss did not descend"
    assert {2, 4} <= set(dps), "elastic resize did not happen"
    print("OK — training survived eviction + regrow with loss descending")


if __name__ == "__main__":
    main()
