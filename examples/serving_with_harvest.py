"""Serve a small LM with batched requests while reacting to WI platform
hints: harvest offers grow the decode batch slots, eviction notices drain.

    PYTHONPATH=src python examples/serving_with_harvest.py
"""
import sys
sys.path.insert(0, "src")

import numpy as np


def main():
    import jax
    from repro.configs.archs import smoke_config
    from repro.configs.base import ParallelConfig
    from repro.core import hints as H
    from repro.core.global_manager import GlobalManager
    from repro.core.local_manager import LocalManager
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config("minitron-8b")
    pcfg = ParallelConfig(data=1, model=1, attn_impl="dense", fsdp=False,
                          seq_shard_acts=False)
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("llm-serve", {
        "scale_up_down": True, "scale_out_in": True,
        "delay_tolerance_ms": 500.0, "preemptibility_pct": 30.0})
    lm = LocalManager("rack0/srv0", gm.bus, clock=gm.clock,
                      vm_hint_rate_per_s=1e6, vm_hint_burst=1e6)
    ep = lm.attach_vm("vm-serve", "llm-serve")

    eng = ServingEngine(cfg, pcfg, params, batch_slots=2, max_len=96)

    def on_event(e):
        if e["event"] == H.PlatformEvent.SCALE_UP_OFFER.value:
            # grow decode slots onto harvested capacity: new engine with
            # more slots; in-flight requests keep their caches... here we
            # drain-then-grow for simplicity
            print(f"  [serve] harvest offer: growing slots 2 -> 4")
            eng.grow_requested = True
    ep.on_event(on_event)
    eng.grow_requested = False

    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=6)
                    .astype(np.int32), max_new=8) for i in range(10)]
    for r in reqs[:6]:
        eng.submit(r)

    # the engine is a WI workload: utilization + queue depth become hints
    for tick in range(200):
        eng.step()
        if tick % 10 == 0:
            ep.set_runtime_hints({
                "x-utilization": eng.utilization(),
                "x-queue-depth": eng.queue_depth(),
                "preemptibility_pct": 20.0 if eng.utilization() > 0.5
                else 80.0})
        if tick == 20:
            # platform sees queue pressure -> harvest offer
            gm.publish_platform_hint(H.PlatformHint(
                event=H.PlatformEvent.SCALE_UP_OFFER.value,
                workload="llm-serve", resource="rack0/srv0/vm-serve",
                payload={"n_devices": 2}, source_opt="harvest"))
            for r in reqs[6:]:
                eng.submit(r)
        if eng.grow_requested:
            # migrate: finish current, rebuild with 4 slots
            eng.run_until_drained()
            done_tokens = {r.rid: r.out_tokens for r in reqs if r.done}
            eng2 = ServingEngine(cfg, pcfg, params, batch_slots=4, max_len=96)
            for r in reqs:
                if not r.done:
                    eng2.submit(r)
            eng2.stats.update(requests=eng.stats["requests"])
            eng = eng2
            eng.grow_requested = False
        if all(r.done for r in reqs):
            break
    eng.run_until_drained()
    assert all(r.done for r in reqs)
    print(f"served {len(reqs)} requests; engine stats: {eng.stats}")
    print("sample completion:", reqs[0].out_tokens)
    print("OK")


if __name__ == "__main__":
    main()
