"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the reproduced
quantity compared against the paper's value where applicable).

    PYTHONPATH=src python -m benchmarks.run [--only t1_survey,...]
"""
from __future__ import annotations

import argparse
import sys
import time


def _timed(fn, repeats=1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def t1_survey():
    """Table 1: workload characterization marginals."""
    from repro.sim.workload import (TABLE1_TARGETS, core_weighted_marginals,
                                    sample_population)
    us, marg = _timed(lambda: core_weighted_marginals(
        sample_population(20_000, seed=3)))
    err = 0.0
    n = 0
    for attr, target in TABLE1_TARGETS.items():
        tot = sum(target.values())
        for k, frac in target.items():
            err += abs(marg[attr].get(k, 0.0) - frac / tot)
            n += 1
    return us, f"mean_marginal_abs_err={err / n:.4f} (target<0.02)"


def t2_pricing():
    """Table 2: pricing & benefit models."""
    from repro.core.pricing import PRICING, combined_price
    def run():
        assert combined_price({"spot", "harvest"}) == \
            PRICING["harvest"].price_multiplier
        return {o: p.user_benefit for o, p in PRICING.items()}
    us, out = _timed(run, repeats=100)
    return us, "spot=0.85,harvest=0.91,rightsizing=0.50_ok"


def t3_applicability():
    """Table 3: applicability matrix from hints."""
    from repro.core import hints as H
    from repro.core.pricing import applicable_set
    from repro.sim.workload import sample_population
    def run():
        pop = sample_population(2000, seed=1)
        cores = sum(w.cores for w in pop)
        per_opt = {}
        for w in pop:
            for o in applicable_set(H.effective(w.hints())):
                per_opt[o] = per_opt.get(o, 0.0) + w.cores / cores
        return per_opt
    us, per_opt = _timed(run)
    return us, ";".join(f"{o}={v:.3f}" for o, v in sorted(per_opt.items()))


def t4_conflicts():
    """Table 4 / Figure 3: priority conflict resolution."""
    from repro.core.coordinator import Claim, Coordinator
    def run():
        co = Coordinator(seed=0)
        co.set_capacity("s/cores", 10.0)
        g = co.submit([
            Claim("harvest", "w1", "s/cores", 8, False, 0.0),
            Claim("spot", "w2", "s/cores", 6, False, 0.0),
            Claim("on_demand", "w3", "s/cores", 7, False, 1.0)])
        return {x.claim.opt: x.amount for x in g}
    us, g = _timed(run, repeats=50)
    return us, (f"on_demand={g['on_demand']},spot={g['spot']},"
                f"harvest={g['harvest']}")


def f4_bigdata():
    """Figure 4: big-data case study (paper: 2.1x/-92.6%, 1.7x/-93.5%)."""
    from repro.sim.casestudies.bigdata import run_all
    us, r = _timed(lambda: run_all(seed=0))
    return us, (f"wi_deploy={r['wi_deploy']['slowdown_x']:.2f}x,"
                f"{r['wi_deploy']['cost_saving']:.3f};"
                f"wi_full={r['wi_full']['slowdown_x']:.2f}x,"
                f"{r['wi_full']['cost_saving']:.3f}")


def s62_microservices():
    """§6.2: microservices (paper: 376->332ms, -44% cost)."""
    from repro.sim.casestudies.microservices import run
    us, r = _timed(run)
    return us, (f"p99={r['baseline']['p99_ms']:.0f}->"
                f"{r['wi']['p99_ms']:.0f}ms,"
                f"cost_saving={r['summary']['cost_saving']:.3f}")


def s63_videoconf():
    """§6.3: video conferencing (paper: -26.3% cost, -51% carbon, +35.4%)."""
    from repro.sim.casestudies.videoconf import run
    us, r = _timed(run)
    s = r["summary"]
    return us, (f"cost={s['cost_saving']:.3f},carbon={s['carbon_saving']:.3f},"
                f"rate=+{s['rate_improvement']:.3f},"
                f"spikes=+{s['spike_rate_improvement']:.3f}")


def f5_savings():
    """Figure 5 / §6.4: provider-scale savings (paper: 48.8% / 27.6%)."""
    from repro.sim.provider_scale import evaluate
    us, r = _timed(evaluate)
    return us, (f"indep={r.saving_independence:.3f},"
                f"carbon={r.carbon_independence:.3f},"
                f"calibrated={r.saving_calibrated:.3f}(rho={r.rho:.3f})")


def wi_hint_throughput():
    """Scalability requirement (§3.2): hint ingest rate through the bus."""
    from repro.core.global_manager import GlobalManager
    gm = GlobalManager(hint_rate_per_s=1e9, hint_burst=1e9)
    gm.register_workload("w")
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        gm.set_hints("w", f"r{i % 50}",
                     {"preemptibility_pct": float(i % 100)},
                     source=f"s{i % 10}")
    dt = time.perf_counter() - t0
    return dt / n * 1e6, f"hints_per_s={n / dt:.0f}"


def kernel_flash():
    """Pallas flash-attention kernel vs oracle (interpret mode)."""
    import jax, jax.numpy as jnp
    from repro.configs.base import AttnConfig
    from repro.kernels.flash_attention import ops, ref
    cfg = AttnConfig(causal=True)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    us, out = _timed(lambda: ops.attention(q, k, v, cfg, 64, 64, True))
    err = float(jnp.abs(out - ref.reference(q, k, v, cfg)).max())
    return us, f"max_err={err:.2e}"


def roofline_table():
    """§Roofline: regenerate the table from dry-run records."""
    from pathlib import Path
    from repro.analysis.roofline import load_all, to_markdown
    def run():
        cells = load_all("results/dryrun")
        Path("results").mkdir(exist_ok=True)
        Path("results/roofline.md").write_text(to_markdown(cells))
        return [c for c in cells if c.status == "ok"]
    us, ok = _timed(run)
    if not ok:
        return us, "no dry-run records (run repro.launch.dryrun first)"
    worst = min(ok, key=lambda c: c.roofline_fraction)
    return us, (f"cells={len(ok)},worst={worst.arch}/{worst.shape}"
                f"@{worst.roofline_fraction:.1%}")


ALL = [t1_survey, t2_pricing, t3_applicability, t4_conflicts, f4_bigdata,
       s62_microservices, s63_videoconf, f5_savings, wi_hint_throughput,
       kernel_flash, roofline_table]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    names = args.only.split(",") if args.only else None
    print("name,us_per_call,derived")
    failed = []
    for fn in ALL:
        if names and fn.__name__ not in names:
            continue
        try:
            us, derived = fn()
            print(f"{fn.__name__},{us:.1f},{derived}", flush=True)
        except Exception as e:   # noqa: BLE001 — report and continue
            failed.append(fn.__name__)
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}", flush=True)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
