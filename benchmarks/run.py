"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (derived = the reproduced
quantity compared against the paper's value where applicable).

    PYTHONPATH=src python -m benchmarks.run [--only t1_survey,...]
    PYTHONPATH=src python -m benchmarks.run --only sched_scale,sched_scale_xl \
        --json BENCH_sched.json
    PYTHONPATH=src python -m benchmarks.run --profile sched_scale_xl \
        --json BENCH_sched.json

``--json PATH`` additionally writes the scheduler-scale metrics
(placements/s, eviction counts, violation counts) as JSON so the perf
trajectory is tracked across PRs (committed as ``BENCH_sched.json``),
plus a ``_meta`` entry (git sha, date, python, env size knobs) so a
number can always be traced back to the configuration that produced it.

``--profile NAMES`` arms the process-wide flight recorder
(``repro.obs.Tracer``) for the named benchmarks (they are added to the
run set): each writes a Chrome/Perfetto trace to
``traces/<name>.trace.json`` (open at https://ui.perfetto.dev) and its
JSON entry gains a ``profile`` block with the per-phase wall-clock
breakdown.  See docs/OBSERVABILITY.md.

Scheduler-scale benchmark sizes honor env overrides (used by the CI smoke
job to run a reduced configuration): ``SCHED_SCALE_SERVERS``,
``SCHED_SCALE_VMS``, ``SCHED_SCALE_XL_SERVERS``, ``SCHED_SCALE_XL_VMS``,
``AGENTS_DIURNAL_SERVERS``, ``AGENTS_DIURNAL_VM_SCALE``,
``E2E_SAVINGS_WORKLOADS``, ``E2E_SAVINGS_SERVERS``, ``AI_TRAINING_STEPS``,
``AI_TRAINING_SERVERS``.
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# scheduler-scale metrics stashed by benchmark functions for --json
JSON_METRICS = {}


def _timed(fn, repeats=1):
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn()
    us = (time.perf_counter() - t0) / repeats * 1e6
    return us, out


def _freeze_heap():
    """Move the fully-built benchmark state out of the GC's working set
    (the CPython-recommended practice for large static heaps): without
    this, gen-2 collections rescan hundreds of thousands of sim objects
    mid-measurement and dominate the timings."""
    gc.collect()
    gc.freeze()


def t1_survey():
    """Table 1: workload characterization marginals."""
    from repro.sim.workload import (TABLE1_TARGETS, core_weighted_marginals,
                                    sample_population)
    us, marg = _timed(lambda: core_weighted_marginals(
        sample_population(20_000, seed=3)))
    err = 0.0
    n = 0
    for attr, target in TABLE1_TARGETS.items():
        tot = sum(target.values())
        for k, frac in target.items():
            err += abs(marg[attr].get(k, 0.0) - frac / tot)
            n += 1
    return us, f"mean_marginal_abs_err={err / n:.4f} (target<0.02)"


def t2_pricing():
    """Table 2: pricing & benefit models."""
    from repro.core.pricing import PRICING, combined_price
    def run():
        assert combined_price({"spot", "harvest"}) == \
            PRICING["harvest"].price_multiplier
        return {o: p.user_benefit for o, p in PRICING.items()}
    us, out = _timed(run, repeats=100)
    return us, "spot=0.85,harvest=0.91,rightsizing=0.50_ok"


def t3_applicability():
    """Table 3: applicability matrix from hints."""
    from repro.core import hints as H
    from repro.core.pricing import applicable_set
    from repro.sim.workload import sample_population
    def run():
        pop = sample_population(2000, seed=1)
        cores = sum(w.cores for w in pop)
        per_opt = {}
        for w in pop:
            for o in applicable_set(H.effective(w.hints())):
                per_opt[o] = per_opt.get(o, 0.0) + w.cores / cores
        return per_opt
    us, per_opt = _timed(run)
    return us, ";".join(f"{o}={v:.3f}" for o, v in sorted(per_opt.items()))


def t4_conflicts():
    """Table 4 / Figure 3: priority conflict resolution."""
    from repro.core.coordinator import Claim, Coordinator
    def run():
        co = Coordinator(seed=0)
        co.set_capacity("s/cores", 10.0)
        g = co.submit([
            Claim("harvest", "w1", "s/cores", 8, False, 0.0),
            Claim("spot", "w2", "s/cores", 6, False, 0.0),
            Claim("on_demand", "w3", "s/cores", 7, False, 1.0)])
        return {x.claim.opt: x.amount for x in g}
    us, g = _timed(run, repeats=50)
    return us, (f"on_demand={g['on_demand']},spot={g['spot']},"
                f"harvest={g['harvest']}")


def f4_bigdata():
    """Figure 4: big-data case study (paper: 2.1x/-92.6%, 1.7x/-93.5%)."""
    from repro.sim.casestudies.bigdata import run_all
    us, r = _timed(lambda: run_all(seed=0))
    return us, (f"wi_deploy={r['wi_deploy']['slowdown_x']:.2f}x,"
                f"{r['wi_deploy']['cost_saving']:.3f};"
                f"wi_full={r['wi_full']['slowdown_x']:.2f}x,"
                f"{r['wi_full']['cost_saving']:.3f}")


def s62_microservices():
    """§6.2: microservices (paper: 376->332ms, -44% cost)."""
    from repro.sim.casestudies.microservices import run
    us, r = _timed(run)
    return us, (f"p99={r['baseline']['p99_ms']:.0f}->"
                f"{r['wi']['p99_ms']:.0f}ms,"
                f"cost_saving={r['summary']['cost_saving']:.3f}")


def s63_videoconf():
    """§6.3: video conferencing (paper: -26.3% cost, -51% carbon, +35.4%)."""
    from repro.sim.casestudies.videoconf import run
    us, r = _timed(run)
    s = r["summary"]
    return us, (f"cost={s['cost_saving']:.3f},carbon={s['carbon_saving']:.3f},"
                f"rate=+{s['rate_improvement']:.3f},"
                f"spikes=+{s['spike_rate_improvement']:.3f}")


def f5_savings():
    """Figure 5 / §6.4: provider-scale savings (paper: 48.8% / 27.6%)."""
    from repro.sim.provider_scale import evaluate
    us, r = _timed(evaluate)
    return us, (f"indep={r.saving_independence:.3f},"
                f"carbon={r.carbon_independence:.3f},"
                f"calibrated={r.saving_calibrated:.3f}(rho={r.rho:.3f})")


def e2e_savings():
    """§6.4 dynamically: a Table-3 fleet through the live scheduler with
    agents + billing meters recovers the 48.8% saving (±3pp), with zero
    notice violations and meters that reconcile with cluster core-hours.
    Sizes honor E2E_SAVINGS_WORKLOADS / E2E_SAVINGS_SERVERS."""
    from repro.sim.casestudies.e2e_savings import run
    n_workloads = int(os.environ.get("E2E_SAVINGS_WORKLOADS", 400))
    n_servers = int(os.environ.get("E2E_SAVINGS_SERVERS", 72))
    us, r = _timed(lambda: run(seed=0, n_workloads=n_workloads,
                               n_servers_per_region=n_servers))
    assert r["abs_err_vs_paper"] <= 0.03, \
        f"saving {r['saving']:.4f} off paper 0.488 by >3pp"
    assert r["abs_err_vs_analytic"] <= 0.03, \
        (f"saving {r['saving']:.4f} off the analytical "
         f"{r['analytic_calibrated']:.4f} by >3pp")
    assert r["violations"] == 0, f"{r['violations']} notice violations"
    assert r["early_releases"] > 0, "no eviction resolved by early release"
    assert r["reconcile_abs_diff"] <= 1e-6 * max(r["cluster_core_hours"],
                                                 1.0), \
        (f"billing meters diverged from cluster core-hours by "
         f"{r['reconcile_abs_diff']}")
    JSON_METRICS["e2e_savings"] = {
        "workloads": n_workloads, "servers_per_region": n_servers,
        "saving": round(r["saving"], 4),
        "paper_saving": r["paper_saving"],
        "analytic_calibrated": round(r["analytic_calibrated"], 4),
        "abs_err_vs_paper": round(r["abs_err_vs_paper"], 4),
        "expected_sampled": round(r["expected_sampled"], 4),
        "core_hours": round(r["core_hours"], 2),
        "violations": r["violations"],
        "evictions_killed": r["evictions_killed"],
        "early_releases": r["early_releases"],
        "replacements_placed": r["replacements_placed"],
        "defrag_migrations": r["defrag_migrations"],
        "reconcile_abs_diff": r["reconcile_abs_diff"],
        "obs_reconcile_ok": r["obs_reconcile_ok"],
        "obs_violations": r["obs_violations"],
        "obs_max_notice_s": r["obs_max_notice_s"],
        "obs_notice_to_ack_p100_s": r["obs_notice_to_ack_p100_s"],
        "obs_acks_observed": r["obs_acks_observed"],
    }
    return us, (f"saving={r['saving']:.3f}(paper=0.488,"
                f"err={r['abs_err_vs_paper']:.4f}),"
                f"violations={r['violations']},"
                f"killed={r['evictions_killed']},"
                f"early={r['early_releases']},"
                f"reconcile_diff={r['reconcile_abs_diff']:.2e}")


def _sched_scale_run(name, n_servers, cores, n_vms, n_workloads, regions,
                     storm_waves, storm_cores, seed=11):
    """Shared body for the scheduler scale benchmarks: pack ``n_vms`` onto
    ``n_servers`` across ``regions``, report placement throughput, then
    survive an eviction storm with every hinted notice window honored."""
    import random
    from repro import obs
    from repro.sched import Scheduler
    from repro.sim.cluster import VM, Region
    from repro.sim.workload import sample_population

    # a live registry + bus-fed lifecycle observer ride along (pull-based
    # collectors and one dict dispatch per batched record — nothing on the
    # timed placement path); the tracer stays the process default, so
    # spans only record under --profile
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(publish_decisions=True, metrics=registry)
    observer = obs.LifecycleObserver(s.gm.bus, registry=registry)
    for j, r in enumerate(regions):
        if r not in s.cluster.regions:
            s.cluster.add_region(Region(r, price=0.85 + 0.05 * j,
                                        carbon_g_kwh=300.0 + 60.0 * j))
    # region-0 is the conservative default for every region-fixed workload
    # (~57% of Table-1 cores), so it gets half the fleet; the remaining
    # regions split the other half and absorb the region-agnostic VMs
    for i in range(n_servers):
        region = (regions[0] if i % 2 == 0
                  else regions[1 + (i // 2) % (len(regions) - 1)])
        s.cluster.add_server(f"s{i}", cores, region=region)
    pop = sample_population(n_workloads, seed=seed)
    for w in pop:
        s.gm.register_workload(w.name, w.hints())
    rng = random.Random(seed)
    for i in range(n_vms):
        w = pop[i % n_workloads]
        vm_cores = rng.choice((2.0, 4.0, 8.0, 8.0, 16.0))
        s.submit(VM(f"vm{i}", w.name, "", vm_cores,
                    util_p95=rng.uniform(0.1, 0.9),
                    spot=w.preemptibility >= 20.0))
    _freeze_heap()
    try:
        t0 = time.perf_counter()
        s.schedule_pending()
        dt = time.perf_counter() - t0
    finally:
        gc.unfreeze()   # a raise must not pin this sim heap for the next
                        # benchmark in the same process
    placed = s.stats["placed"]
    rate = placed / dt if dt else float("inf")
    # eviction storm on top of the packed cluster, alternating regions
    for wave in range(storm_waves):
        region = regions[wave % len(regions)]
        s.engine.at(30.0 + wave * 60.0,
                    lambda r=region: s.capacity_crunch(r, storm_cores))
    s.run_until(30.0 + storm_waves * 60.0 + 600.0)
    violations = len(s.evictor.violations())
    assert placed >= int(0.95 * n_vms), f"only placed {placed}/{n_vms}"
    assert violations == 0, f"{violations} notice violations"
    kills = s.evictor.stats["kills"]
    # the bus-derived lifecycle books must match the pipeline's own, and
    # the histograms must respect the protocol: no kill leads under the
    # hinted window already asserted above, and the derived violation
    # count agrees with violations()
    life = observer.summary()
    recon = observer.reconcile(s.evictor)
    assert recon["ok"], recon["diffs"]
    assert life["violations"] == violations, (life["violations"], violations)
    JSON_METRICS[name] = {
        "servers": n_servers, "vms": n_vms, "regions": len(regions),
        "placed": placed, "placement_seconds": round(dt, 4),
        "placements_per_s": round(rate),
        "storm_evictions": kills, "storm_violations": violations,
        "storm_already_gone": s.evictor.stats.get("already_gone", 0),
        "storm_cancellations": s.evictor.stats.get("cancellations", 0),
        "min_lead_time_s": (None if s.evictor.min_lead_time_s() == float("inf")
                            else s.evictor.min_lead_time_s()),
        "lifecycle": {
            "reconcile_ok": recon["ok"],
            "violations": int(life["violations"]),
            "notices": int(life["notices"]),
            "max_notice_s": life["max_notice_s"],
            "kill_lead_s": life["kill_lead_s"],
            "notice_to_ack_s": life["notice_to_ack_s"],
        },
    }
    return dt * 1e6, (f"placed={placed}/{n_vms},servers={n_servers},"
                      f"placements_per_s={rate:.0f},"
                      f"storm_evictions={kills},"
                      f"storm_violations={violations}")


def sched_scale():
    """Platform-scheduler scale: pack >=10k VMs onto >=2k servers (two
    regions), then an eviction storm with every notice window honored."""
    n_servers = int(os.environ.get("SCHED_SCALE_SERVERS", 2048))
    n_vms = int(os.environ.get("SCHED_SCALE_VMS", 10_500))
    return _sched_scale_run("sched_scale", n_servers, 64, n_vms, 256,
                            ("region-0", "region-green"),
                            storm_waves=4, storm_cores=1500.0)


def sched_scale_xl():
    """Provider-scale stress: 100k VMs / 16k servers across four regions
    with an eviction storm mid-run — the paper's "millions of VMs" pitch
    scaled to what one benchmark process can hold (§6)."""
    n_servers = int(os.environ.get("SCHED_SCALE_XL_SERVERS", 16_384))
    n_vms = int(os.environ.get("SCHED_SCALE_XL_VMS", 100_000))
    return _sched_scale_run("sched_scale_xl", n_servers, 64, n_vms, 512,
                            ("region-0", "region-green", "region-2",
                             "region-3"),
                            storm_waves=6, storm_cores=4000.0)


def wi_hint_throughput():
    """Scalability requirement (§3.2): hint ingest rate through the bus."""
    from repro.core.global_manager import GlobalManager
    gm = GlobalManager(hint_rate_per_s=1e9, hint_burst=1e9)
    gm.register_workload("w")
    n = 2000
    t0 = time.perf_counter()
    for i in range(n):
        gm.set_hints("w", f"r{i % 50}",
                     {"preemptibility_pct": float(i % 100)},
                     source=f"s{i % 10}")
    dt = time.perf_counter() - t0
    return dt / n * 1e6, f"hints_per_s={n / dt:.0f}"


def kernel_flash():
    """Pallas flash-attention kernel vs oracle (interpret mode)."""
    import jax, jax.numpy as jnp
    from repro.configs.base import AttnConfig
    from repro.kernels.flash_attention import ops, ref
    cfg = AttnConfig(causal=True)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 128, 4, 32))
    k = jax.random.normal(ks[1], (1, 128, 2, 32))
    v = jax.random.normal(ks[2], (1, 128, 2, 32))
    us, out = _timed(lambda: ops.attention(q, k, v, cfg, 64, 64, True))
    err = float(jnp.abs(out - ref.reference(q, k, v, cfg)).max())
    return us, f"max_err={err:.2e}"


def roofline_table():
    """§Roofline: regenerate the table from dry-run records."""
    from pathlib import Path
    from repro.analysis.roofline import load_all, to_markdown
    def run():
        cells = load_all("results/dryrun")
        Path("results").mkdir(exist_ok=True)
        Path("results/roofline.md").write_text(to_markdown(cells))
        return [c for c in cells if c.status == "ok"]
    us, ok = _timed(run)
    if not ok:
        return us, "no dry-run records (run repro.launch.dryrun first)"
    worst = min(ok, key=lambda c: c.roofline_fraction)
    return us, (f"cells={len(ok)},worst={worst.arch}/{worst.shape}"
                f"@{worst.roofline_fraction:.1%}")


def agents_diurnal():
    """Bidirectional-loop scenario: workload agents under an eviction storm
    with diurnal hint adaptation (sizes honor AGENTS_DIURNAL_SERVERS /
    AGENTS_DIURNAL_VM_SCALE for the CI smoke job)."""
    from repro.sim.casestudies.diurnal_agents import run
    n_servers = int(os.environ.get("AGENTS_DIURNAL_SERVERS", 30))
    vm_scale = float(os.environ.get("AGENTS_DIURNAL_VM_SCALE", 1.0))
    us, r = _timed(lambda: run(seed=0, n_servers_per_region=n_servers,
                               vm_scale=vm_scale))
    assert r["violations"] == 0, f"{r['violations']} notice violations"
    assert r["early_releases"] > 0, "no eviction resolved by early release"
    assert r["lost_work_s_stateless"] == 0.0, "stateless workloads lost work"
    # the falsifiable form of the stateless bar: every noticed stateless VM
    # consented (acked) before the platform took it
    assert r["stateless_killed_without_ack"] == 0, \
        f"{r['stateless_killed_without_ack']} stateless VMs killed unacked"
    JSON_METRICS["agents_diurnal"] = {
        "servers_per_region": n_servers,
        "evictions_killed": r["evictions_killed"],
        "early_releases": r["early_releases"],
        "early_release_frac": round(r["early_release_frac"], 4),
        "violations": r["violations"],
        "lost_work_s": round(r["lost_work_s"], 2),
        "lost_work_s_stateless": r["lost_work_s_stateless"],
        "stateless_killed_without_ack": r["stateless_killed_without_ack"],
        "replacements_placed": r["replacements_placed"],
        "replacement_lead_s_mean": round(r["replacement_lead_s_mean"], 2),
        "hint_adaptations": r["hint_adaptations"],
        "hint_migrations": r["hint_migrations"],
        "obs_reconcile_ok": r["obs_reconcile_ok"],
        "obs_violations": r["obs_violations"],
        "obs_max_notice_s": r["obs_max_notice_s"],
        "obs_notice_to_ack_p100_s": r["obs_notice_to_ack_p100_s"],
        "obs_acks_observed": r["obs_acks_observed"],
    }
    return us, (f"early_frac={r['early_release_frac']:.2f},"
                f"killed={r['evictions_killed']},"
                f"lost_work_stateless={r['lost_work_s_stateless']:.0f}s,"
                f"repl_lead={r['replacement_lead_s_mean']:.0f}s,"
                f"violations={r['violations']}")


def ai_training():
    """Trainer-as-tenant scenario: the real WITrainer under the live
    scheduler (sim/casestudies/ai_training.py).  Runs in a subprocess so
    XLA_FLAGS can provide the 8 virtual host devices the elastic mesh
    needs; sizes honor AI_TRAINING_STEPS / AI_TRAINING_SERVERS."""
    import subprocess
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8")
    t0 = time.perf_counter()
    out = subprocess.run(
        [sys.executable, "-m", "repro.sim.casestudies.ai_training"],
        env=env, capture_output=True, text=True, timeout=540)
    us = (time.perf_counter() - t0) * 1e6
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert r["waves"] >= 2, r
    assert r["violations"] == 0, f"{r['violations']} notice violations"
    assert r["trainer_early_releases"] >= 1, \
        "no trainer eviction resolved by a guest ack"
    assert r["dp_min"] < r["dp0"], "DP width never shrank"
    assert r["dp_regrown"] > r["dp_min"], "DP width never re-grew"
    # only a ladder kill may lose work (an early release checkpoints and
    # consents first), and never more than one checkpoint interval of it —
    # with 0 ladder kills the bound is exactly 0
    assert r["lost_work_s"] <= \
        r["trainer_ladder_kills"] * r["ckpt_interval_s"] + 1e-9, \
        (f"lost work {r['lost_work_s']}s exceeds one checkpoint interval "
         f"per ladder kill")
    assert r["losses_finite"] and r["loss_last3"] < r["loss_first3"], \
        "loss curve broke across resizes"
    assert r["restores"] >= 1 and r["microbatch_final"] == 0, \
        "throttle -> microbatch-halve -> restore round trip incomplete"
    JSON_METRICS["ai_training"] = {
        "steps": r["steps"], "waves": r["waves"],
        "violations": r["violations"],
        "trainer_early_releases": r["trainer_early_releases"],
        "trainer_ladder_kills": r["trainer_ladder_kills"],
        "fleet_early_releases": r["fleet_early_releases"],
        "dp0": r["dp0"], "dp_min": r["dp_min"],
        "dp_regrown": r["dp_regrown"], "dp_final": r["dp_final"],
        "resizes": r["resizes"],
        "harvest_devices_granted": r["harvest_devices_granted"],
        "lost_work_s": r["lost_work_s"],
        "ckpt_interval_s": r["ckpt_interval_s"],
        "throttles": r["throttles"], "restores": r["restores"],
        "obs_reconcile_ok": r["obs_reconcile_ok"],
        "obs_violations": r["obs_violations"],
        "obs_max_notice_s": r["obs_max_notice_s"],
        "obs_notice_to_ack_p100_s": r["obs_notice_to_ack_p100_s"],
        "obs_acks_observed": r["obs_acks_observed"],
    }
    return us, (f"dp={r['dp0']}->{r['dp_min']}->{r['dp_regrown']},"
                f"early={r['trainer_early_releases']},"
                f"violations={r['violations']},"
                f"lost_work={r['lost_work_s']:.0f}s,"
                f"loss={r['loss_first3']:.2f}->{r['loss_last3']:.2f}")


def chaos_soak():
    """Chaos soak: the full WI loop under lossy channels, unannounced
    hardware crashes, and misbehaving guests — every invariant must still
    hold (the scenario asserts them internally; a failed bar raises).
    Fault rates honor CHAOS_DROP_P / CHAOS_DUP_P / CHAOS_DELAY_P /
    CHAOS_REORDER_P / CHAOS_CRASH_RATE; sizes honor CHAOS_SERVERS /
    CHAOS_VM_SCALE for the CI smoke job.  With every rate at 0 the
    ChaosBus is pass-through and the run degenerates to a clean fleet."""
    from repro.sim.casestudies.chaos_soak import (CRASH_RATE_PER_S, DELAY_P,
                                                  DROP_P, DUP_P, REORDER_P,
                                                  run)
    n_servers = int(os.environ.get("CHAOS_SERVERS", 24))
    vm_scale = float(os.environ.get("CHAOS_VM_SCALE", 1.0))
    knobs = {
        "drop_p": float(os.environ.get("CHAOS_DROP_P", DROP_P)),
        "dup_p": float(os.environ.get("CHAOS_DUP_P", DUP_P)),
        "delay_p": float(os.environ.get("CHAOS_DELAY_P", DELAY_P)),
        "reorder_p": float(os.environ.get("CHAOS_REORDER_P", REORDER_P)),
        "crash_rate_per_s": float(os.environ.get("CHAOS_CRASH_RATE",
                                                 CRASH_RATE_PER_S)),
    }
    us, r = _timed(lambda: run(seed=0, n_servers_per_region=n_servers,
                               vm_scale=vm_scale, **knobs))
    # the headline bars, re-asserted here so the benchmark log shows them
    assert r["violations"] == 0, f"{r['violations']} notice violations"
    assert r["stateless_killed_without_ack"] == 0
    assert r["obs_reconcile_ok"]
    assert r["billing_abs_diff"] < 1e-4, r["billing_abs_diff"]
    assert 0 < r["trainer_lost_steps"] <= r["trainer_ckpt_every"]
    JSON_METRICS["chaos_soak"] = {
        "servers_per_region": n_servers,
        "fault_rates": knobs,
        "violations": r["violations"],
        "notices": r["notices"],
        "killed": r["killed"],
        "early_released": r["early_released"],
        "crashed_vms": r["crashed_vms"],
        "crashed_tickets": r["crashed_tickets"],
        "crash_detect_max_s": round(r["crash_detect_max_s"], 2),
        "mttr_count": r["mttr_count"],
        "mttr_p95_s": round(r["mttr_p95_s"], 2),
        "reminders": r["reminders"],
        "acks_deduped": r["acks_deduped"],
        "silent_guests": r["silent_guests"],
        "bus_dropped": r["bus_dropped"],
        "bus_duplicated": r["bus_duplicated"],
        "bus_delayed": r["bus_delayed"],
        "bus_reordered": r["bus_reordered"],
        "spam_hints_sent": r["spam_hints_sent"],
        "spam_hints_accepted": r["spam_hints_accepted"],
        "rogue_notices_ignored": r["rogue_notices_ignored"],
        "rogue_self_crashes": r["rogue_self_crashes"],
        "alive_web": r["alive_web"],
        "alive_train": r["alive_train"],
        "trainer_steps": r["trainer_steps"],
        "trainer_lost_steps": r["trainer_lost_steps"],
        "trainer_ckpt_every": r["trainer_ckpt_every"],
        "trainer_corrupt_skipped": r["trainer_corrupt_skipped"],
        "stateless_killed_without_ack": r["stateless_killed_without_ack"],
        "billing_abs_diff": r["billing_abs_diff"],
        "obs_reconcile_ok": r["obs_reconcile_ok"],
    }
    return us, (f"crashes={r['crashed_vms']},"
                f"mttr_p95={r['mttr_p95_s']:.1f}s,"
                f"detect_max={r['crash_detect_max_s']:.1f}s,"
                f"dropped={r['bus_dropped']},"
                f"reminders={r['reminders']},"
                f"lost_steps={r['trainer_lost_steps']}"
                f"<= {r['trainer_ckpt_every']},"
                f"violations={r['violations']}")


def serving_fleet():
    """Serving-as-tenant scenario: synthetic-mode ServingEngine replicas
    under the live scheduler and wrk2-style open-loop diurnal traffic
    (sim/casestudies/serving_fleet.py).  Pure python — runs in-process;
    sizes honor SERVING_FLEET_SERVERS / SERVING_FLEET_DAY_S /
    SERVING_FLEET_PEAK_RPS."""
    from repro.sim.casestudies.serving_fleet import (DAY_S, N_SERVERS,
                                                     PEAK_RPS, run)
    us, r = _timed(lambda: run(
        seed=0,
        n_servers=int(os.environ.get("SERVING_FLEET_SERVERS", N_SERVERS)),
        day_s=float(os.environ.get("SERVING_FLEET_DAY_S", DAY_S)),
        peak_rps=float(os.environ.get("SERVING_FLEET_PEAK_RPS",
                                      PEAK_RPS))))
    # the headline bars, re-asserted here so the benchmark log shows them
    assert r["waves"] >= 2, r
    assert r["violations"] == 0, f"{r['violations']} notice violations"
    assert r["serving_early_releases"] >= 1, \
        "no serving eviction resolved by a drain ack"
    assert r["requests_lost"] == 0, \
        f"{r['requests_lost']} requests died with a drained replica"
    assert r["goodput_frac"] >= 0.95, r["goodput_frac"]
    assert r["e2e_p99_s"] <= r["p99_bound_s"], \
        f"e2e p99 {r['e2e_p99_s']:.2f}s blew the {r['p99_bound_s']}s bound"
    assert r["restores"] >= 1 and r["throttle_notices"] >= 1, \
        "throttle -> slot-halve -> restore round trip incomplete"
    assert r["scale_outs"] >= 1, "pressure hint never drove a scale-out"
    assert r["obs_reconcile_ok"]
    JSON_METRICS["serving_fleet"] = {
        "waves": r["waves"], "violations": r["violations"],
        "serving_early_releases": r["serving_early_releases"],
        "serving_ladder_kills": r["serving_ladder_kills"],
        "fleet_early_releases": r["fleet_early_releases"],
        "offered": r["offered"], "completed": r["completed"],
        "goodput_frac": round(r["goodput_frac"], 4),
        "goodput_rps": round(r["goodput_rps"], 3),
        "e2e_p50_s": round(r["e2e_p50_s"], 3),
        "e2e_p99_s": round(r["e2e_p99_s"], 3),
        "ttft_p99_s": round(r["ttft_p99_s"], 3),
        "token_p50_s": round(r["token_p50_s"], 4),
        "token_p99_s": round(r["token_p99_s"], 4),
        "p99_bound_s": r["p99_bound_s"],
        "requests_lost": r["requests_lost"],
        "requests_rerouted": r["requests_rerouted"],
        "drains": r["drains"],
        "throttle_notices": r["throttle_notices"],
        "restores": r["restores"],
        "harvest_slots_granted": r["harvest_slots_granted"],
        "ack_margin_min_s": round(r["ack_margin_min_s"], 2),
        "scale_outs": r["scale_outs"],
        "pressure_signals": r["pressure_signals"],
        "replicas_adopted": r["replicas_adopted"],
        "replicas_final": r["replicas_final"],
        "obs_reconcile_ok": r["obs_reconcile_ok"],
        "obs_max_notice_s": r["obs_max_notice_s"],
        "obs_notice_to_ack_p100_s": r["obs_notice_to_ack_p100_s"],
        "obs_acks_observed": r["obs_acks_observed"],
    }
    return us, (f"p50={r['e2e_p50_s']:.2f}s,p99={r['e2e_p99_s']:.2f}s,"
                f"goodput={r['goodput_frac']:.3f},"
                f"early={r['serving_early_releases']},"
                f"lost={r['requests_lost']:.0f},"
                f"scale_outs={r['scale_outs']},"
                f"violations={r['violations']}")


def sched_scenarios():
    """Eviction-storm + capacity-crunch scenarios (sched/ subsystem)."""
    from repro.sim.casestudies.capacity_crunch import run as run_crunch
    from repro.sim.casestudies.eviction_storm import run as run_storm
    us, storm = _timed(lambda: run_storm(seed=0))
    crunch = run_crunch(seed=0)
    assert storm["violations"] == 0 and crunch["eviction_violations"] == 0
    return us, (f"storm_evictions={storm['evictions']},"
                f"storm_violations={storm['violations']},"
                f"crunch_placed={crunch['placed_after_crunch']}"
                f"/{crunch['surge_vms']},"
                f"crunch_migrations={crunch['defrag_migrations']}")


_SIZE_KNOBS = ("SCHED_SCALE_SERVERS", "SCHED_SCALE_VMS",
               "SCHED_SCALE_XL_SERVERS", "SCHED_SCALE_XL_VMS",
               "AGENTS_DIURNAL_SERVERS", "AGENTS_DIURNAL_VM_SCALE",
               "E2E_SAVINGS_WORKLOADS", "E2E_SAVINGS_SERVERS",
               "AI_TRAINING_STEPS", "AI_TRAINING_SERVERS",
               "CHAOS_SERVERS", "CHAOS_VM_SCALE",
               "CHAOS_DROP_P", "CHAOS_DUP_P", "CHAOS_DELAY_P",
               "CHAOS_REORDER_P", "CHAOS_CRASH_RATE",
               "SERVING_FLEET_SERVERS", "SERVING_FLEET_DAY_S",
               "SERVING_FLEET_PEAK_RPS")


def _run_meta() -> dict:
    """Provenance for --json output: enough to reproduce the run the
    numbers came from (git sha + dirty marker, date, interpreter, the env
    size knobs in effect, the exact argv)."""
    import platform
    import subprocess
    meta = {
        "date_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "argv": sys.argv[1:],
        "env": {k: os.environ[k] for k in _SIZE_KNOBS if k in os.environ},
    }
    try:
        sha = subprocess.run(["git", "rev-parse", "HEAD"],
                             capture_output=True, text=True,
                             timeout=10).stdout.strip()
        dirty = subprocess.run(["git", "status", "--porcelain"],
                               capture_output=True, text=True,
                               timeout=10).stdout.strip()
        meta["git_sha"] = (sha + ("-dirty" if dirty else "")) if sha else None
    except Exception:   # noqa: BLE001 — provenance is best-effort
        meta["git_sha"] = None
    return meta


ALL = [t1_survey, t2_pricing, t3_applicability, t4_conflicts, f4_bigdata,
       s62_microservices, s63_videoconf, f5_savings, e2e_savings,
       sched_scale, sched_scale_xl, sched_scenarios, agents_diurnal,
       ai_training, chaos_soak, serving_fleet, wi_hint_throughput,
       kernel_flash, roofline_table]

# sched_scale_xl is opt-in on full runs (it needs ~100k simulated VMs);
# request it explicitly via --only
DEFAULT_SKIP = {"sched_scale_xl"}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write scheduler-scale metrics (BENCH_sched.json)")
    ap.add_argument("--profile", default=None, metavar="NAMES",
                    help="comma list of benchmarks to run with the flight "
                         "recorder armed; each writes "
                         "traces/<name>.trace.json (Perfetto) and adds a "
                         "per-phase breakdown to its --json entry")
    ap.add_argument("--trace-dir", default="traces",
                    help="where --profile writes trace files")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else None
    profile = set(args.profile.split(",")) if args.profile else set()
    valid = {fn.__name__ for fn in ALL}
    for label, requested in (("benchmark", names or []),
                             ("profile", sorted(profile))):
        unknown = [n for n in requested if n not in valid]
        if unknown:
            ap.error(f"unknown {label} name(s) {', '.join(unknown)}; "
                     f"valid names: {', '.join(sorted(valid))}")
    if profile:
        os.makedirs(args.trace_dir, exist_ok=True)
    print("name,us_per_call,derived")
    failed = []
    for fn in ALL:
        if names is not None:
            if fn.__name__ not in names and fn.__name__ not in profile:
                continue
        elif fn.__name__ in DEFAULT_SKIP and fn.__name__ not in profile:
            continue
        profiled = fn.__name__ in profile
        if profiled:
            # arm the process-wide flight recorder: schedulers constructed
            # inside the benchmark bind it automatically
            from repro import obs
            tracer = obs.Tracer(capacity=131_072)
            prev_tracer = obs.set_default_tracer(tracer)
        try:
            us, derived = fn()
            print(f"{fn.__name__},{us:.1f},{derived}", flush=True)
        except Exception as e:   # noqa: BLE001 — report and continue
            failed.append(fn.__name__)
            print(f"{fn.__name__},ERROR,{type(e).__name__}: {e}", flush=True)
        finally:
            if profiled:
                obs.set_default_tracer(prev_tracer)
        if profiled:
            trace_file = os.path.join(args.trace_dir,
                                      f"{fn.__name__}.trace.json")
            tracer.write(trace_file, process_name=f"wi-{fn.__name__}")
            JSON_METRICS.setdefault(fn.__name__, {})["profile"] = {
                "trace_file": trace_file,
                "events": tracer.recorded,
                "dropped": tracer.dropped,
                "phase_breakdown": {
                    k: {m: round(v, 6) for m, v in row.items()}
                    for k, row in sorted(
                        tracer.phase_breakdown().items())},
            }
            print(f"# wrote {trace_file} ({tracer.recorded} spans, "
                  f"{tracer.dropped} dropped)", file=sys.stderr)
    if args.json is not None:
        JSON_METRICS["_meta"] = _run_meta()
        with open(args.json, "w") as fh:
            json.dump(JSON_METRICS, fh, indent=2, sort_keys=True)
            fh.write("\n")
        print(f"# wrote {args.json}", file=sys.stderr)
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
