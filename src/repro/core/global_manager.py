"""WI Global Manager (paper §4.1-4.3): the per-region broker.

Logically centralized, physically distributed in production; here one object
owning the bus (Kafka stand-in), the store (CloudDB stand-in), safety
machinery, and the coordinator.  All hint traffic flows through it:

  deployment hints  --register_workload/set_hints(scope=deployment)--> store+bus
  runtime hints     --local managers publish to bus--> store (+opt managers)
  platform hints    --opt managers publish--> bus --> local managers --> VMs

Aggregation views (per-VM / per-server / per-rack / per-workload / region)
are computed from the store on demand (§4.1 "aggregate it at multiple
granularities").
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Callable, Dict, List, Optional, Tuple

from repro.core import hints as H
from repro.core.bus import Bus, Record
from repro.core.coordinator import Coordinator
from repro.core.envelope import KeyRegistry, seal, unseal
from repro.core.safety import ConsistencyChecker, RateLimiter
from repro.core.store import Store


class GlobalManager:
    def __init__(self, region: str = "region-0", bus: Optional[Bus] = None,
                 store: Optional[Store] = None, clock=None, seed: int = 0,
                 hint_rate_per_s: float = 10.0, hint_burst: float = 50.0):
        self.region = region
        self.clock = clock or (lambda: 0.0)
        self.bus = bus or Bus(clock=self.clock)
        self.store = store or Store()
        self.keys = KeyRegistry()
        self.coordinator = Coordinator(seed=seed, clock=self.clock)
        self.checker = ConsistencyChecker(self.clock)
        self._limits = {
            H.Scope.DEPLOYMENT.value: RateLimiter(hint_rate_per_s, hint_burst,
                                                  self.clock),
            H.Scope.RUNTIME.value: RateLimiter(hint_rate_per_s, hint_burst,
                                               self.clock),
            "platform": RateLimiter(hint_rate_per_s * 10, hint_burst * 10,
                                    self.clock),
        }
        self._seq = 0
        self.stats = defaultdict(int)
        # callbacks fired on every accepted set_hints(workload) — covers the
        # direct-store runtime path that never touches the bus
        self.hint_listeners: List[Callable[[str], None]] = []
        # ingest runtime hints published by local managers
        self.bus.subscribe(H.TOPIC_RUNTIME_HINTS, self._on_runtime_hint)

    # -- workload lifecycle ---------------------------------------------------
    def register_workload(self, workload: str,
                          deployment_hints: Optional[Dict[str, Any]] = None,
                          resources: Tuple[str, ...] = ("*",)) -> bytes:
        key = self.keys.provision(workload)
        self.store.put(f"workload/{workload}", {"resources": list(resources)})
        if deployment_hints:
            for r in resources:
                self.set_hints(workload, r, deployment_hints,
                               scope=H.Scope.DEPLOYMENT, source="deploy-api")
        return key

    # -- hint ingestion ---------------------------------------------------------
    def set_hints(self, workload: str, resource: str, hint_dict: Dict[str, Any],
                  scope: H.Scope = H.Scope.RUNTIME, source: str = "",
                  envelope: Optional[Dict[str, str]] = None) -> bool:
        """Returns True if accepted.  Rejections are counted + notified."""
        if not self._limits[scope.value].allow((workload, source)):
            self.stats["rejected_rate_limit"] += 1
            return False
        if envelope is not None:
            key = self.keys.key_for(workload)
            payload = unseal(key, envelope) if key else None
            if payload is None:
                self.stats["rejected_bad_envelope"] += 1
                return False
            hint_dict = payload
        try:
            hint_dict = H.validate_hints(hint_dict)
        except H.HintError:
            self.stats["rejected_invalid"] += 1
            return False
        verdict = self.checker.check(workload, resource, hint_dict)
        if not verdict.accepted:
            self.stats["rejected_inconsistent"] += 1
            self.notify_workload(workload, resource, "hints_ignored",
                                 {"reason": verdict.reason})
            return False
        self._seq += 1
        rec = H.HintRecord(workload=workload, resource=resource,
                           scope=scope.value, hints=hint_dict, source=source,
                           seq=self._seq, ts=self.clock())
        self.store.put(f"hints/{scope.value}/{workload}/{resource}",
                       json.loads(rec.to_json()))
        topic = (H.TOPIC_DEPLOY_HINTS if scope == H.Scope.DEPLOYMENT
                 else H.TOPIC_RUNTIME_HINTS)
        if scope == H.Scope.DEPLOYMENT:     # runtime hints already on the bus
            self.bus.publish(topic, json.loads(rec.to_json()), key=workload)
        for cb in self.hint_listeners:
            cb(workload)
        self.stats["accepted"] += 1
        return True

    def _on_runtime_hint(self, rec: Record):
        """Bus-side ingestion for hints published by local managers."""
        d = rec.value
        if not isinstance(d, dict) or "workload" not in d:
            return
        self.store.put(f"hints/runtime/{d['workload']}/{d['resource']}", d)

    # -- hint retrieval -----------------------------------------------------
    def effective_hints(self, workload: str, resource: str = "*"
                        ) -> Dict[str, Any]:
        """Conservative defaults <- deployment hints <- runtime hints."""
        out = dict(H.CONSERVATIVE)
        for scope in ("deployment", "runtime"):
            for res in ("*", resource):
                d = self.store.get(f"hints/{scope}/{workload}/{res}")
                if d and not H.HintRecord(**d).expired(self.clock()):
                    out.update({k: v for k, v in d["hints"].items()
                                if k in H.CONSERVATIVE or k.startswith("x-")})
        return out

    def raw_hints(self, workload: str) -> List[Dict[str, Any]]:
        return [v for _, v in self.store.scan("hints/")
                if v.get("workload") == workload]

    def purge_resource_hints(self, workload: str, resource: str):
        """Drop per-resource hint state once the resource is gone (its VM
        was killed) — under 100k-VM churn these entries otherwise grow
        without bound.  Workload-level ('*') hints are untouched.  The
        consistency checker's per-resource history goes with it: every
        evictor terminal outcome lands here, so safety state stays bounded
        under churn too."""
        if resource == "*":
            return
        for scope in ("deployment", "runtime"):
            self.store.delete(f"hints/{scope}/{workload}/{resource}")
        self.checker.forget(workload, resource)

    # -- aggregation (§4.1) ----------------------------------------------------
    def aggregate(self, level: str = "server") -> Dict[str, Dict[str, Any]]:
        """Aggregate numeric hints by resource prefix.

        Resources are hierarchical: 'rack/server/vm'.  level in
        {'vm','server','rack','workload','region'}.
        """
        buckets: Dict[str, List[Dict[str, Any]]] = defaultdict(list)
        for k, v in self.store.scan("hints/"):
            res = v.get("resource", "*")
            wl = v.get("workload", "?")
            parts = res.split("/") if res != "*" else []
            if level == "workload":
                key = wl
            elif level == "region":
                key = self.region
            elif level == "rack":
                key = parts[0] if parts else "*"
            elif level == "server":
                key = "/".join(parts[:2]) if len(parts) >= 2 else res
            else:
                key = res
            buckets[key].append(H.effective(v.get("hints", {})))
        out = {}
        for k, hs in buckets.items():
            agg: Dict[str, Any] = {"n": len(hs)}
            for hk in H.HINT_KEYS:
                vals = [h[hk] for h in hs]
                if isinstance(H.CONSERVATIVE[hk], bool):
                    agg[hk + "_frac"] = sum(bool(v) for v in vals) / len(vals)
                else:
                    agg[hk + "_min"] = min(vals)
                    agg[hk + "_mean"] = sum(vals) / len(vals)
            out[k] = agg
        return out

    # -- platform -> workload ---------------------------------------------------
    def publish_platform_hint(self, ph: H.PlatformHint) -> bool:
        if not self._limits["platform"].allow((ph.source_opt,)):
            self.stats["platform_rate_limited"] += 1
            return False
        self._seq += 1
        d = json.loads(ph.to_json())
        d["seq"] = self._seq
        d["ts"] = self.clock()
        self.store.put(f"events/{ph.workload}/{ph.resource}/{self._seq}", d)
        self.bus.publish(H.TOPIC_PLATFORM_HINTS, d, key=ph.resource)
        self.stats["platform_hints"] += 1
        return True

    def notify_workload(self, workload: str, resource: str, kind: str,
                        payload: Dict[str, Any]):
        self.publish_platform_hint(H.PlatformHint(
            event=kind, workload=workload, resource=resource,
            payload=payload, source_opt="global-manager"))

    def events_for(self, workload: str, since_seq: int = 0
                   ) -> List[Dict[str, Any]]:
        return [v for _, v in self.store.scan(f"events/{workload}/")
                if v["seq"] > since_seq]

    # -- teardown ----------------------------------------------------------
    def close(self):
        """Release file handles held by the owned store (WAL) and bus
        (durable segments).  Scenario teardown calls this so long soak
        runs don't leak descriptors; idempotent."""
        self.store.close()
        close_bus = getattr(self.bus, "close", None)
        if close_bus is not None:
            close_bus()
