"""Kafka-like pub/sub log bus (paper §4.2).

Semantics mirrored from Kafka because that is what WI deploys on:
  * named topics, each an append-only partitioned log,
  * publishers get (partition, offset) acks,
  * consumer groups with committed offsets (at-least-once delivery),
  * synchronous fan-out to push subscribers + pull (poll) interface,
  * optional durable segments on disk so a restarted manager resumes.

In-process and deterministic (no threads required; thread-safe anyway) —
this is the "user-space implementation of WI" the paper open-sources for
reproducibility (§6.1).
"""
from __future__ import annotations

import json
import os
import threading
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Tuple


class Record:
    __slots__ = ("topic", "partition", "offset", "key", "value", "ts")

    def __init__(self, topic, partition, offset, key, value, ts=0.0):
        self.topic, self.partition, self.offset = topic, partition, offset
        self.key, self.value, self.ts = key, value, ts

    def __repr__(self):
        return (f"Record({self.topic}[{self.partition}]@{self.offset} "
                f"key={self.key!r})")


class _Partition:
    def __init__(self):
        self.log: List[Tuple[Any, Any, float]] = []

    def append(self, key, value, ts) -> int:
        self.log.append((key, value, ts))
        return len(self.log) - 1


class Bus:
    """The WI message bus."""

    def __init__(self, n_partitions: int = 4, durable_dir: Optional[str] = None,
                 clock: Callable[[], float] = None):
        self._n = n_partitions
        self._topics: Dict[str, List[_Partition]] = {}
        self._groups: Dict[Tuple[str, str], Dict[int, int]] = {}
        self._subs: Dict[str, List[Callable[[Record], None]]] = {}
        self._lock = threading.RLock()
        self._clock = clock or (lambda: 0.0)
        self.published = 0      # records ever appended (all topics)
        self._dir = Path(durable_dir) if durable_dir else None
        # segment file handles stay open across publishes (reopening the
        # append fd per record dominated durable publish cost)
        self._handles: Dict[Tuple[str, int], Any] = {}
        self._part_cache: Dict[Any, int] = {}       # key -> crc partition
        if self._dir:
            self._dir.mkdir(parents=True, exist_ok=True)
            self._replay()

    # -- internals ---------------------------------------------------------
    def _topic(self, name: str) -> List[_Partition]:
        if name not in self._topics:
            self._topics[name] = [_Partition() for _ in range(self._n)]
            self._subs.setdefault(name, [])
        return self._topics[name]

    def _partition_for(self, key) -> int:
        if key is None:
            return 0
        try:
            p = self._part_cache.get(key)
        except TypeError:               # unhashable key: hash the repr
            return zlib.crc32(str(key).encode()) % self._n
        if p is None:
            p = zlib.crc32(str(key).encode()) % self._n
            if len(self._part_cache) < 65536:
                self._part_cache[key] = p
        return p

    def _segment_path(self, topic: str, part: int) -> Path:
        return self._dir / f"{topic.replace('/', '_')}.{part}.log"

    def _segment_handle(self, topic: str, part: int):
        fh = self._handles.get((topic, part))
        if fh is None or fh.closed:
            fh = self._segment_path(topic, part).open("a")
            self._handles[(topic, part)] = fh
        return fh

    def close(self):
        """Flush and close all durable segment handles (safe to re-publish
        afterwards: handles reopen lazily)."""
        with self._lock:
            for fh in self._handles.values():
                if not fh.closed:
                    fh.close()
            self._handles.clear()

    def __del__(self):     # best-effort: segments flush on GC too
        try:
            self.close()
        except Exception:
            pass

    def _replay(self):
        for f in sorted(self._dir.glob("*.log")):
            stem = f.name[: -len(".log")]
            topic, part = stem.rsplit(".", 1)
            parts = self._topic(topic)
            with f.open() as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        break   # torn tail write: ignore the rest
                    parts[int(part)].log.append(
                        (rec["k"], rec["v"], rec.get("ts", 0.0)))

    # -- producer ----------------------------------------------------------
    def publish(self, topic: str, value, key=None) -> Tuple[int, int]:
        with self._lock:
            parts = self._topic(topic)
            p = self._partition_for(key)
            ts = self._clock()
            off = parts[p].append(key, value, ts)
            self.published += 1
            if self._dir:
                fh = self._segment_handle(topic, p)
                fh.write(json.dumps({"k": key, "v": value, "ts": ts}) + "\n")
                fh.flush()
            rec = Record(topic, p, off, key, value, ts)
            subs = list(self._subs.get(topic, ()))
        for cb in subs:     # synchronous push delivery (§4.2)
            cb(rec)
        return p, off

    def publish_batch(self, topic: str, items) -> List[Tuple[int, int]]:
        """Publish many ``(key, value)`` pairs with one lock acquisition and
        one durable write+flush per touched partition (the eviction
        pipeline publishes a whole storm wave's notices at once).  Ack
        order and push-subscriber delivery order match ``publish`` called
        in a loop."""
        with self._lock:
            parts = self._topic(topic)
            ts = self._clock()
            subs = list(self._subs.get(topic, ()))
            acks: List[Tuple[int, int]] = []
            # Record objects exist only for push delivery: with no
            # subscriber on the topic (the telemetry common case) the batch
            # reduces to raw log appends
            recs: Optional[List[Record]] = [] if subs else None
            pending_io: Dict[int, List[str]] = {}
            logs = [part.log for part in parts]
            part_cache = self._part_cache
            durable = self._dir is not None
            for key, value in items:
                try:
                    p = part_cache.get(key)
                except TypeError:
                    p = None
                if p is None:
                    p = self._partition_for(key)
                log = logs[p]
                log.append((key, value, ts))
                off = len(log) - 1
                if durable:
                    pending_io.setdefault(p, []).append(
                        json.dumps({"k": key, "v": value, "ts": ts}))
                acks.append((p, off))
                if recs is not None:
                    recs.append(Record(topic, p, off, key, value, ts))
            self.published += len(acks)
            for p, lines in pending_io.items():
                fh = self._segment_handle(topic, p)
                fh.write("\n".join(lines) + "\n")
                fh.flush()
        if recs:            # synchronous push delivery (§4.2)
            for rec in recs:
                for cb in subs:
                    cb(rec)
        return acks

    # -- push subscription ---------------------------------------------------
    def subscribe(self, topic: str, callback: Callable[[Record], None]):
        with self._lock:
            self._topic(topic)
            self._subs[topic].append(callback)
        return lambda: self._subs[topic].remove(callback)

    # -- consumer groups (pull) ---------------------------------------------
    def poll(self, topic: str, group: str, max_records: int = 100
             ) -> List[Record]:
        with self._lock:
            parts = self._topic(topic)
            offsets = self._groups.setdefault((topic, group),
                                              {i: 0 for i in range(self._n)})
            out: List[Record] = []
            for p, part in enumerate(parts):
                start = offsets[p]
                end = min(len(part.log), start + max_records - len(out))
                if end <= start:
                    continue
                # fast path: slice the backlog once instead of indexing the
                # log per offset — huge backlogs pay one list copy, not a
                # Python-level loop of __getitem__ calls
                out.extend(Record(topic, p, off, k, v, ts)
                           for off, (k, v, ts)
                           in enumerate(part.log[start:end], start))
                # advance this partition's group offset by exactly what was
                # delivered, independent of where its records sit in `out`
                offsets[p] = end
                if len(out) >= max_records:
                    break
            return out

    def commit(self, topic: str, group: str, partition: int, offset: int):
        with self._lock:
            self._groups.setdefault((topic, group),
                                    {i: 0 for i in range(self._n)})[partition] \
                = offset + 1

    def seek_to_beginning(self, topic: str, group: str):
        with self._lock:
            self._groups[(topic, group)] = {i: 0 for i in range(self._n)}

    # -- introspection -------------------------------------------------------
    def topics(self) -> List[str]:
        """Topics that exist (published to or subscribed on), sorted."""
        with self._lock:
            return sorted(self._topics)

    def end_offsets(self, topic: str) -> Dict[int, int]:
        with self._lock:
            return {i: len(p.log) for i, p in enumerate(self._topic(topic))}

    def lag(self, topic: str, group: str) -> int:
        with self._lock:
            ends = self.end_offsets(topic)
            offs = self._groups.get((topic, group),
                                    {i: 0 for i in range(self._n)})
            return sum(ends[i] - offs.get(i, 0) for i in ends)
