"""Workload Intelligence hint schema (paper §4).

Seven workload->platform hints, exactly the paper's set:
  scale_up_down (bool), scale_out_in (bool), deploy_time_ms (float),
  availability_nines (float), preemptibility_pct (float),
  delay_tolerance_ms (float), region_independent (bool)

Hints are *best-effort* and *incentive-compatible*: an absent hint means the
platform assumes the most conservative value (CONSERVATIVE below), so not
adopting WI can never hurt a workload (§3.1 Incentives).

Platform->workload hints (§4, "Platform hints"): eviction notices, harvest /
overclock offers, throttle and maintenance events.
"""
from __future__ import annotations

import dataclasses
import enum
import json
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

SCHEMA_VERSION = 1

# The seven hint keys (Table 3 columns).
HINT_KEYS = (
    "scale_up_down",        # bool: can shrink/expand resources in place
    "scale_out_in",         # bool: can add/remove replicas
    "deploy_time_ms",       # float: tolerated deployment latency
    "availability_nines",   # float: required availability (9s)
    "preemptibility_pct",   # float 0..100: % of capacity that may be evicted
    "delay_tolerance_ms",   # float: tolerated added latency/step slack
    "region_independent",   # bool: may migrate across regions
)

# Conservative defaults assumed when a hint is absent (§4: "If unspecified,
# we assume the most conservative setting").
CONSERVATIVE: Dict[str, Any] = {
    "scale_up_down": False,
    "scale_out_in": False,
    "deploy_time_ms": 0.0,          # needs instant deployment
    "availability_nines": 5.0,      # five nines
    "preemptibility_pct": 0.0,      # nothing may be evicted
    "delay_tolerance_ms": 0.0,      # delay sensitive
    "region_independent": False,
}

_VALIDATORS = {
    "scale_up_down": lambda v: isinstance(v, bool),
    "scale_out_in": lambda v: isinstance(v, bool),
    "deploy_time_ms": lambda v: isinstance(v, (int, float)) and 0 <= v <= 1e9,
    "availability_nines": lambda v: isinstance(v, (int, float)) and 0 <= v <= 9,
    "preemptibility_pct": lambda v: isinstance(v, (int, float))
    and 0 <= v <= 100,
    "delay_tolerance_ms": lambda v: isinstance(v, (int, float))
    and 0 <= v <= 1e9,
    "region_independent": lambda v: isinstance(v, bool),
}


class HintError(ValueError):
    pass


def validate_hints(hints: Dict[str, Any], allow_extension=True):
    """Schema validation.  Unknown keys are allowed when the deployment
    registered an extension schema (§3.1 Generality/extensible) but must be
    namespaced 'x-'."""
    for k, v in hints.items():
        if k in _VALIDATORS:
            if not _VALIDATORS[k](v):
                raise HintError(f"invalid value for hint {k!r}: {v!r}")
        elif allow_extension and k.startswith("x-"):
            continue
        else:
            raise HintError(f"unknown hint key {k!r}")
    return dict(hints)


def effective(hints: Optional[Dict[str, Any]]) -> Dict[str, Any]:
    """Hints merged over conservative defaults."""
    out = dict(CONSERVATIVE)
    if hints:
        out.update({k: v for k, v in hints.items() if k in _VALIDATORS})
    return out


class Scope(enum.Enum):
    DEPLOYMENT = "deployment"   # set at deploy time via the deployment API
    RUNTIME = "runtime"         # set from inside the VM / by a workload manager


@dataclass(frozen=True)
class HintRecord:
    """One hint assertion for one resource (VM / replica slice / workload)."""
    workload: str
    resource: str               # vm id or "*" for workload-wide
    scope: str                  # Scope value
    hints: Dict[str, Any]
    source: str = ""            # who set it (vm-local, yarn-rm, deploy-api...)
    seq: int = 0                # assigned by the global manager
    ts: float = 0.0
    ttl_s: Optional[float] = None
    version: int = SCHEMA_VERSION

    def expired(self, now=None) -> bool:
        if self.ttl_s is None:
            return False
        return (now if now is not None else time.time()) > self.ts + self.ttl_s

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "HintRecord":
        return HintRecord(**json.loads(s))


# ---------------------------------------------------------------------------
# Platform -> workload hints (events)
# ---------------------------------------------------------------------------

class PlatformEvent(enum.Enum):
    EVICTION_NOTICE = "eviction_notice"       # Spot: VM will be evicted
    SCALE_DOWN_NOTICE = "scale_down_notice"   # Harvest/MA: resources shrink
    SCALE_UP_OFFER = "scale_up_offer"         # Harvest: spare resources
    OVERCLOCK_OFFER = "overclock_offer"
    UNDERCLOCK_NOTICE = "underclock_notice"
    THROTTLE_NOTICE = "throttle_notice"       # MA DC power event
    MAINTENANCE = "maintenance"
    MIGRATION_NOTICE = "migration_notice"     # region-agnostic placement
    RIGHTSIZE_RECOMMENDATION = "rightsize_recommendation"
    PREPROVISION_STATUS = "preprovision_status"


@dataclass(frozen=True)
class PlatformHint:
    event: str                  # PlatformEvent value
    workload: str
    resource: str
    deadline_s: float = 0.0     # how long the workload has to react
    payload: Dict[str, Any] = field(default_factory=dict)
    source_opt: str = ""        # which optimization manager issued it
    seq: int = 0
    ts: float = 0.0

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @staticmethod
    def from_json(s: str) -> "PlatformHint":
        return PlatformHint(**json.loads(s))


# Topics on the bus (§4.2: Kafka topics).
TOPIC_DEPLOY_HINTS = "wi.hints.deploy"
TOPIC_RUNTIME_HINTS = "wi.hints.runtime"
TOPIC_PLATFORM_HINTS = "wi.hints.platform"
# Platform-scheduler topics (sched/ subsystem): per-decision telemetry and
# the authoritative eviction notice/kill stream.
TOPIC_SCHED_DECISIONS = "wi.sched.decisions"
TOPIC_EVICTIONS = "wi.sched.evictions"
# Guest acknowledgements of scheduled events, fanned in by local managers
# (§4: the workload half of the bidirectional loop — e.g. "done draining,
# take the VM early").
TOPIC_EVENT_ACKS = "wi.events.acks"
# Unannounced hardware failures, published by the scheduler's repair loop
# once it notices a crashed VM (no notice preceded these — the platform
# only learns of them after the fact).
TOPIC_FAILURES = "wi.sched.failures"
# Local-manager lease expiries: a guest that stopped heartbeating is
# declared silent so the platform stops waiting for its ack and lets the
# eviction ladder run to the kill deadline.
TOPIC_LEASES = "wi.events.leases"
