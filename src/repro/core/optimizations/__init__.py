"""The ten cloud-platform optimizations (paper §2.2, Tables 2/3/5).

``policies`` holds the scheduler-substrate implementations (the
``OptimizationPolicy`` interface driven by ``repro.sched.Scheduler``'s
tick/crunch/defrag/power loops against the incremental cluster); the
``*Manager`` names are thin legacy adapters over the same selection cores
for callers that still hold a dict-of-dicts view (tests only).
"""
from repro.core.optimizations.policies import (ALL_POLICIES, Action,
                                               AutoScalingPolicy,
                                               HarvestPolicy,
                                               MADatacenterPolicy,
                                               NonPreprovisionPolicy,
                                               OptimizationPolicy,
                                               OverclockingPolicy,
                                               OversubscriptionPolicy,
                                               RegionAgnosticPolicy,
                                               RightsizingPolicy, SpotPolicy,
                                               UnderclockingPolicy)
from repro.core.optimizations.managers import (ALL_OPTIMIZATIONS,
                                               AutoScalingManager,
                                               HarvestManager,
                                               MADatacenterManager,
                                               NonPreprovisionManager,
                                               OverclockingManager,
                                               OversubscriptionManager,
                                               RegionAgnosticManager,
                                               RightsizingManager,
                                               SpotManager,
                                               UnderclockingManager)

__all__ = [
    "Action", "OptimizationPolicy", "ALL_POLICIES",
    "AutoScalingPolicy", "HarvestPolicy", "MADatacenterPolicy",
    "NonPreprovisionPolicy", "OverclockingPolicy", "OversubscriptionPolicy",
    "RegionAgnosticPolicy", "RightsizingPolicy", "SpotPolicy",
    "UnderclockingPolicy",
    "AutoScalingManager", "HarvestManager", "MADatacenterManager",
    "NonPreprovisionManager", "OverclockingManager",
    "OversubscriptionManager", "RegionAgnosticManager", "RightsizingManager",
    "SpotManager", "UnderclockingManager", "ALL_OPTIMIZATIONS",
]
