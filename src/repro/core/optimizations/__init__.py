"""The ten cloud-platform optimizations (paper §2.2, Tables 2/3/5).

Each manager implements the Table-5 contract against the WI global manager;
the cluster simulator (repro.sim) drives them against simulated servers and
the WI-JAX runtime (repro.runtime) drives spot/harvest/autoscale against real
training jobs.
"""
from repro.core.optimizations.managers import (AutoScalingManager,
                                               HarvestManager,
                                               MADatacenterManager,
                                               NonPreprovisionManager,
                                               OverclockingManager,
                                               OversubscriptionManager,
                                               RegionAgnosticManager,
                                               RightsizingManager,
                                               SpotManager,
                                               UnderclockingManager,
                                               ALL_OPTIMIZATIONS)

__all__ = [
    "AutoScalingManager", "HarvestManager", "MADatacenterManager",
    "NonPreprovisionManager", "OverclockingManager",
    "OversubscriptionManager", "RegionAgnosticManager", "RightsizingManager",
    "SpotManager", "UnderclockingManager", "ALL_OPTIMIZATIONS",
]
