"""Concrete optimization managers.

State they operate on is a plain dict-of-dicts "cluster view":
  view = {
    "vms": {vm_id: {"workload", "server", "cores", "util_p95", "priority_hint",
                     "spot": bool, "harvest": bool, ...}},
    "servers": {server_id: {"cores", "free_cores", "power_cap": bool}},
    "regions": {region: {"price", "carbon_g_kwh"}},
  }
The simulator owns the view; managers mutate it only through returned actions
and platform hints, mirroring the paper's separation (managers never touch
VMs directly — the platform fabric does).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core import hints as H
from repro.core.opt_manager import OptimizationManager
from repro.core.pricing import applicable


@dataclass
class Action:
    kind: str                   # evict / resize / migrate / throttle / ...
    vm: str = ""
    workload: str = ""
    payload: Dict[str, Any] = field(default_factory=dict)


class SpotManager(OptimizationManager):
    """Table 5: consume deployment preemptible hints + runtime preemption
    priority; publish runtime preemption notifications."""
    name = "spot"
    consumes_deploy = ("preemptibility_pct",)
    consumes_runtime = ("preemptibility_pct", "x-preemption-priority")
    publishes = (H.PlatformEvent.EVICTION_NOTICE,)

    def __init__(self, gm, eviction_notice_s: float = 30.0):
        super().__init__(gm)
        self.notice_s = eviction_notice_s
        self.priority_hint: Dict[str, float] = {}   # vm -> priority (low=evict)
        # drop per-resource priority state when its VM is gone: under churn
        # the map otherwise grows monotonically with dead-VM keys
        gm.bus.subscribe(H.TOPIC_EVICTIONS, self._on_eviction_record)

    def _on_eviction_record(self, rec):
        d = rec.value
        if isinstance(d, dict) and d.get("event") in (
                "evicted", "early_released", "already_gone"):
            self.priority_hint.pop(d.get("resource", ""), None)

    def on_runtime_hint(self, d):
        p = d["hints"].get("x-preemption-priority")
        if p is not None:
            self.priority_hint[d["resource"]] = float(p)
        pre = d["hints"].get("preemptibility_pct")
        if pre is not None:
            # high preemptibility => low keep-priority
            self.priority_hint.setdefault(d["resource"], 100.0 - pre)

    def reclaim(self, view, cores_needed: float) -> List[Action]:
        """Pick spot VMs to evict, preferring high-preemptibility ones."""
        cands = []
        for vm, info in view["vms"].items():
            if not info.get("spot"):
                continue
            res = f"{info['server']}/{vm}"
            eff = self.hints_for(info["workload"], res)
            keep = self.priority_hint.get(res, 100.0 - eff["preemptibility_pct"])
            cands.append((keep, vm, info))
        cands.sort()
        actions = []
        freed = 0.0
        for keep, vm, info in cands:
            if freed >= cores_needed:
                break
            res = f"{info['server']}/{vm}"
            self.gm.checker.note_eviction_pending(res)
            self.notify(H.PlatformEvent.EVICTION_NOTICE, info["workload"],
                        res, deadline_s=self.notice_s,
                        cores=info["cores"], keep_priority=keep)
            actions.append(Action("evict", vm=vm, workload=info["workload"],
                                  payload={"after_s": self.notice_s}))
            freed += info["cores"]
            self.stats["evictions"] += 1
        return actions


class HarvestManager(OptimizationManager):
    """Spot semantics + dynamic grow/shrink of spare cores (Table 5)."""
    name = "harvest"
    consumes_deploy = ("preemptibility_pct", "scale_up_down",
                       "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.SCALE_UP_OFFER,
                 H.PlatformEvent.SCALE_DOWN_NOTICE)

    def rebalance(self, view) -> List[Action]:
        actions = []
        for server, sinfo in view["servers"].items():
            spare = sinfo["free_cores"]
            hvms = [(vm, i) for vm, i in view["vms"].items()
                    if i.get("harvest") and i["server"] == server]
            if not hvms:
                continue
            if spare > 0:
                per = spare / len(hvms)
                for vm, info in hvms:
                    self.notify(H.PlatformEvent.SCALE_UP_OFFER,
                                info["workload"], f"{server}/{vm}",
                                extra_cores=per)
                    actions.append(Action("grow", vm=vm,
                                          workload=info["workload"],
                                          payload={"cores": per}))
                    self.stats["grows"] += 1
            elif spare < 0:
                need = -spare
                for vm, info in sorted(
                        hvms, key=lambda kv: kv[1].get("harvested", 0.0),
                        reverse=True):
                    take = min(info.get("harvested", 0.0), need)
                    if take <= 0:
                        continue
                    self.notify(H.PlatformEvent.SCALE_DOWN_NOTICE,
                                info["workload"], f"{server}/{vm}",
                                deadline_s=5.0, cores=take)
                    actions.append(Action("shrink", vm=vm,
                                          workload=info["workload"],
                                          payload={"cores": take}))
                    self.stats["shrinks"] += 1
                    need -= take
                    if need <= 0:
                        break
        return actions


class AutoScalingManager(OptimizationManager):
    name = "auto_scaling"
    consumes_deploy = ("scale_out_in", "deploy_time_ms", "delay_tolerance_ms")
    publishes = ()

    def __init__(self, gm, low: float = 0.25, high: float = 0.6):
        super().__init__(gm)
        self.low, self.high = low, high

    def target_replicas(self, workload: str, current: int, util: float,
                        minimum: int = 1, maximum: int = 1 << 30) -> int:
        eff = self.hints_for(workload)
        if not eff["scale_out_in"]:
            return current
        if util > self.high:
            t = min(maximum, current + max(1, int(current * 0.5)))
        elif util < self.low and current > minimum:
            t = max(minimum, int(current * util / self.low) or minimum)
        else:
            t = current
        if t != current:
            self.stats["rescale"] += 1
        return t


class OverclockingManager(OptimizationManager):
    name = "overclocking"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.OVERCLOCK_OFFER,)
    UTIL_P95_MIN = 0.40

    def offers(self, view, coordinator=None) -> List[Action]:
        acts = []
        for vm, info in view["vms"].items():
            eff = self.hints_for(info["workload"], f"{info['server']}/{vm}")
            if not applicable(self.name, eff):
                continue
            if info.get("util_p95", 0.0) <= self.UTIL_P95_MIN:
                continue
            res = f"{info['server']}/cpu_freq"
            if coordinator is not None:
                g = coordinator.submit([self.claim(info["workload"], res,
                                                   amount=0.2,
                                                   compressible=True)])
                if not g or g[0].amount <= 0:
                    self.stats["denied_by_coordination"] += 1
                    continue
                boost = g[0].amount
            else:
                boost = 0.2
            self.notify(H.PlatformEvent.OVERCLOCK_OFFER, info["workload"],
                        f"{info['server']}/{vm}", boost_frac=boost)
            acts.append(Action("overclock", vm=vm, workload=info["workload"],
                               payload={"boost_frac": boost}))
            self.stats["overclocks"] += 1
        return acts


class UnderclockingManager(OptimizationManager):
    name = "underclocking"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    publishes = (H.PlatformEvent.UNDERCLOCK_NOTICE,)
    UTIL_P95_MAX = 0.20

    def apply(self, view, coordinator=None) -> List[Action]:
        acts = []
        for vm, info in view["vms"].items():
            eff = self.hints_for(info["workload"], f"{info['server']}/{vm}")
            if not applicable(self.name, eff):
                continue
            if info.get("util_p95", 1.0) >= self.UTIL_P95_MAX:
                continue
            res = f"{info['server']}/cpu_freq"
            if coordinator is not None:
                g = coordinator.submit([self.claim(info["workload"], res,
                                                   amount=0.2,
                                                   compressible=True)])
                if not g or g[0].amount <= 0:
                    self.stats["denied_by_coordination"] += 1
                    continue
            self.notify(H.PlatformEvent.UNDERCLOCK_NOTICE, info["workload"],
                        f"{info['server']}/{vm}", slowdown_frac=0.2)
            acts.append(Action("underclock", vm=vm, workload=info["workload"],
                               payload={"slowdown_frac": 0.2}))
            self.stats["underclocks"] += 1
        return acts


class NonPreprovisionManager(OptimizationManager):
    name = "non_preprovision"
    consumes_deploy = ("deploy_time_ms",)
    publishes = (H.PlatformEvent.PREPROVISION_STATUS,)

    def should_preprovision(self, workload: str) -> bool:
        eff = self.hints_for(workload)
        pre = not applicable(self.name, eff)
        self.stats["preprovisioned" if pre else "skipped"] += 1
        return pre


class RegionAgnosticManager(OptimizationManager):
    name = "region_agnostic"
    consumes_deploy = ("region_independent",)
    publishes = (H.PlatformEvent.MIGRATION_NOTICE,)

    def best_region(self, view, objective: str = "price") -> str:
        regs = view["regions"]
        key = (lambda r: regs[r]["price"]) if objective == "price" else \
            (lambda r: regs[r]["carbon_g_kwh"])
        return min(regs, key=key)

    def place(self, view, workload: str, default_region: str,
              objective: str = "price") -> str:
        eff = self.hints_for(workload)
        if not applicable(self.name, eff):
            return default_region
        best = self.best_region(view, objective)
        if best != default_region:
            self.notify(H.PlatformEvent.MIGRATION_NOTICE, workload, "*",
                        to_region=best, objective=objective)
            self.stats["migrations"] += 1
        return best


class OversubscriptionManager(OptimizationManager):
    name = "oversubscription"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.THROTTLE_NOTICE,)
    UTIL_P95_MAX = 0.65

    def eligible(self, workload: str, util_p95: float) -> bool:
        eff = self.hints_for(workload)
        ok = applicable(self.name, eff) and util_p95 < self.UTIL_P95_MAX
        if ok:
            self.stats["eligible"] += 1
        return ok

    def resolve_pressure(self, view, server: str) -> List[Action]:
        """All VMs spiked at once: throttle the least critical (§2.2)."""
        vms = [(vm, i) for vm, i in view["vms"].items()
               if i["server"] == server and i.get("oversubscribed")]
        vms.sort(key=lambda kv: kv[1].get("util_p95", 0.0))
        acts = []
        for vm, info in vms[: max(1, len(vms) // 2)]:
            self.notify(H.PlatformEvent.THROTTLE_NOTICE, info["workload"],
                        f"{server}/{vm}", frac=0.5)
            acts.append(Action("throttle", vm=vm, workload=info["workload"],
                               payload={"frac": 0.5}))
            self.stats["throttles"] += 1
        return acts


class RightsizingManager(OptimizationManager):
    name = "rightsizing"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms",
                       "availability_nines")
    publishes = (H.PlatformEvent.RIGHTSIZE_RECOMMENDATION,)

    def recommend(self, workload: str, vm: str, util_p95: float,
                  cores: float) -> Optional[float]:
        eff = self.hints_for(workload)
        if not applicable(self.name, eff):
            return None
        if util_p95 < 0.5:
            new = max(1.0, cores / 2)
        elif util_p95 > 0.9:
            new = cores * 2
        else:
            return None
        self.notify(H.PlatformEvent.RIGHTSIZE_RECOMMENDATION, workload, vm,
                    new_cores=new, old_cores=cores)
        self.stats["recommendations"] += 1
        return new


class MADatacenterManager(OptimizationManager):
    name = "ma_datacenters"
    consumes_deploy = ("availability_nines", "preemptibility_pct",
                       "scale_up_down")
    publishes = (H.PlatformEvent.THROTTLE_NOTICE,
                 H.PlatformEvent.EVICTION_NOTICE)

    def power_event(self, view, server: str, shed_frac: float) -> List[Action]:
        """Infrastructure event: shed `shed_frac` of the server's power by
        throttling low-availability VMs first, then evicting (§2.2 MA DCs)."""
        vms = []
        for vm, info in view["vms"].items():
            if info["server"] != server:
                continue
            eff = self.hints_for(info["workload"], f"{server}/{vm}")
            vms.append((eff["availability_nines"], vm, info, eff))
        vms.sort()          # lowest availability requirement first
        acts = []
        need = shed_frac * view["servers"][server]["cores"]
        for nines, vm, info, eff in vms:
            if need <= 0:
                break
            if nines <= 3.0:
                self.notify(H.PlatformEvent.THROTTLE_NOTICE, info["workload"],
                            f"{server}/{vm}", frac=0.5, cause="power_event")
                acts.append(Action("throttle", vm=vm,
                                   workload=info["workload"],
                                   payload={"frac": 0.5}))
                need -= info["cores"] * 0.5
                self.stats["throttles"] += 1
            elif eff["preemptibility_pct"] >= 20.0:
                self.notify(H.PlatformEvent.EVICTION_NOTICE, info["workload"],
                            f"{server}/{vm}", deadline_s=10.0,
                            cause="power_event")
                acts.append(Action("evict", vm=vm, workload=info["workload"]))
                need -= info["cores"]
                self.stats["evictions"] += 1
        return acts


ALL_OPTIMIZATIONS = (SpotManager, HarvestManager, AutoScalingManager,
                     OverclockingManager, UnderclockingManager,
                     NonPreprovisionManager, RegionAgnosticManager,
                     OversubscriptionManager, RightsizingManager,
                     MADatacenterManager)
