"""Legacy dict-of-dicts "view" adapters over the substrate policies.

The real optimization logic lives in ``policies.py`` and runs against the
incremental ``Cluster`` through the platform scheduler.  These adapters keep
the retired view API alive for tests and pre-scheduler callers only: each
method converts a

  view = {"vms": {...}, "servers": {...}, "regions": {...}}

snapshot into the policy's shared selection core.  No production caller
builds that view anymore — new code should use the ``*Policy`` classes (or
the scheduler entry points) directly.
"""
from __future__ import annotations

from typing import List, Optional

from repro.core.optimizations.policies import (Action, AutoScalingPolicy,
                                               HarvestPolicy,
                                               MADatacenterPolicy,
                                               NonPreprovisionPolicy,
                                               OverclockingPolicy,
                                               OversubscriptionPolicy,
                                               RegionAgnosticPolicy,
                                               RightsizingPolicy, SpotPolicy,
                                               UnderclockingPolicy)

__all__ = [
    "Action", "SpotManager", "HarvestManager", "AutoScalingManager",
    "OverclockingManager", "UnderclockingManager", "NonPreprovisionManager",
    "RegionAgnosticManager", "OversubscriptionManager", "RightsizingManager",
    "MADatacenterManager", "ALL_OPTIMIZATIONS",
]


class SpotManager(SpotPolicy):
    def reclaim(self, view, cores_needed: float) -> List[Action]:
        cands = [(vm, i["workload"], i["server"], i["cores"],
                  bool(i.get("harvest")))
                 for vm, i in view["vms"].items() if i.get("spot")]
        return self.select_victims(cands, cores_needed)


class HarvestManager(HarvestPolicy):
    def rebalance(self, view) -> List[Action]:
        out: List[Action] = []
        for server, sinfo in view["servers"].items():
            # legacy offers were uncapped (the view has no apply path)
            hvms = [(vm, i["workload"], i.get("harvested", 0.0),
                     float("inf"))
                    for vm, i in view["vms"].items()
                    if i.get("harvest") and i["server"] == server]
            out.extend(self.rebalance_server(server, sinfo["free_cores"],
                                             hvms))
        return out


class AutoScalingManager(AutoScalingPolicy):
    pass


class OverclockingManager(OverclockingPolicy):
    def offers(self, view, coordinator=None) -> List[Action]:
        acts = []
        for vm, info in view["vms"].items():
            a = self._maybe_offer(info["workload"], info["server"], vm,
                                  info.get("util_p95", 0.0), coordinator)
            if a is not None:
                acts.append(a)
        return acts


class UnderclockingManager(UnderclockingPolicy):
    def apply(self, view, coordinator=None) -> List[Action]:
        acts = []
        for vm, info in view["vms"].items():
            a = self._maybe_underclock(info["workload"], info["server"], vm,
                                       info.get("util_p95", 1.0), coordinator)
            if a is not None:
                acts.append(a)
        return acts


class NonPreprovisionManager(NonPreprovisionPolicy):
    pass


class RegionAgnosticManager(RegionAgnosticPolicy):
    pass


class OversubscriptionManager(OversubscriptionPolicy):
    def resolve_pressure(self, view, server: str) -> List[Action]:
        entries = [(i.get("util_p95", 0.0), vm, i["workload"])
                   for vm, i in view["vms"].items()
                   if i["server"] == server and i.get("oversubscribed")]
        return self.throttle_least_critical(server, entries)


class RightsizingManager(RightsizingPolicy):
    pass


class MADatacenterManager(MADatacenterPolicy):
    def power_event(self, view, server: str, shed_frac: float
                    ) -> List[Action]:
        entries = []
        for vm, info in view["vms"].items():
            if info["server"] != server:
                continue
            eff = self.hints_for(info["workload"], f"{server}/{vm}")
            entries.append((eff["availability_nines"], vm, info["workload"],
                            info["cores"], eff))
        need = shed_frac * view["servers"][server]["cores"]
        return self.shed(server, need, entries)


ALL_OPTIMIZATIONS = (SpotManager, HarvestManager, AutoScalingManager,
                     OverclockingManager, UnderclockingManager,
                     NonPreprovisionManager, RegionAgnosticManager,
                     OversubscriptionManager, RightsizingManager,
                     MADatacenterManager)
