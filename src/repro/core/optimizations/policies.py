"""The ten Table-2 optimizations as scheduler-substrate policies.

``OptimizationPolicy`` is the unified interface the platform scheduler
(``repro.sched.Scheduler``) drives: each policy consumes deployment/runtime
hints from the store (via the global manager), reads cluster state straight
off the incremental ``Cluster`` (per-server vm indices, O(1) counters —
never a materialized world copy), and proposes actions through the existing
machinery:

  * spot + harvest reclaim flow through the ``EvictionPipeline`` (notice
    windows honored, Table-4 priority tiers order the victims: harvest
    before spot);
  * rightsizing and auto-scaling produce *resize* decisions enacted through
    ``AdmissionController.resize`` / the pending queue;
  * region-agnostic placement is enacted continuously by the ``Placer``
    and the scheduler's defrag-migration loop;
  * oversubscription packs against p95 headroom at admission and resolves
    correlated demand spikes by throttling the least critical VMs;
  * under/overclocking and MA-datacenters react to utilization and power
    events with offers/notices on the platform-hint channel.

Policies are bound to a scheduler with ``bind``; the scheduler calls
``on_tick`` periodically plus the event-driven hooks (``reclaim_cores``,
``power_event_cluster``).  Unbound policies still work standalone against a
bare ``Cluster`` (examples, tests).

The legacy dict-of-dicts "view" managers in ``managers.py`` are thin
adapters over the shared selection cores below — kept only for tests and
pre-scheduler callers.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core import hints as H
from repro.core.opt_manager import OptimizationManager
from repro.core.pricing import PRIORITY, applicable


@dataclass
class Action:
    kind: str                   # evict / resize / migrate / throttle / ...
    vm: str = ""
    workload: str = ""
    payload: Dict[str, Any] = field(default_factory=dict)


class OptimizationPolicy(OptimizationManager):
    """Base: an optimization manager that runs on the scheduler substrate.

    Subclasses override ``on_tick`` (periodic scans bounded by the
    scheduler's policy period) and/or provide event-driven entry points the
    scheduler routes (capacity crunch -> ``SpotPolicy.reclaim_cores``,
    power events -> ``MADatacenterPolicy.power_event_cluster``).
    """

    def __init__(self, gm, **kw):
        super().__init__(gm, **kw)
        self.sched = None           # set by bind()

    def bind(self, sched) -> "OptimizationPolicy":
        self.sched = sched
        # pull-based exposition: per-policy stats dicts show up under
        # snapshot()["collected"]["policy.<name>"] with zero hot-path cost
        # (no-op on the default disabled registry)
        sched.metrics.add_collector(f"policy.{self.name}",
                                    lambda: dict(self.stats))
        return self

    def on_tick(self, now: float) -> List[Action]:
        """Periodic hook, driven from ``Scheduler.run_policies``."""
        return []

    # -- helpers over the incremental cluster -------------------------------
    @staticmethod
    def _alive_placed(cluster) -> Iterable:
        """Alive placed VMs in deterministic (vm_id) order."""
        for vid in sorted(cluster.vms):
            v = cluster.vms[vid]
            if v.alive and v.server:
                yield v

    @staticmethod
    def _vms_on(cluster, server: str) -> List:
        return [cluster.vms[vid] for vid in sorted(cluster.vm_ids_on(server))]


class SpotPolicy(OptimizationPolicy):
    """Table 5: consume deployment preemptible hints + runtime preemption
    priority; pick eviction victims for the pipeline (Table-4 tiers:
    harvest VMs are reclaimed before plain spot)."""
    name = "spot"
    consumes_deploy = ("preemptibility_pct",)
    consumes_runtime = ("preemptibility_pct", "x-preemption-priority")
    publishes = (H.PlatformEvent.EVICTION_NOTICE,)

    def __init__(self, gm, eviction_notice_s: float = 30.0):
        super().__init__(gm)
        self.notice_s = eviction_notice_s
        self.priority_hint: Dict[str, float] = {}   # resource -> keep prio
        # drop per-resource priority state when its VM is gone: under churn
        # the map otherwise grows monotonically with dead-VM keys
        gm.bus.subscribe(H.TOPIC_EVICTIONS, self._on_eviction_record)

    def _on_eviction_record(self, rec):
        d = rec.value
        if isinstance(d, dict) and d.get("event") in (
                "evicted", "early_released", "already_gone"):
            self.priority_hint.pop(d.get("resource", ""), None)

    def on_runtime_hint(self, d):
        p = d["hints"].get("x-preemption-priority")
        if p is not None:
            self.priority_hint[d["resource"]] = float(p)
        pre = d["hints"].get("preemptibility_pct")
        if pre is not None:
            # high preemptibility => low keep-priority
            self.priority_hint.setdefault(d["resource"], 100.0 - pre)

    def keep_priority(self, workload: str, resource: str) -> float:
        p = self.priority_hint.get(resource)
        if p is not None:
            return p
        eff = self.hints_for(workload, resource)
        return 100.0 - eff["preemptibility_pct"]

    def select_victims(self, cands: Iterable[Tuple[str, str, str, float,
                                                   bool]],
                       cores_needed: float) -> List[Action]:
        """Shared selection core.  ``cands`` rows are (vm_id, workload,
        server, cores, is_harvest); victims are taken in Table-4 priority
        order (harvest tier reclaims before spot) then by keep-priority."""
        scored = []
        for vm_id, workload, server, cores, harvest in cands:
            res = f"{server}/{vm_id}"
            tier = PRIORITY["harvest"] if harvest else PRIORITY["spot"]
            scored.append((-tier, self.keep_priority(workload, res),
                           vm_id, workload, res, cores))
        scored.sort()
        actions: List[Action] = []
        freed = 0.0
        for _tier, keep, vm_id, workload, res, cores in scored:
            if freed >= cores_needed:
                break
            self.gm.checker.note_eviction_pending(res)
            self.notify(H.PlatformEvent.EVICTION_NOTICE, workload, res,
                        deadline_s=self.notice_s, cores=cores,
                        keep_priority=keep)
            actions.append(Action("evict", vm=vm_id, workload=workload,
                                  payload={"after_s": self.notice_s}))
            freed += cores
            self.stats["evictions"] += 1
        return actions

    def reclaim_cores(self, cluster, cores_needed: float,
                      region: Optional[str] = None,
                      exclude=frozenset()) -> List[Action]:
        """Pick spot/harvest VMs to evict straight off the cluster indices
        (O(region VMs)); ``exclude`` skips VMs already mid-eviction."""
        if region is None:
            it = self._alive_placed(cluster)
        else:
            it = (cluster.vms[vid]
                  for sid in cluster.servers_in_region(region)
                  for vid in sorted(cluster.vm_ids_on(sid)))
        cands = [(v.vm_id, v.workload, v.server, v.cores, v.harvest)
                 for v in it if v.spot and v.vm_id not in exclude]
        return self.select_victims(cands, cores_needed)


class HarvestPolicy(OptimizationPolicy):
    """Spot semantics + dynamic grow/shrink of spare cores (Table 5)."""
    name = "harvest"
    consumes_deploy = ("preemptibility_pct", "scale_up_down",
                       "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.SCALE_UP_OFFER,
                 H.PlatformEvent.SCALE_DOWN_NOTICE)

    def rebalance_server(self, server: str, spare: float,
                         hvms: Sequence[Tuple[str, str, float, float]]
                         ) -> List[Action]:
        """Shared core: grow/shrink actions for one server.  ``hvms`` rows
        are (vm_id, workload, harvested, grow_cap); the advertised offer is
        the *granted* amount (fair spare share clipped to the VM's
        remaining grow cap), so workloads never scale for capacity they
        will not receive."""
        actions: List[Action] = []
        if not hvms:
            return actions
        if spare > 0:
            per = spare / len(hvms)
            for vm_id, workload, _h, cap in hvms:
                grant = min(per, cap)
                if grant <= 0:
                    continue
                self.notify(H.PlatformEvent.SCALE_UP_OFFER, workload,
                            f"{server}/{vm_id}", extra_cores=grant)
                actions.append(Action("grow", vm=vm_id, workload=workload,
                                      payload={"cores": grant}))
                self.stats["grows"] += 1
        elif spare < 0:
            need = -spare
            for vm_id, workload, harvested, _cap in sorted(
                    hvms, key=lambda r: (-r[2], r[0])):
                take = min(harvested, need)
                if take <= 0:
                    continue
                self.notify(H.PlatformEvent.SCALE_DOWN_NOTICE, workload,
                            f"{server}/{vm_id}", deadline_s=5.0, cores=take)
                actions.append(Action("shrink", vm=vm_id, workload=workload,
                                      payload={"cores": take}))
                self.stats["shrinks"] += 1
                need -= take
                if need <= 0:
                    break
        return actions

    GROW_CAP_FRAC = 0.5     # harvested spare capped vs nominal cores

    def rebalance_cluster(self, cluster, admission=None,
                          apply: bool = False) -> List[Action]:
        """Walk servers off the incremental counters; with ``apply`` the
        grow/shrink is enacted (``harvested`` moves through the cluster's
        field interception, and the admission reservation follows so a
        later release does not leak phantom capacity).  Growth is capped at
        ``GROW_CAP_FRAC`` of the VM's nominal cores — the cap is applied
        *before* the offer goes out, so a mostly empty server cannot
        promise one harvest VM a whole host."""
        out: List[Action] = []
        for sid in cluster.servers:
            spare = cluster.free_cores(sid)
            if spare == 0:
                continue
            hvms = [(v.vm_id, v.workload, v.harvested,
                     max(0.0, self.GROW_CAP_FRAC * v.cores - v.harvested))
                    for v in self._vms_on(cluster, sid) if v.harvest]
            acts = self.rebalance_server(sid, spare, hvms)
            if apply:
                for a in acts:
                    vm = cluster.vms[a.vm]
                    delta = (a.payload["cores"] if a.kind == "grow"
                             else -a.payload["cores"])
                    vm.harvested = max(0.0, vm.harvested + delta)
                    if admission is not None and not vm.oversubscribed:
                        admission.shift_demand(sid, delta)
            out.extend(acts)
        return out

    def on_tick(self, now: float) -> List[Action]:
        if self.sched is None:
            return []
        acts = self.rebalance_cluster(self.sched.cluster,
                                      self.sched.admission, apply=True)
        self.sched.note_policy_actions(self.name, acts)
        return acts


class AutoScalingPolicy(OptimizationPolicy):
    name = "auto_scaling"
    consumes_deploy = ("scale_out_in", "deploy_time_ms", "delay_tolerance_ms")
    consumes_runtime = ("x-autoscale-pressure",)
    publishes = ()

    def __init__(self, gm, low: float = 0.25, high: float = 0.6):
        super().__init__(gm)
        self.low, self.high = low, high
        self._clone_seq = 0
        # clone vm_id -> (workload, demand share, passes queued, VM object).
        # The object reference matters: a VM sitting in the pending queue
        # is not registered with the cluster yet, so an id lookup cannot
        # distinguish "still queued" from "gone".
        self._pending_clones: Dict[str, Tuple[str, float, int, Any]] = {}
        # workload -> remaining passes to hold off scale-out after a clone
        # failed to place (the cluster was full; retrying every pass would
        # just churn the pending queue)
        self._scale_out_backoff: Dict[str, int] = {}

    def target_replicas(self, workload: str, current: int, util: float,
                        minimum: int = 1, maximum: int = 1 << 30) -> int:
        eff = self.hints_for(workload)
        if not eff["scale_out_in"]:
            return current
        if util > self.high:
            t = min(maximum, current + max(1, int(current * 0.5)))
        elif util < self.low and current > minimum:
            t = max(minimum, int(current * util / self.low) or minimum)
        else:
            t = current
        if t != current:
            self.stats["rescale"] += 1
        return t

    @staticmethod
    def _spread_demand(sched, vms, new_util: float):
        """Demand conservation on a rescale: the workload's total demand is
        fixed, so per-replica p95 utilization moves with the replica count
        (books follow through ``AdmissionController.set_util_p95``)."""
        for v in vms:
            sched.admission.set_util_p95(v, new_util)

    MAX_CLONE_WAIT_PASSES = 3
    FAILED_CLONE_BACKOFF_PASSES = 4

    def _settle_clones(self, sched, by_w: Dict[str, List]) -> set:
        """Reconcile clones from earlier passes.  A clone that landed has
        its demand share for real; one still queued holds its workload
        steady (no rescale this pass); one that died unplaced — or queued
        past ``MAX_CLONE_WAIT_PASSES`` (the cluster cannot take it) — gets
        its share restored onto the live replicas, so the workload's total
        demand never silently evaporates."""
        waiting = set()
        for cid, (w, share, passes, vm) in \
                list(self._pending_clones.items()):
            if vm.alive and vm.server:
                del self._pending_clones[cid]       # landed
                continue
            if vm.alive and passes < self.MAX_CLONE_WAIT_PASSES:
                self._pending_clones[cid] = (w, share, passes + 1, vm)
                waiting.add(w)
                continue
            # never placed: give up (mark it dead so the pending-queue
            # drain discards it, and back off further scale-outs) and put
            # its demand share back on the live replicas
            del self._pending_clones[cid]
            if vm.alive:
                vm.alive = False
                self.stats["clones_unplaceable"] += 1
                self._scale_out_backoff[w] = self.FAILED_CLONE_BACKOFF_PASSES
            vms = by_w.get(w)
            if vms:
                total = sum(v.cores for v in vms)
                cur = sum(v.util_p95 * v.cores for v in vms)
                self._spread_demand(sched, vms,
                                    min(0.95, (cur + share) / total))
        return waiting

    def scan(self, sched, max_changes: int = 32,
             vms: Optional[Sequence] = None) -> List[Action]:
        """Per-workload scale-out/in against live cluster utilization:
        scale-out submits clone VMs into the pending queue; scale-in drains
        the emptiest replicas through the eviction pipeline (a *consented*
        shrink still pays the hinted notice window).  Total demand per
        workload is conserved — per-replica utilization drops/rises as the
        replica count changes (and a clone that never places gives its
        share back via ``_settle_clones``), so the controller settles
        instead of compounding."""
        cluster = sched.cluster
        # VMs already mid-eviction are leaving: they neither count as
        # replicas nor receive redistributed demand (their raised share
        # would die with them at the deadline)
        mid_eviction = sched.evictor.tickets
        by_w: Dict[str, List] = {}
        for v in (vms if vms is not None else self._alive_placed(cluster)):
            if v.alive and v.server and v.vm_id not in mid_eviction:
                by_w.setdefault(v.workload, []).append(v)
        waiting = self._settle_clones(sched, by_w)
        actions: List[Action] = []
        changes = 0
        for w in sorted(by_w):
            if changes >= max_changes:
                break
            if w in waiting:
                continue
            eff = self.hints_for(w)
            if not applicable(self.name, eff):
                continue
            vms_w = by_w[w]
            total = sum(v.cores for v in vms_w)
            util = sum(v.util_p95 * v.cores for v in vms_w) / total
            # a guest-published x-autoscale-pressure runtime hint (queue
            # depth + tail latency, see agents.ServingTenant) overrides the
            # platform's utilization view: the workload knows its own
            # backlog better than util_p95 does
            pressure = eff.get("x-autoscale-pressure")
            if pressure is not None:
                try:
                    util = min(1.0, max(0.0, float(pressure)))
                    self.stats["pressure_signals"] += 1
                except (TypeError, ValueError):
                    pass
            tgt = self.target_replicas(w, len(vms_w), util)
            if tgt > len(vms_w):
                backoff = self._scale_out_backoff.get(w, 0)
                if backoff > 0:         # a recent clone could not place
                    self._scale_out_backoff[w] = backoff - 1
                    continue
                n_new = min(tgt - len(vms_w), max_changes - changes)
                new_util = min(0.95, util * len(vms_w) / (len(vms_w) + n_new))
                proto = min(vms_w, key=lambda v: (v.cores, v.vm_id))
                self._spread_demand(sched, vms_w, new_util)
                for _ in range(n_new):
                    self._clone_seq += 1
                    from repro.sim.cluster import VM
                    clone = VM(f"{w}.as{self._clone_seq}", w, "",
                               proto.cores, util_p95=new_util,
                               spot=proto.spot, harvest=proto.harvest)
                    sched.submit(clone)
                    self._pending_clones[clone.vm_id] = (
                        w, proto.cores * new_util, 0, clone)
                    actions.append(Action("scale_out", vm=clone.vm_id,
                                          workload=w,
                                          payload={"cores": proto.cores}))
                    changes += 1
            elif tgt < len(vms_w):
                n_drop = min(len(vms_w) - tgt, max_changes - changes)
                surplus = sorted(vms_w, key=lambda v: (v.util_p95, v.vm_id))
                evicts = [Action("evict", vm=v.vm_id, workload=w,
                                 payload={"after_s": 0.0})
                          for v in surplus[:n_drop]]
                keep = surplus[n_drop:]
                if keep:
                    new_util = min(0.95, util * len(vms_w) / len(keep))
                    self._spread_demand(sched, keep, new_util)
                sched.evictor.submit(evicts, source=self.name)
                actions.extend(evicts)
                changes += len(evicts)
        return actions

    def on_tick(self, now: float) -> List[Action]:
        if self.sched is None:
            return []
        acts = self.scan(self.sched, vms=self.sched.alive_placed_vms())
        self.sched.note_policy_actions(self.name, acts)
        return acts


class OverclockingPolicy(OptimizationPolicy):
    name = "overclocking"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.OVERCLOCK_OFFER,)
    UTIL_P95_MIN = 0.40

    def _maybe_offer(self, workload: str, server: str, vm_id: str,
                     util_p95: float, coordinator=None) -> Optional[Action]:
        eff = self.hints_for(workload, f"{server}/{vm_id}")
        if not applicable(self.name, eff):
            return None
        if util_p95 <= self.UTIL_P95_MIN:
            return None
        if coordinator is not None:
            g = coordinator.submit([self.claim(workload,
                                               f"{server}/cpu_freq",
                                               amount=0.2,
                                               compressible=True)])
            if not g or g[0].amount <= 0:
                self.stats["denied_by_coordination"] += 1
                return None
            boost = g[0].amount
        else:
            boost = 0.2
        self.notify(H.PlatformEvent.OVERCLOCK_OFFER, workload,
                    f"{server}/{vm_id}", boost_frac=boost)
        self.stats["overclocks"] += 1
        return Action("overclock", vm=vm_id, workload=workload,
                      payload={"boost_frac": boost})

    def offers_cluster(self, cluster, coordinator=None,
                       vms: Optional[Sequence] = None) -> List[Action]:
        acts = []
        for v in (vms if vms is not None else self._alive_placed(cluster)):
            if not v.alive or not v.server:
                continue
            a = self._maybe_offer(v.workload, v.server, v.vm_id, v.util_p95,
                                  coordinator)
            if a is not None:
                acts.append(a)
        return acts

    def on_tick(self, now: float) -> List[Action]:
        if self.sched is None:
            return []
        acts = self.offers_cluster(self.sched.cluster, self.gm.coordinator,
                                   vms=self.sched.alive_placed_vms())
        self.sched.note_policy_actions(self.name, acts)
        return acts


class UnderclockingPolicy(OptimizationPolicy):
    name = "underclocking"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    publishes = (H.PlatformEvent.UNDERCLOCK_NOTICE,)
    UTIL_P95_MAX = 0.20

    def _maybe_underclock(self, workload: str, server: str, vm_id: str,
                          util_p95: float, coordinator=None
                          ) -> Optional[Action]:
        eff = self.hints_for(workload, f"{server}/{vm_id}")
        if not applicable(self.name, eff):
            return None
        if util_p95 >= self.UTIL_P95_MAX:
            return None
        if coordinator is not None:
            g = coordinator.submit([self.claim(workload,
                                               f"{server}/cpu_freq",
                                               amount=0.2,
                                               compressible=True)])
            if not g or g[0].amount <= 0:
                self.stats["denied_by_coordination"] += 1
                return None
        self.notify(H.PlatformEvent.UNDERCLOCK_NOTICE, workload,
                    f"{server}/{vm_id}", slowdown_frac=0.2)
        self.stats["underclocks"] += 1
        return Action("underclock", vm=vm_id, workload=workload,
                      payload={"slowdown_frac": 0.2})

    def apply_cluster(self, cluster, coordinator=None,
                      vms: Optional[Sequence] = None) -> List[Action]:
        acts = []
        for v in (vms if vms is not None else self._alive_placed(cluster)):
            if not v.alive or not v.server:
                continue
            a = self._maybe_underclock(v.workload, v.server, v.vm_id,
                                       v.util_p95, coordinator)
            if a is not None:
                acts.append(a)
        return acts

    def on_tick(self, now: float) -> List[Action]:
        if self.sched is None:
            return []
        acts = self.apply_cluster(self.sched.cluster, self.gm.coordinator,
                                  vms=self.sched.alive_placed_vms())
        self.sched.note_policy_actions(self.name, acts)
        return acts


class NonPreprovisionPolicy(OptimizationPolicy):
    name = "non_preprovision"
    consumes_deploy = ("deploy_time_ms",)
    publishes = (H.PlatformEvent.PREPROVISION_STATUS,)

    def should_preprovision(self, workload: str) -> bool:
        eff = self.hints_for(workload)
        pre = not applicable(self.name, eff)
        self.stats["preprovisioned" if pre else "skipped"] += 1
        return pre


class RegionAgnosticPolicy(OptimizationPolicy):
    name = "region_agnostic"
    consumes_deploy = ("region_independent",)
    publishes = (H.PlatformEvent.MIGRATION_NOTICE,)

    @staticmethod
    def _regions_of(world) -> Dict[str, Any]:
        """Accept a ``Cluster``, a regions mapping, or (legacy) a view."""
        regions = getattr(world, "regions", world)
        if isinstance(regions, dict) and "regions" in regions \
                and "vms" in regions:
            regions = regions["regions"]        # legacy dict-of-dicts view
        return regions

    @staticmethod
    def _metric(region, objective: str) -> float:
        if isinstance(region, dict):
            return region["price" if objective == "price" else "carbon_g_kwh"]
        return region.price if objective == "price" else region.carbon_g_kwh

    def best_region(self, world, objective: str = "price") -> str:
        regs = self._regions_of(world)
        return min(regs, key=lambda r: self._metric(regs[r], objective))

    def place(self, world, workload: str, default_region: str,
              objective: str = "price") -> str:
        eff = self.hints_for(workload)
        if not applicable(self.name, eff):
            return default_region
        best = self.best_region(world, objective)
        if best != default_region:
            self.notify(H.PlatformEvent.MIGRATION_NOTICE, workload, "*",
                        to_region=best, objective=objective)
            self.stats["migrations"] += 1
        return best


class OversubscriptionPolicy(OptimizationPolicy):
    name = "oversubscription"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms")
    consumes_runtime = ("x-scale-priority",)
    publishes = (H.PlatformEvent.THROTTLE_NOTICE,)
    UTIL_P95_MAX = 0.65

    def eligible(self, workload: str, util_p95: float) -> bool:
        eff = self.hints_for(workload)
        ok = applicable(self.name, eff) and util_p95 < self.UTIL_P95_MAX
        if ok:
            self.stats["eligible"] += 1
        return ok

    def throttle_least_critical(self, server: str,
                                entries: Sequence[Tuple[float, str, str]]
                                ) -> List[Action]:
        """Shared core: all VMs spiked at once — throttle the least
        critical half (§2.2).  ``entries`` rows are (util_p95, vm_id,
        workload)."""
        if not entries:
            return []
        ordered = sorted(entries, key=lambda r: (r[0], r[1]))
        acts = []
        for util, vm_id, workload in ordered[: max(1, len(ordered) // 2)]:
            self.notify(H.PlatformEvent.THROTTLE_NOTICE, workload,
                        f"{server}/{vm_id}", frac=0.5)
            acts.append(Action("throttle", vm=vm_id, workload=workload,
                               payload={"frac": 0.5}))
            self.stats["throttles"] += 1
        return acts

    def resolve_pressure_cluster(self, cluster, server: str) -> List[Action]:
        entries = [(v.util_p95, v.vm_id, v.workload)
                   for v in self._vms_on(cluster, server)
                   if v.oversubscribed]
        return self.throttle_least_critical(server, entries)

    def on_tick(self, now: float) -> List[Action]:
        """Correlated-spike watch: any server whose p95 demand exceeds its
        physical cores gets its oversubscribed VMs throttled."""
        if self.sched is None:
            return []
        cluster = self.sched.cluster
        acts: List[Action] = []
        for sid, srv in cluster.servers.items():
            if cluster.p95_used(sid) > srv.cores + 1e-9:
                acts.extend(self.resolve_pressure_cluster(cluster, sid))
        self.sched.note_policy_actions(self.name, acts)
        return acts


class RightsizingPolicy(OptimizationPolicy):
    name = "rightsizing"
    consumes_deploy = ("scale_up_down", "delay_tolerance_ms",
                       "availability_nines")
    publishes = (H.PlatformEvent.RIGHTSIZE_RECOMMENDATION,)
    # applied shrinks must leave post-resize utilization at or below this
    # (the grow trigger), or grow/shrink would oscillate every pass
    SHRINK_UTIL_CAP = 0.9

    def recommend(self, workload: str, vm: str, util_p95: float,
                  cores: float) -> Optional[float]:
        eff = self.hints_for(workload)
        if not applicable(self.name, eff):
            return None
        if util_p95 < 0.5:
            new = max(1.0, cores / 2)
        elif util_p95 > 0.9:
            new = cores * 2
        else:
            return None
        self.notify(H.PlatformEvent.RIGHTSIZE_RECOMMENDATION, workload, vm,
                    new_cores=new, old_cores=cores)
        self.stats["recommendations"] += 1
        return new

    def scan_cluster(self, cluster, admission=None, apply: bool = False,
                     max_changes: int = 64,
                     vms: Optional[Sequence] = None) -> List[Action]:
        """Recommend (and with ``apply`` enact through the admission books)
        resizes for over/under-provisioned VMs of rightsizing-applicable
        workloads."""
        acts: List[Action] = []
        for v in (vms if vms is not None else self._alive_placed(cluster)):
            if len(acts) >= max_changes:
                break
            if not v.alive or not v.server:
                continue
            new = self.recommend(v.workload, v.vm_id, v.util_p95, v.cores)
            if new is None or new == v.cores:
                continue
            if apply and admission is not None:
                old_cores, old_util = v.cores, v.util_p95
                if new < old_cores and \
                        old_util * old_cores / new > self.SHRINK_UTIL_CAP:
                    # hysteresis: a shrink whose post-resize utilization
                    # would immediately re-trigger the grow rule (util in
                    # (0.9, 1.0) flaps 2x<->0.5x forever otherwise) is not
                    # applied — the recommendation still goes out
                    self.stats["resize_skipped_unstable"] += 1
                    acts.append(Action("recommend_only", vm=v.vm_id,
                                       workload=v.workload,
                                       payload={"new_cores": new}))
                    continue
                ok, reason = admission.resize(v, new)
                if not ok:
                    self.stats["resize_rejected"] += 1
                    continue
                # demand conservation: the workload's load did not change,
                # so p95 utilization moves inversely with the size — which
                # also keeps the pass from re-resizing the same VM forever
                admission.set_util_p95(
                    v, min(0.95, old_util * old_cores / new))
                self.stats["resized"] += 1
            acts.append(Action("resize", vm=v.vm_id, workload=v.workload,
                               payload={"new_cores": new}))
        return acts

    def on_tick(self, now: float) -> List[Action]:
        if self.sched is None:
            return []
        acts = self.scan_cluster(self.sched.cluster, self.sched.admission,
                                 apply=self.sched.apply_rightsizing,
                                 vms=self.sched.alive_placed_vms())
        self.sched.note_policy_actions(self.name, acts)
        return acts


class MADatacenterPolicy(OptimizationPolicy):
    name = "ma_datacenters"
    consumes_deploy = ("availability_nines", "preemptibility_pct",
                       "scale_up_down")
    publishes = (H.PlatformEvent.THROTTLE_NOTICE,
                 H.PlatformEvent.EVICTION_NOTICE)

    def shed(self, server: str, need: float,
             entries: Sequence[Tuple[float, str, str, float, Dict]]
             ) -> List[Action]:
        """Shared core: shed ``need`` cores of power by throttling
        low-availability VMs first, then evicting preemptible ones (§2.2 MA
        DCs).  ``entries`` rows are (availability_nines, vm_id, workload,
        cores, eff_hints), any order."""
        acts: List[Action] = []
        for nines, vm_id, workload, cores, eff in sorted(
                entries, key=lambda r: (r[0], r[1])):
            if need <= 0:
                break
            if nines <= 3.0:
                self.notify(H.PlatformEvent.THROTTLE_NOTICE, workload,
                            f"{server}/{vm_id}", frac=0.5,
                            cause="power_event")
                acts.append(Action("throttle", vm=vm_id, workload=workload,
                                   payload={"frac": 0.5}))
                need -= cores * 0.5
                self.stats["throttles"] += 1
            elif eff["preemptibility_pct"] >= 20.0:
                self.notify(H.PlatformEvent.EVICTION_NOTICE, workload,
                            f"{server}/{vm_id}", deadline_s=10.0,
                            cause="power_event")
                acts.append(Action("evict", vm=vm_id, workload=workload))
                need -= cores
                self.stats["evictions"] += 1
        return acts

    def power_event_cluster(self, cluster, server: str, shed_frac: float,
                            exclude=frozenset()) -> List[Action]:
        """Infrastructure event against the live cluster: walked via the
        per-server vm index; ``exclude`` skips VMs already mid-eviction."""
        entries = []
        for v in self._vms_on(cluster, server):
            if v.vm_id in exclude:
                continue
            eff = self.hints_for(v.workload, f"{server}/{v.vm_id}")
            entries.append((eff["availability_nines"], v.vm_id, v.workload,
                            v.cores, eff))
        need = shed_frac * cluster.servers[server].cores
        return self.shed(server, need, entries)


ALL_POLICIES = (SpotPolicy, HarvestPolicy, AutoScalingPolicy,
                OverclockingPolicy, UnderclockingPolicy,
                NonPreprovisionPolicy, RegionAgnosticPolicy,
                OversubscriptionPolicy, RightsizingPolicy,
                MADatacenterPolicy)
