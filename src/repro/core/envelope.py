"""Signed + (demo-grade) encrypted hint envelopes (paper §4.3).

"To protect workload owners from side-channel attacks, we encrypt the hint
communication."  Offline we implement HMAC-SHA256 authenticity over a
per-workload key plus an XOR keystream derived from the key (stand-in for
TLS/AES on the wire — documented as such; the *interface* is what matters:
managers only accept envelopes that verify).
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple


class KeyRegistry:
    """Per-workload symmetric keys (provisioned at deployment)."""

    def __init__(self):
        self._keys: Dict[str, bytes] = {}

    def provision(self, workload: str, key: Optional[bytes] = None) -> bytes:
        k = key or hashlib.sha256(f"wi-key::{workload}".encode()).digest()
        self._keys[workload] = k
        return k

    def key_for(self, workload: str) -> Optional[bytes]:
        return self._keys.get(workload)


def _keystream(key: bytes, nonce: bytes, n: int) -> bytes:
    out = b""
    counter = 0
    while len(out) < n:
        out += hashlib.sha256(key + nonce + counter.to_bytes(4, "big")).digest()
        counter += 1
    return out[:n]


def seal(key: bytes, payload: Dict[str, Any], nonce: Optional[bytes] = None
         ) -> Dict[str, str]:
    raw = json.dumps(payload, sort_keys=True).encode()
    nonce = nonce or os.urandom(12)
    ks = _keystream(key, nonce, len(raw))
    ct = bytes(a ^ b for a, b in zip(raw, ks))
    mac = hmac.new(key, nonce + ct, hashlib.sha256).hexdigest()
    return {"nonce": nonce.hex(), "ct": ct.hex(), "mac": mac}


def unseal(key: bytes, env: Dict[str, str]) -> Optional[Dict[str, Any]]:
    try:
        nonce, ct = bytes.fromhex(env["nonce"]), bytes.fromhex(env["ct"])
        mac = env["mac"]
    except (KeyError, ValueError):
        return None
    want = hmac.new(key, nonce + ct, hashlib.sha256).hexdigest()
    if not hmac.compare_digest(mac, want):
        return None
    ks = _keystream(key, nonce, len(ct))
    raw = bytes(a ^ b for a, b in zip(ct, ks))
    try:
        return json.loads(raw.decode())
    except (json.JSONDecodeError, UnicodeDecodeError):
        return None
