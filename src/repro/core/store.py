"""CloudDB stand-in: a durable KV store with WAL + snapshot recovery (§4.2).

Guarantees the paper needs from "CloudDB":
  * durability: every committed write survives process crash (WAL fsync'd),
  * recovery: state after restart == snapshot + WAL replay (prefix of the
    write sequence; torn tail writes are discarded),
  * versioned values (monotonic seq) so optimization managers can do
    consistent pull reads,
  * range scans by key prefix (aggregation queries).

Property-tested in tests/test_wi_store.py (hypothesis): crash at any WAL
byte prefix recovers a prefix of committed writes.

The store owns a WAL file handle when given a root directory; call
``close()`` (or use the store as a context manager) from scenario teardown
so long soak runs do not leak descriptors.  ``GlobalManager.close()`` does
this for the store it owns.
"""
from __future__ import annotations

import json
import os
import threading
from pathlib import Path
from typing import Any, Dict, Iterator, List, Optional, Tuple


class Store:
    def __init__(self, root: Optional[str] = None, snapshot_every: int = 256,
                 fsync: bool = False):
        self._mem: Dict[str, Tuple[int, Any]] = {}
        self._seq = 0
        self._lock = threading.RLock()
        self._root = Path(root) if root else None
        self._snapshot_every = snapshot_every
        self._writes_since_snap = 0
        self._fsync = fsync
        self._wal = None
        if self._root:
            self._root.mkdir(parents=True, exist_ok=True)
            self._recover()
            self._wal = (self._root / "wal.log").open("a")

    # -- recovery ------------------------------------------------------------
    def _recover(self):
        snap = self._root / "snapshot.json"
        if snap.exists():
            try:
                data = json.loads(snap.read_text())
                self._mem = {k: (v[0], v[1]) for k, v in data["kv"].items()}
                self._seq = data["seq"]
            except (json.JSONDecodeError, KeyError):
                self._mem, self._seq = {}, 0
        wal = self._root / "wal.log"
        if wal.exists():
            with wal.open() as fh:
                for line in fh:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        break       # torn tail: stop replay
                    if rec["seq"] <= self._seq:
                        continue    # already in snapshot
                    if rec["op"] == "put":
                        self._mem[rec["key"]] = (rec["seq"], rec["val"])
                    elif rec["op"] == "del":
                        self._mem.pop(rec["key"], None)
                    self._seq = rec["seq"]

    def _append_wal(self, rec: dict):
        if self._wal is None:
            return
        self._wal.write(json.dumps(rec) + "\n")
        self._wal.flush()
        if self._fsync:
            os.fsync(self._wal.fileno())
        self._writes_since_snap += 1
        if self._writes_since_snap >= self._snapshot_every:
            self._snapshot()

    def _snapshot(self):
        """Checkpoint memory to snapshot.json and truncate the WAL — in an
        order that cannot lose committed writes.  The tmp file (and, under
        ``fsync=True``, the directory entry from ``os.replace``) is made
        durable BEFORE the WAL is truncated: a crash anywhere in between
        leaves either the old snapshot + full WAL or the new snapshot +
        stale WAL (replay skips records with ``seq <= snapshot.seq``), both
        of which recover every committed write."""
        if self._root is None:
            return
        tmp = self._root / "snapshot.json.tmp"
        with tmp.open("w") as fh:
            fh.write(json.dumps(
                {"seq": self._seq,
                 "kv": {k: list(v) for k, v in self._mem.items()}}))
            fh.flush()
            if self._fsync:
                os.fsync(fh.fileno())
        os.replace(tmp, self._root / "snapshot.json")
        if self._fsync:
            # the rename itself must survive: fsync the directory
            dfd = os.open(self._root, os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        # only now is it safe to drop the WAL (atomically recreate)
        if self._wal is not None:
            self._wal.close()
        with (self._root / "wal.log").open("w") as fh:
            if self._fsync:
                fh.flush()
                os.fsync(fh.fileno())
        self._wal = (self._root / "wal.log").open("a")
        self._writes_since_snap = 0

    # -- API -----------------------------------------------------------------
    def put(self, key: str, value: Any) -> int:
        with self._lock:
            self._seq += 1
            self._mem[key] = (self._seq, value)
            self._append_wal({"op": "put", "key": key, "val": value,
                              "seq": self._seq})
            return self._seq

    def get(self, key: str, default=None) -> Any:
        with self._lock:
            v = self._mem.get(key)
            return v[1] if v else default

    def get_versioned(self, key: str) -> Optional[Tuple[int, Any]]:
        with self._lock:
            return self._mem.get(key)

    def delete(self, key: str):
        with self._lock:
            if key in self._mem:
                self._seq += 1
                del self._mem[key]
                self._append_wal({"op": "del", "key": key, "seq": self._seq})

    def scan(self, prefix: str) -> Iterator[Tuple[str, Any]]:
        with self._lock:
            items = [(k, v[1]) for k, v in self._mem.items()
                     if k.startswith(prefix)]
        return iter(sorted(items))

    def count(self, prefix: str = "") -> int:
        with self._lock:
            return sum(1 for k in self._mem if k.startswith(prefix))

    @property
    def seq(self) -> int:
        return self._seq

    def close(self):
        with self._lock:
            if self._wal is not None:
                self._wal.close()
                self._wal = None

    def __enter__(self) -> "Store":
        return self

    def __exit__(self, exc_type, exc, tb) -> None:
        self.close()
