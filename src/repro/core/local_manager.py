"""WI Local Manager (paper §4.1): one per server.

Collects runtime hints from the VMs on its server through a guest/host
channel (Hyper-V KVP / XenStore stand-in: ``VMEndpoint``), rate-limits and
forwards them onto the bus; subscribes to platform hints and exposes them to
VMs through the metadata-service + scheduled-events interfaces the paper
cites (§4.2).
"""
from __future__ import annotations

import json
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Optional

from repro.core import hints as H
from repro.core.bus import Bus, Record
from repro.core.safety import RateLimiter


class VMEndpoint:
    """What a workload sees from inside its VM.

    set_runtime_hints  — KVP/XenStore-style write (rate limited at the host)
    metadata           — metadata-service style attribute read
    scheduled_events   — poll upcoming platform events (eviction, throttle…)
    ack_event          — acknowledge a scheduled event (graceful shutdown)
    on_event           — optional push callback
    """

    def __init__(self, vm_id: str, workload: str, local: "LocalManager"):
        self.vm_id, self.workload, self._local = vm_id, workload, local
        self._events: deque = deque(maxlen=256)
        self._acked: set = set()
        self._cb: Optional[Callable[[Dict[str, Any]], None]] = None
        self.metadata: Dict[str, Any] = {"vm_id": vm_id, "workload": workload}

    def set_runtime_hints(self, hint_dict: Dict[str, Any]) -> bool:
        return self._local._vm_hint(self.vm_id, self.workload, hint_dict)

    def scheduled_events(self) -> List[Dict[str, Any]]:
        return [e for e in self._events if e["seq"] not in self._acked]

    def ack_event(self, seq: int):
        self._acked.add(seq)
        self._local._event_acked(self.vm_id, seq)

    def on_event(self, cb: Callable[[Dict[str, Any]], None]):
        self._cb = cb

    def _deliver(self, event: Dict[str, Any]):
        self._events.append(event)
        if self._cb:
            self._cb(event)


class LocalManager:
    def __init__(self, server_id: str, bus: Bus, clock=None,
                 vm_hint_rate_per_s: float = 2.0, vm_hint_burst: float = 10.0):
        self.server_id = server_id
        self.bus = bus
        self.clock = clock or (lambda: 0.0)
        self._vms: Dict[str, VMEndpoint] = {}
        self._limiter = RateLimiter(vm_hint_rate_per_s, vm_hint_burst,
                                    self.clock)
        self.stats = defaultdict(int)
        self._acks: Dict[int, set] = defaultdict(set)
        bus.subscribe(H.TOPIC_PLATFORM_HINTS, self._on_platform_hint)

    # -- VM lifecycle -------------------------------------------------------
    def attach_vm(self, vm_id: str, workload: str) -> VMEndpoint:
        ep = VMEndpoint(vm_id, workload, self)
        self._vms[vm_id] = ep
        return ep

    def detach_vm(self, vm_id: str):
        self._vms.pop(vm_id, None)

    # -- guest -> platform ------------------------------------------------------
    def _vm_hint(self, vm_id: str, workload: str,
                 hint_dict: Dict[str, Any]) -> bool:
        if not self._limiter.allow((vm_id,)):
            self.stats["vm_hint_rate_limited"] += 1
            return False
        try:
            hint_dict = H.validate_hints(hint_dict)
        except H.HintError:
            self.stats["vm_hint_invalid"] += 1
            return False
        resource = f"{self.server_id}/{vm_id}"
        rec = H.HintRecord(workload=workload, resource=resource,
                           scope=H.Scope.RUNTIME.value, hints=hint_dict,
                           source=f"vm:{vm_id}", ts=self.clock())
        self.bus.publish(H.TOPIC_RUNTIME_HINTS, json.loads(rec.to_json()),
                         key=resource)
        self.stats["vm_hints_forwarded"] += 1
        return True

    # -- platform -> guest -------------------------------------------------------
    def _on_platform_hint(self, rec: Record):
        d = rec.value
        res = d.get("resource", "")
        # resource is 'server/vm' or 'server' or '*'
        if res == "*" or res == self.server_id:
            targets = list(self._vms.values())
        elif res.startswith(self.server_id + "/"):
            vm = res[len(self.server_id) + 1:]
            targets = [self._vms[vm]] if vm in self._vms else []
        else:
            # workload-addressed events go to that workload's VMs here
            targets = [ep for ep in self._vms.values()
                       if ep.workload == d.get("workload")] \
                if res == "" else []
        for ep in targets:
            ep._deliver(d)
            self.stats["events_delivered"] += 1

    def _event_acked(self, vm_id: str, seq: int):
        self._acks[seq].add(vm_id)
        self.stats["events_acked"] += 1

    def acked(self, seq: int) -> set:
        return self._acks.get(seq, set())
