"""WI Local Manager (paper §4.1): one per server.

Collects runtime hints from the VMs on its server through a guest/host
channel (Hyper-V KVP / XenStore stand-in: ``VMEndpoint``), rate-limits and
forwards them onto the bus; subscribes to platform hints and exposes them to
VMs through the metadata-service + scheduled-events interfaces the paper
cites (§4.2).
"""
from __future__ import annotations

import json
from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Optional

from repro.core import hints as H
from repro.core.bus import Bus, Record
from repro.core.safety import RateLimiter


class VMEndpoint:
    """What a workload sees from inside its VM.

    set_runtime_hints  — KVP/XenStore-style write (rate limited at the host)
    metadata           — metadata-service style attribute read
    scheduled_events   — poll upcoming platform events (eviction, throttle…)
    ack_event          — acknowledge a scheduled event (graceful shutdown)
    on_event           — optional push callback
    """

    def __init__(self, vm_id: str, workload: str, local: "LocalManager",
                 workload_manager: bool = False):
        self.vm_id, self.workload, self._local = vm_id, workload, local
        self._events: deque = deque(maxlen=256)
        self._acked: set = set()
        self._cb: Optional[Callable[[Dict[str, Any]], None]] = None
        self.metadata: Dict[str, Any] = {"vm_id": vm_id, "workload": workload}
        # host-side flag: only the deployment's designated workload-manager
        # VM (e.g. a YARN RM) may assert workload-wide runtime hints
        self._workload_manager = workload_manager

    def heartbeat(self):
        """Liveness signal to the host (the lease the local manager tracks).
        Hint writes and acks count as implicit heartbeats; an agent with
        nothing to say calls this periodically."""
        self._local.heartbeat(self.vm_id)

    def set_runtime_hints(self, hint_dict: Dict[str, Any],
                          workload_wide: bool = False) -> bool:
        """KVP/XenStore-style hint write.  ``workload_wide`` asserts the
        hints for the whole workload (resource ``*``) rather than this VM —
        the in-guest workload-manager path (e.g. a YARN RM adapting its
        deployment's hints to the diurnal phase).  Authorization is
        host-side: the write is rejected unless this VM was attached (or
        later promoted) as the workload's manager."""
        return self._local._vm_hint(self, hint_dict, workload_wide)

    def scheduled_events(self) -> List[Dict[str, Any]]:
        return [e for e in self._events if e["seq"] not in self._acked]

    def ack_event(self, seq: int):
        if seq in self._acked:
            return                      # idempotent: one ack per event
        event = next((e for e in reversed(self._events)
                      if e.get("seq") == seq), None)
        if event is None:
            return      # unknown or expired seq: nothing to ack (and the
            # ring-pruning bound on _acked must hold — see _deliver)
        self._acked.add(seq)
        self._local._event_acked(self.vm_id, seq, event)

    def on_event(self, cb: Callable[[Dict[str, Any]], None]):
        self._cb = cb

    def _deliver(self, event: Dict[str, Any]):
        if len(self._events) == self._events.maxlen:
            # the oldest event falls off the ring buffer: drop its ack-seq
            # too, so ``_acked`` can never outgrow the buffer
            self._acked.discard(self._events[0].get("seq"))
        self._events.append(event)
        if self._cb:
            self._cb(event)


class LocalManager:
    def __init__(self, server_id: str, bus: Bus, clock=None,
                 vm_hint_rate_per_s: float = 2.0, vm_hint_burst: float = 10.0,
                 lease_s: float = 0.0):
        self.server_id = server_id
        self.bus = bus
        self.clock = clock or (lambda: 0.0)
        self._vms: Dict[str, VMEndpoint] = {}
        self._limiter = RateLimiter(vm_hint_rate_per_s, vm_hint_burst,
                                    self.clock)
        self.stats = defaultdict(int)
        self._acks: Dict[int, set] = defaultdict(set)
        self._vm_acks: Dict[str, set] = defaultdict(set)    # vm -> seqs
        # heartbeat lease (0 disables): vm -> last sign of life; expired
        # guests are declared silent exactly once per silence episode
        self.lease_s = lease_s
        self._last_seen: Dict[str, float] = {}
        self._lease_lost: set = set()
        bus.subscribe(H.TOPIC_PLATFORM_HINTS, self._on_platform_hint)

    # -- VM lifecycle -------------------------------------------------------
    def attach_vm(self, vm_id: str, workload: str,
                  workload_manager: bool = False) -> VMEndpoint:
        ep = VMEndpoint(vm_id, workload, self, workload_manager)
        self._vms[vm_id] = ep
        self._last_seen[vm_id] = self.clock()
        self._lease_lost.discard(vm_id)
        return ep

    def authorize_workload_manager(self, vm_id: str, on: bool = True):
        """Host-side promotion/demotion of a VM's workload-manager role
        (e.g. the deployment fabric re-elects a leader after a kill)."""
        ep = self._vms.get(vm_id)
        if ep is not None:
            ep._workload_manager = on

    def detach_vm(self, vm_id: str):
        """Drop the endpoint AND every per-VM host-side entry (token-bucket
        state, ack fan-in sets) — under 100k-VM churn these otherwise grow
        without bound."""
        self._vms.pop(vm_id, None)
        self._limiter.forget((vm_id,))
        self._last_seen.pop(vm_id, None)
        self._lease_lost.discard(vm_id)
        for seq in self._vm_acks.pop(vm_id, ()):
            acked = self._acks.get(seq)
            if acked is not None:
                acked.discard(vm_id)
                if not acked:
                    del self._acks[seq]

    # -- heartbeat lease ----------------------------------------------------
    def heartbeat(self, vm_id: str):
        if vm_id in self._vms:
            self._last_seen[vm_id] = self.clock()
            self._lease_lost.discard(vm_id)

    def check_leases(self, now=None) -> List[str]:
        """Declare guests silent whose lease expired (no heartbeat, hint,
        or ack within ``lease_s``).  One ``lease_expired`` record per
        silence episode goes to ``wi.events.leases`` so the scheduler can
        stop redelivering notices to them; a later sign of life clears the
        flag and re-arms the lease."""
        if self.lease_s <= 0.0:
            return []
        now = self.clock() if now is None else now
        expired: List[str] = []
        for vm_id, ep in self._vms.items():
            if vm_id in self._lease_lost:
                continue
            seen = self._last_seen.get(vm_id, now)
            if now - seen > self.lease_s:
                self._lease_lost.add(vm_id)
                self.stats["leases_expired"] += 1
                expired.append(vm_id)
                self.bus.publish(H.TOPIC_LEASES, {
                    "event": "lease_expired", "vm": vm_id,
                    "server": self.server_id, "workload": ep.workload,
                    "last_seen_t": seen, "t": now}, key=vm_id)
        return expired

    # -- guest -> platform ------------------------------------------------------
    def _vm_hint(self, ep: VMEndpoint, hint_dict: Dict[str, Any],
                 workload_wide: bool = False) -> bool:
        vm_id, workload = ep.vm_id, ep.workload
        self.heartbeat(vm_id)           # any hint write is a sign of life
        if workload_wide and not ep._workload_manager:
            # any guest can hint about itself; only the designated
            # workload-manager VM may speak for the whole workload
            self.stats["vm_hint_unauthorized"] += 1
            return False
        if not self._limiter.allow((vm_id,)):
            self.stats["vm_hint_rate_limited"] += 1
            return False
        try:
            hint_dict = H.validate_hints(hint_dict)
        except H.HintError:
            self.stats["vm_hint_invalid"] += 1
            return False
        resource = "*" if workload_wide else f"{self.server_id}/{vm_id}"
        rec = H.HintRecord(workload=workload, resource=resource,
                           scope=H.Scope.RUNTIME.value, hints=hint_dict,
                           source=f"vm:{vm_id}", ts=self.clock())
        self.bus.publish(H.TOPIC_RUNTIME_HINTS, json.loads(rec.to_json()),
                         key=resource)
        self.stats["vm_hints_forwarded"] += 1
        return True

    # -- platform -> guest -------------------------------------------------------
    def _on_platform_hint(self, rec: Record):
        d = rec.value
        res = d.get("resource", "")
        # resource is 'server/vm' or 'server' or '*'
        if res == "*" or res == self.server_id:
            targets = list(self._vms.values())
        elif res.startswith(self.server_id + "/"):
            vm = res[len(self.server_id) + 1:]
            targets = [self._vms[vm]] if vm in self._vms else []
        else:
            # workload-addressed events go to that workload's VMs here
            targets = [ep for ep in self._vms.values()
                       if ep.workload == d.get("workload")] \
                if res == "" else []
        for ep in targets:
            ep._deliver(d)
            self.stats["events_delivered"] += 1

    def _event_acked(self, vm_id: str, seq: int,
                     event: Optional[Dict[str, Any]] = None):
        """Record a guest ack and forward it onto the bus so the platform
        can react (the eviction pipeline releases acked VMs early)."""
        self.heartbeat(vm_id)           # an ack is a sign of life
        self._acks[seq].add(vm_id)
        self._vm_acks[vm_id].add(seq)
        self.stats["events_acked"] += 1
        ack = {"vm": vm_id, "server": self.server_id, "seq": seq,
               "t": self.clock()}
        if event is not None:
            ack["event"] = event.get("event")
            ack["resource"] = event.get("resource")
            ack["workload"] = event.get("workload")
            # the deadline the guest believes it is acking: pins the ack to
            # its ticket generation at the pipeline (lossy channels can
            # deliver acks arbitrarily late)
            kill_t = event.get("payload", {}).get("kill_t")
            if kill_t is not None:
                ack["kill_t"] = kill_t
        self.bus.publish(H.TOPIC_EVENT_ACKS, ack, key=vm_id)

    def acked(self, seq: int) -> set:
        return self._acks.get(seq, set())
