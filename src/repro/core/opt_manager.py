"""Optimization-manager base (paper §4.1 right side, §5.2, Table 5).

Onboarding an optimization = define (1) managed resource, (2) priority
(Table 4 — keyed by ``name`` into pricing.PRIORITY), (3) owner benefit,
(4) pricing, (5) cost model (pricing.PRICING), plus the Table-5 contract:
which hints it consumes (pull via the store / push via bus subscription) and
which platform hints it publishes.

Concrete optimizations subclass ``optimizations.policies.OptimizationPolicy``
(this base + the scheduler-substrate hooks); billing for enabled
optimizations is metered per VM by ``pricing.BillingMeter`` off the
scheduler's decision records.
"""
from __future__ import annotations

import json
from collections import defaultdict
from typing import Any, Dict, Iterable, List, Optional

from repro.core import hints as H
from repro.core.coordinator import Claim
from repro.core.global_manager import GlobalManager
from repro.core.pricing import PRICING, PRIORITY, applicable


class OptimizationManager:
    name: str = "base"
    consumes_deploy: tuple = ()
    consumes_runtime: tuple = ()
    publishes: tuple = ()

    def __init__(self, gm: GlobalManager):
        assert self.name in PRIORITY, self.name
        self.gm = gm
        self.stats = defaultdict(int)
        self._group = f"opt:{self.name}"
        # push subscriptions for runtime hints this optimization reacts to
        if self.consumes_runtime:
            gm.bus.subscribe(H.TOPIC_RUNTIME_HINTS, self._on_runtime_hint)

    # -- hint access -------------------------------------------------------
    def applicable_workloads(self, workloads: Iterable[str]) -> List[str]:
        return [w for w in workloads
                if applicable(self.name, self.gm.effective_hints(w))]

    def hints_for(self, workload: str, resource: str = "*") -> Dict[str, Any]:
        return self.gm.effective_hints(workload, resource)

    def poll_runtime_hints(self, max_records=100):
        return self.gm.bus.poll(H.TOPIC_RUNTIME_HINTS, self._group,
                                max_records)

    def _on_runtime_hint(self, rec):
        d = rec.value
        if any(k in d.get("hints", {}) for k in self.consumes_runtime):
            self.on_runtime_hint(d)

    def on_runtime_hint(self, hint_record: Dict[str, Any]):
        """Override: react to a runtime hint push."""

    # -- actions ------------------------------------------------------------
    def notify(self, event: H.PlatformEvent, workload: str, resource: str,
               deadline_s: float = 0.0, **payload):
        ok = self.gm.publish_platform_hint(H.PlatformHint(
            event=event.value, workload=workload, resource=resource,
            deadline_s=deadline_s, payload=payload, source_opt=self.name))
        self.stats["notices" if ok else "notices_rate_limited"] += 1
        return ok

    def claim(self, workload: str, resource: str, amount: float,
              compressible: bool):
        return Claim(opt=self.name, workload=workload, resource=resource,
                     amount=amount, compressible=compressible,
                     ts=self.gm.clock())

    @property
    def pricing(self):
        return PRICING[self.name]

    @property
    def priority(self) -> int:
        return PRIORITY[self.name]
