"""Conflict resolution across optimizations (paper §4.4, Table 4, Figure 3).

Resources are *claimed* by optimization managers.  The coordinator resolves:
  1. different priority  -> higher priority (lower Table-4 number) wins;
  2. equal priority, compressible resource (CPU freq, harvested cores)
     -> max-min fair share;
  3. equal priority, non-compressible -> earliest request time wins;
  4. simultaneous requests -> deterministic seeded random pick.

It also enforces fair sharing *between workloads* inside one optimization's
allocation (§4.4 last sentence).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core.pricing import PRIORITY
from repro.core.safety import FairShare


@dataclass
class Claim:
    opt: str                    # optimization name (PRIORITY key)
    workload: str
    resource: str               # resource id, e.g. "server3/cpu_freq"
    amount: float               # requested units
    compressible: bool
    ts: float                   # request time
    claim_id: int = 0


@dataclass
class Grant:
    claim: Claim
    amount: float               # granted units (0 = denied)
    reason: str = ""


class Coordinator:
    def __init__(self, seed: int = 0, clock=None):
        self._rng = random.Random(seed)
        self._clock = clock or (lambda: 0.0)
        self._capacity: Dict[str, float] = {}
        self._grants: Dict[str, List[Grant]] = {}
        self._next_id = 0

    def set_capacity(self, resource: str, capacity: float):
        self._capacity[resource] = capacity

    def submit(self, claims: List[Claim]) -> List[Grant]:
        """Resolve a batch of claims resource by resource."""
        for c in claims:
            self._next_id += 1
            c.claim_id = self._next_id
        out: List[Grant] = []
        by_res: Dict[str, List[Claim]] = {}
        for c in claims:
            by_res.setdefault(c.resource, []).append(c)
        for res, cs in by_res.items():
            out.extend(self._resolve(res, cs))
        return out

    # -- Figure 3 ------------------------------------------------------------
    def _resolve(self, resource: str, claims: List[Claim]) -> List[Grant]:
        cap = self._capacity.get(resource, float("inf"))
        # already-granted amounts still count against capacity
        cap -= sum(g.amount for g in self._grants.get(resource, ()))
        grants: List[Grant] = []
        # 1) order by priority (on-demand = 0 beats everything)
        claims = sorted(claims, key=lambda c: (PRIORITY.get(c.opt, 99),))
        i = 0
        while i < len(claims):
            prio = PRIORITY.get(claims[i].opt, 99)
            tier = [c for c in claims if PRIORITY.get(c.opt, 99) == prio]
            i += len(tier)
            if cap <= 1e-12:
                grants.extend(Grant(c, 0.0, "no capacity") for c in tier)
                continue
            if len(tier) == 1:
                g = min(tier[0].amount, cap)
                grants.append(Grant(tier[0], g, "sole claimant at priority"))
                cap -= g
                continue
            if all(c.compressible for c in tier):
                # 2) fair share among equal-priority compressible claims,
                #    fair BETWEEN workloads first, then within a workload.
                by_wl: Dict[str, List[Claim]] = {}
                for c in tier:
                    by_wl.setdefault(c.workload, []).append(c)
                wl_alloc = FairShare.allocate(
                    cap, {w: sum(c.amount for c in cs)
                          for w, cs in by_wl.items()})
                for w, cs in by_wl.items():
                    inner = FairShare.allocate(
                        wl_alloc[w], {str(c.claim_id): c.amount for c in cs})
                    for c in cs:
                        g = inner[str(c.claim_id)]
                        grants.append(Grant(c, g, "fair share"))
                        cap -= g
            else:
                # 3) earliest request wins; 4) random tiebreak
                tier = sorted(tier, key=lambda c: (c.ts, self._rng.random()))
                for c in tier:
                    g = min(c.amount, cap)
                    grants.append(Grant(
                        c, g, "earliest request" if g else "no capacity"))
                    cap -= g
        self._grants.setdefault(resource, []).extend(
            g for g in grants if g.amount > 0)
        return grants

    def release(self, resource: str, claim_id: int):
        gs = self._grants.get(resource, [])
        self._grants[resource] = [g for g in gs
                                  if g.claim.claim_id != claim_id]

    def granted(self, resource: str) -> float:
        return sum(g.amount for g in self._grants.get(resource, ()))
