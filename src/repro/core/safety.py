"""Safety machinery (paper §4.3).

  * Token-bucket rate limiting *per interface x per workload x per
    optimization* ("we enforce maximum rates ... for all interfaces
    separately") — DoS protection.
  * History-based consistency checking: hints that flip-flop implausibly
    fast or contradict observed behaviour are ignored and the workload is
    notified ("the cloud platform ignores any inconsistent/incompatible
    hints based on history").
  * Fair-share accounting across workloads for compressible resources.
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple


class RateLimiter:
    """Token bucket per (interface, principal)."""

    def __init__(self, rate_per_s: float, burst: float,
                 clock: Callable[[], float]):
        self.rate = rate_per_s
        self.burst = burst
        self._clock = clock
        self._state: Dict[Any, Tuple[float, float]] = {}  # key -> (tokens, ts)

    def allow(self, key) -> bool:
        now = self._clock()
        tokens, ts = self._state.get(key, (self.burst, now))
        tokens = min(self.burst, tokens + (now - ts) * self.rate)
        if tokens >= 1.0:
            self._state[key] = (tokens - 1.0, now)
            return True
        self._state[key] = (tokens, now)
        return False

    def forget(self, key):
        """Drop a principal's bucket (its VM detached / workload retired) so
        per-key state cannot grow unboundedly under churn."""
        self._state.pop(key, None)


@dataclass
class ConsistencyVerdict:
    accepted: bool
    reason: str = ""


class ConsistencyChecker:
    """Rejects implausible hint updates based on history.

    Rules (conservative, per §4.3):
      * flip-flop: a boolean/threshold hint may not change direction more
        than ``max_flips`` times within ``window_s`` seconds;
      * contradiction: preemptibility_pct may not *rise* while an eviction
        issued under the previous value is still pending acknowledgement
        (prevents gaming eviction choice mid-flight);
      * magnitude: numeric hints may not change by more than
        ``max_jump`` x the historical span in one update.
    """

    def __init__(self, clock, window_s=60.0, max_flips=4, max_jump=100.0):
        self._clock = clock
        self.window_s, self.max_flips, self.max_jump = (window_s, max_flips,
                                                        max_jump)
        self._hist: Dict[Tuple[str, str, str], collections.deque] = \
            collections.defaultdict(lambda: collections.deque(maxlen=64))
        self._pending_evictions: set = set()
        # (workload, resource) -> hint keys with history, so forget() can
        # drop a dead resource's entries without scanning every key ever
        # seen (under 100k-VM churn _hist would otherwise grow unboundedly)
        self._keys_by_resource: Dict[Tuple[str, str], set] = {}

    def note_eviction_pending(self, resource: str):
        self._pending_evictions.add(resource)

    def note_eviction_done(self, resource: str):
        self._pending_evictions.discard(resource)

    def forget(self, workload: str, resource: str):
        """Drop all consistency history for a resource that no longer
        exists (its VM was killed, crashed, or released) — mirrors
        ``RateLimiter.forget``.  Workload-level ('*') history survives."""
        if resource == "*":
            return
        keys = self._keys_by_resource.pop((workload, resource), None)
        if keys:
            for k in keys:
                self._hist.pop((workload, resource, k), None)
        self._pending_evictions.discard(resource)

    def check(self, workload: str, resource: str,
              hints: Dict[str, Any]) -> ConsistencyVerdict:
        now = self._clock()
        for k, v in hints.items():
            h = self._hist[(workload, resource, k)]
            recent = [(t, old) for (t, old) in h if now - t <= self.window_s]
            # flip-flop detection: count direction changes in the window
            seq = [old for _, old in recent] + [v]
            flips = 0
            if all(isinstance(s, bool) for s in seq):
                flips = sum(int(a != b) for a, b in zip(seq, seq[1:]))
            elif all(isinstance(s, (int, float)) for s in seq) and len(seq) > 2:
                dirs = [1 if b > a else (-1 if b < a else 0)
                        for a, b in zip(seq, seq[1:]) if b != a]
                flips = sum(int(a != b) for a, b in zip(dirs, dirs[1:]))
            if flips > self.max_flips:
                return ConsistencyVerdict(False, f"flip-flop on {k}")
            # contradiction: raising preemptibility during pending eviction
            if (k == "preemptibility_pct" and resource
                    in self._pending_evictions and recent
                    and v > recent[-1][1]):
                return ConsistencyVerdict(
                    False, "preemptibility raised during pending eviction")
            # magnitude jumps
            if (isinstance(v, (int, float)) and not isinstance(v, bool)
                    and recent):
                vals = [old for _, old in recent
                        if isinstance(old, (int, float))]
                if vals:
                    span = max(max(vals) - min(vals), 1e-9)
                    if abs(v - vals[-1]) > self.max_jump * max(span, 1.0):
                        return ConsistencyVerdict(False,
                                                  f"implausible jump on {k}")
        if hints:
            idx = self._keys_by_resource.setdefault((workload, resource),
                                                    set())
            idx.update(hints)
        for k, v in hints.items():
            self._hist[(workload, resource, k)].append((now, v))
        return ConsistencyVerdict(True)


class FairShare:
    """Max-min fair allocation of a compressible resource (§4.4 Fig 3)."""

    @staticmethod
    def allocate(capacity: float, demands: Dict[str, float]
                 ) -> Dict[str, float]:
        """Water-filling: sort by demand; every unsatisfied claimant gets an
        equal share of what remains, capped at its demand."""
        if not demands:
            return {}
        alloc = {k: 0.0 for k in demands}
        cap = capacity
        for i, (k, d) in enumerate(sorted(demands.items(),
                                          key=lambda kv: kv[1])):
            n_left = len(demands) - i
            share = min(d, cap / n_left)
            alloc[k] = share
            cap -= share
        return alloc
