"""Pricing & benefit models for the ten optimizations (paper Table 2),
plus the per-VM metering/billing layer.

Each optimization has: the resource it manages, the average user benefit
(relative cost multiplier vs a Regular VM), min/max pricing anchors, and the
platform benefit model.  These are the paper's published numbers — the §6.4
provider-scale reproduction (sim/provider_scale.py) must recover the 48.8%
average saving from them, analytically *and* dynamically: ``BillingMeter``
accumulates per-VM core-hour meters at the Table-2 price multipliers from
the scheduler's decision records on the bus (places/migrations/resizes on
``wi.sched.decisions``, kills and early releases on ``wi.sched.evictions``)
and reconciles against the cluster's own core-hour integral.  Within each
§6.4 conflict set at most one optimization is ever billed on a VM
(``billed_set``); Table-4 priorities order the managers' actions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, FrozenSet, Iterable, List, Optional, Tuple

REGULAR_PRICE = 1.0     # normalized $/core-hour


@dataclass(frozen=True)
class OptPricing:
    name: str
    resource: str                   # what it manages (Table 2 "Cloud Resources")
    user_benefit: float             # average fractional cost saving (Table 2)
    price_multiplier: float         # price paid vs Regular when enabled
    platform_benefit: str
    carbon_benefit: float = 0.0     # fractional carbon saving when enabled
    perf_benefit: float = 0.0       # fractional perf gain (overclocking)


# Table 2 rows.  price_multiplier = 1 - user_benefit on average.
PRICING: Dict[str, OptPricing] = {p.name: p for p in [
    OptPricing("auto_scaling", "compute", 0.19, 0.81, "compute allocation",
               carbon_benefit=0.19),
    OptPricing("spot", "spare_compute", 0.85, 0.15, "compute allocation"),
    OptPricing("harvest", "spare_compute", 0.91, 0.09, "compute allocation"),
    OptPricing("overclocking", "cpu_frequency", 0.11, 0.89,
               "reliability, power/energy", perf_benefit=0.11),
    OptPricing("underclocking", "cpu_frequency", 0.01, 0.99, "power, energy",
               carbon_benefit=0.01),
    OptPricing("non_preprovision", "spare_compute", 0.02, 0.98,
               "compute allocation"),
    OptPricing("region_agnostic", "compute", 0.22, 0.78, "efficient region",
               carbon_benefit=0.51),
    OptPricing("oversubscription", "compute", 0.15, 0.85,
               "compute allocation", carbon_benefit=0.15),
    OptPricing("rightsizing", "compute", 0.50, 0.50, "compute allocation",
               carbon_benefit=0.50),
    OptPricing("ma_datacenters", "cpu_frequency", 0.40, 0.60,
               "infrastructure cost"),
]}

# Priorities (Table 4): 0 = highest (on-demand).
PRIORITY: Dict[str, int] = {
    "on_demand": 0,
    "ma_datacenters": 1,
    "rightsizing": 2,
    "oversubscription": 3,
    "auto_scaling": 4,
    "non_preprovision": 5,
    "region_agnostic": 6,
    "underclocking": 7,
    "overclocking": 8,
    "spot": 9,
    "harvest": 10,
}

# §6.4: optimizations that contend and cannot stack multiplicatively.
CONFLICT_SETS: Tuple[FrozenSet[str], ...] = (
    frozenset({"spot", "harvest", "non_preprovision"}),      # spare compute
    frozenset({"overclocking", "underclocking", "ma_datacenters"}),  # CPU freq
)

# Table 3: required workload characteristics per optimization.
# (hint key, predicate) — all must hold for the optimization to apply.
REQUIREMENTS = {
    "auto_scaling": [("scale_out_in", lambda v: v is True),
                     ("delay_tolerance_ms", lambda v: v > 0)],
    "spot": [("preemptibility_pct", lambda v: v >= 20.0)],
    "harvest": [("scale_up_down", lambda v: v is True),
                ("preemptibility_pct", lambda v: v >= 20.0),
                ("delay_tolerance_ms", lambda v: v > 0)],
    "overclocking": [("scale_up_down", lambda v: v is True),
                     ("delay_tolerance_ms", lambda v: v > 0)],
    "underclocking": [("scale_up_down", lambda v: v is True),
                      ("delay_tolerance_ms", lambda v: v > 0)],
    "non_preprovision": [("deploy_time_ms", lambda v: v >= 60_000)],
    "region_agnostic": [("region_independent", lambda v: v is True)],
    "oversubscription": [("delay_tolerance_ms", lambda v: v > 0)],
    "rightsizing": [("availability_nines", lambda v: v <= 4.0),
                    ("scale_up_down", lambda v: v is True)],
    "ma_datacenters": [("availability_nines", lambda v: v <= 3.0)],
}


def applicable(opt: str, eff_hints: Dict) -> bool:
    return all(pred(eff_hints.get(key)) for key, pred in REQUIREMENTS[opt])


def applicable_set(eff_hints: Dict) -> Tuple[str, ...]:
    return tuple(o for o in PRICING if applicable(o, eff_hints))


def combined_price(opts) -> float:
    """Price multiplier for a set of enabled optimizations.

    Within each conflict set only the single best (cheapest) optimization
    applies (§6.4); independent optimizations stack multiplicatively.
    """
    opts = set(opts)
    mult = 1.0
    for cs in CONFLICT_SETS:
        inter = opts & cs
        if inter:
            best = min(inter, key=lambda o: PRICING[o].price_multiplier)
            mult *= PRICING[best].price_multiplier
            opts -= cs
    for o in opts:
        mult *= PRICING[o].price_multiplier
    return mult


def combined_carbon(opts) -> float:
    """Fractional carbon saving for a set of optimizations (independent
    savings compose as products of remainders)."""
    opts = set(opts)
    keep = 1.0
    chosen = []
    for cs in CONFLICT_SETS:
        inter = opts & cs
        if inter:
            best = max(inter, key=lambda o: PRICING[o].carbon_benefit)
            chosen.append(best)
            opts -= cs
    chosen.extend(opts)
    for o in chosen:
        keep *= 1.0 - PRICING[o].carbon_benefit
    return 1.0 - keep


def billed_set(opts: Iterable[str],
               eff_hints: Optional[Dict] = None) -> Tuple[str, ...]:
    """Conflict-resolved billable optimization set for one VM.

    Keeps only known optimizations, drops any that the workload's effective
    hints make inapplicable (Table 3 requirements, when hints are given),
    and collapses each §6.4 conflict set to its single cheapest member —
    the invariant the metering layer enforces: two optimizations that
    contend for the same resource are never co-billed on one VM.
    """
    out = {o for o in opts if o in PRICING}
    if eff_hints is not None:
        out = {o for o in out if applicable(o, eff_hints)}
    for cs in CONFLICT_SETS:
        inter = out & cs
        if len(inter) > 1:
            best = min(inter, key=lambda o: (PRICING[o].price_multiplier, o))
            out -= cs
            out.add(best)
    return tuple(sorted(out))


# Extension hint carrying a workload's chosen optimization enrollments
# (validated by the 'x-' namespace rule); absent means "bill everything
# the hints make applicable".
ENROLLED_HINT_KEY = "x-enrolled-opts"


class _VMMeter:
    """One VM's running bill: core-hours x Table-2 multiplier."""
    __slots__ = ("vm_id", "workload", "cores", "rate", "opts", "last_t",
                 "core_hours", "cost", "open")

    def __init__(self, vm_id: str, workload: str, cores: float, rate: float,
                 opts: Tuple[str, ...], t: float):
        self.vm_id = vm_id
        self.workload = workload
        self.cores = cores
        self.rate = rate
        self.opts = opts
        self.last_t = t
        self.core_hours = 0.0
        self.cost = 0.0
        self.open = True


class BillingMeter:
    """Per-VM metering driven by the scheduler's bus records.

    Construct it *before* the first placement so it observes every decision
    record.  Lifecycle it tracks:

      * ``wi.sched.decisions`` — ``place`` opens a meter at the decision's
        timestamp (cores read from the cluster registry); ``migrate`` /
        ``defrag`` are continuity (the VM never stopped running);
        ``resize`` re-reads the VM's cores after accruing at the old size;
      * ``wi.sched.evictions`` — ``evicted`` / ``early_released`` close the
        meter at the record's timestamp;
      * cluster kill listeners — kills that bypass the pipeline (scenario
        churn) close at the cluster clock; closing is idempotent, so the
        eviction record arriving afterwards is a no-op;
      * hint-change topics — a workload's billed set is re-resolved from
        the store and its open meters re-rated (accrued at the old rate up
        to the change, the new rate after).

    The billed set per workload is ``billed_set(enrolled, effective
    hints)``: the workload's ``x-enrolled-opts`` extension hint (all
    applicable optimizations when absent) filtered by Table-3 applicability
    and collapsed per §6.4 conflict set.  ``reconcile`` cross-checks total
    metered core-hours against the cluster's own core-hour integral.
    """

    def __init__(self, gm, cluster):
        from repro.core import hints as H
        self.gm = gm
        self.cluster = cluster
        self.meters: Dict[str, _VMMeter] = {}
        self.core_hours = 0.0
        self.cost = 0.0
        self._rate_cache: Dict[str, Tuple[Tuple[str, ...], float]] = {}
        # workload -> open meter vm_ids: hint-change re-rating touches only
        # the affected workload's open meters, not the whole (growing)
        # meter registry — per-VM runtime hints under churn would otherwise
        # cost O(hint events x total VMs)
        self._open_by_workload: Dict[str, set] = {}
        gm.bus.subscribe(H.TOPIC_SCHED_DECISIONS, self._on_decisions)
        gm.bus.subscribe(H.TOPIC_EVICTIONS, self._on_eviction)
        gm.bus.subscribe(H.TOPIC_DEPLOY_HINTS, self._on_hint_change)
        gm.bus.subscribe(H.TOPIC_RUNTIME_HINTS, self._on_hint_change)
        kills = getattr(cluster, "kill_listeners", None)
        if kills is not None:
            kills.append(self._on_kill)

    # -- rate resolution ----------------------------------------------------
    def billed_for(self, workload: str) -> Tuple[Tuple[str, ...], float]:
        """(billed opts, price multiplier) for a workload, cached until its
        hints change."""
        cached = self._rate_cache.get(workload)
        if cached is None:
            eff = self.gm.effective_hints(workload)
            enrolled = eff.get(ENROLLED_HINT_KEY)
            cand = tuple(PRICING) if enrolled is None else tuple(enrolled)
            opts = billed_set(cand, eff)
            cached = self._rate_cache[workload] = (opts, combined_price(opts))
        return cached

    # -- accrual ------------------------------------------------------------
    def _now(self) -> float:
        clock = getattr(self.cluster, "clock", None)
        return clock() if clock is not None else 0.0

    def _accrue(self, m: _VMMeter, t: float):
        dt = t - m.last_t
        if dt > 0:
            ch = m.cores * dt / 3600.0
            m.core_hours += ch
            m.cost += ch * REGULAR_PRICE * m.rate
            self.core_hours += ch
            self.cost += ch * REGULAR_PRICE * m.rate
            m.last_t = t

    def _open(self, vm_id: str, workload: str, t: float):
        m = self.meters.get(vm_id)
        if m is not None and m.open:
            return
        vm = self.cluster.vms.get(vm_id)
        cores = (vm.cores + vm.harvested) if vm is not None else 0.0
        opts, rate = self.billed_for(workload)
        if m is not None:           # re-placed after a close (failover):
            # the gap while it was down is not billed — restart the clock
            m.cores, m.rate, m.opts, m.last_t, m.open = \
                cores, rate, opts, t, True
        else:
            self.meters[vm_id] = _VMMeter(vm_id, workload, cores, rate,
                                          opts, t)
        self._open_by_workload.setdefault(workload, set()).add(vm_id)

    def _close(self, vm_id: str, t: float):
        m = self.meters.get(vm_id)
        if m is not None and m.open:
            self._accrue(m, t)
            m.open = False
            open_ids = self._open_by_workload.get(m.workload)
            if open_ids is not None:
                open_ids.discard(vm_id)

    def _rerate_cores(self, vm_id: str, t: float):
        m = self.meters.get(vm_id)
        vm = self.cluster.vms.get(vm_id)
        if m is None or not m.open or vm is None:
            return
        self._accrue(m, t)
        m.cores = vm.cores + vm.harvested

    # -- bus reactions ------------------------------------------------------
    def _on_decisions(self, rec):
        d = rec.value
        if not isinstance(d, dict):
            return
        kind = d.get("kind")
        fields = d.get("fields", ())
        for row in d.get("decisions", ()):
            r = (row._asdict() if hasattr(row, "_asdict")
                 else dict(zip(fields, row)))
            if not r.get("server"):
                continue                    # rejected placement
            if kind in ("place", "migrate", "defrag"):
                self._open(r["vm_id"], r["workload"], r.get("t", 0.0))
            elif kind == "resize":
                self._rerate_cores(r["vm_id"], r.get("t", 0.0))

    def _on_eviction(self, rec):
        d = rec.value
        if isinstance(d, dict) and d.get("event") in (
                "evicted", "early_released", "already_gone"):
            self._close(d.get("vm", ""), d.get("t", self._now()))

    def _on_kill(self, vm):
        self._close(vm.vm_id, self._now())

    def _on_hint_change(self, rec):
        d = rec.value
        if not isinstance(d, dict) or "workload" not in d:
            return
        w = d["workload"]
        if self._rate_cache.pop(w, None) is None:
            return                          # never billed: nothing to re-rate
        t = d.get("ts", d.get("t", self._now()))
        opts, rate = self.billed_for(w)
        for vm_id in self._open_by_workload.get(w, ()):
            m = self.meters[vm_id]
            if m.open:
                self._accrue(m, t)
                m.rate, m.opts = rate, opts

    # -- reporting ----------------------------------------------------------
    def accrue_all(self, now: float):
        for m in self.meters.values():
            if m.open:
                self._accrue(m, now)

    @property
    def regular_cost(self) -> float:
        return self.core_hours * REGULAR_PRICE

    @property
    def saving(self) -> float:
        reg = self.regular_cost
        return 1.0 - self.cost / reg if reg else 0.0

    def summary(self, now: float) -> Dict[str, Any]:
        self.accrue_all(now)
        return {
            "core_hours": self.core_hours,
            "cost": self.cost,
            "regular_cost": self.regular_cost,
            "saving": self.saving,
            "vms_metered": len(self.meters),
            "vms_open": sum(1 for m in self.meters.values() if m.open),
        }

    def reconcile(self, now: float) -> Dict[str, float]:
        """Metered core-hours vs the cluster's own integral (must agree)."""
        self.accrue_all(now)
        cluster_ch = self.cluster.core_hours(now)
        return {"metered_core_hours": self.core_hours,
                "cluster_core_hours": cluster_ch,
                "abs_diff": abs(self.core_hours - cluster_ch)}


class CostMeter:
    """Accumulates core-hours x price for a workload (case studies)."""

    def __init__(self):
        self.core_hours = 0.0
        self.cost = 0.0
        self.regular_cost = 0.0

    def charge(self, cores: float, hours: float, opts=()):
        self.core_hours += cores * hours
        self.cost += cores * hours * REGULAR_PRICE * combined_price(opts)
        self.regular_cost += cores * hours * REGULAR_PRICE

    @property
    def saving(self) -> float:
        if self.regular_cost == 0:
            return 0.0
        return 1.0 - self.cost / self.regular_cost
