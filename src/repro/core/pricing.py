"""Pricing & benefit models for the ten optimizations (paper Table 2).

Each optimization has: the resource it manages, the average user benefit
(relative cost multiplier vs a Regular VM), min/max pricing anchors, and the
platform benefit model.  These are the paper's published numbers — the §6.4
provider-scale reproduction (sim/provider_scale.py) must recover the 48.8%
average saving from them.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, FrozenSet, Tuple

REGULAR_PRICE = 1.0     # normalized $/core-hour


@dataclass(frozen=True)
class OptPricing:
    name: str
    resource: str                   # what it manages (Table 2 "Cloud Resources")
    user_benefit: float             # average fractional cost saving (Table 2)
    price_multiplier: float         # price paid vs Regular when enabled
    platform_benefit: str
    carbon_benefit: float = 0.0     # fractional carbon saving when enabled
    perf_benefit: float = 0.0       # fractional perf gain (overclocking)


# Table 2 rows.  price_multiplier = 1 - user_benefit on average.
PRICING: Dict[str, OptPricing] = {p.name: p for p in [
    OptPricing("auto_scaling", "compute", 0.19, 0.81, "compute allocation",
               carbon_benefit=0.19),
    OptPricing("spot", "spare_compute", 0.85, 0.15, "compute allocation"),
    OptPricing("harvest", "spare_compute", 0.91, 0.09, "compute allocation"),
    OptPricing("overclocking", "cpu_frequency", 0.11, 0.89,
               "reliability, power/energy", perf_benefit=0.11),
    OptPricing("underclocking", "cpu_frequency", 0.01, 0.99, "power, energy",
               carbon_benefit=0.01),
    OptPricing("non_preprovision", "spare_compute", 0.02, 0.98,
               "compute allocation"),
    OptPricing("region_agnostic", "compute", 0.22, 0.78, "efficient region",
               carbon_benefit=0.51),
    OptPricing("oversubscription", "compute", 0.15, 0.85,
               "compute allocation", carbon_benefit=0.15),
    OptPricing("rightsizing", "compute", 0.50, 0.50, "compute allocation",
               carbon_benefit=0.50),
    OptPricing("ma_datacenters", "cpu_frequency", 0.40, 0.60,
               "infrastructure cost"),
]}

# Priorities (Table 4): 0 = highest (on-demand).
PRIORITY: Dict[str, int] = {
    "on_demand": 0,
    "ma_datacenters": 1,
    "rightsizing": 2,
    "oversubscription": 3,
    "auto_scaling": 4,
    "non_preprovision": 5,
    "region_agnostic": 6,
    "underclocking": 7,
    "overclocking": 8,
    "spot": 9,
    "harvest": 10,
}

# §6.4: optimizations that contend and cannot stack multiplicatively.
CONFLICT_SETS: Tuple[FrozenSet[str], ...] = (
    frozenset({"spot", "harvest", "non_preprovision"}),      # spare compute
    frozenset({"overclocking", "underclocking", "ma_datacenters"}),  # CPU freq
)

# Table 3: required workload characteristics per optimization.
# (hint key, predicate) — all must hold for the optimization to apply.
REQUIREMENTS = {
    "auto_scaling": [("scale_out_in", lambda v: v is True),
                     ("delay_tolerance_ms", lambda v: v > 0)],
    "spot": [("preemptibility_pct", lambda v: v >= 20.0)],
    "harvest": [("scale_up_down", lambda v: v is True),
                ("preemptibility_pct", lambda v: v >= 20.0),
                ("delay_tolerance_ms", lambda v: v > 0)],
    "overclocking": [("scale_up_down", lambda v: v is True),
                     ("delay_tolerance_ms", lambda v: v > 0)],
    "underclocking": [("scale_up_down", lambda v: v is True),
                      ("delay_tolerance_ms", lambda v: v > 0)],
    "non_preprovision": [("deploy_time_ms", lambda v: v >= 60_000)],
    "region_agnostic": [("region_independent", lambda v: v is True)],
    "oversubscription": [("delay_tolerance_ms", lambda v: v > 0)],
    "rightsizing": [("availability_nines", lambda v: v <= 4.0),
                    ("scale_up_down", lambda v: v is True)],
    "ma_datacenters": [("availability_nines", lambda v: v <= 3.0)],
}


def applicable(opt: str, eff_hints: Dict) -> bool:
    return all(pred(eff_hints.get(key)) for key, pred in REQUIREMENTS[opt])


def applicable_set(eff_hints: Dict) -> Tuple[str, ...]:
    return tuple(o for o in PRICING if applicable(o, eff_hints))


def combined_price(opts) -> float:
    """Price multiplier for a set of enabled optimizations.

    Within each conflict set only the single best (cheapest) optimization
    applies (§6.4); independent optimizations stack multiplicatively.
    """
    opts = set(opts)
    mult = 1.0
    for cs in CONFLICT_SETS:
        inter = opts & cs
        if inter:
            best = min(inter, key=lambda o: PRICING[o].price_multiplier)
            mult *= PRICING[best].price_multiplier
            opts -= cs
    for o in opts:
        mult *= PRICING[o].price_multiplier
    return mult


def combined_carbon(opts) -> float:
    """Fractional carbon saving for a set of optimizations (independent
    savings compose as products of remainders)."""
    opts = set(opts)
    keep = 1.0
    chosen = []
    for cs in CONFLICT_SETS:
        inter = opts & cs
        if inter:
            best = max(inter, key=lambda o: PRICING[o].carbon_benefit)
            chosen.append(best)
            opts -= cs
    chosen.extend(opts)
    for o in chosen:
        keep *= 1.0 - PRICING[o].carbon_benefit
    return 1.0 - keep


class CostMeter:
    """Accumulates core-hours x price for a workload (case studies)."""

    def __init__(self):
        self.core_hours = 0.0
        self.cost = 0.0
        self.regular_cost = 0.0

    def charge(self, cores: float, hours: float, opts=()):
        self.core_hours += cores * hours
        self.cost += cores * hours * REGULAR_PRICE * combined_price(opts)
        self.regular_cost += cores * hours * REGULAR_PRICE

    @property
    def saving(self) -> float:
        if self.regular_cost == 0:
            return 0.0
        return 1.0 - self.cost / self.regular_cost
