"""REST-ish JSON-over-TCP interface to the Global Manager (paper §4.2:
"the WI global manager REST interface").

Line-delimited JSON requests: {"op": ..., ...} -> {"ok": bool, ...}.
Used by deployment tooling and logically-centralized workload managers (the
YARN ResourceManager example in §4.2).  Runs on a thread; tests exercise a
real socket round-trip.
"""
from __future__ import annotations

import json
import socket
import socketserver
import threading
from typing import Any, Dict, Optional

from repro.core import hints as H
from repro.core.global_manager import GlobalManager


class _Handler(socketserver.StreamRequestHandler):
    def handle(self):
        gm: GlobalManager = self.server.gm   # type: ignore[attr-defined]
        for line in self.rfile:
            try:
                req = json.loads(line.decode())
                resp = _dispatch(gm, req)
            except Exception as e:   # noqa: BLE001 — server must not die
                resp = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            self.wfile.write((json.dumps(resp) + "\n").encode())
            self.wfile.flush()


def _dispatch(gm: GlobalManager, req: Dict[str, Any]) -> Dict[str, Any]:
    op = req.get("op")
    if op == "register":
        key = gm.register_workload(req["workload"], req.get("hints"),
                                   tuple(req.get("resources", ["*"])))
        return {"ok": True, "key": key.hex()}
    if op == "set_hints":
        ok = gm.set_hints(req["workload"], req.get("resource", "*"),
                          req.get("hints", {}),
                          scope=H.Scope(req.get("scope", "runtime")),
                          source=req.get("source", "api"),
                          envelope=req.get("envelope"))
        return {"ok": ok}
    if op == "get_hints":
        return {"ok": True,
                "hints": gm.effective_hints(req["workload"],
                                            req.get("resource", "*"))}
    if op == "aggregate":
        return {"ok": True, "agg": gm.aggregate(req.get("level", "server"))}
    if op == "events":
        return {"ok": True,
                "events": gm.events_for(req["workload"],
                                        req.get("since_seq", 0))}
    if op == "stats":
        return {"ok": True, "stats": dict(gm.stats)}
    return {"ok": False, "error": f"unknown op {op!r}"}


class ApiServer:
    def __init__(self, gm: GlobalManager, host: str = "127.0.0.1",
                 port: int = 0):
        self._srv = socketserver.ThreadingTCPServer((host, port), _Handler,
                                                    bind_and_activate=True)
        self._srv.daemon_threads = True
        self._srv.gm = gm                      # type: ignore[attr-defined]
        self._thread = threading.Thread(target=self._srv.serve_forever,
                                        daemon=True)

    @property
    def address(self):
        return self._srv.server_address

    def start(self):
        self._thread.start()
        return self

    def stop(self):
        self._srv.shutdown()
        self._srv.server_close()


class ApiClient:
    def __init__(self, address):
        self._sock = socket.create_connection(address)
        self._f = self._sock.makefile("rwb")

    def call(self, **req) -> Dict[str, Any]:
        self._f.write((json.dumps(req) + "\n").encode())
        self._f.flush()
        return json.loads(self._f.readline().decode())

    def close(self):
        self._f.close()
        self._sock.close()
