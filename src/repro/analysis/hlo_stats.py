"""Parse compiled HLO text into roofline inputs.

``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE, not
multiplied by trip count — useless for scanned layer stacks.  This module
parses the post-SPMD optimized HLO, building a per-computation symbol table
(instruction name -> result shape; operand types are not printed inline),
and extracts per computation:
  * dot FLOPs (dot shapes + contracting dims via the lhs symbol lookup),
  * collective wire bytes (all-gather / all-reduce / reduce-scatter /
    all-to-all / collective-permute, replica-group-aware ring factors),
  * an HBM-traffic estimate (operand + result bytes of top-level ops),
then walks the call graph multiplying by while-loop trip counts (recovered
from the canonical `iter < K` loop-condition pattern).

Cross-checked against analytic per-arch models in analysis/roofline.py.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z][a-z0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*?)\)\s*->")
_OP_RE = re.compile(
    r"^(?:\(?\s*[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?\s*,?\s*)+\)?\s*"
    r"([a-z][a-z0-9\-]*)\(")

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_list_bytes(type_str: str) -> List[int]:
    """All dtype[shape] sizes in a type string (handles tuples)."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Collective:
    kind: str
    wire_bytes: float
    payload_bytes: float
    group_size: int


@dataclasses.dataclass
class CompStats:
    dot_flops: float = 0.0
    mem_bytes: float = 0.0
    collectives: List[Collective] = dataclasses.field(default_factory=list)
    calls: List[Tuple[str, float]] = dataclasses.field(default_factory=list)


def _split_computations(text: str) -> Dict[str, List[str]]:
    comps: Dict[str, List[str]] = {}
    cur = None
    for line in text.splitlines():
        if not line.startswith(" ") and "{" in line and "->" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.startswith("}"):
            cur = None
            continue
        if cur is not None and line.strip().startswith(("%", "ROOT")):
            comps[cur].append(re.sub(r"/\*.*?\*/", "", line.strip()))
    return comps


def _group_size(line: str, default: int) -> int:
    m = re.search(r"replica_groups=\{\{([^}]*)\}", line)
    if m:
        return len(m.group(1).split(","))
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    return default


def _wire_bytes(kind: str, in_bytes: float, out_bytes: float, n: int) -> float:
    if n <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * (n - 1) / n * max(in_bytes, out_bytes)
    if kind == "all-gather":
        return (n - 1) / n * max(out_bytes, in_bytes)
    if kind == "reduce-scatter":
        return (n - 1) / n * max(in_bytes, out_bytes)
    if kind == "all-to-all":
        return (n - 1) / n * max(in_bytes, out_bytes)
    return float(max(in_bytes, out_bytes))      # collective-permute


def _trip_counts(comps: Dict[str, List[str]]) -> Dict[str, int]:
    trips: Dict[str, int] = {}
    for name, lines in comps.items():
        consts = {}
        for ln in lines:
            m = _CONST_RE.search(ln)
            if m:
                consts[m.group(1)] = int(m.group(2))
        for ln in lines:
            if not ln.startswith("ROOT"):
                continue
            # direct compare: ROOT %c = pred[] compare(%i, %k), direction=LT
            # fused compare:  ROOT %c = pred[] fusion(%i, %k), calls=...
            #                 (the canonical scan condition after CPU fusion)
            args = ln.split("compare(", 1)[-1] if " compare(" in ln else \
                ln.split("fusion(", 1)[-1] if " fusion(" in ln else None
            if args is None:
                continue
            bound = None
            for o in re.findall(r"%([\w.\-]+)", args.split(")")[0]):
                if o in consts:
                    bound = consts[o]
            if bound is not None:
                trips[name] = bound
    return trips


def _operand_names(line: str, opcode: str) -> List[str]:
    seg = line.split(opcode + "(", 1)
    if len(seg) < 2:
        return []
    args = seg[1]
    depth = 1
    out_chars = []
    for ch in args:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        out_chars.append(ch)
    return re.findall(r"%([\w.\-]+)", "".join(out_chars))


def analyze(text: str, n_devices: int):
    comps = _split_computations(text)
    trips = _trip_counts(comps)

    # pass 1: global symbol table name -> (type_str, bytes_total)
    sym_type: Dict[str, str] = {}
    for lines in comps.values():
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            name, rest = m.groups()
            # result type = text before the opcode token
            om = _OP_RE.match(rest)
            type_str = rest[: om.start(1)] if om else rest.split(" ", 1)[0]
            sym_type[name] = type_str

    def _bytes_of(name: str) -> float:
        return float(sum(_shape_list_bytes(sym_type.get(name, ""))))

    def _lhs_dims(name: str) -> List[int]:
        m = _SHAPE_RE.search(sym_type.get(name, ""))
        if not m:
            return []
        return [int(d) for d in m.group(2).split(",") if d]

    _PASS_THROUGH = ("convert", "bitcast", "copy", "reshape", "transpose")

    def _fusion_mem(fc_name: str, operands: List[str], out_b: float) -> float:
        """HBM traffic of one fusion with TPU semantics:
        * parameters consumed only through (dynamic-)slice/gather (possibly
          via dtype converts — a CPU-backend artifact, free on TPU) are
          charged at window size;
        * a parameter whose only terminal use is the *base* of a ROOT
          dynamic-update-slice is updated in place: charge the window, not
          the buffer;
        * ROOT DUS writes the update window only."""
        fc = comps.get(fc_name)
        if fc is None:
            return out_b + sum(_bytes_of(o) for o in operands)
        instr: Dict[str, Tuple[str, List[str]]] = {}
        root_name = None
        params: Dict[int, str] = {}
        for ln in fc:
            m0 = _INSTR_RE.match(ln)
            if not m0:
                continue
            nm, rest0 = m0.groups()
            om0 = _OP_RE.match(rest0)
            op0 = om0.group(1) if om0 else ""
            instr[nm] = (op0, _operand_names(ln, op0) if op0 else [])
            if ln.startswith("ROOT"):
                root_name = nm
            pm = re.search(r"\bparameter\((\d+)\)", ln)
            if pm:
                params[int(pm.group(1))] = nm

        uses: Dict[str, List[str]] = defaultdict(list)
        for nm, (op0, ops0) in instr.items():
            for o in ops0:
                uses[o].append(nm)

        def terminal_uses(nm, depth=0):
            out = []
            for u in uses.get(nm, []):
                op0, ops0 = instr[u]
                if op0 in _PASS_THROUGH and depth < 6:
                    out.extend(terminal_uses(u, depth + 1))
                else:
                    out.append((u, op0, ops0.index(nm) if nm in ops0 else -1))
            return out

        def root_is(nm, depth=0):
            """Does nm reach ROOT only through pass-through ops?"""
            if nm == root_name:
                return True
            return any(instr[u][0] in _PASS_THROUGH and root_is(u, depth + 1)
                       for u in uses.get(nm, []) if depth < 6)

        reads = 0.0
        for i, oname in enumerate(operands):
            pname = params.get(i)
            full = _bytes_of(oname)
            if pname is None:
                reads += full
                continue
            terms = terminal_uses(pname)
            if not terms:
                continue
            charged = 0.0
            ok = True
            for u, op0, pos in terms:
                if op0 in ("dynamic-slice", "gather", "slice"):
                    charged += _bytes_of(u)
                elif op0 == "dynamic-update-slice" and pos == 0 \
                        and root_is(u):
                    # in-place base: read+write only the window
                    _, dus_ops = instr[u]
                    charged += _bytes_of(dus_ops[1]) if len(dus_ops) > 1 \
                        else 0.0
                else:
                    ok = False
                    break
            reads += charged if ok else full
        # write side
        write = out_b
        if root_name:
            rop, rops = instr[root_name]
            seen = root_name
            depth = 0
            while rop in _PASS_THROUGH and rops and depth < 6:
                seen = rops[0]
                rop, rops = instr.get(seen, ("", []))
                depth += 1
            if rop == "dynamic-update-slice" and len(rops) > 1:
                write = _bytes_of(rops[1])
        return reads + write

    stats: Dict[str, CompStats] = {}
    fusion_callees = set()
    for cname, lines in comps.items():
        cs = CompStats()
        for ln in lines:
            m = _INSTR_RE.match(ln)
            if not m:
                continue
            name, rest = m.groups()
            om = _OP_RE.match(rest)
            if not om:
                continue
            op = om.group(1)
            operands = _operand_names(ln, op)
            out_b = _bytes_of(name)
            in_b = sum(_bytes_of(o) for o in operands
                       if not o.startswith("constant"))
            if op == "parameter" or op == "constant":
                continue

            if op == "dot":
                out_elems = 0
                msh = _SHAPE_RE.search(sym_type.get(name, ""))
                if msh:
                    out_elems = _shape_elems(msh.group(2))
                contr = 1
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ln)
                if mc and mc.group(1) and operands:
                    lhs = _lhs_dims(operands[0])
                    for ix in mc.group(1).split(","):
                        if int(ix) < len(lhs):
                            contr *= lhs[int(ix)]
                cs.dot_flops += 2.0 * out_elems * contr
            elif op == "convolution":
                cs.dot_flops += 2.0 * out_b    # rough, tiny here

            base_kind = op[:-6] if op.endswith("-start") else op
            if base_kind in _COLL_KINDS and not op.endswith("-done"):
                n = _group_size(ln, n_devices)
                cs.collectives.append(Collective(
                    base_kind, _wire_bytes(base_kind, in_b, out_b, n),
                    max(in_b, out_b), n))

            if op == "while":
                cm = re.search(r"condition=%?([\w.\-]+)", ln)
                bm = re.search(r"body=%?([\w.\-]+)", ln)
                if cm and bm:
                    trip = trips.get(cm.group(1), 1)
                    cs.calls.append((bm.group(1), float(trip)))
                    cs.calls.append((cm.group(1), float(trip + 1)))
            for key in ("calls=", "to_apply=", "true_computation=",
                        "false_computation="):
                for mm in re.finditer(key + r"%?([\w.\-]+)", ln):
                    cs.calls.append((mm.group(1), 1.0))
            mm = re.search(r"branch_computations=\{([^}]*)\}", ln)
            if mm:
                for nm in mm.group(1).split(","):
                    cs.calls.append((nm.strip().lstrip("%"), 1.0))

            # HBM-traffic estimate with slice-aware accounting: a (fused)
            # dynamic-slice reads only its output-sized window, and an
            # in-place DUS writes only the update, not the whole buffer.
            if op in ("tuple", "get-tuple-element", "bitcast", "while",
                      "conditional", "call", "copy-start", "copy-done"):
                pass
            elif op == "fusion":
                cm_ = re.search(r"calls=%?([\w.\-]+)", ln)
                cs.mem_bytes += _fusion_mem(cm_.group(1) if cm_ else "",
                                            operands, out_b)
                if cm_:   # body accounted inline; don't double-walk its mem
                    fusion_callees.add(cm_.group(1))
            elif op in ("dynamic-slice", "gather", "slice"):
                cs.mem_bytes += 2.0 * out_b
            elif op == "dynamic-update-slice":
                upd = min((b for b in (_bytes_of(o) for o in operands)
                           if b > 0), default=out_b)
                cs.mem_bytes += 3.0 * upd     # read window + write + update
            else:
                cs.mem_bytes += in_b + out_b
        stats[cname] = cs

    entry = next((n for n in comps if ".main" in n or n.startswith("main")),
                 None) or next(iter(comps))
    mult = _topo_multipliers(stats, entry)

    flops = sum(stats[c].dot_flops * m for c, m in mult.items())
    mem = sum(stats[c].mem_bytes * m for c, m in mult.items()
              if c not in fusion_callees)
    coll_total = payload = 0.0
    ncoll = 0
    by_kind: Dict[str, float] = defaultdict(float)
    by_group: Dict[int, float] = defaultdict(float)
    for c, m in mult.items():
        for col in stats[c].collectives:
            coll_total += col.wire_bytes * m
            payload += col.payload_bytes * m
            by_kind[col.kind] += col.wire_bytes * m
            by_group[col.group_size] += col.wire_bytes * m
            ncoll += max(int(m), 1)
    unknown = sum(1 for lines in comps.values() for ln in lines
                  if " while(" in ln and "condition=" not in ln)
    return HloStats(dot_flops=flops, mem_bytes=mem,
                    collective_wire_bytes=coll_total,
                    collective_by_kind=dict(by_kind),
                    collective_by_group=dict(by_group),
                    collective_payload_bytes=payload,
                    n_collectives=ncoll, unknown_loops=unknown)


@dataclasses.dataclass
class HloStats:
    dot_flops: float
    mem_bytes: float
    collective_wire_bytes: float
    collective_by_kind: Dict[str, float]
    collective_payload_bytes: float
    n_collectives: int
    unknown_loops: int
    collective_by_group: Dict[int, float] = dataclasses.field(
        default_factory=dict)


def _topo_multipliers(stats: Dict[str, CompStats], entry: str):
    indeg = defaultdict(int)
    for c, cs in stats.items():
        for callee, _ in cs.calls:
            if callee in stats:
                indeg[callee] += 1
    mult = defaultdict(float)
    mult[entry] = 1.0
    queue = [c for c in stats if indeg[c] == 0]
    indeg2 = dict(indeg)
    out = {}
    while queue:
        c = queue.pop()
        out[c] = mult[c]
        for callee, k in stats[c].calls:
            if callee not in stats:
                continue
            mult[callee] += mult[c] * k
            indeg2[callee] -= 1
            if indeg2[callee] == 0:
                queue.append(callee)
    for c in stats:
        out.setdefault(c, mult[c])
    return out
