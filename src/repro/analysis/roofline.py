"""Roofline analysis from dry-run records (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, three terms in seconds-per-step:

  compute    = HLO_dot_FLOPs_per_dev / PEAK_FLOPS
  memory     = HLO_bytes_per_dev     / HBM_BW       (upper-bound estimate:
               sum of top-level operand+result bytes; fusion-internal
               traffic not visible — see hlo_stats docstring)
  collective = wire_bytes_per_dev    / ICI_BW       (ring-algorithm wire
               bytes; DCN rows noted separately for the pod axis)

plus MODEL_FLOPS (6*N_active*D analytic) and the usefulness ratio
MODEL_FLOPS / HLO_FLOPs.  HLO numbers come from analysis/hlo_stats.py,
which multiplies while-loop (scan) bodies by trip count — XLA's own
cost_analysis() counts loop bodies once and is reported only as a
cross-check column.

Hardware constants (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (we charge one link — conservative; a 2D torus can
spread ring traffic over more links).
"""
from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, List, Optional

from repro.configs.archs import ARCHS
from repro.configs.base import SHAPES
from repro.models.model import count_params

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    """Analytic useful FLOPs per step (whole job, not per device)."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    n = count_params(cfg, active_only=True)
    # exclude embedding gather (not matmul flops); unembed is matmul. For
    # tied embeddings the [V,D] matrix is counted once in n — fine at this
    # granularity.
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        base = 6.0 * n * B * S
        attn_mult = 3.0          # fwd + bwd(2x) on the attention quadratic
    elif shape.kind == "prefill":
        base = 2.0 * n * B * S
        attn_mult = 1.0
    else:
        base = 2.0 * n * B       # one token per sequence
        attn_mult = 0.0          # matvec attention counted via memory, not MXU
    # attention quadratic term (causal ~ S^2/2; window ~ S*W)
    attn = 0.0
    if cfg.n_heads and shape.kind != "decode":
        per_layer = {}
        kinds = [k for pat in cfg.pattern for k in pat]
        n_attn_global = sum(1 for k in kinds if k == "attn"
                            and cfg.attn.window is None)
        n_attn_local = sum(1 for k in kinds if k == "attn_local"
                           or (k == "attn" and cfg.attn.window is not None))
        unit = len(cfg.pattern)
        reps = cfg.n_layers / unit
        hd, H = cfg.head_dim, cfg.n_heads
        full = 2 * 2 * B * H * hd * S * (S / 2)
        win = cfg.attn_local.window if cfg.attn_local else (cfg.attn.window
                                                            or S)
        local = 2 * 2 * B * H * hd * S * min(win, S)
        attn = reps * (n_attn_global * full + n_attn_local * local) \
            * attn_mult
    return base + attn


@dataclass
class Cell:
    arch: str
    shape: str
    mesh: str
    status: str
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    dominant: str = ""
    model_flops_per_dev: float = 0.0
    hlo_flops_per_dev: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    peak_gib: float = 0.0
    suggestion: str = ""
    tag: str = ""


SUGGEST = {
    "compute": ("cut non-useful FLOPs: causal block-skipping in flash "
                "attention, lighter remat policy, drop redundant recompute"),
    "memory": ("shrink bytes moved: quantize KV cache / weights, fuse "
               "elementwise chains, smaller activation saves"),
    "collective": ("cut wire bytes: reduce-scatter instead of all-reduce, "
                   "int8 gradient compression on the pod axis, shard weights "
                   "so gathers stay per-layer"),
}


def load_cell(path: Path) -> Optional[Cell]:
    r = json.loads(path.read_text())
    c = Cell(arch=r["arch"], shape=r["shape"], mesh=r["mesh"],
             status=r["status"], tag=r.get("tag", ""))
    if r["status"] != "ok":
        c.suggestion = r.get("reason", r.get("error", ""))[:80]
        return c
    n_dev = 512 if r["mesh"] == "2x16x16" else 256
    h = r["hlo"]
    c.hlo_flops_per_dev = h["dot_flops_per_dev"]
    c.compute_s = h["dot_flops_per_dev"] / PEAK_FLOPS
    c.memory_s = h["mem_bytes_per_dev"] / HBM_BW
    c.collective_s = h["collective_wire_bytes_per_dev"] / ICI_BW
    c.model_flops_per_dev = model_flops(r["arch"], r["shape"]) / n_dev
    c.useful_ratio = (c.model_flops_per_dev
                      / max(c.hlo_flops_per_dev, 1.0))
    terms = {"compute": c.compute_s, "memory": c.memory_s,
             "collective": c.collective_s}
    c.dominant = max(terms, key=terms.get)
    ideal = c.model_flops_per_dev / PEAK_FLOPS
    c.roofline_fraction = ideal / max(max(terms.values()), 1e-12)
    c.peak_gib = r["memory"]["peak_bytes_per_dev"] / 2 ** 30
    c.suggestion = SUGGEST[c.dominant]
    return c


def load_all(root: str = "results/dryrun") -> List[Cell]:
    cells = []
    for p in sorted(Path(root).rglob("*.json")):
        c = load_cell(p)
        if c is not None:
            cells.append(c)
    return cells


def to_markdown(cells: List[Cell]) -> str:
    hdr = ("| arch | shape | mesh | compute s | memory s | coll s | "
           "dominant | 6ND/HLO | roofline | peak GiB |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    rows = []
    for c in cells:
        if c.status != "ok":
            rows.append(f"| {c.arch} | {c.shape} | {c.mesh} | — | — | — | "
                        f"{c.status}: {c.suggestion} | | | |")
            continue
        rows.append(
            f"| {c.arch} | {c.shape} | {c.mesh} | {c.compute_s:.3e} | "
            f"{c.memory_s:.3e} | {c.collective_s:.3e} | **{c.dominant}** | "
            f"{c.useful_ratio:.2f} | {c.roofline_fraction:.1%} | "
            f"{c.peak_gib:.1f} |")
    return hdr + "\n".join(rows) + "\n"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--root", default="results/dryrun")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    cells = load_all(args.root)
    if args.mesh:
        cells = [c for c in cells if c.mesh == args.mesh]
    md = to_markdown(cells)
    print(md)
    if args.out:
        Path(args.out).write_text(md)
    # summary for the perf loop
    ok = [c for c in cells if c.status == "ok"]
    if ok:
        worst = sorted(ok, key=lambda c: c.roofline_fraction)[:5]
        collb = sorted(ok, key=lambda c: -c.collective_s)[:5]
        print("\nWorst roofline fraction:")
        for c in worst:
            print(f"  {c.arch} {c.shape} {c.mesh}: {c.roofline_fraction:.1%}"
                  f" dominant={c.dominant}")
        print("Most collective-bound:")
        for c in collb:
            print(f"  {c.arch} {c.shape} {c.mesh}: coll={c.collective_s:.3e}s"
                  f" ({c.collective_s / max(c.compute_s, 1e-12):.1f}x compute)")


if __name__ == "__main__":
    main()
