"""Sharded, atomic, async checkpointing (no orbax/tensorstore offline).

Layout:  <root>/step_<N>/
           manifest.json        — treedef, shapes, dtypes, metadata
           <leaf-path>.npy      — one file per leaf (per shard in multi-host)
         <root>/step_<N>.COMMITTED   — atomic commit marker

Guarantees:
  * atomicity — writers stage into step_<N>.tmp and rename; a checkpoint
    without the COMMITTED marker is ignored and garbage-collected,
  * async — ``save_async`` snapshots to host memory synchronously (cheap)
    and writes on a background thread; ``wait()`` joins,
  * elastic restore — ``restore`` takes target shardings and device_puts
    leaves onto a *different* mesh than the one that saved them (the
    WI elastic-resize path),
  * integrity — each leaf's crc32 is recorded in the manifest at write
    time; ``restore(verify=True)`` (the default) raises
    ``CheckpointCorruptError`` on mismatch, and ``latest_good_step()``
    walks committed checkpoints newest-first to find one that verifies
    (the unannounced-crash recovery path: a torn or bit-flipped emergency
    checkpoint must not brick the job),
  * retention — keep the newest K committed checkpoints.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax
import numpy as np


class CheckpointCorruptError(RuntimeError):
    """A committed checkpoint failed integrity verification (torn write,
    bit flip, or truncated leaf file)."""


def _crc(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _leaf_name(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "__".join(parts) or "root"


class Checkpointer:
    def __init__(self, root: str, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ----------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: Optional[Dict] = None):
        self.wait()
        host = self._snapshot(tree)
        self._write(step, host, metadata or {})

    def save_async(self, step: int, tree: Any,
                   metadata: Optional[Dict] = None):
        """Snapshot synchronously; write on a background thread."""
        self.wait()
        host = self._snapshot(tree)
        md = dict(metadata or {})

        def work():
            try:
                self._write(step, host, md)
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise e

    @staticmethod
    def _snapshot(tree):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        host = [np.asarray(l) for l in leaves]
        return host, treedef

    def _write(self, step: int, host, metadata):
        leaves, treedef = host
        tmp = self.root / f"step_{step}.tmp"
        final = self.root / f"step_{step}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        # flatten-with-path over an index skeleton for stable leaf names
        skeleton = jax.tree_util.tree_unflatten(treedef,
                                                list(range(len(leaves))))
        names = {}
        for path, idx in jax.tree_util.tree_flatten_with_path(skeleton)[0]:
            names[idx] = _leaf_name(path)
        for i, arr in enumerate(leaves):
            np.save(tmp / f"{names[i]}.npy", arr)
        manifest = {
            "step": step, "metadata": metadata, "n_leaves": len(leaves),
            "names": [names[i] for i in range(len(leaves))],
            "dtypes": [str(a.dtype) for a in leaves],
            "shapes": [list(a.shape) for a in leaves],
            "crc32": [_crc(a) for a in leaves],
            "ts": time.time(),
        }
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        os.replace(tmp, final)
        marker = self.root / f"step_{step}.COMMITTED"
        marker.write_text(str(step))
        self._gc()

    def _gc(self):
        steps = sorted(self.committed_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.root / f"step_{s}", ignore_errors=True)
            (self.root / f"step_{s}.COMMITTED").unlink(missing_ok=True)
        # remove uncommitted debris
        for d in self.root.glob("step_*.tmp"):
            shutil.rmtree(d, ignore_errors=True)
        for d in self.root.glob("step_*"):
            m = re.fullmatch(r"step_(\d+)", d.name)
            if m and int(m.group(1)) not in steps:
                shutil.rmtree(d, ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def committed_steps(self):
        out = []
        for f in self.root.glob("step_*.COMMITTED"):
            m = re.fullmatch(r"step_(\d+)\.COMMITTED", f.name)
            if m and (self.root / f"step_{m.group(1)}").exists():
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.committed_steps()
        return s[-1] if s else None

    # -- integrity ------------------------------------------------------------
    def verify(self, step: int) -> bool:
        """True iff the committed checkpoint's leaves all match their
        manifest crc32s.  Legacy manifests without a ``crc32`` list verify
        trivially (nothing to check against); unreadable manifests or leaf
        files verify False."""
        d = self.root / f"step_{step}"
        try:
            manifest = json.loads((d / "manifest.json").read_text())
        except (OSError, json.JSONDecodeError):
            return False
        crcs = manifest.get("crc32")
        if crcs is None:
            return True
        names = manifest.get("names", [])
        if len(crcs) != len(names):
            return False
        for name, want in zip(names, crcs):
            try:
                arr = np.load(d / f"{name}.npy")
            except Exception:
                return False        # truncated / unparseable leaf
            if _crc(arr) != int(want):
                return False
        return True

    def latest_good_step(self) -> Optional[int]:
        """Newest committed checkpoint that passes integrity verification
        (the crash-recovery entry point: skips torn/corrupt checkpoints)."""
        for s in reversed(self.committed_steps()):
            if self.verify(s):
                return s
        return None

    def restore(self, step: int, like: Any, shardings: Any = None,
                verify: bool = True) -> Any:
        """Restore into the structure of ``like``; optionally device_put each
        leaf to ``shardings`` (elastic resharding onto a new mesh).  With
        ``verify`` (the default) each leaf is checked against its manifest
        crc32 and a mismatch raises ``CheckpointCorruptError`` — callers
        fall back to ``latest_good_step()``."""
        d = self.root / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        crcs: Optional[List] = manifest.get("crc32") if verify else None
        leaves, treedef = jax.tree_util.tree_flatten(like)
        assert len(leaves) == manifest["n_leaves"], "tree structure changed"
        skeleton = jax.tree_util.tree_unflatten(treedef,
                                                list(range(len(leaves))))
        names = {}
        for path, idx in jax.tree_util.tree_flatten_with_path(skeleton)[0]:
            names[idx] = _leaf_name(path)
        shard_leaves = (jax.tree_util.tree_flatten(shardings)[0]
                        if shardings is not None else [None] * len(leaves))
        out = []
        for i in range(len(leaves)):
            try:
                arr = np.load(d / f"{names[i]}.npy")
            except Exception as e:
                if verify:
                    raise CheckpointCorruptError(
                        f"step {step}: leaf {names[i]} unreadable") from e
                raise
            if crcs is not None and _crc(arr) != int(crcs[i]):
                raise CheckpointCorruptError(
                    f"step {step}: leaf {names[i]} crc mismatch")
            want = leaves[i]
            if hasattr(want, "dtype"):
                arr = arr.astype(want.dtype)
            if shard_leaves[i] is not None:
                out.append(jax.device_put(arr, shard_leaves[i]))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def metadata(self, step: int) -> Dict:
        d = self.root / f"step_{step}"
        return json.loads((d / "manifest.json").read_text())["metadata"]
