"""jit'd wrapper for the SSD Pallas kernel: model-layer layout in/out."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_scan


def ssd_mixer(x, dt, a_log, Bm, Cm, *, chunk=128, interpret=True):
    """x [B,S,H,P]; dt [B,S,H] (post-softplus); a_log [H];
    Bm/Cm [B,S,G,N] -> y [B,S,H,P].  Matches layers.ssd.ssd_chunked."""
    B, S, H, P = x.shape
    G = Bm.shape[2]
    rep = H // G
    xg = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    dtg = dt.transpose(0, 2, 1).reshape(B * H, S)
    Bg = jnp.repeat(Bm, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(B * H, S, -1)
    Cg = jnp.repeat(Cm, rep, axis=2).transpose(0, 2, 1, 3) \
        .reshape(B * H, S, -1)
    ag = jnp.tile(a_log, B)
    y = ssd_scan(xg, dtg, ag, Bg, Cg, chunk=chunk, interpret=interpret)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3)
