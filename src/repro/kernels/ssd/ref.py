"""Pure-jnp oracle for the SSD kernel: the model layer's chunked scan
(itself validated against the sequential recurrence in tests)."""
from repro.models.layers.ssd import ssd_chunked, ssd_recurrent_step


def reference(x, dt, a_log, Bm, Cm, chunk=128):
    y, _ = ssd_chunked(x, dt, a_log, Bm, Cm, chunk)
    return y
