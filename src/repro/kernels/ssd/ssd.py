"""Pallas TPU kernel for the Mamba-2 SSD chunked scan  [arXiv:2405.21060].

TPU adaptation: the SSD algorithm decomposes into (a) an intra-chunk
quadratic term — two MXU matmuls per chunk tile — and (b) a sequential
inter-chunk state recurrence.  The kernel grid is (B*H, n_chunks); the
chunk axis is the innermost (sequential on TPU), so the running state
[hd, N] lives in VMEM scratch across chunk iterations, exactly like the
flash-attention accumulator.  CUDA implementations spread the recurrence
over thread blocks with global-memory handoffs; on TPU the sequential grid
+ persistent VMEM scratch is the natural (and faster) shape.

Per (b, h) the kernel consumes blocks x [L, P], dt [L, 1], B/C [L, N] and
emits y [L, P]; heads are independent (n_groups=1 is broadcast by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np


def _kernel(x_ref, dt_ref, b_ref, c_ref, alog_ref, y_ref, st_scr, *,
            chunk, nc):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        st_scr[...] = jnp.zeros_like(st_scr)

    x = x_ref[...].astype(jnp.float32)        # [L, P]
    dt = dt_ref[...].astype(jnp.float32)      # [L, 1]
    Bm = b_ref[...].astype(jnp.float32)       # [L, N]
    Cm = c_ref[...].astype(jnp.float32)       # [L, N]
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))       # scalar (per head)

    dA = dt * a                               # [L, 1] log-decay per step
    seg = jnp.cumsum(dA, axis=0)              # [L, 1]
    total = seg[-1:, :]                       # [1, 1]

    # intra-chunk: scores[l, s] = (C_l . B_s) * exp(seg_l - seg_s) * dt_s
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    dec = seg - seg.T                          # [L, L] (broadcast over cols)
    li = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)
    si = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 1)
    w = jnp.where(li >= si, scores * jnp.exp(dec) * dt.T, 0.0)
    y = jax.lax.dot_general(w, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (C exp(seg)) @ state_in ;  state [N, P]
    y += jax.lax.dot_general(Cm * jnp.exp(seg), st_scr[...],
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    # state_in' = exp(total) * state_in + sum_s dt_s exp(total-seg_s) B_s x_s
    contrib = (dt * jnp.exp(total - seg))     # [L, 1]
    new_state = jax.lax.dot_general((x * contrib), Bm,
                                    (((0,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)
    st_scr[...] = st_scr[...] * jnp.exp(total[0, 0]) + new_state.T  # [N->?]
    y_ref[...] = y.astype(y_ref.dtype)


def ssd_scan(x, dt, a_log, Bm, Cm, *, chunk=128, interpret=False):
    """x [G, S, P]; dt [G, S]; a_log [G]; Bm/Cm [G, S, N] -> y [G, S, P].

    G = batch*heads (ops.py folds + broadcasts groups).
    """
    G, S, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    kernel = functools.partial(_kernel, chunk=L, nc=nc)
    y = pl.pallas_call(
        kernel,
        grid=(G, nc),
        in_specs=[
            pl.BlockSpec((None, L, P), lambda g, j: (g, j, 0)),
            pl.BlockSpec((None, L, 1), lambda g, j: (g, j, 0)),
            pl.BlockSpec((None, L, N), lambda g, j: (g, j, 0)),
            pl.BlockSpec((None, L, N), lambda g, j: (g, j, 0)),
            pl.BlockSpec((None, 1), lambda g, j: (g, 0)),
        ],
        out_specs=pl.BlockSpec((None, L, P), lambda g, j: (g, j, 0)),
        out_shape=jax.ShapeDtypeStruct((G, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(x, dt[..., None], Bm, Cm, a_log[:, None])
    return y
