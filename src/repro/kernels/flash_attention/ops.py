"""jit'd wrapper around the Pallas flash-attention kernel.

Public entry matches models/layers/flash.flash_attention: q [B,S,H,hd],
k/v [B,S,K,hd].  Forward = Pallas kernel; backward = the pure-JAX chunked
VJP from models/layers/flash (identical math, recomputation-based).
``interpret=True`` executes the kernel body in Python on CPU (how this repo
validates TPU kernels offline); on a real TPU backend pass interpret=False.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig
from repro.kernels.flash_attention.flash_attention import flash_attention_fwd
from repro.models.layers import flash as jflash


def _fold(q, k, v):
    B, S, H, hd = q.shape
    K = k.shape[2]
    R = H // K
    qf = q.reshape(B, S, K, R, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B * K, S, R, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * K, k.shape[1], hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * K, v.shape[1], hd)
    return qf, kf, vf, (B, S, H, K, R, hd)


def _unfold(out, dims):
    B, S, H, K, R, hd = dims
    return out.reshape(B, K, S, R, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(B, S, H, hd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention_kernel(q, k, v, cfg: AttnConfig, q_chunk=512,
                           kv_chunk=512, interpret=True):
    qf, kf, vf, dims = _fold(q, k, v)
    scale = (cfg.query_scale if cfg.query_scale is not None
             else 1.0 / np.sqrt(q.shape[-1]))
    out = flash_attention_fwd(qf, kf, vf, scale=scale, causal=cfg.causal,
                              window=cfg.window, softcap=cfg.logit_softcap,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              interpret=interpret)
    return _unfold(out, dims)


def _fwd(q, k, v, cfg, q_chunk, kv_chunk, interpret):
    out = flash_attention_kernel(q, k, v, cfg, q_chunk, kv_chunk, interpret)
    # lse recomputed in bwd by the pure-JAX path; save primals only
    return out, (q, k, v)


def _bwd(cfg, q_chunk, kv_chunk, interpret, res, dout):
    q, k, v = res
    # reuse the chunked pure-JAX VJP: re-run its forward for (out, lse)
    # residuals, then its backward — recomputation, no big saves.
    _, vjp = jax.vjp(
        lambda q_, k_, v_: jflash.flash_attention(q_, k_, v_, cfg, q_chunk,
                                                  kv_chunk, False), q, k, v)
    return vjp(dout)


flash_attention_kernel.defvjp(_fwd, _bwd)


def attention(q, k, v, cfg: AttnConfig, q_chunk=512, kv_chunk=512,
              interpret=True):
    """Drop-in attention entry point selecting the Pallas kernel."""
    return flash_attention_kernel(q, k, v, cfg, q_chunk, kv_chunk, interpret)
