"""Pure-jnp oracle for the flash-attention kernel.

The reference is the dense attention used by every smoke test, plus the
chunked pure-JAX flash (already validated against dense incl. gradients).
"""
from repro.configs.base import AttnConfig
from repro.models.layers.attention import dense_attention


def reference(q, k, v, cfg: AttnConfig):
    return dense_attention(q, k, v, cfg)
