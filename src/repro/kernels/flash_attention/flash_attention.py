"""Pallas TPU flash-attention forward kernel.

TPU-native adaptation (HARDWARE ADAPTATION note, DESIGN.md): instead of the
CUDA warp-level layout, tiling follows the TPU memory hierarchy — HBM
operands are carved into VMEM blocks by BlockSpecs; the MXU consumes
(R*CQ, hd) x (hd, CK) tiles (dims padded to lane multiples of 128 by the
caller); the online-softmax running state (m, l, acc) lives in VMEM scratch
that persists across the *sequential* innermost grid dimension (kv chunks) —
the Pallas/TPU idiom replacing CUDA's shared-memory accumulators.

Grid: (B*K, nq, nk); one program instance processes the (q-chunk i,
kv-chunk j) tile for one (batch, kv-head) pair, all R grouped query heads
folded into rows (row = r*CQ + qi).

Supports: causal masking, sliding window, logit soft-capping, GQA.
The backward pass reuses the pure-JAX chunked VJP (ops.py) — recomputation
there matches this kernel's forward exactly.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import numpy as np

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, softcap, cq, ck, nk, r):
    i = pl.program_id(1)          # q chunk
    j = pl.program_id(2)          # kv chunk

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[...]                # [R*CQ, hd]
    k = k_ref[...]                # [CK, hd]
    v = v_ref[...]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    # row -> q position (R heads folded: row = r*CQ + qi)
    rows = jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    qpos = i * cq + rows % cq
    kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones_like(s, dtype=jnp.bool_)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]           # [R*CQ, 1]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(j == nk - 1)
    def _finalize():
        o_ref[...] = (acc_scr[...]
                      / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale, causal=True, window=None,
                        softcap=None, q_chunk=512, kv_chunk=512,
                        interpret=False):
    """q [G, S, R, hd]; k/v [G, S, hd] (G = batch*kv_heads) -> [G, S, R, hd].

    The caller (ops.py) folds batch and kv-heads into G and grouped query
    heads into R.
    """
    G, S, R, hd = q.shape
    cq = min(q_chunk, S)
    ck = min(kv_chunk, k.shape[1])
    assert S % cq == 0 and k.shape[1] % ck == 0
    nq, nk = S // cq, k.shape[1] // ck
    # fold (R, CQ) into rows: [G, nq, R*CQ, hd] row = r*cq + qi
    qr = q.transpose(0, 2, 1, 3).reshape(G, R, nq, cq, hd) \
        .transpose(0, 2, 1, 3, 4).reshape(G, nq, R * cq, hd)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, softcap=softcap,
        cq=cq, ck=ck, nk=nk, r=R)

    out = pl.pallas_call(
        kernel,
        grid=(G, nq, nk),
        in_specs=[
            pl.BlockSpec((None, None, R * cq, hd),
                         lambda g, i, j: (g, i, 0, 0)),
            pl.BlockSpec((None, ck, hd), lambda g, i, j: (g, j, 0)),
            pl.BlockSpec((None, ck, hd), lambda g, i, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, R * cq, hd),
                               lambda g, i, j: (g, i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((G, nq, R * cq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((R * cq, 1), jnp.float32),
            pltpu.VMEM((R * cq, 1), jnp.float32),
            pltpu.VMEM((R * cq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qr, k, v)
    # unfold rows
    out = out.reshape(G, nq, R, cq, hd).transpose(0, 2, 1, 3, 4) \
        .reshape(G, R, S, hd).transpose(0, 2, 1, 3)
    return out
