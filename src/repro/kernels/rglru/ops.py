"""jit'd wrapper for the RG-LRU Pallas kernel (model-layer layout)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.rglru.rglru import rglru_scan as _kernel_scan


def rglru_mixer(x_gated, log_a, *, chunk=256, interpret=True):
    """x_gated [B,S,W] (input-gated), log_a [B,S,W] -> h [B,S,W] f32.

    Matches layers.rglru.rglru_scan (zero initial state).
    """
    return _kernel_scan(x_gated, log_a, chunk=chunk, interpret=interpret)
