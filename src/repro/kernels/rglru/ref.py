"""Pure-jnp oracle: the associative-scan RG-LRU from the model layer
(validated against the sequential step in tests)."""
from repro.models.layers.rglru import rglru_scan, rglru_step


def reference(x_gated, log_a):
    h, _ = rglru_scan(x_gated, log_a)
    return h
