"""Pallas TPU kernel for the RG-LRU linear recurrence  [arXiv:2402.19427].

h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * x_t   (per channel, diagonal)

TPU adaptation: the recurrence is *diagonal*, so there is no MXU work — this
is a VPU (vector-unit) kernel and it is memory-bound.  The Griffin paper
makes the same observation and implements the scan *sequentially* on TPU
(Appendix: "linear scan"), which beats associative-scan lowering because
the bottleneck is HBM traffic, not the O(S) dependency chain.  We follow
that design: channels map to lanes (blocks of W channels), sequence blocks
map to the sequential innermost grid dim with the carry h in VMEM scratch,
and inside a block a ``fori_loop`` walks time steps with pure VPU ops.
A log-space closed form (two cumsums) was rejected: cumulative decays reach
exp(+-8*L) inside a block and overflow f32 (documented trade-off).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, loga_ref, y_ref, h_scr):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[...].astype(jnp.float32)          # [L, W]
    log_a = loga_ref[...].astype(jnp.float32)   # [L, W]
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * x
    L = x.shape[0]

    def step(t, carry):
        h = carry
        h = a[t] * h + b[t]
        y_ref[t, :] = h.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, L, step, h_scr[0, :])
    h_scr[...] = h[None, :]


def rglru_scan(x, log_a, *, chunk=256, interpret=False):
    """x [G, S, W]; log_a same shape -> h [G, S, W] (f32).

    G folds batch; W should be a multiple of 128 for TPU lanes (caller pads).
    """
    G, S, W = x.shape
    L = min(chunk, S)
    assert S % L == 0
    nc = S // L
    y = pl.pallas_call(
        _kernel,
        grid=(G, nc),
        in_specs=[
            pl.BlockSpec((None, L, W), lambda g, j: (g, j, 0)),
            pl.BlockSpec((None, L, W), lambda g, j: (g, j, 0)),
        ],
        out_specs=pl.BlockSpec((None, L, W), lambda g, j: (g, j, 0)),
        out_shape=jax.ShapeDtypeStruct((G, S, W), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, W), jnp.float32)],
        interpret=interpret,
    )(x, log_a)
    return y
