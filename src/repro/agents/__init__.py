"""Workload-side WI agent runtime (paper §4, the guest half).

PRs 1–2 built the platform half (hint-aware placement, admission, the
eviction-notice ladder); this package closes the bidirectional loop: per-VM
``WorkloadAgent``s attach through ``LocalManager.attach_vm``, react to
platform events (checkpoint-then-drain, replace-and-ack-early, shed load),
and drive dynamic hint adaptation over diurnal phases.
"""
from repro.agents.agent import WorkloadAgent
from repro.agents.policy import (PARTIAL, STATEFUL, STATELESS, AgentPolicy,
                                 DiurnalProfile)
from repro.agents.runtime import AgentRuntime
from repro.agents.serving_agent import ServingAgent, ServingTenant
from repro.agents.trainer_agent import TrainerAgent, TrainerTenant

__all__ = [
    "AgentPolicy", "AgentRuntime", "DiurnalProfile", "PARTIAL", "STATEFUL",
    "STATELESS", "ServingAgent", "ServingTenant", "TrainerAgent",
    "TrainerTenant", "WorkloadAgent",
]
