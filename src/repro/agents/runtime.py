"""AgentRuntime: wires per-VM ``WorkloadAgent``s into a running scheduler.

The runtime is the deployment fabric the paper assumes exists inside every
guest image: it owns one ``LocalManager`` per server (the Hyper-V KVP /
XenStore host side), attaches an agent to every placed VM through
``LocalManager.attach_vm``, and keeps the population current entirely from
bus traffic — placement/migration decisions on ``wi.sched.decisions``
attach or rebind agents, cluster kill callbacks detach them and meter lost
work, eviction cancellations re-arm them.  Replacement requests from
stateless agents are submitted straight back into the scheduler's pending
queue, and the replacement's *lead time* (how long before the original kill
deadline the replacement was running) is recorded when it lands.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro import obs
from repro.core import hints as H
from repro.core.local_manager import LocalManager
from repro.sim.cluster import VM

from repro.agents.agent import WorkloadAgent
from repro.agents.policy import STATELESS, AgentPolicy


class AgentRuntime:
    def __init__(self, scheduler, policies: Optional[Dict[str, AgentPolicy]]
                 = None, default_policy: Optional[AgentPolicy] = None,
                 vm_hint_rate_per_s: float = 10.0,
                 vm_hint_burst: float = 50.0,
                 registry=None):
        self.sched = scheduler
        self.gm = scheduler.gm
        self.engine = scheduler.engine
        self.cluster = scheduler.cluster
        self.policies: Dict[str, AgentPolicy] = dict(policies or {})
        self.default_policy = default_policy or AgentPolicy()
        self._hint_rate = (vm_hint_rate_per_s, vm_hint_burst)
        self._locals: Dict[str, LocalManager] = {}      # per server
        self.agents: Dict[str, WorkloadAgent] = {}      # per vm
        self._leaders: Dict[str, str] = {}              # workload -> vm_id
        # replacement vm_id -> the original VM's kill deadline
        self._repl_pending: Dict[str, float] = {}
        self._repl_seq = 0
        self.phase = "peak"
        # crash-replacement backoff: workload -> (next_delay_s, last_crash_t)
        # — a workload whose replicas keep crashing backs off exponentially
        # instead of hammering the pending queue
        self._crash_backoff: Dict[str, tuple] = {}
        self._lease_s = 0.0
        # defaultdict(float) semantics preserved (MetricDict's internal
        # float dict is the source of truth) with every key mirrored into
        # a registry gauge; defaults to the scheduler's registry, so agent
        # counters land next to the scheduler's own series
        self.registry = registry if registry is not None \
            else scheduler.metrics
        self.metrics = obs.MetricDict(self.registry, prefix="wi_agents_")
        self.registry.add_collector("agents", self.telemetry)
        self.cluster.kill_listeners.append(self._on_vm_killed)
        self.gm.bus.subscribe(H.TOPIC_SCHED_DECISIONS, self._on_decisions)
        self.gm.bus.subscribe(H.TOPIC_EVICTIONS, self._on_eviction_record)
        self.attach_placed()

    # -- plumbing ------------------------------------------------------------
    def now(self) -> float:
        return self.engine.clock.t

    def local(self, server_id: str) -> LocalManager:
        lm = self._locals.get(server_id)
        if lm is None:
            lm = self._locals[server_id] = LocalManager(
                server_id, self.gm.bus, clock=self.engine.clock,
                vm_hint_rate_per_s=self._hint_rate[0],
                vm_hint_burst=self._hint_rate[1],
                lease_s=self._lease_s)
        return lm

    def enable_leases(self, lease_s: float, until: float,
                      check_period_s: float = 5.0):
        """Turn on the heartbeat/lease loop: every ``check_period_s`` each
        live responsive agent heartbeats its endpoint and every local
        manager sweeps its leases, publishing ``lease_expired`` for silent
        guests (the scheduler then stops redelivering notices to them and
        lets the ladder kill stand).  Existing local managers adopt the
        lease too."""
        self._lease_s = lease_s
        for lm in self._locals.values():
            lm.lease_s = lease_s

        def beat():
            for agent in list(self.agents.values()):
                agent.heartbeat()
            for lm in self._locals.values():
                expired = lm.check_leases()
                if expired:
                    self.metrics["leases_expired"] += len(expired)
        self.engine.every(check_period_s, beat, until)

    def policy_for(self, workload: str) -> AgentPolicy:
        return self.policies.get(workload, self.default_policy)

    def is_leader(self, agent: WorkloadAgent) -> bool:
        return self._leaders.get(agent.vm.workload) == agent.vm.vm_id

    # -- attach / detach -----------------------------------------------------
    def attach(self, vm: VM) -> Optional[WorkloadAgent]:
        if not vm.alive or not vm.server:
            return None
        agent = self.agents.get(vm.vm_id)
        if agent is not None:
            if agent.server_id == vm.server:
                return agent            # already attached here
            # migrated: move the endpoint to the new server's local manager
            self._detach_endpoint(agent)
            agent.rebind(self.local(vm.server).attach_vm(
                vm.vm_id, vm.workload,
                workload_manager=self.is_leader(agent)))
            self.metrics["agents_rebound"] += 1
            return agent
        # the deployment fabric designates each workload's manager VM: the
        # host only honors workload-wide hints from that endpoint
        leader = self._leaders.setdefault(vm.workload, vm.vm_id)
        ep = self.local(vm.server).attach_vm(
            vm.vm_id, vm.workload, workload_manager=leader == vm.vm_id)
        policy = self.policy_for(vm.workload)
        factory = policy.agent_factory or WorkloadAgent
        agent = factory(vm, ep, self, policy)
        self.agents[vm.vm_id] = agent
        self.metrics["agents_attached"] += 1
        kill_t = self._repl_pending.pop(vm.vm_id, None)
        if kill_t is not None:
            self.metrics["replacements_placed"] += 1
            # positive lead: the replacement was up before the original died
            self.metrics["replacement_lead_s_sum"] += kill_t - self.now()
        # a fresh VM of a diurnal workload should start on-phase
        agent.on_phase(self.phase)
        return agent

    def attach_placed(self):
        """Attach agents to every alive placed VM that lacks one (initial
        adoption of a pre-populated cluster)."""
        for vm in list(self.cluster.vms.values()):
            self.attach(vm)

    def _detach_endpoint(self, agent: WorkloadAgent):
        lm = self._locals.get(agent.server_id)
        if lm is not None:
            lm.detach_vm(agent.vm.vm_id)

    def detach(self, vm_id: str) -> Optional[WorkloadAgent]:
        agent = self.agents.pop(vm_id, None)
        if agent is None:
            return None
        self._detach_endpoint(agent)
        workload = agent.vm.workload
        if self._leaders.get(workload) == vm_id:
            del self._leaders[workload]
            for other in self.agents.values():      # re-elect a live leader
                if other.vm.workload == workload:
                    self._leaders[workload] = other.vm.vm_id
                    lm = self._locals.get(other.server_id)
                    if lm is not None:              # host-side promotion
                        lm.authorize_workload_manager(other.vm.vm_id)
                    break
        return agent

    # -- bus reactions -------------------------------------------------------
    def _on_decisions(self, rec):
        d = rec.value
        if not isinstance(d, dict):
            return
        for dec in d.get("decisions", ()):
            server = getattr(dec, "server", "")
            if not server:
                continue
            vm = self.cluster.vms.get(dec.vm_id)
            if vm is not None:
                self.attach(vm)

    def _on_eviction_record(self, rec):
        d = rec.value
        if not isinstance(d, dict) or d.get("event") != "cancelled":
            return
        agent = self.agents.get(d.get("vm", ""))
        if agent is not None:           # re-arm: the next notice is fresh
            agent.on_eviction_cancelled()

    def _on_vm_killed(self, vm: VM):
        agent = self.detach(vm.vm_id)
        if agent is None:
            return
        crashed = vm.vm_id in self.cluster.crashed_vms
        lost = agent.on_killed(self.now())
        self.metrics["lost_work_s"] += lost
        if crashed:
            # an unannounced hardware crash, not an eviction: no notice
            # preceded it, so the without-ack bar does not apply.  The
            # workload observes replica death and (scale-out classes)
            # requests a replacement with per-workload backoff.
            self.metrics["agent_vms_crashed"] += 1
            self.metrics["lost_work_s_crash"] += lost
            if agent.policy.scale_out_in and not agent.draining:
                self._replace_after_crash(agent)
            return
        if agent.policy.statefulness == STATELESS:
            self.metrics["lost_work_s_stateless"] += lost
            if agent.draining and not agent.acked_eviction:
                # the falsifiable bar for "stateless workloads never lose
                # anything": a noticed stateless VM must always have
                # consented (acked) before the platform took it
                self.metrics["stateless_killed_without_ack"] += 1
        self.metrics["agent_vms_killed"] += 1

    # -- crash recovery ------------------------------------------------------
    _CRASH_BACKOFF_BASE_S = 2.0
    _CRASH_BACKOFF_CAP_S = 32.0
    _CRASH_BACKOFF_RESET_S = 300.0

    def _replace_after_crash(self, agent: WorkloadAgent):
        """Request a replacement for a crashed replica, with per-workload
        exponential backoff (reset after a quiet period): a workload whose
        replicas crash repeatedly must not flood the pending queue."""
        w = agent.vm.workload
        now = self.now()
        delay, last = self._crash_backoff.get(
            w, (self._CRASH_BACKOFF_BASE_S, -1e18))
        if now - last > self._CRASH_BACKOFF_RESET_S:
            delay = self._CRASH_BACKOFF_BASE_S
        self._crash_backoff[w] = (
            min(delay * 2.0, self._CRASH_BACKOFF_CAP_S), now)
        self.metrics["crash_replacements_requested"] += 1
        self.engine.after(delay, lambda a=agent:
                          self.request_replacement(a, {"deadline_s": 0.0}))

    # -- workload-side actions ----------------------------------------------
    def shed_load(self, agent: WorkloadAgent, new_util_p95: float):
        """Drop a VM's p95 demand.  The cluster books follow through field
        interception; the admission reservation moves with it (through the
        controller, which otherwise has no per-VM records — without this
        the later release subtracts the new lower demand and leaks phantom
        reservation)."""
        self.sched.admission.set_util_p95(agent.vm, new_util_p95)

    def request_replacement(self, agent: WorkloadAgent, event) -> str:
        """Scale-out reaction to an eviction notice: submit a replacement VM
        for placement elsewhere; the original can then be acked away."""
        vm = agent.vm
        now = self.now()
        # lazily drop bookkeeping for replacements that never landed (their
        # original's deadline is long past) so the map stays bounded when
        # the cluster is too full to place them
        if len(self._repl_pending) > 256:
            stale = [k for k, kt in self._repl_pending.items()
                     if kt < now - 600.0]
            for k in stale:
                del self._repl_pending[k]
        self._repl_seq += 1
        new_id = f"{vm.vm_id}.r{self._repl_seq}"
        self.sched.submit(VM(new_id, vm.workload, "", vm.cores,
                             util_p95=vm.util_p95, spot=vm.spot,
                             harvest=vm.harvest))
        self._repl_pending[new_id] = now + float(event.get("deadline_s", 0.0))
        self.metrics["replacements_requested"] += 1
        return new_id

    def set_phase(self, phase: str):
        """Diurnal phase flip: every leader agent re-asserts its workload's
        phase hints through the guest channel (rate-limited at the host,
        visible to the scheduler via the runtime-hint topic)."""
        if phase == self.phase:
            return
        self.phase = phase
        self.metrics["phase_changes"] += 1
        for agent in list(self.agents.values()):
            agent.on_phase(phase)

    # -- reporting -----------------------------------------------------------
    def replacement_lead_s_mean(self) -> float:
        n = self.metrics["replacements_placed"]
        return self.metrics["replacement_lead_s_sum"] / n if n else 0.0

    def telemetry(self) -> Dict[str, float]:
        out = dict(self.metrics)
        out["agents_live"] = float(len(self.agents))
        out["replacement_lead_s_mean"] = self.replacement_lead_s_mean()
        return out
