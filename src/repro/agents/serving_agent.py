"""The batched serving engine as a real scheduler tenant (paper §4).

``serve.engine.ServingEngine`` has always *claimed* to be a WI workload;
this module closes the loop the way ``trainer_agent`` did for training:
one ``ServingAgent`` per placed VM = one serving replica, and a shared
``ServingTenant`` that owns replica membership plus the request router.
Serving is the latency-critical class the paper says must keep its
availability/latency hints honored while the platform reclaims around it —
so every elastic reaction here preserves in-flight decodes:

  * ``EVICTION_NOTICE`` — stop admitting to the noticed replica, hand its
    queued-but-unstarted requests back to the router, and schedule the ack
    after the modeled drain latency (worst-case remaining decode steps x
    ``token_time_s``).  If the drain beats the ``kill_t`` deadline the ack
    lands on ``wi.events.acks`` and the VM is early-released; otherwise the
    ladder kill wins and the requests still in flight are metered as lost
    (bounded by the replica's batch slots).
  * ``SCALE_UP_OFFER`` (harvest) — granted ``extra_cores`` convert to extra
    decode slots (``cores_per_slot`` = nominal cores / nominal slots).
  * ``SCALE_DOWN_NOTICE`` — granted slots are revoked and the shrink acked.
  * ``THROTTLE_NOTICE`` / ``UNDERCLOCK_NOTICE`` — the fleet halves its
    decode slots: *compute* shed, not p95 demand shed (the PR 5 lesson —
    demand shed would disqualify the ``OVERCLOCK_OFFER`` that restores).
  * autoscaling — the leader publishes an ``x-autoscale-pressure`` runtime
    hint driven by queue depth AND p99 token latency (not utilization
    alone); ``AutoScalingPolicy`` consumes it to clone replicas out or
    drain them back in through the eviction pipeline.

The tenant is engine-agnostic: anything exposing ``submit`` / ``drain`` /
``resize_slots`` / ``queue_depth`` / ``active_count`` / ``step_once``
works, so the choreography is unit-testable without jax; real replicas are
built by the ``engine_factory`` (the ``serving_fleet`` case study attaches
synthetic-mode ``ServingEngine``s running on the sim clock).
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Any, Callable, Dict, List, Optional

from repro.core import hints as H

from repro.agents.agent import WorkloadAgent
from repro.agents.policy import STATEFUL, AgentPolicy

_EVICTION = H.PlatformEvent.EVICTION_NOTICE.value
_THROTTLES = (H.PlatformEvent.THROTTLE_NOTICE.value,
              H.PlatformEvent.UNDERCLOCK_NOTICE.value)
_RESTORE = H.PlatformEvent.OVERCLOCK_OFFER.value
_SCALE_UP = H.PlatformEvent.SCALE_UP_OFFER.value
_SCALE_DOWN = H.PlatformEvent.SCALE_DOWN_NOTICE.value


class ServingAgent(WorkloadAgent):
    """Per-VM agent for one replica of a live serving deployment."""

    def __init__(self, vm, endpoint, runtime, policy: AgentPolicy,
                 tenant: "ServingTenant"):
        super().__init__(vm, endpoint, runtime, policy)
        self.tenant = tenant
        tenant.adopt(self)

    def _on_event(self, event: Dict[str, Any]):
        if self.dead:
            return
        kind = event.get("event")
        if kind == _EVICTION:
            self._on_eviction(event)
        elif kind in _THROTTLES:
            self.tenant.on_throttle(self, event)
        elif kind == _RESTORE:
            self.tenant.on_restore(self, event)
        elif kind == _SCALE_UP:
            self.tenant.on_scale_up(self, event)
        elif kind == _SCALE_DOWN:
            self.tenant.on_scale_down(self, event)

    def _begin_checkpoint(self, event: Dict[str, Any]) -> float:
        """For serving, "checkpoint" = drain: admission stops NOW (queued
        requests re-route immediately), and the base class schedules the
        ack after the modeled drain latency returned here — worst-case
        remaining decode steps of the in-flight batch."""
        drain_s = self.tenant.begin_drain(self)
        now = self.rt.now()
        kill_t = float(event.get("payload", {}).get(
            "kill_t", now + float(event.get("deadline_s", 0.0))))
        self.tenant.note_ack_margin(kill_t - (now + drain_s))
        return drain_s

    def on_killed(self, t: float) -> float:
        self.dead = True
        lost = max(0.0, t - self.last_ckpt_t)
        self.tenant.on_vm_killed(self, lost)
        return lost


class ServingTenant:
    """Shared state for one serving workload's agents: replica membership,
    the request router, and the fleet-wide elastic reactions."""

    def __init__(self, workload: str,
                 engine_factory: Callable[[str, int], Any],
                 slots_per_vm: int = 4, token_time_s: float = 0.25,
                 p99_target_s: float = 5.0):
        self.workload = workload
        self.engine_factory = engine_factory
        self.slots_per_vm = max(1, int(slots_per_vm))
        # modeled sim seconds per decode step: the drain-latency unit (the
        # pump loop that steps real engines should use the same cadence so
        # the modeled ack matches what the engines actually do)
        self.token_time_s = float(token_time_s)
        self.p99_target_s = float(p99_target_s)
        self.runtime = None
        self.agents: Dict[str, ServingAgent] = {}
        self.replicas: Dict[str, Any] = {}      # vm_id -> engine
        self._order: List[str] = []             # adopt order: stable routing
        self._draining: set = set()
        self._granted_cores: Dict[str, float] = {}
        self._extra_slots: Dict[str, int] = {}
        self._throttled = False
        # requests with nowhere to go (total reclaim): replayed into the
        # first replica that can take them
        self._overflow: deque = deque()
        self.completion_sinks: List[Callable[[Any], None]] = []
        self.metrics = defaultdict(float)

    # -- wiring --------------------------------------------------------------
    def policy(self, **kw) -> AgentPolicy:
        """An ``AgentPolicy`` that constructs this tenant's agents."""
        kw.setdefault("statefulness", STATEFUL)
        kw.setdefault("scale_out_in", True)
        return AgentPolicy(agent_factory=lambda vm, ep, rt, pol:
                           ServingAgent(vm, ep, rt, pol, self), **kw)

    def adopt(self, agent: ServingAgent):
        if self.runtime is None:
            self.runtime = agent.rt
        vm_id = agent.vm.vm_id
        if vm_id in self.agents:                # re-adopt: keep the engine
            self.agents[vm_id] = agent
            return
        self.agents[vm_id] = agent
        self._order.append(vm_id)
        self._granted_cores[vm_id] = 0.0
        self._extra_slots[vm_id] = 0
        self.replicas[vm_id] = self.engine_factory(
            vm_id, self._slot_target(vm_id))
        self.metrics["replicas_adopted"] += 1
        self._drain_overflow()      # parked requests board the new replica

    # -- router --------------------------------------------------------------
    def _load(self, vm_id: str) -> int:
        e = self.replicas[vm_id]
        return e.queue_depth() + e.active_count()

    def _admitting_order(self) -> List[str]:
        """Live replicas by (load, adopt order) — deterministic min-load."""
        cands = [(self._load(vid), i, vid)
                 for i, vid in enumerate(self._order)
                 if vid not in self._draining]
        return [vid for _, _, vid in sorted(cands)]

    def submit(self, req) -> Optional[str]:
        """Route a request to the least-loaded admitting replica; with none
        (total reclaim) it parks in the overflow queue until a replacement
        replica lands."""
        for vid in self._admitting_order():
            if self.replicas[vid].submit(req):
                self.metrics["requests_routed"] += 1
                return vid
        self._overflow.append(req)
        self.metrics["requests_overflowed"] += 1
        return None

    def _drain_overflow(self):
        while self._overflow:
            req = self._overflow[0]
            placed = None
            for vid in self._admitting_order():
                if self.replicas[vid].submit(req):
                    placed = vid
                    break
            if placed is None:
                return
            self._overflow.popleft()
            self.metrics["overflow_replayed"] += 1

    def _request_done(self, req):
        """Engine completion hook (wired by the engine factory): count
        goodput and fan out to registered sinks (the traffic generator's
        latency recorder)."""
        self.metrics["requests_completed"] += 1
        for sink in self.completion_sinks:
            sink(req)

    # -- event reactions (called by ServingAgent) ----------------------------
    def begin_drain(self, agent: ServingAgent) -> float:
        """Eviction notice: the replica stops admitting immediately, its
        queued requests re-route, and the modeled drain latency (worst-case
        in-flight decode steps x token_time_s) is returned for the ack
        timer."""
        vm_id = agent.vm.vm_id
        eng = self.replicas.get(vm_id)
        if eng is None:
            return 0.0
        self._draining.add(vm_id)
        steps, requeued = eng.drain()
        self.metrics["drains"] += 1
        self.metrics["requests_rerouted"] += len(requeued)
        for r in requeued:
            self.submit(r)
        return steps * self.token_time_s

    def on_vm_killed(self, agent: ServingAgent, lost_s: float):
        vm_id = agent.vm.vm_id
        self.agents.pop(vm_id, None)
        if vm_id in self._order:
            self._order.remove(vm_id)
        self._draining.discard(vm_id)
        self._granted_cores.pop(vm_id, None)
        self._extra_slots.pop(vm_id, None)
        eng = self.replicas.pop(vm_id, None)
        if eng is not None:
            # a drained replica finished its batch before the ack; only a
            # ladder kill (or crash) takes in-flight/queued requests with it
            lost = eng.active_count() + eng.queue_depth()
            self.metrics["requests_lost"] += lost
        self.metrics["replicas_killed"] += 1
        self.metrics["lost_work_s"] += lost_s

    def _cores_per_slot(self, vm) -> float:
        return max(vm.cores / self.slots_per_vm, 1e-9)

    def _slot_target(self, vm_id: str) -> int:
        want = self.slots_per_vm + self._extra_slots.get(vm_id, 0)
        if self._throttled:
            want = max(1, want // 2)
        return want

    def _apply_slots(self, vm_id: str):
        eng = self.replicas.get(vm_id)
        if eng is not None:
            eng.resize_slots(self._slot_target(vm_id))

    def on_scale_up(self, agent: ServingAgent, event: Dict[str, Any]):
        """Harvest granted spare cores to this VM: whole-slot grants grow
        the replica's decode batch."""
        vm_id = agent.vm.vm_id
        extra = float(event.get("payload", {}).get("extra_cores", 0.0))
        if extra <= 0 or vm_id not in self._granted_cores:
            return
        self._granted_cores[vm_id] += extra
        want = int(self._granted_cores[vm_id]
                   // self._cores_per_slot(agent.vm))
        if want > self._extra_slots[vm_id]:
            self.metrics["harvest_slots_granted"] += \
                want - self._extra_slots[vm_id]
            self._extra_slots[vm_id] = want
            self._apply_slots(vm_id)

    def on_scale_down(self, agent: ServingAgent, event: Dict[str, Any]):
        """Harvest revoked cores: shrink the decode batch back and ack
        (the engine defers the shrink until in-flight sequences fit)."""
        vm_id = agent.vm.vm_id
        taken = float(event.get("payload", {}).get("cores", 0.0))
        if vm_id not in self._granted_cores:
            return
        self._granted_cores[vm_id] = max(
            0.0, self._granted_cores[vm_id] - taken)
        want = int(self._granted_cores[vm_id]
                   // self._cores_per_slot(agent.vm))
        if want < self._extra_slots[vm_id]:
            self.metrics["harvest_slots_revoked"] += \
                self._extra_slots[vm_id] - want
            self._extra_slots[vm_id] = want
            self._apply_slots(vm_id)
        seq = event.get("seq")
        if seq is not None:
            agent.ep.ack_event(seq)

    def on_throttle(self, agent: ServingAgent, event: Dict[str, Any]):
        """Oversubscription / power throttle: the whole fleet halves its
        decode slots — compute shed, not p95 demand shed."""
        self.metrics["throttle_notices"] += 1
        if not self._throttled:
            self._throttled = True
            self.metrics["throttled"] = 1.0
            for vid in self._order:
                self._apply_slots(vid)
        seq = event.get("seq")
        if seq is not None:
            agent.ep.ack_event(seq)

    def on_restore(self, agent: ServingAgent, event: Dict[str, Any]):
        if self._throttled:
            self._throttled = False
            self.metrics["throttled"] = 0.0
            self.metrics["restores"] += 1
            for vid in self._order:
                self._apply_slots(vid)

    def note_ack_margin(self, margin_s: float):
        """How much sim time the scheduled ack beats the kill deadline by
        (negative: the ladder will win and in-flight requests are lost)."""
        if ("ack_margin_min_s" not in self.metrics
                or margin_s < self.metrics["ack_margin_min_s"]):
            self.metrics["ack_margin_min_s"] = margin_s

    # -- autoscaling signal --------------------------------------------------
    def queue_depth(self) -> int:
        return sum(self.replicas[vid].queue_depth()
                   for vid in self._order) + len(self._overflow)

    def p99_token_latency_s(self) -> float:
        vals = []
        for vid in self._order:
            fn = getattr(self.replicas[vid], "p99_token_latency", None)
            if fn is not None:
                v = fn()
                if v == v:              # NaN-safe
                    vals.append(v)
        return max(vals) if vals else float("nan")

    def autoscale_pressure(self) -> float:
        """A util_p95-shaped scale signal in [0, 1] driven by queue depth
        and p99 token latency instead of utilization alone.  Calibrated so
        a full batch with an empty queue and healthy latency sits at 0.5
        (the policy's hold band); a growing queue or a p99 past target
        crosses the 0.6 scale-out trigger; a mostly idle fleet falls under
        the 0.25 scale-in trigger.  With zero live replicas (total
        reclaim) any parked request pins the signal to 1."""
        slots = sum(self.replicas[vid].slots for vid in self._order
                    if vid not in self._draining)
        active = sum(self.replicas[vid].active_count()
                     for vid in self._order if vid not in self._draining)
        queued = self.queue_depth()
        if slots == 0:
            return 1.0 if queued else 0.0
        occupancy = (active + queued) / slots
        p99 = self.p99_token_latency_s()
        lat_ratio = p99 / self.p99_target_s if p99 == p99 else 0.0
        return min(1.0, 0.5 * max(occupancy, lat_ratio))

    def publish_autoscale_hint(self) -> bool:
        """The leader agent asserts the workload-wide autoscale signal
        through its guest channel (KVP write -> local manager -> runtime
        hint on the bus -> ``AutoScalingPolicy``)."""
        if self.runtime is None:
            return False
        lead = next((a for a in self.agents.values()
                     if self.runtime.is_leader(a)), None)
        if lead is None:
            lead = next(iter(self.agents.values()), None)
        if lead is None or lead.dead:
            return False
        pressure = self.autoscale_pressure()
        self.metrics["autoscale_pressure"] = pressure
        p99 = self.p99_token_latency_s()
        ok = lead.ep.set_runtime_hints({
            "x-autoscale-pressure": round(pressure, 4),
            "x-queue-depth": float(self.queue_depth()),
            "x-p99-token-latency-s": round(p99, 4) if p99 == p99 else -1.0,
        }, workload_wide=True)
        if ok:
            self.metrics["autoscale_hints_published"] += 1
        return ok

    # -- stepping ------------------------------------------------------------
    @property
    def paused(self) -> bool:
        """No replica is admitting: requests park in overflow until a
        replacement lands (the serving analogue of the trainer's pause)."""
        return not any(vid not in self._draining for vid in self._order)

    def step_all(self) -> int:
        """One decode step on every replica (draining ones too — their
        in-flight batch must finish for the early release to be honest),
        then replay any parked overflow into freed capacity."""
        batches = 0
        for vid in list(self._order):
            eng = self.replicas.get(vid)
            if eng is not None:
                batches += 1 if eng.step_once() else 0
        self._drain_overflow()
        return batches

    def telemetry(self) -> Dict[str, float]:
        out = dict(self.metrics)
        out["replicas_live"] = float(len(self._order))
        out["replicas_admitting"] = float(
            sum(1 for vid in self._order if vid not in self._draining))
        out["slots_total"] = float(
            sum(self.replicas[vid].slots for vid in self._order))
        out["queue_depth"] = float(self.queue_depth())
        out["overflow_depth"] = float(len(self._overflow))
        return out
