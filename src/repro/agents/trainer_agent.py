"""The elastic JAX trainer as a real scheduler tenant (paper §4).

``runtime.trainer.WITrainer`` has always *reacted* to platform events, but
until now only to synthetic ones from ``repro.chaos.FaultInjector``.
This module attaches the trainer to VMs placed by the real platform
scheduler (``repro.sched``), closing the loop the paper's AI-training
pitch needs end-to-end:

  spot/harvest reclaim -> EvictionPipeline notice -> emergency checkpoint
  (the *real* ``Checkpointer``) -> guest ack over ``wi.events.acks`` ->
  early release -> data-parallel resize over the surviving device set ->
  replacement VM lands -> DP width re-grows.

Two pieces:

  * ``TrainerAgent`` — a per-VM ``WorkloadAgent`` subclass.  Each VM of the
    training workload is one slice of the device fleet; the agent reacts to
    the platform events its endpoint delivers and routes them to the shared
    tenant.  Everything it does goes through the guest channel: the ack
    that early-releases a VM is ``VMEndpoint.ack_event`` fanned onto
    ``wi.events.acks``, never a direct call into the pipeline.
  * ``TrainerTenant`` — owns the shared trainer plus the VM -> device
    mapping.  It is deliberately trainer-agnostic (anything exposing
    ``step_once`` / ``resize_to_devices`` / ``set_throttled`` /
    ``emergency_checkpoint`` / ``ckpt.wait`` works), so the mapping logic
    is unit-testable without JAX; the real ``WITrainer`` is attached by the
    ``ai_training`` case study.

Event semantics:

  * ``EVICTION_NOTICE`` — checkpoint the real training state *now* (it must
    be durable before consent), schedule the ack after the modeled write
    latency (``emergency_ckpt_s``), and request a replacement VM.  If the
    modeled latency beats the ``kill_t`` deadline the ack lands and the VM
    is early-released; otherwise the ladder kill wins and the work since
    the last durable checkpoint is metered as lost.
  * ``SCALE_UP_OFFER`` (harvest) — the granted ``extra_cores`` convert to
    spare accelerators; DP width grows at the next step boundary.
  * ``SCALE_DOWN_NOTICE`` (harvest shrink) — granted devices are revoked.
  * ``THROTTLE_NOTICE`` / ``UNDERCLOCK_NOTICE`` — halve the microbatch
    (compute shed, not demand shed); a later ``OVERCLOCK_OFFER`` restores.

Resize policy: kills apply eagerly (the devices are gone — training cannot
continue at the old width), grows apply lazily at the next step boundary so
a replacement wave coalesces into one re-jit instead of one per VM.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Any, Dict, List, Optional

from repro.core import hints as H

from repro.agents.agent import WorkloadAgent
from repro.agents.policy import STATEFUL, AgentPolicy

_EVICTION = H.PlatformEvent.EVICTION_NOTICE.value
_THROTTLES = (H.PlatformEvent.THROTTLE_NOTICE.value,
              H.PlatformEvent.UNDERCLOCK_NOTICE.value)
_RESTORE = H.PlatformEvent.OVERCLOCK_OFFER.value
_SCALE_UP = H.PlatformEvent.SCALE_UP_OFFER.value
_SCALE_DOWN = H.PlatformEvent.SCALE_DOWN_NOTICE.value


class TrainerAgent(WorkloadAgent):
    """Per-VM agent for one data-parallel slice of a live trainer."""

    def __init__(self, vm, endpoint, runtime, policy: AgentPolicy, tenant:
                 "TrainerTenant"):
        super().__init__(vm, endpoint, runtime, policy)
        self.tenant = tenant
        tenant.adopt(self)

    def _on_event(self, event: Dict[str, Any]):
        if self.dead:
            return
        kind = event.get("event")
        if kind == _EVICTION:
            self._on_eviction(event)
        elif kind in _THROTTLES:
            self.tenant.on_throttle(self, event)
        elif kind == _RESTORE:
            self.tenant.on_restore(self, event)
        elif kind == _SCALE_UP:
            self.tenant.on_scale_up(self, event)
        elif kind == _SCALE_DOWN:
            self.tenant.on_scale_down(self, event)

    def _begin_checkpoint(self, event: Dict[str, Any]) -> float:
        """The real emergency checkpoint happens NOW (params + opt state
        are durable on disk before any consent); the base class schedules
        the ack after the modeled write latency returned here, so the
        platform sees checkpoint-then-ack in simulated time."""
        ckpt_s = self.tenant.emergency_checkpoint(self)
        now = self.rt.now()
        kill_t = float(event.get("payload", {}).get(
            "kill_t", now + float(event.get("deadline_s", 0.0))))
        self.tenant.note_ack_margin(kill_t - (now + ckpt_s))
        return ckpt_s

    def on_killed(self, t: float) -> float:
        self.dead = True
        lost = max(0.0, t - self.last_ckpt_t)
        self.tenant.on_vm_killed(self, lost)
        return lost


class TrainerTenant:
    """Shared state for one training workload's agents: the trainer itself
    and which accelerators each placed VM contributes."""

    def __init__(self, workload: str, devices, devices_per_vm: int = 1,
                 model_axis: int = 1, min_dp: int = 1,
                 emergency_ckpt_s: float = 4.0):
        self.workload = workload
        self.devices_per_vm = max(1, int(devices_per_vm))
        self.model_axis = max(1, int(model_axis))
        self.min_dp = max(1, int(min_dp))
        # FIXED modeled durable-write latency of the emergency checkpoint
        # in sim seconds — the real save is instantaneous on the sim clock.
        # Callers pick it for their timeline; the ai_training scenario
        # reports the write bandwidth it implies for the trainer's real
        # ``state_bytes()`` so the constant stays auditable.
        self.emergency_ckpt_s = float(emergency_ckpt_s)
        self.trainer = None
        self.runtime = None
        self.agents: Dict[str, TrainerAgent] = {}
        self._order: List[str] = []             # adopt order: stable mapping
        self._assigned: Dict[str, List] = {}    # vm -> base devices
        self._extra: Dict[str, List] = {}       # vm -> harvest-granted
        self._granted_cores: Dict[str, float] = {}
        self._spare: List = list(devices)
        self._paused = False
        self._dirty = False                     # grow pending a step boundary
        self._last_emergency = None             # (step, sim_t) coalescing key
        self.metrics = defaultdict(float)

    # -- wiring --------------------------------------------------------------
    def policy(self, **kw) -> AgentPolicy:
        """An ``AgentPolicy`` that constructs this tenant's agents."""
        kw.setdefault("statefulness", STATEFUL)
        kw.setdefault("scale_out_in", True)
        return AgentPolicy(agent_factory=lambda vm, ep, rt, pol:
                           TrainerAgent(vm, ep, rt, pol, self), **kw)

    def attach_trainer(self, trainer):
        """Hand over the (already built) trainer; it must be running on
        exactly ``active_devices()``."""
        self.trainer = trainer
        self._dirty = False
        self._paused = False

    def adopt(self, agent: TrainerAgent):
        if self.runtime is None:
            self.runtime = agent.rt
        vm_id = agent.vm.vm_id
        if vm_id in self.agents:                # re-adopt: keep the mapping
            self.agents[vm_id] = agent
            return
        self.agents[vm_id] = agent
        self._order.append(vm_id)
        take = min(self.devices_per_vm, len(self._spare))
        self._assigned[vm_id] = [self._spare.pop(0) for _ in range(take)]
        self._extra[vm_id] = []
        self._granted_cores[vm_id] = 0.0
        if take < self.devices_per_vm:
            self.metrics["underfilled_adoptions"] += 1
        self._dirty = True
        self.metrics["vms_adopted"] += 1

    # -- device bookkeeping --------------------------------------------------
    def active_devices(self) -> List:
        devs: List = []
        for vm_id in self._order:
            devs.extend(self._assigned[vm_id])
            devs.extend(self._extra[vm_id])
        return devs

    def _refill(self):
        """Top up underfilled live VMs (a replacement adopted while its
        original still held the devices) from the spare pool."""
        for vm_id in self._order:
            want = self.devices_per_vm - len(self._assigned[vm_id])
            while want > 0 and self._spare:
                self._assigned[vm_id].append(self._spare.pop(0))
                want -= 1
                self._dirty = True

    def _apply_devices(self):
        self._dirty = False
        if self.trainer is None:
            return
        ok = self.trainer.resize_to_devices(self.active_devices())
        if ok and self._paused:
            self._paused = False
            self.metrics["resumes"] += 1
        elif not ok:
            # below the minimum mesh: hold the old state and stop stepping
            # until replacements bring capacity back
            if not self._paused:
                self.metrics["pauses"] += 1
            self._paused = True

    def apply_pending(self):
        """Enact any deferred device-map change (step boundaries call this;
        tests may call it directly)."""
        if self._dirty:
            self._apply_devices()

    # -- event reactions (called by TrainerAgent) ----------------------------
    def emergency_checkpoint(self, agent: TrainerAgent) -> float:
        """Durable checkpoint for an eviction notice; one real save covers
        every notice of the same wave (same step, same sim instant).
        Returns the modeled durable-write latency in sim seconds."""
        now = self.runtime.now() if self.runtime else 0.0
        key = (getattr(self.trainer, "step", 0), now)
        if key != self._last_emergency:
            self._last_emergency = key
            if self.trainer is not None:
                self.trainer.emergency_checkpoint()
            self.metrics["emergency_checkpoints"] += 1
        return self.emergency_ckpt_s

    def on_vm_killed(self, agent: TrainerAgent, lost_s: float):
        vm_id = agent.vm.vm_id
        self.agents.pop(vm_id, None)
        if vm_id in self._order:
            self._order.remove(vm_id)
        freed = self._assigned.pop(vm_id, []) + self._extra.pop(vm_id, [])
        self._granted_cores.pop(vm_id, None)
        self._spare.extend(freed)
        self.metrics["vms_killed"] += 1
        self.metrics["lost_work_s"] += lost_s
        self._refill()
        # kills apply eagerly: the dead VM's devices cannot keep training
        self._apply_devices()

    def _per_device_cores(self, vm) -> float:
        return max(vm.cores / self.devices_per_vm, 1e-9)

    def on_scale_up(self, agent: TrainerAgent, event: Dict[str, Any]):
        """Harvest granted spare cores to this VM: convert whole-device
        grants into extra DP capacity at the next step boundary."""
        vm_id = agent.vm.vm_id
        extra = float(event.get("payload", {}).get("extra_cores", 0.0))
        if extra <= 0 or vm_id not in self._granted_cores:
            return
        self._granted_cores[vm_id] += extra
        want = int(self._granted_cores[vm_id]
                   // self._per_device_cores(agent.vm))
        while len(self._extra[vm_id]) < want and self._spare:
            self._extra[vm_id].append(self._spare.pop(0))
            self._dirty = True
            self.metrics["harvest_devices_granted"] += 1

    def on_scale_down(self, agent: TrainerAgent, event: Dict[str, Any]):
        """Harvest revoked cores: give granted devices back and ack."""
        vm_id = agent.vm.vm_id
        taken = float(event.get("payload", {}).get("cores", 0.0))
        if vm_id not in self._granted_cores:
            return
        self._granted_cores[vm_id] = max(
            0.0, self._granted_cores[vm_id] - taken)
        want = int(self._granted_cores[vm_id]
                   // self._per_device_cores(agent.vm))
        while len(self._extra[vm_id]) > want:
            self._spare.append(self._extra[vm_id].pop())
            self._dirty = True
            self.metrics["harvest_devices_revoked"] += 1
        seq = event.get("seq")
        if seq is not None:
            agent.ep.ack_event(seq)

    def on_throttle(self, agent: TrainerAgent, event: Dict[str, Any]):
        """Oversubscription / power throttle: the whole job halves its
        microbatch — compute shed, not p95 demand shed."""
        self.metrics["throttle_notices"] += 1
        if not self.metrics["throttled"]:
            self.metrics["throttled"] = 1.0
            if self.trainer is not None:
                self.trainer.set_throttled(True)
        seq = event.get("seq")
        if seq is not None:
            agent.ep.ack_event(seq)

    def on_restore(self, agent: TrainerAgent, event: Dict[str, Any]):
        if self.metrics["throttled"]:
            self.metrics["throttled"] = 0.0
            self.metrics["restores"] += 1
            if self.trainer is not None:
                self.trainer.set_throttled(False)

    def note_ack_margin(self, margin_s: float):
        """How much sim time the scheduled ack beats the kill deadline by
        (negative: the ladder will win and the work rides to the kill)."""
        if ("ack_margin_min_s" not in self.metrics
                or margin_s < self.metrics["ack_margin_min_s"]):
            self.metrics["ack_margin_min_s"] = margin_s

    # -- stepping ------------------------------------------------------------
    def note_durable(self):
        """A periodic checkpoint just became durable: lost-work meters reset
        for every live slice."""
        now = self.runtime.now() if self.runtime else 0.0
        for a in self.agents.values():
            a.last_ckpt_t = now

    def publish_runtime_hints(self, hints: Dict[str, Any]) -> bool:
        """The trainer's per-step runtime hints go out through the leader
        agent's guest channel (``WITrainer.hint_sink``)."""
        if self.runtime is None:
            return False
        lead = next((a for a in self.agents.values()
                     if self.runtime.is_leader(a)), None)
        if lead is None:
            lead = next(iter(self.agents.values()), None)
        if lead is None or lead.dead:
            return False
        return lead.ep.set_runtime_hints(dict(hints))

    @property
    def paused(self) -> bool:
        return self._paused

    def run(self, n_steps: int, sim_s_per_step: float = 5.0,
            max_sim_s: Optional[float] = None):
        """Interleave real training steps with the platform's simulated
        clock: every step advances the engine by ``sim_s_per_step`` (firing
        scheduler ticks, notices, ladder kills, policy passes), applies any
        deferred resize, then runs one real step."""
        eng = self.runtime.engine
        horizon = eng.clock.t + (max_sim_s if max_sim_s is not None
                                 else 4.0 * n_steps * sim_s_per_step)
        while self.trainer.step < n_steps and eng.clock.t < horizon:
            eng.run(until=eng.clock.t + sim_s_per_step)
            self.apply_pending()
            if self._paused:
                continue                # waiting for replacement capacity
            self.trainer.step_once()
            if self.trainer.step % self.trainer.ckpt_every == 0:
                self.trainer.ckpt.wait()        # async write is durable now
                self.note_durable()
        self.trainer.ckpt.wait()
        return self.trainer.metrics_log

    def telemetry(self) -> Dict[str, float]:
        out = dict(self.metrics)
        out["vms_live"] = float(len(self.agents))
        out["devices_active"] = float(len(self.active_devices()))
        out["devices_spare"] = float(len(self._spare))
        return out
