"""The per-VM workload agent: the guest half of the bidirectional loop.

One ``WorkloadAgent`` runs "inside" each VM, attached to the local
manager's ``VMEndpoint``.  It receives platform events through the
scheduled-events push channel and reacts the way the paper says workloads
do (§4):

  * ``EVICTION_NOTICE`` — stateless scale-out workloads request a
    replacement VM from the platform and *ack immediately*: the eviction
    pipeline releases the VM (freeing its capacity) long before the kill
    deadline.  Stateful/partial workloads first checkpoint — simulated
    latency proportional to state size — and ack once the checkpoint is
    durable; work since the last checkpoint is metered as lost-work-seconds
    if the deadline beats the checkpoint.
  * ``THROTTLE_NOTICE`` / ``UNDERCLOCK_NOTICE`` / ``SCALE_DOWN_NOTICE`` —
    shed load (the VM's p95 demand drops; the cluster books follow) and
    advertise a lower keep-priority runtime hint so future reclaims pick
    this VM first.
  * diurnal phase changes — the workload's leader agent re-asserts
    workload-wide runtime hints (``set_runtime_hints(workload_wide=True)``)
    so placement, eviction choice, and notice windows track the phase.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.core import hints as H

from repro.agents.policy import STATELESS, AgentPolicy

_EVICTION = H.PlatformEvent.EVICTION_NOTICE.value
_SHED_EVENTS = (H.PlatformEvent.THROTTLE_NOTICE.value,
                H.PlatformEvent.UNDERCLOCK_NOTICE.value,
                H.PlatformEvent.SCALE_DOWN_NOTICE.value)


class WorkloadAgent:
    def __init__(self, vm, endpoint, runtime, policy: AgentPolicy):
        self.vm = vm
        self.ep = endpoint
        self.rt = runtime
        self.policy = policy
        self.server_id = vm.server
        now = runtime.now()
        self.attached_t = now
        self.last_ckpt_t = now          # work before attach is not ours
        self.draining = False
        self.ckpt_running = False
        self.acked_eviction = False     # consented to at least one release
        self.dead = False
        # a rogue (never-ack) agent sets this: the lease loop stops
        # heartbeating for it, so the local manager declares it silent
        self.unresponsive = False
        # generation guard: cancel/rebind invalidate in-flight checkpoint
        # timers, so a stale timer can never ack a *later* ticket
        self._ckpt_gen = 0
        endpoint.on_event(self._on_event)

    # -- endpoint rebinding (migration moved the VM to another server) ------
    def rebind(self, endpoint):
        self.ep = endpoint
        self.server_id = self.vm.server
        self.draining = False           # a pending eviction cancels on move
        self.ckpt_running = False
        self._ckpt_gen += 1
        endpoint.on_event(self._on_event)

    def on_eviction_cancelled(self):
        """The platform recovered capacity: re-arm for the next notice and
        invalidate any in-flight checkpoint timer."""
        self.draining = False
        self.ckpt_running = False
        self._ckpt_gen += 1

    # -- event dispatch ------------------------------------------------------
    def _on_event(self, event: Dict[str, Any]):
        if self.dead:
            return
        kind = event.get("event")
        if kind == _EVICTION:
            self._on_eviction(event)
        elif kind in _SHED_EVENTS:
            self._on_shed(event)

    def heartbeat(self):
        """Refresh the host-side lease (driven by the runtime's lease loop;
        acks and hint writes also count as signs of life)."""
        if not self.dead and not self.unresponsive:
            self.ep.heartbeat()

    def _on_eviction(self, event: Dict[str, Any]):
        if self.draining:
            # reminder / duplicate: already on it — but if we acked and the
            # ack record was lost in transit, the platform is redelivering
            # because it never saw it.  Re-ack (each redelivery carries a
            # fresh seq, so this is not endpoint-deduped; the pipeline's
            # ticket has long been released in the loss-free case, making
            # this a no-op there).
            if self.acked_eviction and not self.ckpt_running:
                self._ack(event)
            return
        self.draining = True
        self.rt.metrics["eviction_notices_seen"] += 1
        pol = self.policy
        if pol.scale_out_in:
            # scale-out: a replacement starts deploying immediately, racing
            # the notice window
            self.rt.request_replacement(self, event)
        if pol.statefulness == STATELESS:
            # nothing to lose: hand the VM back right away
            self._ack(event)
            return
        # stateful/partial: checkpoint first, ack only once durable
        self.ckpt_running = True
        self._ckpt_gen += 1
        self.rt.metrics["checkpoints_started"] += 1
        self.rt.engine.after(self._begin_checkpoint(event),
                             lambda e=event, g=self._ckpt_gen:
                             self._ckpt_done(e, g))

    def _begin_checkpoint(self, event: Dict[str, Any]) -> float:
        """Start making state durable; return the modeled write latency in
        sim seconds.  Subclasses that own real state (the trainer agent)
        override this — the draining/ack choreography and the stale-timer
        generation guard stay here, in one place."""
        return self.policy.checkpoint_s()

    def _ckpt_done(self, event: Dict[str, Any], gen: int):
        if self.dead or gen != self._ckpt_gen:
            return      # deadline won, or the ticket this checkpoint served
            # was cancelled/moved — a stale timer must not ack a later one
        self.ckpt_running = False
        self.last_ckpt_t = self.rt.now()
        self.rt.metrics["checkpoints_completed"] += 1
        self._ack(event)                # drained: release early

    def _ack(self, event: Dict[str, Any]):
        seq = event.get("seq")
        if seq is not None:
            self.acked_eviction = True
            self.ep.ack_event(seq)
            self.rt.metrics["acks_sent"] += 1

    def _on_shed(self, event: Dict[str, Any]):
        # the platform's requested fraction when it names one (throttle:
        # "frac", underclock: "slowdown_frac"), else the policy's default
        payload = event.get("payload", {})
        frac = payload.get("frac", payload.get(
            "slowdown_frac", self.policy.throttle_shed_frac))
        shed = min(max(float(frac), 0.0), 1.0)
        # shed load through the runtime so BOTH the cluster's incremental
        # books and the admission controller's reservation follow the drop
        self.rt.shed_load(self, max(0.05, self.vm.util_p95 * (1.0 - shed)))
        # advertise low keep-priority: future reclaims should pick us first
        self.ep.set_runtime_hints({"x-preemption-priority": 5.0})
        self.rt.metrics["shed_reactions"] += 1

    # -- diurnal adaptation --------------------------------------------------
    def on_phase(self, phase: str):
        prof = self.policy.diurnal
        if prof is None or not self.rt.is_leader(self):
            return
        hints = prof.hints_for(phase)
        if hints and self.ep.set_runtime_hints(hints, workload_wide=True):
            self.rt.metrics["hint_adaptations"] += 1

    # -- lifecycle -----------------------------------------------------------
    def on_killed(self, t: float) -> float:
        """The platform took the VM; return lost work in seconds (work since
        the last durable checkpoint — zero for stateless workloads)."""
        self.dead = True
        if self.policy.statefulness == STATELESS:
            return 0.0
        return max(0.0, t - self.last_ckpt_t)
