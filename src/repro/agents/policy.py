"""Per-workload agent policies: how a workload reacts to platform events.

A policy is the workload-side contract the paper's §4 "dynamically adapt
behaviors" claim needs: what state the workload carries (and therefore how
long a checkpoint takes), whether it can scale out (replace an evicted VM
instead of draining it), how hard it sheds load on a throttle, and how its
hints swing with the diurnal phase (Parayil et al.'s characterization:
bigdata turns delay-tolerant/preemptible off-peak, interactive classes
raise availability at peak).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

STATELESS = "stateless"
PARTIAL = "partial"
STATEFUL = "stateful"


@dataclass(frozen=True)
class DiurnalProfile:
    """Runtime hints asserted per phase (workload-wide, by the workload's
    leader agent through its guest channel)."""
    peak_hints: Dict[str, Any] = field(default_factory=dict)
    offpeak_hints: Dict[str, Any] = field(default_factory=dict)

    def hints_for(self, phase: str) -> Dict[str, Any]:
        return dict(self.peak_hints if phase == "peak"
                    else self.offpeak_hints)


@dataclass
class AgentPolicy:
    """How one workload's per-VM agents behave."""
    statefulness: str = STATELESS       # stateless | partial | stateful
    state_gb: float = 0.0               # checkpointable state per VM
    ckpt_gbps: float = 1.0              # checkpoint write bandwidth
    scale_out_in: bool = False          # may replace an evicted VM elsewhere
    throttle_shed_frac: float = 0.5     # p95 load shed on a throttle notice
    diurnal: Optional[DiurnalProfile] = None
    # constructs the per-VM agent ``(vm, endpoint, runtime, policy)`` —
    # lets a workload supply a richer agent than the default
    # ``WorkloadAgent`` (e.g. the trainer-backed ``TrainerAgent``)
    agent_factory: Optional[Callable] = None

    def checkpoint_s(self) -> float:
        """Simulated checkpoint latency, proportional to state size."""
        if self.statefulness == STATELESS or self.state_gb <= 0.0:
            return 0.0
        return self.state_gb / max(self.ckpt_gbps, 1e-9)
