"""Assigned architecture config: mamba2_370m (see archs.py for the full definition)."""
from repro.configs.archs import MAMBA2_370M as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
