"""Config system for WI-JAX.

Three layers of config:
  * ModelConfig     — architecture hyperparameters (one per assigned arch).
  * ShapeConfig     — the assigned input-shape cells (train_4k, prefill_32k, ...).
  * ParallelConfig  — mesh / sharding / remat / microbatching knobs.
  * RunConfig       — bundles the above plus runtime (WI) options.

Everything is a frozen dataclass so configs hash and can key jit caches.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Sub-layer / block structure
# ---------------------------------------------------------------------------
# A model is a stack of *groups*; each group repeats a *pattern* of blocks
# R times via lax.scan.  A block is a named kind:
#   'attn'        self-attention (+ mlp handled separately in pattern)
#   'mlp'         gated FFN
#   'moe'         mixture-of-experts FFN
#   'ssd'         Mamba-2 SSD block (includes its own in/out projections)
#   'rglru'       Griffin RG-LRU recurrent block
#   'cross_attn'  decoder cross-attention (enc-dec only)
# Patterns are tuples of tuples: e.g. (('attn', 'mlp'),) repeated R times, or
# gemma-2's (('attn_local', 'mlp'), ('attn_global', 'mlp')) repeated L/2 times.


@dataclass(frozen=True)
class AttnConfig:
    causal: bool = True
    window: Optional[int] = None          # sliding-window size (None = global)
    logit_softcap: Optional[float] = None  # gemma-2 style attn softcap
    query_scale: Optional[float] = None    # override 1/sqrt(head_dim)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    capacity_factor: float = 1.25
    aux_loss_coef: float = 0.01
    router_dtype: str = "float32"


@dataclass(frozen=True)
class SSDConfig:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk_size: int = 256
    n_groups: int = 1


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: int = 0           # 0 => d_model
    conv_width: int = 4
    block_width: int = 0         # diagonal-block proj width (0 => heads of 256? unused)
    c: float = 8.0               # Griffin's fixed constant


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # block pattern: tuple of block-kind tuples; repeated scan groups derived in
    # models/model.py.  Default: uniform ('attn','mlp') stack.
    pattern: Tuple[Tuple[str, ...], ...] = (("attn", "mlp"),)
    attn: AttnConfig = AttnConfig()
    attn_local: Optional[AttnConfig] = None   # for *_local blocks
    moe: Optional[MoEConfig] = None
    ssd: Optional[SSDConfig] = None
    rglru: Optional[RGLRUConfig] = None
    # enc-dec (whisper): encoder stack config
    enc_layers: int = 0
    enc_seq_ratio: int = 1        # encoder frames per decoder token (shape split)
    # vlm: number of leading positions fed by the (stubbed) vision frontend
    n_vision_tokens: int = 0
    # misc
    rope_theta: float = 10_000.0
    rms_eps: float = 1e-6
    final_logit_softcap: Optional[float] = None
    tie_embeddings: bool = True
    emb_scale_by_sqrt_dim: bool = False     # gemma family
    post_block_norm: bool = False            # gemma-2 sandwich norms
    act_dtype: str = "bfloat16"

    @property
    def padded_vocab(self) -> int:
        """Embedding-table rows padded to a multiple of 256 so the vocab dim
        shards evenly on the 16-wide model axis (MaxText-style padding; the
        logical vocab is unchanged — padded logits are masked to -inf)."""
        return (self.vocab_size + 255) // 256 * 256

    @property
    def sub_quadratic(self) -> bool:
        """True if every block avoids global quadratic attention."""
        kinds = [k for pat in self.pattern for k in pat]
        for k in kinds:
            if k == "attn" and self.attn.window is None:
                return False
            if k == "cross_attn":
                return False
        return True

    @property
    def n_params(self) -> int:
        """Analytical parameter count (matches abstract_params; see tests)."""
        from repro.models.model import count_params  # local import, no cycle
        return count_params(self)

    @property
    def n_active_params(self) -> int:
        from repro.models.model import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    name: str                      # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                      # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class ParallelConfig:
    # mesh axis sizes; pod=1 means single-pod
    pod: int = 1
    data: int = 16
    model: int = 16
    # sharding strategy
    fsdp: bool = True              # shard params over the data axis too (ZeRO-3)
    seq_shard_acts: bool = True    # sequence-shard saved activations (SP)
    # training memory knobs
    microbatch: int = 0            # 0 => no accumulation (single microbatch)
    grad_accum_dtype: str = "float32"
    opt_state_dtype: str = "float32"
    remat: str = "full"            # full | dots | none
    # hillclimb levers (see EXPERIMENTS.md §Perf)
    gather_barrier: bool = False   # pin FSDP weight gathers at loop-body top
    moe_cap_shard: bool = False    # shard MoE dispatch buffers over data
    # attention impl: dense | flash | pallas
    attn_impl: str = "flash"
    flash_q_chunk: int = 512
    flash_kv_chunk: int = 512
    flash_causal_skip: bool = False   # balanced triangular schedule (hillclimb opt)
    # loss computation chunk (tokens per step of the chunked x-ent)
    loss_chunk: int = 0            # 0 => unchunked
    # gradient compression: none | int8
    grad_compression: str = "none"
    # collective schedule for the DP gradient reduction under shard_map paths
    dp_collective: str = "all_reduce"  # all_reduce | reduce_scatter

    def axis_names(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.pod > 1 else ("data", "model")

    def mesh_shape(self) -> Tuple[int, ...]:
        return ((self.pod, self.data, self.model) if self.pod > 1
                else (self.data, self.model))

    @property
    def dp_axes(self):
        return ("pod", "data") if self.pod > 1 else ("data",)

    @property
    def n_devices(self):
        return self.pod * self.data * self.model


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    parallel: ParallelConfig = ParallelConfig()
    seed: int = 0
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    optimizer: str = "adamw"       # adamw | adafactor
    z_loss: float = 0.0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


def mconfig_replace(cfg: ModelConfig, **kw) -> ModelConfig:
    return dataclasses.replace(cfg, **kw)


def pconfig_replace(cfg: ParallelConfig, **kw) -> ParallelConfig:
    return dataclasses.replace(cfg, **kw)
