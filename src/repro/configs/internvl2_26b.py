"""Assigned architecture config: internvl2_26b (see archs.py for the full definition)."""
from repro.configs.archs import INTERNVL2_26B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
