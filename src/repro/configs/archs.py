"""The 10 assigned architectures (exact configs from the assignment sheet).

Each also defines a ``smoke`` reduction (same family, tiny dims) used by the
per-arch CPU smoke tests; the full configs are exercised only via the dry-run
(ShapeDtypeStruct, no allocation).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (AttnConfig, MoEConfig, ModelConfig,
                                RGLRUConfig, SSDConfig)

# --------------------------------------------------------------------------
# MoE family [hf:ibm-granite/granite-3.0-1b-a400m-base]
# --------------------------------------------------------------------------

GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=32, top_k=8, expert_d_ff=512),
    tie_embeddings=True,
)

GRANITE_MOE_3B = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49_155,
    pattern=(("attn", "moe"),),
    moe=MoEConfig(n_experts=40, top_k=8, expert_d_ff=512),
    tie_embeddings=True,
)

# --------------------------------------------------------------------------
# Gemma-2 family [arXiv:2408.00118]: alternating local/global attention,
# logit softcaps, sandwich norms, tied + sqrt(d)-scaled embeddings.
# --------------------------------------------------------------------------

GEMMA2_27B = ModelConfig(
    name="gemma2-27b", family="dense",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=36_864, vocab_size=256_000,
    pattern=(("attn_local", "mlp"), ("attn", "mlp")),
    attn=AttnConfig(causal=True, logit_softcap=50.0,
                    query_scale=(4608 / 32) ** -0.5),
    attn_local=AttnConfig(causal=True, window=4096, logit_softcap=50.0,
                          query_scale=(4608 / 32) ** -0.5),
    final_logit_softcap=30.0, tie_embeddings=True,
    emb_scale_by_sqrt_dim=True, post_block_norm=True,
)

GEMMA2_9B = ModelConfig(
    name="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14_336, vocab_size=256_000,
    pattern=(("attn_local", "mlp"), ("attn", "mlp")),
    attn=AttnConfig(causal=True, logit_softcap=50.0, query_scale=256.0 ** -0.5),
    attn_local=AttnConfig(causal=True, window=4096, logit_softcap=50.0,
                          query_scale=256.0 ** -0.5),
    final_logit_softcap=30.0, tie_embeddings=True,
    emb_scale_by_sqrt_dim=True, post_block_norm=True,
)

# --------------------------------------------------------------------------
# Dense [arXiv:2407.21783, arXiv:2407.14679]
# --------------------------------------------------------------------------

LLAMA3_405B = ModelConfig(
    name="llama3-405b", family="dense",
    n_layers=126, d_model=16_384, n_heads=128, n_kv_heads=8, head_dim=128,
    d_ff=53_248, vocab_size=128_256,
    rope_theta=500_000.0, tie_embeddings=False,
)

MINITRON_8B = ModelConfig(
    name="minitron-8b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=16_384, vocab_size=256_000,
    tie_embeddings=False,
)

# --------------------------------------------------------------------------
# Mamba-2 [arXiv:2405.21060]: SSD, attention-free.
# --------------------------------------------------------------------------

MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50_280,
    pattern=(("ssd",),),
    ssd=SSDConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  chunk_size=256, n_groups=1),
    tie_embeddings=True,
)

# --------------------------------------------------------------------------
# RecurrentGemma / Griffin [arXiv:2402.19427]: RG-LRU + local attention 1:2.
# --------------------------------------------------------------------------

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid",
    n_layers=38, d_model=4096, n_heads=16, n_kv_heads=1, head_dim=256,
    d_ff=12_288, vocab_size=256_000,
    pattern=(("rglru", "mlp"), ("rglru", "mlp"), ("attn", "mlp")),
    attn=AttnConfig(causal=True, window=2048),
    rglru=RGLRUConfig(lru_width=4096, conv_width=4),
    tie_embeddings=True, emb_scale_by_sqrt_dim=True,
)

# --------------------------------------------------------------------------
# Whisper [arXiv:2212.04356]: enc-dec, conv frontend stubbed (input_specs
# provides precomputed frame embeddings at d_model).
# --------------------------------------------------------------------------

WHISPER_TINY = ModelConfig(
    name="whisper-tiny", family="encdec",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536, vocab_size=51_865,
    pattern=(("attn", "cross_attn", "mlp"),),
    enc_layers=4, enc_seq_ratio=4,
    tie_embeddings=True,
)

# --------------------------------------------------------------------------
# InternVL2 [arXiv:2404.16821]: InternViT frontend stubbed (patch embeddings
# at 3200 dims -> vis_proj); backbone = InternLM2-style decoder.
# --------------------------------------------------------------------------

INTERNVL2_26B = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=16_384, vocab_size=92_553,
    n_vision_tokens=1024,
    tie_embeddings=False,
)

ARCHS = {c.name: c for c in [
    GRANITE_MOE_1B, GRANITE_MOE_3B, GEMMA2_27B, GEMMA2_9B, LLAMA3_405B,
    MINITRON_8B, MAMBA2_370M, RECURRENTGEMMA_9B, WHISPER_TINY, INTERNVL2_26B,
]}


# --------------------------------------------------------------------------
# Smoke reductions: same family/pattern, tiny dims.
# --------------------------------------------------------------------------

def smoke_config(name: str) -> ModelConfig:
    full = ARCHS[name]
    kw = dict(
        name=full.name + "-smoke", n_layers=min(full.n_layers,
                                                3 * len(full.pattern)),
        d_model=64, vocab_size=256,
        act_dtype="float32",  # keeps decode-vs-forward checks tie-break stable
    )
    if full.n_heads:
        kw.update(n_heads=4, n_kv_heads=min(full.n_kv_heads, 2), head_dim=16)
    if full.d_ff:
        kw.update(d_ff=128)
    if full.moe:
        # capacity_factor = E/k => zero token drops (keeps the smoke
        # prefill/decode-vs-forward consistency checks exact)
        kw.update(moe=dataclasses.replace(full.moe, n_experts=4, top_k=2,
                                          expert_d_ff=32, capacity_factor=2.0))
    if full.ssd:
        kw.update(ssd=dataclasses.replace(full.ssd, d_state=16, head_dim=8,
                                          chunk_size=16))
    if full.rglru:
        kw.update(rglru=dataclasses.replace(full.rglru, lru_width=64))
    if full.attn_local:
        kw.update(attn_local=dataclasses.replace(full.attn_local, window=32))
    if full.attn.window:
        kw.update(attn=dataclasses.replace(full.attn, window=32))
    if full.family == "encdec":
        kw.update(enc_layers=2)
    if full.family == "vlm":
        kw.update(n_vision_tokens=8)
    return dataclasses.replace(full, **kw)
