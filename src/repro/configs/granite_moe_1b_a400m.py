"""Assigned architecture config: granite_moe_1b_a400m (see archs.py for the full definition)."""
from repro.configs.archs import GRANITE_MOE_1B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
