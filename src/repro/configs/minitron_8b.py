"""Assigned architecture config: minitron_8b (see archs.py for the full definition)."""
from repro.configs.archs import MINITRON_8B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
