"""Assigned architecture config: llama3_405b (see archs.py for the full definition)."""
from repro.configs.archs import LLAMA3_405B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
