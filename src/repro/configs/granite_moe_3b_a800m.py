"""Assigned architecture config: granite_moe_3b_a800m (see archs.py for the full definition)."""
from repro.configs.archs import GRANITE_MOE_3B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
