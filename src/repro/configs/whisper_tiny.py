"""Assigned architecture config: whisper_tiny (see archs.py for the full definition)."""
from repro.configs.archs import WHISPER_TINY as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
