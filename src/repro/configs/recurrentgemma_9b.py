"""Assigned architecture config: recurrentgemma_9b (see archs.py for the full definition)."""
from repro.configs.archs import RECURRENTGEMMA_9B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
