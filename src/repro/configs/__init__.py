from repro.configs.base import *  # noqa
from repro.configs.archs import ARCHS, smoke_config  # noqa
