"""Assigned architecture config: gemma2_27b (see archs.py for the full definition)."""
from repro.configs.archs import GEMMA2_27B as CONFIG
from repro.configs.archs import smoke_config

SMOKE = smoke_config(CONFIG.name)
