"""Provider-scale savings model (paper §6.4, Figure 5).

Inputs: Table 3 per-optimization applicable-core fractions + Table 2 pricing
+ the §6.4 conflict sets ({spot, harvest, non-preprovision} contend for spare
compute; {over, under, MA} for CPU frequency).

Method: the paper enables optimizations per workload "in decreasing order of
the owner benefits" and attributes the incremental saving of each step
(Figure 5 waterfall).  We reproduce that attribution under (a) an
*independence* assumption across opt applicabilities (with the natural
nesting harvest ⊂ spot, since harvest's requirements are a superset), and
(b) a one-parameter *overlap-calibrated* variant: a scalar ρ models the
positive correlation between applicabilities (flexible workloads qualify for
many opts at once, concentrating discounts on the same cores), fit by
bisection to the paper's 48.8% total.  The paper's own LP over pairwise
joints plays the same role; the joint data is not public.

Targets: 48.8% average cost saving, 27.6% carbon saving (both reproduced to
within 2pp by the independence baseline alone; see EXPERIMENTS.md).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Tuple

from repro.core.pricing import PRICING

# Table 3 "Cores (%)" column.
TABLE3_CORE_FRAC = {
    "auto_scaling": 0.331, "spot": 0.216, "harvest": 0.064,
    "overclocking": 0.413, "underclocking": 0.360,
    "non_preprovision": 0.688, "region_agnostic": 0.430,
    "oversubscription": 0.076, "rightsizing": 0.021,
    "ma_datacenters": 0.596,
}

# Figure 5 reported contributions (the named bars).
FIGURE5_CONTRIB = {
    "ma_datacenters": 0.183, "spot": 0.130, "region_agnostic": 0.060,
    "harvest": 0.058, "auto_scaling": 0.028, "overclocking": 0.013,
}
PAPER_TOTAL_SAVING = 0.488
PAPER_CARBON_SAVING = 0.276

# Decreasing owner benefit (Table 2) — the paper's enablement order.
BENEFIT_ORDER = ("harvest", "spot", "rightsizing", "ma_datacenters",
                 "region_agnostic", "auto_scaling", "oversubscription",
                 "overclocking", "non_preprovision", "underclocking")

_SPARE = ("harvest", "spot", "non_preprovision")
_FREQ = ("ma_datacenters", "overclocking", "underclocking")


def waterfall(fracs: Dict[str, float], value=None, rho: float = 0.0
              ) -> Tuple[float, Dict[str, float]]:
    """Sequential enablement in BENEFIT_ORDER.

    Returns (final expected multiplier, per-opt incremental contribution).
    ``value(o)`` maps an opt to its multiplier (price by default, carbon
    keep-fraction for the carbon variant).  ``rho`` shrinks each step's
    *newly reachable* core fraction by (1-rho) to model applicability
    overlap beyond the explicit conflict sets.
    """
    value = value or (lambda o: PRICING[o].price_multiplier)
    price = 1.0
    contrib: Dict[str, float] = {}
    spare_taken = 0.0       # fraction of cores already served by spare set
    freq_taken = 0.0
    for o in BENEFIT_ORDER:
        f = fracs[o]
        if o in _SPARE:
            # nesting harvest ⊂ spot; non-preprovision independent of both
            if o == "harvest":
                newly = f
            elif o == "spot":
                newly = max(f - spare_taken, 0.0)
            else:
                newly = f * (1.0 - spare_taken)
            spare_taken = min(1.0, spare_taken + newly)
        elif o in _FREQ:
            newly = f * (1.0 - freq_taken)
            freq_taken = min(1.0, freq_taken + newly)
        else:
            newly = f
        newly *= (1.0 - rho)
        new_price = price * (newly * value(o) + (1.0 - newly))
        contrib[o] = price - new_price
        price = new_price
    return price, contrib


def carbon_value(o: str) -> float:
    return 1.0 - PRICING[o].carbon_benefit


def fit_rho(target: float = PAPER_TOTAL_SAVING,
            fracs: Dict[str, float] = None) -> float:
    """Bisection on the single overlap parameter to match the paper total."""
    fracs = fracs or TABLE3_CORE_FRAC
    lo, hi = -0.5, 0.9
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        saving = 1.0 - waterfall(fracs, rho=mid)[0]
        # saving decreases in rho: overshoot -> rho too small -> raise lo
        if saving > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


# ---------------------------------------------------------------------------
# Live-fleet enablement model (sim/casestudies/e2e_savings.py)
#
# The dynamic reproduction samples per-workload optimization *enrollments*
# instead of attributing savings analytically: within each §6.4 conflict set
# the waterfall's "newly reachable" derivation turns Table 3's core
# fractions into mutually exclusive enrollment probabilities (a VM enrolls
# in at most one member, so conflicting optimizations are never co-billed),
# while the independent optimizations keep their raw fractions.  A single
# shrink parameter plays rho's role: it models applicability overlap beyond
# the conflict sets and is fit so the closed-form expected fleet saving
# equals the paper's 48.8% — the live scheduler run then has to *recover*
# that number through the billing meters.
# ---------------------------------------------------------------------------

def enablement_probs(fracs: Dict[str, float] = None,
                     shrink: float = 0.0) -> Dict[str, float]:
    """Per-workload enrollment probabilities matching Table 3 core
    fractions, exclusive within each conflict set (waterfall "newly"
    derivation), scaled by ``(1 - shrink)``."""
    fracs = fracs or TABLE3_CORE_FRAC
    p: Dict[str, float] = {}
    spare_taken = 0.0
    freq_taken = 0.0
    for o in BENEFIT_ORDER:
        f = fracs[o]
        if o in _SPARE:
            if o == "harvest":
                newly = f
            elif o == "spot":
                newly = max(f - spare_taken, 0.0)
            else:
                newly = f * (1.0 - spare_taken)
            spare_taken = min(1.0, spare_taken + newly)
        elif o in _FREQ:
            newly = f * (1.0 - freq_taken)
            freq_taken = min(1.0, freq_taken + newly)
        else:
            newly = f
        p[o] = newly * (1.0 - shrink)
    return p


def expected_fleet_saving(probs: Dict[str, float]) -> float:
    """Closed-form expected saving of a fleet sampled from
    ``enablement_probs``: conflict-set members are exclusive within a VM,
    groups independent across, prices stack multiplicatively
    (``pricing.combined_price`` on the sampled enrollment)."""
    from repro.core.pricing import CONFLICT_SETS
    total = 1.0
    in_conflict = set()
    for cs in CONFLICT_SETS:
        members = sorted(cs)
        in_conflict.update(members)
        e = sum(probs[o] * PRICING[o].price_multiplier for o in members)
        e += 1.0 - sum(probs[o] for o in members)
        total *= e
    for o in PRICING:
        if o not in in_conflict:
            total *= probs[o] * PRICING[o].price_multiplier + (1.0 - probs[o])
    return 1.0 - total


def fit_enablement_shrink(target: float = PAPER_TOTAL_SAVING,
                          fracs: Dict[str, float] = None) -> float:
    """Bisection on the shrink parameter so the expected fleet saving hits
    the paper total (mirrors ``fit_rho`` for the analytical waterfall)."""
    lo, hi = -0.5, 0.9
    for _ in range(60):
        mid = 0.5 * (lo + hi)
        if expected_fleet_saving(enablement_probs(fracs, shrink=mid)) \
                > target:
            lo = mid
        else:
            hi = mid
    return 0.5 * (lo + hi)


@dataclass
class ProviderScaleResult:
    saving_independence: float
    carbon_independence: float
    contrib_independence: Dict[str, float]
    rho: float
    saving_calibrated: float
    carbon_calibrated: float
    contrib_calibrated: Dict[str, float]


def evaluate() -> ProviderScaleResult:
    f = dict(TABLE3_CORE_FRAC)
    p0, c0 = waterfall(f)
    k0, _ = waterfall(f, value=carbon_value)
    rho = fit_rho()
    p1, c1 = waterfall(f, rho=rho)
    k1, _ = waterfall(f, value=carbon_value, rho=rho)
    return ProviderScaleResult(
        saving_independence=1.0 - p0, carbon_independence=1.0 - k0,
        contrib_independence=c0, rho=rho,
        saving_calibrated=1.0 - p1, carbon_calibrated=1.0 - k1,
        contrib_calibrated=c1)
