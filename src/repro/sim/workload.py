"""Survey-derived workload population (paper Table 1).

Core-usage-weighted marginal distributions for the six characteristics; a
seeded sampler draws synthetic workload populations whose (core-weighted)
marginals converge to Table 1 — verified by benchmark ``t1_survey``.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

# Table 1 (fraction of cores).
STATELESS = [("stateless", 0.455), ("partial", 0.174), ("stateful", 0.371)]
DEPLOY_TIME = [("strict", 0.285), ("not_strict", 0.715)]
AVAILABILITY = [(5.0, 0.024), (4.0, 0.345), (3.0, 0.580), (2.0, 0.039),
                (1.0, 0.005), (0.0, 0.004)]  # wait: five nines=2.4%? see note
# NOTE: paper row order: Five=2.4, Four=34.5, Three=58.0, Two=3.9, One=0.5,
# None=0.4 (sums to 99.7 due to rounding; renormalized at sample time).
PREEMPTIBILITY = [(0.0, 0.393), (10.0, 0.411), (30.0, 0.048), (50.0, 0.065),
                  (70.0, 0.003), (90.0, 0.018), (100.0, 0.061)]
DELAY = [("tolerant", 0.245), ("sensitive", 0.755)]
REGION = [("agnostic", 0.475), ("partial", 0.139), ("fixed", 0.386)]

CLASS_MIX = [("bigdata", 0.30), ("web", 0.34), ("realtime", 0.20),
             ("other", 0.16)]   # §6: three classes cover 84% of cores


@dataclass
class SimWorkload:
    name: str
    cls: str
    cores: float
    stateless: str
    deploy: str
    availability: float
    preemptibility: float
    delay: str
    region: str

    def hints(self) -> Dict:
        """WI deployment hints implied by the characteristics (§4)."""
        h: Dict = {}
        if self.stateless in ("stateless", "partial"):
            h["scale_out_in"] = True
            h["scale_up_down"] = True
        if self.deploy == "not_strict":
            h["deploy_time_ms"] = 300_000.0
        h["availability_nines"] = self.availability
        h["preemptibility_pct"] = self.preemptibility
        if self.delay == "tolerant":
            h["delay_tolerance_ms"] = 1_000.0
        if self.region == "agnostic":
            h["region_independent"] = True
        return h


def _draw(rng: random.Random, table: List[Tuple]):
    r = rng.random() * sum(w for _, w in table)
    acc = 0.0
    for v, w in table:
        acc += w
        if r <= acc:
            return v
    return table[-1][0]


def sample_population(n: int, seed: int = 0,
                      lognormal_cores: bool = True) -> List[SimWorkload]:
    """Synthetic population: marginals follow Table 1 *core-weighted*, so
    characteristics are drawn per core-mass unit (we approximate by drawing
    per workload and weighting later samples by cores drawn i.i.d.)."""
    rng = random.Random(seed)
    out = []
    for i in range(n):
        cores = (rng.lognormvariate(3.0, 1.2) if lognormal_cores
                 else 100.0)
        out.append(SimWorkload(
            name=f"wl{i}", cls=_draw(rng, CLASS_MIX), cores=cores,
            stateless=_draw(rng, STATELESS), deploy=_draw(rng, DEPLOY_TIME),
            availability=_draw(rng, AVAILABILITY),
            preemptibility=_draw(rng, PREEMPTIBILITY),
            delay=_draw(rng, DELAY), region=_draw(rng, REGION)))
    return out


def core_weighted_marginals(pop: List[SimWorkload]) -> Dict[str, Dict]:
    total = sum(w.cores for w in pop)
    out: Dict[str, Dict] = {}
    for attr in ("stateless", "deploy", "availability", "preemptibility",
                 "delay", "region"):
        d: Dict = {}
        for w in pop:
            k = getattr(w, attr)
            d[k] = d.get(k, 0.0) + w.cores / total
        out[attr] = d
    return out


TABLE1_TARGETS = {
    "stateless": dict(STATELESS), "deploy": dict(DEPLOY_TIME),
    "availability": dict(AVAILABILITY),
    "preemptibility": dict(PREEMPTIBILITY), "delay": dict(DELAY),
    "region": dict(REGION),
}
