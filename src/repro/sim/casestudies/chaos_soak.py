"""Scenario: the full WI loop under chaos — and every invariant still holds.

A diurnal fleet (stateless web frontends, stateful bigdata, an elastic
training tenant, and four deliberately *rogue* workloads) runs the usual
storm of spot-reclaim waves and power events, but this time:

  * every guest-facing channel is lossy: eviction notices, guest acks, and
    runtime hints are dropped / duplicated / delayed / reordered by a
    seeded ``FaultPlan`` through ``ChaosBus`` (the platform's own decision
    / eviction / failure topics stay transactional — the plan refuses to
    fault them);
  * servers and VMs hardware-crash *unannounced* (no notice, no event):
    the scheduler's repair loop detects them at its next tick, closes the
    books, publishes ``wi.sched.failures``, and agents request
    replacements with per-workload backoff;
  * four guests misbehave: one goes silent (never acks — the heartbeat
    lease expires and the ladder kill stands), one acks slower than any
    window, one hardware-crashes itself mid-checkpoint, one floods the
    hint channel (the local manager's rate limiter absorbs it);
  * the training tenant takes real emergency checkpoints through the real
    ``Checkpointer``; after the run one is corrupted on disk and recovery
    must fall back to the last *verified* generation, losing at most one
    checkpoint interval of steps.

Invariants asserted at the end of the soak (the PR's acceptance bars):

  * zero notice-window violations among notices the pipeline delivered;
  * the ``BillingMeter`` reconciles against the cluster's core-hour
    integral (crashes close meters at the crash instant — no phantom
    core-hours);
  * ``LifecycleObserver.reconcile(pipeline)`` is clean with ``crashed``
    outcomes counted, and every crash shows a finite detection latency
    and (for replaceable classes) a finite MTTR;
  * scale-out workloads converge back to at least their target replica
    counts once the chaos stops;
  * the trainer's lost work is bounded by its checkpoint interval even
    through the corrupt-checkpoint drill;
  * the cluster's incremental books survive (``assert_consistent``) — no
    double release, no capacity leak.
"""
from __future__ import annotations

import random
import tempfile
from typing import Dict, Optional

import numpy as np

from repro import obs
from repro.agents import (STATEFUL, STATELESS, AgentPolicy, AgentRuntime,
                          DiurnalProfile)
from repro.agents.trainer_agent import TrainerTenant
from repro.chaos import (ChaosBus, CrashInjector, FaultPlan,
                         install_guest_modes, lossy_guest_plan)
from repro.chaos import plan as CP
from repro.core.bus import Bus
from repro.core.global_manager import GlobalManager
from repro.core.pricing import BillingMeter
from repro.sched import Scheduler
from repro.sim.cluster import VM
from repro.sim.engine import Engine

N_SERVERS_PER_REGION = 24
CORES_PER_SERVER = 48
TICK_S = 5.0
PHASE_PERIOD_S = 300.0
STORM_WAVES = 5
WAVE_PERIOD_S = 120.0
WAVE_CORES = 150.0
POWER_EVENTS = 4
LEASE_S = 45.0
QUIET_TAIL_S = 300.0            # no new chaos in the last stretch: converge
HORIZON_S = 60.0 + STORM_WAVES * WAVE_PERIOD_S + 2 * QUIET_TAIL_S

N_WEB = 6
WEB_VMS = 12
N_BIGDATA = 4
BIGDATA_VMS = 8
ROGUE_VMS = 6
TRAIN_VMS = 6
TRAIN_STEP_S = 5.0
TRAIN_CKPT_EVERY = 20

DROP_P = 0.08
DUP_P = 0.05
DELAY_P = 0.05
REORDER_P = 0.04
CRASH_RATE_PER_S = 0.004        # expected ~1 background crash / 250 s

ROGUE_MODES = {
    "rogue-silent": CP.GUEST_NEVER_ACK,
    "rogue-slow": CP.GUEST_SLOW_ACK,
    "rogue-crash": CP.GUEST_CRASH_MID_CKPT,
    "rogue-spam": CP.GUEST_HINT_SPAM,
}


class SimCkptTrainer:
    """A trainer-shaped tenant backend exercising the *real*
    ``Checkpointer`` (crc-verified restore) without a real model: state is
    a small numpy tree advanced one deterministic step at a time, saved
    periodically and on every emergency checkpoint.  Implements the
    surface ``TrainerTenant`` requires (``step_once`` /
    ``resize_to_devices`` / ``set_throttled`` / ``emergency_checkpoint`` /
    ``ckpt.wait``) plus the same corrupt-checkpoint recovery walk as
    ``WITrainer._init_state``."""

    def __init__(self, ckpt_dir: str, ckpt_every: int = TRAIN_CKPT_EVERY,
                 min_devices: int = 2, n_params: int = 64):
        from repro.ckpt.checkpoint import Checkpointer
        # keep enough generations that an emergency-checkpoint burst can
        # never GC the last periodic save the corruption drill falls
        # back to
        self.ckpt = Checkpointer(ckpt_dir, keep=8)
        self.ckpt_every = ckpt_every
        self.min_devices = min_devices
        self.step = 0
        self.state = {"w": np.zeros(n_params, dtype=np.float64)}
        self.metrics_log: list = []
        self.events_log: list = []
        self.resizes = 0
        self.throttled = False
        self.recover()

    # -- recovery (mirrors WITrainer._init_state) ----------------------------
    def recover(self) -> Optional[int]:
        from repro.ckpt.checkpoint import CheckpointCorruptError
        for s in reversed(self.ckpt.committed_steps()):
            try:
                tree = self.ckpt.restore(s, {"w": self.state["w"]})
                self.state = {"w": np.asarray(tree["w"])}
                self.step = int(self.ckpt.metadata(s).get("step", s))
                return s
            except CheckpointCorruptError:
                self.events_log.append(
                    {"kind": "corrupt_checkpoint_skipped", "step": s})
        return None

    # -- TrainerTenant surface ----------------------------------------------
    def step_once(self) -> Dict:
        self.state["w"] = self.state["w"] + 1.0
        self.step += 1
        rec = {"step": self.step}
        self.metrics_log.append(rec)
        if self.step % self.ckpt_every == 0:
            self._save()
        return rec

    def resize_to_devices(self, devices) -> bool:
        if len(devices) < self.min_devices:
            return False
        self.resizes += 1
        return True

    def set_throttled(self, on: bool):
        self.throttled = bool(on)

    def emergency_checkpoint(self):
        self._save()
        self.events_log.append({"kind": "emergency_checkpoint",
                                "step": self.step})

    def _save(self):
        self.ckpt.save(self.step, {"w": self.state["w"]},
                       {"step": self.step})

    def corrupt_newest(self) -> Optional[int]:
        """Corrupt one leaf of the newest committed checkpoint on disk
        (the drill: a torn emergency checkpoint must not brick the job)."""
        newest = self.ckpt.latest_step()
        if newest is None:
            return None
        leaf = next((self.ckpt.root / f"step_{newest}").glob("*.npy"))
        leaf.write_bytes(b"torn write: not a numpy file")
        return newest


def build(seed: int = 0,
          n_servers_per_region: int = N_SERVERS_PER_REGION,
          vm_scale: float = 1.0,
          drop_p: float = DROP_P, dup_p: float = DUP_P,
          delay_p: float = DELAY_P, reorder_p: float = REORDER_P,
          ckpt_dir: Optional[str] = None):
    rng = random.Random(seed)
    engine = Engine()
    plan: FaultPlan = lossy_guest_plan(
        seed=seed, drop_p=drop_p, dup_p=dup_p, delay_p=delay_p,
        reorder_p=reorder_p, guest_modes=dict(ROGUE_MODES))
    bus = ChaosBus(Bus(clock=engine.clock), plan, engine)
    gm = GlobalManager(bus=bus, clock=engine.clock,
                       hint_rate_per_s=1e6, hint_burst=1e6)
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(gm=gm, engine=engine, default_notice_s=30.0,
                  metrics=registry)
    s.lifecycle = obs.LifecycleObserver(gm.bus, registry=registry)
    # the meter exists before the first placement so it observes every
    # decision record; crashes close meters at the crash instant through
    # the cluster's kill listeners
    meter = BillingMeter(gm, s.cluster)
    for r in ("region-0", "region-green"):
        for i in range(n_servers_per_region):
            s.cluster.add_server(f"{r}/s{i}", CORES_PER_SERVER, region=r)

    policies: Dict[str, AgentPolicy] = {}

    for i in range(N_WEB):
        w = f"web-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "scale_up_down": True,
            "preemptibility_pct": 70.0, "availability_nines": 3.0,
            "delay_tolerance_ms": 5_000.0})
        policies[w] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)

    diurnal_bigdata = DiurnalProfile(
        peak_hints={"delay_tolerance_ms": 5_000.0,
                    "preemptibility_pct": 20.0},
        offpeak_hints={"delay_tolerance_ms": 120_000.0,
                       "preemptibility_pct": 80.0})
    for i in range(N_BIGDATA):
        w = f"bigdata-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "scale_up_down": True,
            "preemptibility_pct": 60.0, "availability_nines": 2.0,
            "delay_tolerance_ms": 30_000.0,
            "x-eviction-notice-s": 120.0})
        policies[w] = AgentPolicy(statefulness=STATEFUL, state_gb=8.0,
                                  ckpt_gbps=0.5, diurnal=diurnal_bigdata)

    # the rogues: stateful (except the spammer — it evicts honestly, so
    # the stateless-never-loses-work bar must keep holding for it)
    for w in ROGUE_MODES:
        # most-preemptible class: the first reclaim wave reaches them, so
        # every misbehaving-guest drill actually fires
        s.gm.register_workload(w, {
            "scale_out_in": False, "scale_up_down": True,
            "preemptibility_pct": 90.0, "availability_nines": 2.0})
        if w == "rogue-spam":
            policies[w] = AgentPolicy(statefulness=STATELESS,
                                      scale_out_in=True)
        else:
            # small state: the mid-checkpoint self-crash (10 s write) fires
            # well before the 30 s deadline
            policies[w] = AgentPolicy(statefulness=STATEFUL, state_gb=2.0,
                                      ckpt_gbps=0.2)
    install_guest_modes(plan, policies)

    # the elastic training tenant: real Checkpointer, VM->device mapping
    tenant = TrainerTenant("train-0", devices=[f"d{i}" for i in range(16)],
                           devices_per_vm=2, min_dp=2,
                           emergency_ckpt_s=4.0)
    s.gm.register_workload("train-0", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 80.0, "delay_tolerance_ms": 60_000.0})
    policies["train-0"] = tenant.policy(state_gb=2.0, ckpt_gbps=0.5)

    vm = 0
    first_ids: Dict[str, str] = {}
    for i in range(N_WEB):
        for _ in range(max(1, round(WEB_VMS * vm_scale))):
            first_ids.setdefault("web", f"vm{vm}")
            s.submit(VM(f"vm{vm}", f"web-{i}", "", 4,
                        util_p95=rng.uniform(0.2, 0.6), spot=True))
            vm += 1
    for i in range(N_BIGDATA):
        for _ in range(max(1, round(BIGDATA_VMS * vm_scale))):
            s.submit(VM(f"vm{vm}", f"bigdata-{i}", "", 8,
                        util_p95=rng.uniform(0.3, 0.8), spot=True))
            vm += 1
    for w in ROGUE_MODES:
        for _ in range(max(1, round(ROGUE_VMS * vm_scale))):
            s.submit(VM(f"vm{vm}", w, "", 4,
                        util_p95=rng.uniform(0.3, 0.7), spot=True))
            vm += 1
    for _ in range(TRAIN_VMS):
        first_ids.setdefault("train", f"vm{vm}")
        s.submit(VM(f"vm{vm}", "train-0", "", 8,
                    util_p95=rng.uniform(0.5, 0.8), spot=True))
        vm += 1
    s.schedule_pending()

    # rate-limit the guest hint channel tightly enough that the spammer's
    # bursts actually hit the limiter (honest guests write far below it)
    rt = AgentRuntime(s, policies=policies,
                      vm_hint_rate_per_s=1.0, vm_hint_burst=10.0)

    trainer = SimCkptTrainer(
        ckpt_dir or tempfile.mkdtemp(prefix="wi-chaos-ckpt-"))
    tenant.attach_trainer(trainer)

    # the unannounced-failure schedule: one targeted web crash, one
    # targeted trainer-VM crash (both before the first reclaim wave, while
    # those exact VMs are still alive), one whole-server failure — plus
    # seeded random background crashes armed in run().  Crash instants sit
    # off the 5 s tick grid so detection latency is measured honestly.
    plan.vm_crashes.extend([(33.7, first_ids["web"]),
                            (48.3, first_ids["train"])])
    plan.server_crashes.append((421.9, "region-0/s0"))
    crasher = CrashInjector(s.cluster, engine, plan)
    return s, rt, meter, tenant, trainer, plan, crasher


def run(seed: int = 0,
        n_servers_per_region: int = N_SERVERS_PER_REGION,
        vm_scale: float = 1.0,
        drop_p: float = DROP_P, dup_p: float = DUP_P,
        delay_p: float = DELAY_P, reorder_p: float = REORDER_P,
        crash_rate_per_s: float = CRASH_RATE_PER_S) -> Dict[str, float]:
    rng = random.Random(seed + 1)
    with tempfile.TemporaryDirectory(prefix="wi-chaos-") as ckpt_dir:
        s, rt, meter, tenant, trainer, plan, crasher = build(
            seed, n_servers_per_region, vm_scale,
            drop_p, dup_p, delay_p, reorder_p, ckpt_dir=ckpt_dir)
        horizon = HORIZON_S
        initial = {w: sum(1 for v in s.cluster.vms.values()
                          if v.workload.startswith(w) and v.alive)
                   for w in ("web-", "train-")}

        def flip_phase():
            rt.set_phase("offpeak" if rt.phase == "peak" else "peak")
        s.engine.every(PHASE_PERIOD_S, flip_phase, horizon)

        for w in range(STORM_WAVES):
            region = "region-0" if w % 2 == 0 else "region-green"
            s.engine.at(61.0 + w * WAVE_PERIOD_S,
                        lambda r=region: s.capacity_crunch(r, WAVE_CORES))
        servers = list(s.cluster.servers)
        for i in range(POWER_EVENTS):
            srv = rng.choice(servers)
            s.engine.at(93.0 + i * 110.0,
                        lambda sv=srv: s.power_event(sv, shed_frac=0.4))

        # unannounced failures: the targeted schedule plus background
        # crashes, all stopping before the quiet tail so the fleet can
        # converge back
        crasher.arm()
        if crash_rate_per_s > 0:
            crasher.arm_random_vm_crashes(crash_rate_per_s,
                                          until=horizon - QUIET_TAIL_S)

        # heartbeat leases: the silent rogue is detected, redelivery stops
        rt.enable_leases(LEASE_S, horizon, check_period_s=TICK_S)

        # the training loop interleaved with the platform clock
        def train_step():
            tenant.apply_pending()
            if tenant.paused or trainer is not tenant.trainer:
                return
            trainer.step_once()
            if trainer.step % trainer.ckpt_every == 0:
                tenant.note_durable()
        s.engine.every(TRAIN_STEP_S, train_step, horizon)

        s.start(TICK_S, horizon)
        s.run_until(horizon)

        # ---- the invariant wall -------------------------------------------
        ev = s.evictor
        life = s.lifecycle.summary()
        recon = s.lifecycle.reconcile(ev)
        assert recon["ok"], recon["diffs"]
        violations = ev.violations()
        assert not violations, [vars(t) for t in violations]
        assert life["violations"] == 0

        # books: metered core-hours == the cluster's own integral, crashes
        # included (meters closed at the crash instant)
        bill = meter.reconcile(horizon)
        assert bill["abs_diff"] < max(1e-4, 1e-9 * bill[
            "cluster_core_hours"]), bill

        # every queued crash was repaired and published
        assert s.stats.get("crashed_vms", 0) == s.cluster.crashes_total
        assert life["crashed_vms"] == s.cluster.crashes_total
        assert s.cluster.crashes_total > 0, "chaos run injected no crashes"
        detect = life["crash_detect_s"]
        assert detect["count"] == s.cluster.crashes_total
        assert 0.0 < detect["max"] <= TICK_S + 1e-6, detect
        mttr = life["mttr_s"]
        assert mttr.get("count", 0) >= 1, "no crash was ever repaired"

        # convergence: scale-out classes are back to >= target replicas
        alive_by: Dict[str, int] = {}
        for v in s.cluster.vms.values():
            if v.alive and v.server:
                key = v.workload.split("-")[0]
                alive_by[key] = alive_by.get(key, 0) + 1
        assert alive_by.get("web", 0) >= initial["web-"], \
            (alive_by.get("web", 0), initial["web-"])
        assert alive_by.get("train", 0) >= initial["train-"], \
            (alive_by.get("train", 0), initial["train-"])

        m = rt.telemetry()
        # the stateless bar holds even under chaos: a noticed stateless VM
        # is never killed without its consent having been *sent* (lost ack
        # records are re-sent on redelivered notices)
        assert m.get("stateless_killed_without_ack", 0.0) == 0.0
        # every misbehaving-guest drill engaged: the silent rogue was
        # detected (lease) and ignored at least one notice, the
        # mid-checkpoint rogue hardware-crashed itself, and the spammer
        # was rate-limited (some hints through, most rejected)
        assert ev.stats.get("silent_guests", 0) >= 1
        assert m.get("rogue_notices_ignored", 0) >= 1
        assert m.get("rogue_self_crashes", 0) >= 1
        assert 0 < m.get("spam_hints_accepted", 0) < m.get(
            "spam_hints_sent", 0)
        # the lossy channel was genuinely lossy and the ladder covered it
        bus_stats = dict(s.gm.bus.stats)
        assert bus_stats.get("dropped", 0) > 0
        assert ev.stats.get("reminders", 0) > 0

        # no double release / capacity leak anywhere in the books
        s.cluster.assert_consistent()

        # ---- corrupt-checkpoint drill -------------------------------------
        # make the newest checkpoint durable at the final step, corrupt it,
        # and recover: the fallback must land on the last *verified*
        # generation, losing at most one checkpoint interval
        steps_total = trainer.step
        trainer.emergency_checkpoint()
        corrupted_step = trainer.corrupt_newest()
        recovered = SimCkptTrainer(ckpt_dir,
                                   ckpt_every=trainer.ckpt_every)
        skipped = [e for e in recovered.events_log
                   if e["kind"] == "corrupt_checkpoint_skipped"]
        assert corrupted_step is not None and skipped, \
            "corruption drill never engaged"
        lost_steps = steps_total - recovered.step
        assert 0 < lost_steps <= trainer.ckpt_every, \
            (steps_total, recovered.step, trainer.ckpt_every)

        tm = tenant.telemetry()
        return {
            "horizon_s": horizon,
            "placed": s.stats.get("placed", 0),
            "violations": int(life["violations"]),
            "notices": int(life["notices"]),
            "killed": int(life["killed"]),
            "early_released": int(life["early_released"]),
            "already_gone": int(life["already_gone"]),
            "cancelled": int(life["cancelled"]),
            "crashed_tickets": int(life["crashed"]),
            "crashed_vms": int(life["crashed_vms"]),
            "crash_detect_p95_s": detect.get("p95", 0.0),
            "crash_detect_max_s": detect["max"],
            "mttr_count": int(mttr.get("count", 0)),
            "mttr_p95_s": mttr.get("p95", 0.0),
            "mttr_max_s": mttr.get("max", 0.0),
            "reminders": ev.stats.get("reminders", 0),
            "acks_deduped": ev.stats.get("acks_deduped", 0),
            "acks_stale_generation": ev.stats.get(
                "acks_stale_generation", 0),
            "silent_guests": ev.stats.get("silent_guests", 0),
            "leases_expired": m.get("leases_expired", 0.0),
            "bus_dropped": bus_stats.get("dropped", 0),
            "bus_duplicated": bus_stats.get("duplicated", 0),
            "bus_delayed": bus_stats.get("delayed", 0),
            "bus_reordered": bus_stats.get("reordered", 0),
            "spam_hints_sent": m.get("spam_hints_sent", 0.0),
            "spam_hints_accepted": m.get("spam_hints_accepted", 0.0),
            "rogue_notices_ignored": m.get("rogue_notices_ignored", 0.0),
            "rogue_self_crashes": m.get("rogue_self_crashes", 0.0),
            "crash_replacements_requested": m.get(
                "crash_replacements_requested", 0.0),
            "replacements_placed": m.get("replacements_placed", 0.0),
            "lost_work_s": m.get("lost_work_s", 0.0),
            "lost_work_s_crash": m.get("lost_work_s_crash", 0.0),
            "stateless_killed_without_ack": m.get(
                "stateless_killed_without_ack", 0.0),
            "alive_web": alive_by.get("web", 0),
            "alive_train": alive_by.get("train", 0),
            "trainer_steps": steps_total,
            "trainer_emergency_ckpts": tm.get("emergency_checkpoints", 0.0),
            "trainer_resizes": trainer.resizes,
            "trainer_lost_steps": lost_steps,
            "trainer_ckpt_every": trainer.ckpt_every,
            "trainer_corrupt_skipped": len(skipped),
            "metered_core_hours": bill["metered_core_hours"],
            "cluster_core_hours": bill["cluster_core_hours"],
            "billing_abs_diff": bill["abs_diff"],
            "obs_reconcile_ok": recon["ok"],
            "obs_notice_to_ack_p100_s": life["notice_to_ack_s"].get("p100"),
        }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
