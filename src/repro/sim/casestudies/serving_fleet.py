"""Scenario: the serving fleet as a real scheduler tenant under open-loop
traffic.

The last seed workload joins the bidirectional loop here: a fleet of
synthetic-mode ``ServingEngine`` replicas (one per placed VM, all on the
sim clock) runs as one workload of the live platform scheduler,
co-tenanted with the background fleet classes the savings scenarios use
(stateless scale-out web frontends, harvest-elastic web, stateful batch).
A seeded wrk2-style open-loop generator (``sim.traffic``) drives a diurnal
day of requests with a flash-crowd spike; because arrivals never wait on
completions, every queueing episode the platform causes lands in the
latency histograms instead of silently thinning the load:

  * **spot/harvest reclaim** — two capacity-crunch waves chew through the
    harvest web tier and into the serving replicas.  A noticed replica
    stops admitting immediately, reroutes its queued requests, finishes
    its in-flight decodes, and acks well inside the hinted 60 s window —
    early release with zero lost requests;
  * **power events** — an MA-datacenter power event on a serving server
    throttles the fleet (availability 2.5 ≤ 3): decode slots halve
    (compute shed, demand untouched); the next policy pass's
    ``OVERCLOCK_OFFER`` restores them;
  * **harvest growth** — ``SCALE_UP_OFFER`` grants convert spare cores
    into extra decode slots;
  * **autoscaling** — the leader agent publishes ``x-autoscale-pressure``
    (queue depth + p99 token latency, not util) every 15 s;
    ``AutoScalingPolicy`` consumes it: the diurnal trough drains surplus
    replicas through the *consented* eviction path, the midday ramp and
    the spike clone replicas back out.

Invariants (asserted by the ``serving_fleet`` benchmark and the tenant
tests): zero notice-window violations, ≥1 serving early release via a
guest ack, zero lost requests, goodput ≥ 95%, e2e p99 under the committed
bound, and the bus-derived lifecycle books reconcile with the pipeline.

Pure python (no jax): run as ``python -m
repro.sim.casestudies.serving_fleet``.  Sizes honor
``SERVING_FLEET_SERVERS`` / ``SERVING_FLEET_DAY_S`` /
``SERVING_FLEET_PEAK_RPS``.
"""
from __future__ import annotations

import json
import os
import random
from typing import Dict

from repro import obs
from repro.agents import (STATEFUL, STATELESS, AgentPolicy, AgentRuntime,
                          ServingTenant)
from repro.sched import Scheduler
from repro.serve.engine import ServingEngine
from repro.sim.cluster import VM
from repro.sim.traffic import OpenLoopTraffic, diurnal_rate, with_spike

DAY_S = 1200.0                  # one diurnal period == the sim day
TAIL_S = 90.0                   # post-horizon drain window
STEP_S = 0.25                   # decode pump cadence (sim s per token)
TICK_S = 15.0
POLICY_PERIOD_S = 45.0
HINT_PERIOD_S = 15.0            # autoscale-pressure publish cadence
N_SERVERS = 12
CORES_PER_SERVER = 48.0

WORKLOAD = "svc"
N_SERVE_VMS = 4
SERVE_VM_CORES = 8.0
SLOTS_PER_VM = 4
MAX_LEN = 64
SERVE_NOTICE_S = 60.0
# modeled drain seconds per remaining decode step: deliberately above the
# pump cadence so the in-flight batch always finishes before the ack fires
TOKEN_TIME_S = STEP_S * 1.6
P99_TARGET_S = 5.0              # token-latency target feeding the pressure
P99_BOUND_S = 30.0              # committed e2e bound (benchmark + CI)

BASE_RPS = 2.0
PEAK_RPS = 5.0
SPIKE_MULT = 2.5
SPIKE_DUR_S = 60.0

N_WEBH_VMS = 6                  # harvest web: the pre-serving reclaim tier
N_WEB_WORKLOADS = 3
N_WEB_VMS = 8
N_BATCH_WORKLOADS = 2
N_BATCH_VMS = 6

# wave sizes mirror ``ai_training``: the harvest web tier (lowest keep) is
# reclaimed first, then the waves bite into the serving replicas
WAVE1_CORES = N_WEBH_VMS * 4.0 + 2.0                    # 1 serving VM
WAVE2_CORES = N_WEBH_VMS * 4.0 + SERVE_VM_CORES + 2.0   # 2 serving VMs


def _event_t(frac: float, horizon: float) -> float:
    """An event instant just after a tick, so replacement placements wait
    for the next tick and the drain window is visible in the histograms."""
    return (int(frac * horizon) // int(TICK_S)) * TICK_S + 2.0


def build(seed: int, n_servers: int, day_s: float, peak_rps: float):
    rng = random.Random(seed)
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(default_notice_s=30.0, policy_period_s=POLICY_PERIOD_S,
                  metrics=registry)
    s.lifecycle = obs.LifecycleObserver(s.gm.bus, registry=registry)
    for i in range(n_servers):
        s.cluster.add_server(f"region-0/s{i}", CORES_PER_SERVER,
                             region="region-0")

    policies: Dict[str, AgentPolicy] = {}

    # harvest web: stateless scale-out, the tier every wave reclaims first
    s.gm.register_workload("webh", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 90.0, "availability_nines": 3.0,
        "delay_tolerance_ms": 5_000.0})
    policies["webh"] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)
    vm_id = 0
    for _ in range(N_WEBH_VMS):
        s.submit(VM(f"vm{vm_id}", "webh", "", 4.0,
                    util_p95=rng.uniform(0.30, 0.55), spot=True,
                    harvest=True))
        vm_id += 1

    # plain spot web: stateless scale-out; power events evict them
    for i in range(N_WEB_WORKLOADS):
        w = f"web-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "preemptibility_pct": 90.0,
            "availability_nines": 3.5, "delay_tolerance_ms": 5_000.0})
        policies[w] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)
        for _ in range(N_WEB_VMS):
            s.submit(VM(f"vm{vm_id}", w, "", 4.0,
                        util_p95=rng.uniform(0.30, 0.55), spot=True))
            vm_id += 1

    # stateful batch: background load that checkpoints-then-drains
    for i in range(N_BATCH_WORKLOADS):
        w = f"batch-{i}"
        s.gm.register_workload(w, {
            "preemptibility_pct": 45.0, "availability_nines": 2.5,
            "delay_tolerance_ms": 30_000.0, "x-eviction-notice-s": 120.0})
        policies[w] = AgentPolicy(statefulness=STATEFUL,
                                  state_gb=8.0 if i % 2 == 0 else 30.0,
                                  ckpt_gbps=0.2)
        for _ in range(N_BATCH_VMS):
            s.submit(VM(f"vm{vm_id}", w, "", 8.0,
                        util_p95=rng.uniform(0.2, 0.8), spot=True))
            vm_id += 1

    s.schedule_pending()                # the background fleet lands first

    # the serving deployment: latency-critical (availability 2.5 keeps
    # power events in throttle territory), harvest-elastic, consenting to
    # scale-out/in — and a hinted 60 s eviction notice its drains honor
    s.gm.register_workload(WORKLOAD, {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 80.0, "availability_nines": 2.5,
        "delay_tolerance_ms": 1_000.0,
        "x-eviction-notice-s": SERVE_NOTICE_S})

    def engine_factory(vm_id: str, slots: int) -> ServingEngine:
        return ServingEngine(None, None, None, batch_slots=slots,
                             max_len=MAX_LEN, now=s.engine.clock,
                             registry=registry, name=vm_id,
                             on_complete=lambda r: tenant._request_done(r))

    tenant = ServingTenant(WORKLOAD, engine_factory,
                           slots_per_vm=SLOTS_PER_VM,
                           token_time_s=TOKEN_TIME_S,
                           p99_target_s=P99_TARGET_S)
    policies[WORKLOAD] = tenant.policy()
    for i in range(N_SERVE_VMS):
        s.submit(VM(f"svc{i}", WORKLOAD, "", SERVE_VM_CORES, util_p95=0.5,
                    spot=True, harvest=True))
    s.schedule_pending()                # the replicas land on the spare
    runtime = AgentRuntime(s, policies=policies)    # adopts the replicas

    rate = with_spike(
        diurnal_rate(BASE_RPS, peak_rps, day_s),
        at_s=0.7 * day_s, dur_s=SPIKE_DUR_S, mult=SPIKE_MULT)
    traffic = OpenLoopTraffic(s.engine, tenant.submit, rate, day_s,
                              seed=seed, prompt_len=(2, 8),
                              max_new=(4, 16), registry=registry)
    tenant.completion_sinks.append(traffic.observe_completion)
    return s, runtime, tenant, traffic, registry


def run(seed: int = 0, n_servers: int = N_SERVERS, day_s: float = DAY_S,
        peak_rps: float = PEAK_RPS) -> Dict:
    s, runtime, tenant, traffic, registry = build(seed, n_servers, day_s,
                                                  peak_rps)
    horizon = day_s

    for frac, cores in ((0.3, WAVE1_CORES), (0.6, WAVE2_CORES)):
        s.engine.at(_event_t(frac, horizon),
                    lambda c=cores: s.capacity_crunch("region-0", c))

    def power_on_replica():
        lead = next((v for v in tenant._order
                     if s.cluster.vms.get(v) is not None
                     and s.cluster.vms[v].server), None)
        if lead is not None:
            s.power_event(s.cluster.vms[lead].server, shed_frac=0.5)
    s.engine.at(_event_t(0.45, horizon), power_on_replica)

    # the decode pump: every replica (draining ones included — their
    # in-flight batch must finish for the early release to be honest)
    # advances one token per cadence; past the horizon it keeps running
    # through the tail so the last arrivals complete
    s.engine.every(STEP_S, tenant.step_all, until=horizon + TAIL_S)
    # the leader's autoscale signal, refreshed well inside a policy period
    s.engine.every(HINT_PERIOD_S, tenant.publish_autoscale_hint,
                   until=horizon)

    # ticks must cover the replacement horizon (placements only happen on
    # a tick); traffic arms its own arrival chain on the same engine
    s.start(TICK_S, 4.0 * horizon)
    traffic.start()
    s.run_until(horizon + TAIL_S)

    ev = s.evictor
    slog = [t for t in ev.log if t.workload == WORKLOAD]
    early_all = [t for t in ev.log if t.outcome == "early_released"]
    tm = tenant.telemetry()
    rm = runtime.telemetry()
    ts = traffic.summary()
    tok = registry.histogram("wi_serving_token_latency_s").summary()
    life = s.lifecycle.summary()
    recon = s.lifecycle.reconcile(ev)
    # the bus-derived lifecycle books must agree with the pipeline's own
    assert recon["ok"], recon["diffs"]
    assert life["early_released"] == len(early_all)
    assert life["violations"] == len(ev.violations())
    scale_outs = sum(1 for v in s.cluster.vms
                     if v.startswith(f"{WORKLOAD}.as"))
    out = {
        "waves": s.stats.get("capacity_crunches", 0),
        "violations": int(life["violations"]),
        "serving_early_releases":
            sum(1 for t in slog if t.outcome == "early_released"),
        "serving_ladder_kills":
            sum(1 for t in slog if t.outcome == "killed"),
        "fleet_early_releases": len(early_all) - sum(
            1 for t in slog if t.outcome == "early_released"),
        "offered": ts["offered"],
        "completed": ts["completed"],
        "goodput_frac": ts["goodput_frac"],
        "goodput_rps": ts["completed"] / horizon,
        "e2e_p50_s": ts["e2e_p50_s"],
        "e2e_p99_s": ts["e2e_p99_s"],
        "ttft_p99_s": ts["ttft_p99_s"],
        "token_p50_s": tok.get("p50", float("nan")),
        "token_p99_s": tok.get("p99", float("nan")),
        "p99_bound_s": P99_BOUND_S,
        "requests_lost": tm.get("requests_lost", 0.0),
        "requests_rerouted": tm.get("requests_rerouted", 0.0),
        "requests_overflowed": tm.get("requests_overflowed", 0.0),
        "drains": tm.get("drains", 0.0),
        "throttle_notices": tm.get("throttle_notices", 0.0),
        "restores": tm.get("restores", 0.0),
        "harvest_slots_granted": tm.get("harvest_slots_granted", 0.0),
        "ack_margin_min_s": tm.get("ack_margin_min_s", float("nan")),
        "scale_outs": scale_outs,
        "pressure_signals":
            s.policies["auto_scaling"].stats.get("pressure_signals", 0),
        "replicas_adopted": tm.get("replicas_adopted", 0.0),
        "replicas_final": len(tenant._order),
        "replacements_placed": rm.get("replacements_placed", 0.0),
        "obs_violations": int(life["violations"]),
        "obs_reconcile_ok": recon["ok"],
        "obs_max_notice_s": life["max_notice_s"],
        "obs_notice_to_ack_p100_s": life["notice_to_ack_s"].get("p100"),
        "obs_acks_observed": life["notice_to_ack_s"].get("count", 0),
    }
    s.gm.close()        # scenario teardown: release WAL/segment handles
    return out


if __name__ == "__main__":
    result = run(
        seed=0,
        n_servers=int(os.environ.get("SERVING_FLEET_SERVERS", N_SERVERS)),
        day_s=float(os.environ.get("SERVING_FLEET_DAY_S", DAY_S)),
        peak_rps=float(os.environ.get("SERVING_FLEET_PEAK_RPS", PEAK_RPS)))
    for k, v in result.items():
        print(f"{k}: {v}")
    print("RESULT " + json.dumps(result))
