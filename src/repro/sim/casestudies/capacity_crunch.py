"""Scenario: capacity crunch — admission control, defragmentation, reclaim.

Region-0 is filled to ~90% with a mix of region-fixed on-demand VMs,
region-*agnostic* flexible services, and a spot pool.  Then a surge of
region-fixed on-demand VMs arrives that cannot possibly fit.  The
scheduler's crunch pipeline has to make room in priority order:

  1. admission control first rejects the overflow (no silent overcommit);
  2. defragmentation migrates region-agnostic VMs to the other region
     (they are indifferent — that is what the hint *means*), freeing cores
     without hurting anyone;
  3. what is still missing comes from spot reclaim — evictions that pay
     their full hinted notice window before the kill;
  4. after the notices mature, the surge is re-scheduled and admitted.

Returns enough metrics for tests to pin the behavior: surge placement
before/after, migrations, evictions, notice violations (must be 0), and
that no server ever exceeds its commitment cap.
"""
from __future__ import annotations

import random
from typing import Dict

from repro.sched import Scheduler
from repro.sim.cluster import VM

N_SERVERS = 40
CORES = 32
SURGE_VMS = 30
SURGE_CORES = 16.0
NOTICE_S = 60.0


def build(seed: int = 0) -> Scheduler:
    rng = random.Random(seed)
    s = Scheduler(default_notice_s=30.0)
    # home region is (initially) the cheap one, so region-agnostic VMs start
    # there and defragmentation has real work during the crunch
    s.cluster.regions["region-0"].price = 0.70
    for r in ("region-0", "region-green"):
        for i in range(N_SERVERS):
            s.cluster.add_server(f"{r}/s{i}", CORES, region=r)

    s.gm.register_workload("fixed-svc", {"availability_nines": 3.0})
    s.gm.register_workload("flex-svc", {
        "scale_out_in": True, "scale_up_down": True,
        "region_independent": True, "availability_nines": 3.0,
        "delay_tolerance_ms": 5_000.0})
    s.gm.register_workload("spot-pool", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "delay_tolerance_ms": 60_000.0, "x-eviction-notice-s": NOTICE_S})
    s.gm.register_workload("surge", {"availability_nines": 3.0})

    vm = 0
    for _ in range(60):                 # 480 cores, region-fixed
        s.submit(VM(f"vm{vm}", "fixed-svc", "", 8,
                    util_p95=rng.uniform(0.5, 0.9)))
        vm += 1
    for _ in range(30):                 # 240 cores, migratable
        s.submit(VM(f"vm{vm}", "flex-svc", "", 8,
                    util_p95=rng.uniform(0.3, 0.7)))
        vm += 1
    for _ in range(50):                 # 400 cores, evictable
        s.submit(VM(f"vm{vm}", "spot-pool", "", 8,
                    util_p95=rng.uniform(0.1, 0.5), spot=True))
        vm += 1
    s.schedule_pending()
    return s


def run(seed: int = 0) -> Dict[str, float]:
    s = build(seed)
    # flex VMs prefer region-green (cheaper) at placement time already, so
    # pin the initial state: what matters is region-0's fill level
    region0_used = sum(s.admission.nominal[sid]
                       for sid in s.cluster.servers_in_region("region-0"))

    for i in range(SURGE_VMS):
        s.submit(VM(f"surge{i}", "surge", "", SURGE_CORES, util_p95=0.8))
    before = [d for d in s.schedule_pending()]
    placed_before = sum(1 for d in before if d.placed)
    shortfall = sum(SURGE_CORES for d in before if not d.placed)

    crunch = s.capacity_crunch("region-0", shortfall) if shortfall else \
        {"freed_cores": 0.0, "evictions": 0}
    s.run_until(s.engine.clock.t + NOTICE_S + 1.0)     # notices mature
    after = s.schedule_pending()
    placed_after = placed_before + sum(1 for d in after if d.placed)

    # hard invariant: no server over its commitment cap
    overcommitted = [
        sid for sid, srv in s.cluster.servers.items()
        if s.admission.nominal[sid] > srv.cores * s.admission.oversub_ratio
        + 1e-6]
    return {
        "region0_used_cores_initial": region0_used,
        "surge_vms": SURGE_VMS,
        "placed_before_crunch": placed_before,
        "placed_after_crunch": placed_after,
        "defrag_migrations": s.stats["defrag_migrations"],
        "evictions": crunch["evictions"],
        "eviction_violations": len(s.evictor.violations()),
        "min_lead_s": s.evictor.min_lead_time_s(),
        "admission_rejections": sum(
            v for k, v in s.admission.stats.items()
            if k.startswith("rejected_")),
        "overcommitted_servers": len(overcommitted),
        "pending_final": len(s.cluster.pending),
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
