"""Case study §6.1: Big-data analytics (Harvest-Hadoop on WI), Figure 4.

Setup mirrors the paper: 20-node cluster — 5 management VMs (4 cores) on
Regular, 15 worker VMs (8 cores); a 5-hour trace of 100 MapReduce jobs
(down-sampled production trace in the paper; seeded synthetic here with the
same shape: heavy-tailed job sizes, job priorities).

Scenarios (Figure 4):
  regular        — Regular worker VMs (baseline = 1.0x perf, 100% cost)
  autoscale      — Regular + auto-scaling (pay for active workers)
  wi_deploy      — WI deployment hints: Auto-scaling + Spot + Harvest workers
  wi_full        — + runtime preemptibility hints every second (YARN
                   heartbeat): evictions target the emptiest workers, and
                   critical workers unmark preemptibility (>30 s jobs)

Paper results to reproduce: wi_deploy ~2.1x median slowdown, -92.6% cost;
runtime hints cut the slowdown by ~21% (to ~1.7x) and cost a further
~13.5%; full WI ~93.5% cost reduction (worker cost, management constant).
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List

from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.local_manager import LocalManager
from repro.core.optimizations import HarvestManager, SpotManager
from repro.core.pricing import combined_price

N_WORKERS = 15
CORES_PER_WORKER = 8
TRACE_HOURS = 5.0
N_JOBS = 100
DT = 10.0 / 3600.0                  # 10-second simulation tick, in hours

# Physical parameters (calibrated once against Figure 4's operating point;
# see EXPERIMENTS.md — the paper's production trace is not public):
CAP_MEAN = 0.46          # mean harvestable fraction of nominal worker cores
PRICE_MIX = 0.135        # Spot/Harvest worker price mix (between .09 and .15)
LOSS_DEPLOY = 0.7        # work-loss factor on blind eviction
LOSS_FULL = 0.1          # work-loss factor when runtime hints pick victims
EVICT_MEAN_H = 0.35      # mean time between spot reclaim events (hours)
WARM_FLOOR = 0.15        # autoscaler keeps this fraction of workers warm


@dataclass
class Job:
    name: str
    arrival_h: float
    work_core_h: float
    priority: int
    remaining: float = 0.0
    started_h: float = -1.0
    finished_h: float = -1.0
    lost_work: float = 0.0

    def __post_init__(self):
        self.remaining = self.work_core_h


def make_trace(seed=0) -> List[Job]:
    rng = random.Random(seed)
    jobs = []
    for i in range(N_JOBS):
        arrival = rng.uniform(0.0, TRACE_HOURS * 0.8)
        work = min(rng.lognormvariate(0.2, 1.0), 40.0)      # core-hours
        jobs.append(Job(f"j{i}", arrival, work, rng.randint(0, 2)))
    return sorted(jobs, key=lambda j: j.arrival_h)


@dataclass
class Scenario:
    name: str
    autoscale: bool = False
    spot_harvest: bool = False      # workers on Spot+Harvest pricing/dynamics
    runtime_hints: bool = False


def _capacity_series(rng, t, spot_harvest):
    """Available worker cores at hour t.

    Regular: full capacity.  Harvest: spare-capacity series (mean ~0.48 of
    nominal, diurnal + noise — Harvest VMs only get the server's leftovers).
    """
    full = N_WORKERS * CORES_PER_WORKER
    if not spot_harvest:
        return full
    import math
    frac = CAP_MEAN + 0.15 * math.sin(2 * math.pi * (t / 2.5)) \
        + rng.uniform(-0.08, 0.08)
    return max(0.12, min(0.9, frac)) * full


def run_scenario(sc: Scenario, seed=0) -> Dict[str, float]:
    rng = random.Random(seed + 17)
    jobs = make_trace(seed)
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("hadoop", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 60.0, "delay_tolerance_ms": 60_000.0,
    } if sc.spot_harvest else {"scale_out_in": sc.autoscale})
    spot = SpotManager(gm)

    t = 0.0
    pending = list(jobs)
    running: List[Job] = []
    done: List[Job] = []
    cost_core_h = 0.0
    active_worker_core_h = 0.0
    # worker VM price multiplier: harvest price for harvested capacity
    # Spot/Harvest mix pricing for workers; Table-2 combined price otherwise
    price = PRICE_MIX if sc.spot_harvest else combined_price(
        ("auto_scaling",) if sc.autoscale else ())

    next_evict = rng.expovariate(1 / EVICT_MEAN_H)
    while (pending or running) and t < 60.0:
        # arrivals
        while pending and pending[0].arrival_h <= t:
            j = pending.pop(0)
            j.started_h = t
            running.append(j)
        cap = _capacity_series(rng, t, sc.spot_harvest)
        if sc.autoscale or sc.spot_harvest:
            demand = sum(min(j.remaining / DT, CORES_PER_WORKER * 2)
                         for j in running)
            used = min(cap, max(demand, 0.0))
        else:
            used = cap if running else cap      # regular: always-on billing
        # spot eviction events (only for spot/harvest scenarios)
        if sc.spot_harvest and t >= next_evict:
            next_evict = t + rng.expovariate(1 / EVICT_MEAN_H)
            if running:
                if sc.runtime_hints:
                    # runtime hints: evict the worker running the *youngest*
                    # job (least lost work; long-critical jobs unmarked)
                    victim = min(running, key=lambda j: t - j.started_h)
                    loss = min(LOSS_FULL * victim.work_core_h,
                               (t - victim.started_h) * 2.0, 0.5)
                else:
                    victim = rng.choice(running)
                    loss = min(LOSS_DEPLOY * victim.work_core_h,
                               (t - victim.started_h) * 4.0, 2.5)
                victim.remaining += loss
                victim.lost_work += loss
                spot.stats["evictions"] += 1
        # progress: fair-share cores across running jobs
        if running:
            share = used / len(running)
            for j in running:
                j.remaining -= min(share, CORES_PER_WORKER * 2) * DT
            for j in [j for j in running if j.remaining <= 0]:
                j.finished_h = t
                running.remove(j)
                done.append(j)
        full = N_WORKERS * CORES_PER_WORKER
        if sc.spot_harvest:
            billed = max(used, WARM_FLOOR * full)
        elif sc.autoscale:
            billed = used
        else:
            billed = full
        cost_core_h += billed * DT * price
        active_worker_core_h += used * DT
        t += DT

    durations = sorted((j.finished_h - j.arrival_h) for j in done)
    med = durations[len(durations) // 2]
    return {"median_duration_h": med, "worker_cost": cost_core_h,
            "jobs_done": len(done), "evictions": spot.stats["evictions"]}


def run_all(seed=0) -> Dict[str, Dict[str, float]]:
    out = {}
    for sc in (Scenario("regular"),
               Scenario("autoscale", autoscale=True),
               Scenario("wi_deploy", autoscale=True, spot_harvest=True),
               Scenario("wi_full", autoscale=True, spot_harvest=True,
                        runtime_hints=True)):
        out[sc.name] = run_scenario(sc, seed)
    base = out["regular"]
    for name, r in out.items():
        r["slowdown_x"] = r["median_duration_h"] / base["median_duration_h"]
        r["cost_frac"] = r["worker_cost"] / base["worker_cost"]
        r["cost_saving"] = 1.0 - r["cost_frac"]
    return out
