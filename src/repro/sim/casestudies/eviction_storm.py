"""Scenario: eviction storm under the hint-aware platform scheduler.

A two-region cluster runs a mix of regular frontends (anti-affinity spread),
region-fixed batch workloads with *heterogeneous hinted notice windows*
(``x-eviction-notice-s`` from 30 s to 300 s), and a deeply preemptible spot
pool.  Then the platform gets hit with a storm: repeated capacity crunches
(spot reclaim waves) plus maintenance-aware power events on individual
servers — the paper's §2.2 "all VMs spike at once / MA datacenter sheds
power" stress cases at cluster scale.

The invariant under test (the PR's acceptance criterion): **every eviction
notice is delivered no later than the workload's hinted preemptibility
notice window before the VM is killed** — ``violations == 0`` no matter how
hard the storm hits, because the eviction pipeline stretches each manager's
deadline to the hinted window and kills only on the engine's clock.
"""
from __future__ import annotations

import random
from typing import Dict

from repro.sched import Scheduler
from repro.sim.cluster import VM

N_SERVERS_PER_REGION = 60
CORES_PER_SERVER = 48
NOTICE_LADDER = (30.0, 60.0, 120.0, 300.0)
STORM_WAVES = 6
WAVE_PERIOD_S = 120.0
WAVE_CORES = 220.0              # cores reclaimed per wave
POWER_EVENTS = 8


def build(seed: int = 0) -> Scheduler:
    rng = random.Random(seed)
    s = Scheduler(default_notice_s=30.0)
    for r in ("region-0", "region-green"):
        for i in range(N_SERVERS_PER_REGION):
            s.cluster.add_server(f"{r}/s{i}", CORES_PER_SERVER, region=r)

    # frontends: four nines, not preemptible, spread hard
    for i in range(6):
        s.gm.register_workload(f"frontend-{i}", {"availability_nines": 4.0})
    # region-fixed batch: preemptible with per-workload hinted notice windows
    for i in range(12):
        s.gm.register_workload(f"batch-{i}", {
            "scale_out_in": True, "scale_up_down": True,
            "preemptibility_pct": 60.0, "delay_tolerance_ms": 30_000.0,
            "availability_nines": 2.0,
            "x-eviction-notice-s": NOTICE_LADDER[i % len(NOTICE_LADDER)]})
    # spot pool: deeply preemptible, default 30 s notice
    for i in range(6):
        s.gm.register_workload(f"spotpool-{i}", {
            "preemptibility_pct": 90.0, "availability_nines": 1.0,
            "delay_tolerance_ms": 60_000.0})

    vm = 0
    for i in range(6):
        for _ in range(10):
            s.submit(VM(f"vm{vm}", f"frontend-{i}", "", 8,
                        util_p95=rng.uniform(0.5, 0.9)))
            vm += 1
    for i in range(12):
        for _ in range(20):
            s.submit(VM(f"vm{vm}", f"batch-{i}", "", 8,
                        util_p95=rng.uniform(0.2, 0.6), spot=True))
            vm += 1
    for i in range(6):
        for _ in range(30):
            s.submit(VM(f"vm{vm}", f"spotpool-{i}", "", 4,
                        util_p95=rng.uniform(0.1, 0.5), spot=True))
            vm += 1
    s.schedule_pending()
    return s


def run(seed: int = 0) -> Dict[str, float]:
    rng = random.Random(seed + 1)
    s = build(seed)
    placed0 = s.stats["placed"]

    # the storm: reclaim waves alternating regions + power events
    for w in range(STORM_WAVES):
        region = "region-0" if w % 2 == 0 else "region-green"
        s.engine.at(60.0 + w * WAVE_PERIOD_S,
                    lambda r=region: s.capacity_crunch(r, WAVE_CORES))
    servers = list(s.cluster.servers)
    for i in range(POWER_EVENTS):
        srv = rng.choice(servers)
        s.engine.at(90.0 + i * 100.0,
                    lambda sv=srv: s.power_event(sv, shed_frac=0.4))

    horizon = 60.0 + STORM_WAVES * WAVE_PERIOD_S + max(NOTICE_LADDER) + 60.0
    s.run_until(horizon)

    killed = [t for t in s.evictor.log if t.killed]
    leads = [t.lead_time_s for t in killed]
    violations = s.evictor.violations()
    alive = sum(1 for v in s.cluster.vms.values() if v.alive and v.server)
    by_window: Dict[float, int] = {}
    for t in killed:
        by_window[t.notice_s] = by_window.get(t.notice_s, 0) + 1
    return {
        "placed": placed0,
        "evictions": len(killed),
        "violations": len(violations),
        "min_lead_s": min(leads) if leads else float("inf"),
        "mean_lead_s": sum(leads) / len(leads) if leads else 0.0,
        "max_hinted_window_s": max((t.notice_s for t in killed), default=0.0),
        "evictions_by_window": by_window,
        "alive_vms": alive,
        "notices": s.evictor.stats["notices"],
        "reminders": s.evictor.stats["reminders"],
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
