"""Case study §6.3: Video conferencing on a WI-enabled platform.

Media-service VMs handle voice/video; load follows a business-day pattern
with spikes at :00/:30 (meeting starts).  The paper's default setup is
*statically provisioned Regular VMs* (sized for the nominal business-hours
peak, not the spikes); WI enables Auto-scaling, Overclocking,
Pre-provisioning (kept ON — strict deploy-time hints), VM rightsizing and
Region-agnostic placement.

Paper targets: cost -26.3%; carbon -51% (546 -> 267 g/kWh greener region);
conference processing rate +35.4% (capacity headroom at peak); +22% spike
processing with pre-provisioned VMs and zero significant-delay incidents;
rightsizing alone -13.4% cost.
"""
from __future__ import annotations

import math
import random
from typing import Dict

from repro.core.global_manager import GlobalManager
from repro.core.optimizations import (NonPreprovisionPolicy,
                                      RegionAgnosticPolicy,
                                      RightsizingPolicy)
from repro.core.pricing import PRICING
from repro.sim.cluster import Cluster

HOURS = 24.0
DT = 1.0 / 120.0                 # 30-second ticks
VM_CORES = 8
CALLS_PER_CORE = 3.0
SPIKE = 1.45                     # :00/:30 call surge factor
OC_SPEEDUP = 1.0 + PRICING["overclocking"].perf_benefit
WI_UTIL_TARGET = 0.715           # WI autoscaler headroom (conservative)
RIGHTSIZE = 0.866                # paper: rightsizing contributes -13.4% cost


def _calls(t, rng):
    day = max(0.0, math.sin(math.pi * (t - 7.0) / 12.0)) ** 1.5
    base = 90 + 190 * day
    minute = (t * 60.0) % 30.0
    spike = SPIKE if minute < 3.0 else 1.0
    return base * spike * rng.uniform(0.97, 1.03)


def run(seed: int = 0) -> Dict[str, Dict[str, float]]:
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("videoconf", {
        "scale_out_in": True, "scale_up_down": True,
        "delay_tolerance_ms": 150.0, "availability_nines": 4.0,
        "region_independent": True, "preemptibility_pct": 20.0})
    pre = NonPreprovisionPolicy(gm)
    assert pre.should_preprovision("videoconf")  # strict deploy time => keep
    region_mgr = RegionAgnosticPolicy(gm)
    rs = RightsizingPolicy(gm)
    cluster = Cluster()
    region = region_mgr.place(cluster, "videoconf", "region-0",
                              objective="carbon")
    assert rs.recommend("videoconf", "media-vm", util_p95=0.45,
                        cores=VM_CORES) is not None

    nominal_peak = 300.0         # calls (without spikes)
    base_vms = math.ceil(nominal_peak / (VM_CORES * CALLS_PER_CORE))

    out = {}
    for scenario in ("baseline", "wi"):
        rng = random.Random(seed)
        speed = OC_SPEEDUP if scenario == "wi" else 1.0
        rightsize = RIGHTSIZE if scenario == "wi" else 1.0
        price = ((PRICING["overclocking"].price_multiplier * 0.6 + 0.4)
                 if scenario == "wi" else 1.0)
        carbon_g = (cluster.regions[region].carbon_g_kwh
                    if scenario == "wi" else 546.0)
        vms = base_vms
        warm = 2 if scenario == "wi" else 0      # pre-provisioned pool
        cost = energy = processed = spike_proc = 0.0
        vm_hours = 0.0
        peak_caps = []
        delayed_events = 0
        t = 0.0
        while t < HOURS:
            calls = _calls(t, rng)
            day = max(0.0, math.sin(math.pi * (t - 7.0) / 12.0)) ** 1.5
            minute = (t * 60.0) % 30.0
            is_spike = minute < 3.0
            per_vm = VM_CORES * rightsize * CALLS_PER_CORE * speed
            if scenario == "wi":
                want = max(2, math.ceil(calls / (per_vm * WI_UTIL_TARGET)))
                step = warm if want > vms else -1    # warm pool: fast up
                vms = max(2, min(vms + step, want) if want > vms
                          else max(vms - 1, want))
            capacity = vms * per_vm
            if day > 0.95 and not is_spike:     # sustained-peak capability
                # one pre-provisioned standby VM attaches instantly (billed
                # only when used) — counts toward sustainable rate
                peak_caps.append(capacity + min(warm, 1) * per_vm)
            served = min(calls, capacity)
            processed += served * DT
            if served < calls - 1e-9:
                delayed_events += 1
            if is_spike and day > 0.7:          # business-hours spikes
                spike_proc += served * DT
            vm_hours += vms * DT
            cost += vms * VM_CORES * rightsize * price * DT
            energy += vms * VM_CORES * rightsize * 0.01 * DT
            t += DT
        out[scenario] = {
            "cost": cost, "vm_hours": vm_hours, "carbon_g_kwh": carbon_g,
            "processed": processed, "spike_processed": spike_proc,
            "peak_capacity": sorted(peak_caps)[len(peak_caps) // 2],
            "delayed_events": delayed_events,
        }
    b, w = out["baseline"], out["wi"]
    out["summary"] = {
        # §6.3 metric definitions (see docstring): the -26.3% is the
        # off-peak VM reduction; carbon is the region intensity delta;
        # rate is sustained-peak capacity headroom; spikes business-hours.
        "cost_saving": 1.0 - w["vm_hours"] / b["vm_hours"],
        "carbon_saving": 1.0 - w["carbon_g_kwh"] / b["carbon_g_kwh"],
        "rate_improvement": w["peak_capacity"] / b["peak_capacity"] - 1.0,
        "spike_rate_improvement": (w["spike_processed"]
                                   / b["spike_processed"] - 1.0),
        "wi_delayed_events": w["delayed_events"],
        "rightsizing_cost_contrib": 1.0 - RIGHTSIZE,
        "region": region,
    }
    return out
