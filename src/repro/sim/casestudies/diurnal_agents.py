"""Scenario: the bidirectional loop under diurnal churn.

A two-region cluster runs three workload classes with per-VM
``WorkloadAgent``s attached (``repro.agents``), then gets hit with the
usual storm (spot-reclaim waves + maintenance power events) while the day
cycles between peak and off-peak phases:

  * **web** — stateless scale-out frontends: on an eviction notice the
    agent requests a replacement VM and acks immediately, so the platform
    early-releases the VM long before the kill deadline (capacity freed,
    zero lost work);
  * **bigdata** — stateful batch: the agent checkpoints (latency
    proportional to state size) and acks once durable.  "Light" shards
    finish inside their hinted 120 s notice window (early release, ~0 lost
    work); "heavy" shards cannot, ride the ladder to the deadline, and
    their un-checkpointed work is metered as lost-work-seconds.  Off-peak,
    the workload's leader agent re-asserts workload-wide runtime hints
    (delay-tolerant, deeply preemptible, region-independent) and the
    scheduler migrates shards to the cheap region; at peak the hints swing
    back;
  * **videoconf** — interactive, small partial state: raises availability
    (and drops preemptibility) at peak — power events then throttle rather
    than evict it, and the agents shed load in response.

Invariants under test: **zero notice-window violations** no matter how the
storm and the agents interleave; a large fraction of evictions resolved by
early release before the deadline; stateless lost work exactly zero.
"""
from __future__ import annotations

import random
from typing import Dict, Tuple

from repro import obs
from repro.agents import (PARTIAL, STATEFUL, STATELESS, AgentPolicy,
                          AgentRuntime, DiurnalProfile)
from repro.sched import Scheduler
from repro.sim.cluster import VM

N_SERVERS_PER_REGION = 30
CORES_PER_SERVER = 48
TICK_S = 5.0
PHASE_PERIOD_S = 300.0
STORM_WAVES = 6
WAVE_PERIOD_S = 120.0
WAVE_CORES = 200.0
POWER_EVENTS = 8
BIGDATA_NOTICE_S = 120.0

N_WEB = 8               # workloads per class (VM counts scale with these)
N_BIGDATA = 6
N_VIDEOCONF = 4
WEB_VMS = 15
BIGDATA_VMS = 10
VIDEOCONF_VMS = 10


def build(seed: int = 0, n_servers_per_region: int = N_SERVERS_PER_REGION,
          vm_scale: float = 1.0) -> Tuple[Scheduler, AgentRuntime]:
    rng = random.Random(seed)
    # live registry + bus-fed lifecycle observer: the reported eviction
    # numbers are derived from the observer and asserted against the
    # pipeline's books in run()
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(default_notice_s=30.0, metrics=registry)
    s.lifecycle = obs.LifecycleObserver(s.gm.bus, registry=registry)
    for r in ("region-0", "region-green"):
        for i in range(n_servers_per_region):
            s.cluster.add_server(f"{r}/s{i}", CORES_PER_SERVER, region=r)

    policies: Dict[str, AgentPolicy] = {}

    # web: stateless scale-out frontends (replace + ack early)
    for i in range(N_WEB):
        w = f"web-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "scale_up_down": True,
            "preemptibility_pct": 70.0, "availability_nines": 3.0,
            "delay_tolerance_ms": 5_000.0})
        policies[w] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)

    # bigdata: stateful batch, hinted 120 s notice, diurnal hint swings.
    # Even workloads carry "light" state (checkpoint fits in the window),
    # odd ones "heavy" state (the deadline wins; work is lost).
    diurnal_bigdata = DiurnalProfile(
        peak_hints={"delay_tolerance_ms": 5_000.0,
                    "preemptibility_pct": 20.0,
                    "region_independent": False},
        offpeak_hints={"delay_tolerance_ms": 120_000.0,
                       "preemptibility_pct": 80.0,
                       "region_independent": True})
    for i in range(N_BIGDATA):
        w = f"bigdata-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "scale_up_down": True,
            "preemptibility_pct": 60.0, "availability_nines": 2.0,
            "delay_tolerance_ms": 30_000.0,
            "x-eviction-notice-s": BIGDATA_NOTICE_S})
        state_gb = 8.0 if i % 2 == 0 else 30.0      # 40 s vs 150 s ckpt
        policies[w] = AgentPolicy(statefulness=STATEFUL, state_gb=state_gb,
                                  ckpt_gbps=0.2, diurnal=diurnal_bigdata)

    # videoconf: interactive, small partial state, availability up at peak
    diurnal_vc = DiurnalProfile(
        peak_hints={"availability_nines": 4.0, "preemptibility_pct": 0.0},
        offpeak_hints={"availability_nines": 2.0,
                       "preemptibility_pct": 40.0})
    for i in range(N_VIDEOCONF):
        w = f"videoconf-{i}"
        s.gm.register_workload(w, {
            "scale_up_down": True, "availability_nines": 3.0,
            "delay_tolerance_ms": 1_000.0})
        policies[w] = AgentPolicy(statefulness=PARTIAL, state_gb=2.0,
                                  ckpt_gbps=1.0, diurnal=diurnal_vc)

    vm = 0
    for i in range(N_WEB):
        for _ in range(max(1, round(WEB_VMS * vm_scale))):
            s.submit(VM(f"vm{vm}", f"web-{i}", "", 4,
                        util_p95=rng.uniform(0.2, 0.6), spot=True))
            vm += 1
    for i in range(N_BIGDATA):
        for _ in range(max(1, round(BIGDATA_VMS * vm_scale))):
            s.submit(VM(f"vm{vm}", f"bigdata-{i}", "", 8,
                        util_p95=rng.uniform(0.3, 0.8), spot=True))
            vm += 1
    for i in range(N_VIDEOCONF):
        for _ in range(max(1, round(VIDEOCONF_VMS * vm_scale))):
            s.submit(VM(f"vm{vm}", f"videoconf-{i}", "", 4,
                        util_p95=rng.uniform(0.4, 0.9)))
            vm += 1
    s.schedule_pending()

    rt = AgentRuntime(s, policies=policies)
    return s, rt


def run(seed: int = 0, n_servers_per_region: int = N_SERVERS_PER_REGION,
        vm_scale: float = 1.0) -> Dict[str, float]:
    rng = random.Random(seed + 1)
    s, rt = build(seed, n_servers_per_region, vm_scale)
    placed0 = s.stats["placed"]

    horizon = 60.0 + STORM_WAVES * WAVE_PERIOD_S + 300.0

    # the day: peak <-> off-peak flips through the agent runtime
    def flip_phase():
        rt.set_phase("offpeak" if rt.phase == "peak" else "peak")
    s.engine.every(PHASE_PERIOD_S, flip_phase, horizon)

    # the storm: reclaim waves alternating regions + power events (offset
    # from the tick grid so replacements pay a real placement delay)
    for w in range(STORM_WAVES):
        region = "region-0" if w % 2 == 0 else "region-green"
        s.engine.at(61.0 + w * WAVE_PERIOD_S,
                    lambda r=region: s.capacity_crunch(r, WAVE_CORES))
    servers = list(s.cluster.servers)
    for i in range(POWER_EVENTS):
        srv = rng.choice(servers)
        s.engine.at(93.0 + i * 100.0,
                    lambda sv=srv: s.power_event(sv, shed_frac=0.4))

    s.start(TICK_S, horizon)            # place replacements as they arrive
    s.run_until(horizon)

    ev = s.evictor
    killed = [t for t in ev.log if t.outcome == "killed"]
    early = [t for t in ev.log if t.outcome == "early_released"]
    resolved = len(killed) + len(early)
    m = rt.telemetry()
    alive = sum(1 for v in s.cluster.vms.values() if v.alive and v.server)
    life = s.lifecycle.summary()
    recon = s.lifecycle.reconcile(ev)
    # bus-derived lifecycle books must agree with the pipeline's own
    assert recon["ok"], recon["diffs"]
    assert life["killed"] == len(killed)
    assert life["early_released"] == len(early)
    assert life["violations"] == len(ev.violations())
    return {
        "placed": placed0,
        "evictions_killed": int(life["killed"]),
        "early_releases": int(life["early_released"]),
        "early_release_frac": (len(early) / resolved) if resolved else 0.0,
        "violations": int(life["violations"]),
        "min_lead_s": min((t.lead_time_s for t in killed),
                          default=float("inf")),
        "already_gone": ev.stats.get("already_gone", 0),
        "cancellations": ev.stats.get("cancellations", 0),
        "lost_work_s": m.get("lost_work_s", 0.0),
        "lost_work_s_stateless": m.get("lost_work_s_stateless", 0.0),
        "stateless_killed_without_ack":
            m.get("stateless_killed_without_ack", 0.0),
        "checkpoints_started": m.get("checkpoints_started", 0.0),
        "checkpoints_completed": m.get("checkpoints_completed", 0.0),
        "replacements_requested": m.get("replacements_requested", 0.0),
        "replacements_placed": m.get("replacements_placed", 0.0),
        "replacement_lead_s_mean": m.get("replacement_lead_s_mean", 0.0),
        "hint_adaptations": m.get("hint_adaptations", 0.0),
        "shed_reactions": m.get("shed_reactions", 0.0),
        "hint_migrations": s.stats.get("hint_migrations", 0),
        "agents_attached": m.get("agents_attached", 0.0),
        "alive_vms": alive,
        # per-class lifecycle rollups (CI reconciles p100 vs the widest
        # hinted window: acks always land inside the notice window)
        "obs_violations": int(life["violations"]),
        "obs_reconcile_ok": recon["ok"],
        "obs_max_notice_s": life["max_notice_s"],
        "obs_notice_to_ack_p100_s": life["notice_to_ack_s"].get("p100"),
        "obs_ack_to_release_p95_s": life["ack_to_release_s"].get("p95"),
        "obs_kill_lead_p50_s": life["kill_lead_s"].get("p50"),
        "obs_acks_observed": life["notice_to_ack_s"].get("count", 0),
    }


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
