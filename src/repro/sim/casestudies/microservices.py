"""Case study §6.2: Microservices (DeathStarBench social network on K8s+WI).

Model: control plane (LB, Media Frontend, Memcached, MongoDB, Redis) on
"management"-requirement VMs; worker pods (Nginx + logic) replicated behind
the LB.  Load is diurnal; tail latency follows an M/M/c-flavored
approximation latency(util) = base + q / (1 - util^c).

Scenarios:
  baseline — Regular VMs + plain autoscaling (paper: 376 ms p99)
  wi       — WI hints enable: CPU oversubscription on control VMs (50% CPU /
             20% memory), Harvest VMs + Overclocking for workers, MA DCs.
             Overclocking cuts worker service time (Table 2: +11% perf);
             evictions covered by graceful pod migration (no latency spikes).

Paper targets: p99 376 -> 332 ms (-13.3%); owner cost -44% (most from
overclocking, rest from Harvest).
"""
from __future__ import annotations

import math
import random
from dataclasses import dataclass
from typing import Dict

from repro.core.global_manager import GlobalManager
from repro.core.optimizations import (HarvestManager, OverclockingManager,
                                      OversubscriptionManager)
from repro.core.pricing import PRICING

N_CONTROL = 2
MIN_WORKERS = 4
VM_CORES = 8
BASE_MS = 215.0          # irreducible path latency
Q_MS = 132.0             # queueing coefficient
UTIL_TARGET = 0.55       # autoscaler's target utilization
OC_SPEEDUP = 1.0 + PRICING["overclocking"].perf_benefit  # +11% (Table 2)
HOURS = 24.0
DT = 1.0 / 60.0


def _load(t):       # diurnal request rate in "worker-cores of demand"
    return 22.0 * (0.55 + 0.45 * math.sin(2 * math.pi * (t - 8.0) / 24.0) ** 2)


def _p99(util, speed=1.0):
    """Overclocking shortens service time, shrinking every latency term."""
    util = min(util, 0.97)
    return (BASE_MS + Q_MS / (1.0 - util ** 3)) / speed


def run(seed: int = 0) -> Dict[str, Dict[str, float]]:
    rng = random.Random(seed)
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("socialnet-workers", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 50.0, "delay_tolerance_ms": 200.0,
        "availability_nines": 3.0, "deploy_time_ms": 120_000.0})
    gm.register_workload("socialnet-control", {
        "scale_up_down": True, "delay_tolerance_ms": 50.0,
        "availability_nines": 4.0})
    oversub = OversubscriptionManager(gm)
    assert oversub.eligible("socialnet-control", util_p95=0.45)

    out = {}
    for scenario in ("baseline", "wi"):
        speed = OC_SPEEDUP if scenario == "wi" else 1.0
        worker_price = (PRICING["harvest"].price_multiplier * 0.55
                        + PRICING["overclocking"].price_multiplier * 0.45) \
            if scenario == "wi" else 1.0
        control_price = (PRICING["oversubscription"].price_multiplier
                         if scenario == "wi" else 1.0)
        t, cost, lat_samples = 0.0, 0.0, []
        workers = MIN_WORKERS
        while t < HOURS:
            demand = _load(t) + rng.uniform(-0.8, 0.8)
            eff_capacity = workers * VM_CORES * speed
            util = demand / eff_capacity
            # autoscaler (both scenarios have it — paper baseline includes it)
            want = max(MIN_WORKERS,
                       math.ceil(demand / (VM_CORES * speed * UTIL_TARGET)))
            workers += max(min(want - workers, 2), -1)     # bounded steps
            lat_samples.append(_p99(util, speed))
            cost += (workers * VM_CORES * worker_price
                     + N_CONTROL * VM_CORES * control_price) * DT
            t += DT
        lat_samples.sort()
        out[scenario] = {
            "p99_ms": lat_samples[int(0.99 * len(lat_samples))],
            "mean_p99_ms": sum(lat_samples) / len(lat_samples),
            "cost": cost,
        }
    b, w = out["baseline"], out["wi"]
    out["summary"] = {
        "latency_improvement": 1.0 - w["p99_ms"] / b["p99_ms"],
        "cost_saving": 1.0 - w["cost"] / b["cost"],
    }
    return out
