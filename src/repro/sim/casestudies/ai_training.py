"""Scenario: the elastic JAX trainer as a real scheduler tenant.

The repo's two halves meet here.  A *real* ``WITrainer`` (jit-compiled
training steps, sharded params, the atomic ``Checkpointer``) runs as one
workload of the live platform scheduler, co-tenanted with the background
fleet classes the savings scenarios use (stateless scale-out web frontends,
harvest-elastic web, stateful batch).  Every platform interaction flows
through the guest channel — VM endpoints, scheduled events, acks on
``wi.events.acks`` — never a direct call into the pipeline:

  * **spot/harvest reclaim** — ≥2 capacity-crunch waves pick harvest-tier
    VMs first (Table 4), so each wave early-releases the harvest web
    frontends and one or two trainer VMs.  A noticed trainer VM triggers a
    real emergency checkpoint, an ack after the modeled durable-write
    latency (early release well inside the hinted 60 s window), an eager
    DP shrink over the surviving accelerators, and a replacement VM that
    re-grows the width when it lands;
  * **harvest growth** — ``SCALE_UP_OFFER`` grants convert spare
    accelerators into extra DP ranks at the next step boundary;
  * **power events** — an MA-datacenter power event on the leader's server
    throttles the job (availability 2.0 ≤ 3): the microbatch halves; the
    next policy pass's ``OVERCLOCK_OFFER`` restores it;
  * the trainer's leader agent publishes per-step runtime hints
    (``preemptibility_pct`` fresh/stale, ``x-step-time-ms``,
    ``x-dp-width``) through its endpoint, which is what keeps the leader's
    keep-priority above the other slices in victim selection.

Invariants (asserted by the ``ai_training`` benchmark and the tenant
tests): zero notice-window violations, ≥1 trainer early release via a
guest ack, DP width shrinks then re-grows with finite/decreasing losses
across the resizes, and lost work is bounded by one checkpoint interval
per kill.

Needs 8 virtual host devices — run as ``python -m
repro.sim.casestudies.ai_training`` (the module sets ``XLA_FLAGS`` before
importing jax) or from the benchmark harness's subprocess.  Sizes honor
``AI_TRAINING_STEPS`` / ``AI_TRAINING_SERVERS``.
"""
from __future__ import annotations

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import json
import random
import tempfile
from typing import Dict

import jax

from repro import obs
from repro.agents import (STATEFUL, STATELESS, AgentPolicy, AgentRuntime,
                          TrainerTenant)
from repro.configs.archs import smoke_config
from repro.configs.base import RunConfig
from repro.runtime.trainer import WITrainer, deployment_hints_from
from repro.sched import Scheduler
from repro.sim.cluster import VM

N_STEPS = 40
MIN_STEPS = 24                  # the event timeline needs room to play out
SIM_S_PER_STEP = 5.0            # sim seconds advanced per training step
TICK_S = 15.0
POLICY_PERIOD_S = 45.0
CKPT_EVERY = 4                  # steps; cadence = CKPT_EVERY*SIM_S_PER_STEP
N_SERVERS = 12
CORES_PER_SERVER = 48.0

WORKLOAD = "ai-train"
N_TRAIN_VMS = 3
TRAIN_VM_CORES = 4.0            # 2 cores per accelerator
DEVICES_PER_VM = 2
MODEL_AXIS = 2
TRAIN_NOTICE_S = 60.0
EMERGENCY_CKPT_S = 4.0          # modeled durable-write latency (sim s)

N_WEBH_VMS = 6                  # harvest web: the pre-trainer reclaim tier
N_WEB_WORKLOADS = 3
N_WEB_VMS = 8
N_BATCH_WORKLOADS = 2
N_BATCH_VMS = 6

# wave sizes: the harvest tier is reclaimed first (Table 4), ordered by
# keep-priority — harvest web (keep 10) before trainer slices (keep 20)
# before the leader (keep 60 once its runtime hints land).  24 harvest-web
# cores, then into the trainer:
WAVE1_CORES = N_WEBH_VMS * 4.0 + 2.0                     # 1 trainer VM
WAVE2_CORES = N_WEBH_VMS * 4.0 + TRAIN_VM_CORES + 2.0    # 2 trainer VMs


def _event_t(frac: float, horizon: float) -> float:
    """An event instant just *after* a tick, so replacements wait for the
    next tick and the DP shrink is visible for at least one step."""
    return (int(frac * horizon) // int(TICK_S)) * TICK_S + 2.0


def build(seed: int, n_servers: int):
    rng = random.Random(seed)
    devices = list(jax.devices())
    need = N_TRAIN_VMS * DEVICES_PER_VM + 2
    if len(devices) < need:
        raise RuntimeError(
            f"needs {need} host devices, got {len(devices)} — run via "
            f"'python -m repro.sim.casestudies.ai_training' so XLA_FLAGS "
            f"is set before jax initializes")
    devices = devices[:need]

    # live registry + bus-fed lifecycle observer (reported eviction
    # numbers below are observer-derived, asserted against the pipeline)
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(default_notice_s=30.0, policy_period_s=POLICY_PERIOD_S,
                  metrics=registry)
    s.lifecycle = obs.LifecycleObserver(s.gm.bus, registry=registry)
    for i in range(n_servers):
        s.cluster.add_server(f"region-0/s{i}", CORES_PER_SERVER,
                             region="region-0")

    policies: Dict[str, AgentPolicy] = {}

    # harvest web: stateless scale-out, the tier every wave reclaims first
    s.gm.register_workload("webh", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 90.0, "availability_nines": 3.0,
        "delay_tolerance_ms": 5_000.0})
    policies["webh"] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)
    vm_id = 0
    for _ in range(N_WEBH_VMS):
        s.submit(VM(f"vm{vm_id}", "webh", "", 4.0,
                    util_p95=rng.uniform(0.30, 0.55), spot=True,
                    harvest=True))
        vm_id += 1

    # plain spot web: stateless scale-out; power events evict them
    # (availability 3.5 > 3 rules out throttling, preemptibility 90 >= 20)
    for i in range(N_WEB_WORKLOADS):
        w = f"web-{i}"
        s.gm.register_workload(w, {
            "scale_out_in": True, "preemptibility_pct": 90.0,
            "availability_nines": 3.5, "delay_tolerance_ms": 5_000.0})
        policies[w] = AgentPolicy(statefulness=STATELESS, scale_out_in=True)
        for _ in range(N_WEB_VMS):
            s.submit(VM(f"vm{vm_id}", w, "", 4.0,
                        util_p95=rng.uniform(0.30, 0.55), spot=True))
            vm_id += 1

    # stateful batch: background load that checkpoints-then-drains
    for i in range(N_BATCH_WORKLOADS):
        w = f"batch-{i}"
        s.gm.register_workload(w, {
            "preemptibility_pct": 45.0, "availability_nines": 2.5,
            "delay_tolerance_ms": 30_000.0, "x-eviction-notice-s": 120.0})
        policies[w] = AgentPolicy(statefulness=STATEFUL,
                                  state_gb=8.0 if i % 2 == 0 else 30.0,
                                  ckpt_gbps=0.2)
        for _ in range(N_BATCH_VMS):
            s.submit(VM(f"vm{vm_id}", w, "", 8.0,
                        util_p95=rng.uniform(0.2, 0.8), spot=True))
            vm_id += 1

    s.schedule_pending()                # the background fleet lands first

    # the training job: WI hints straight from the trainer's own mapping,
    # except region pinned — the dataset has gravity, and an unpinned
    # trainer would be "migrated" toward the cheap region on every hint
    # tick, resetting the per-resource keep-priority its leader maintains
    cfg = smoke_config("minitron-8b")
    rcfg = RunConfig(model=cfg, learning_rate=1e-3, warmup_steps=5,
                     total_steps=max(N_STEPS, 200))
    hints = deployment_hints_from(rcfg, CKPT_EVERY, elastic=True)
    hints["region_independent"] = False
    hints["x-eviction-notice-s"] = TRAIN_NOTICE_S
    s.gm.register_workload(WORKLOAD, hints)
    tenant = TrainerTenant(WORKLOAD, devices,
                           devices_per_vm=DEVICES_PER_VM,
                           model_axis=MODEL_AXIS, min_dp=1,
                           emergency_ckpt_s=EMERGENCY_CKPT_S)
    policies[WORKLOAD] = tenant.policy(state_gb=1.0, ckpt_gbps=0.25)
    for i in range(N_TRAIN_VMS):
        s.submit(VM(f"ai{i}", WORKLOAD, "", TRAIN_VM_CORES, util_p95=0.5,
                    spot=True, harvest=True))
    s.schedule_pending()                # trainer slices land on the spare
    runtime = AgentRuntime(s, policies=policies)    # adopts trainer slices

    trainer = WITrainer(rcfg, s.gm, ckpt_dir=tempfile.mkdtemp(),
                        devices=tenant.active_devices(),
                        model_axis=MODEL_AXIS, ckpt_every=CKPT_EVERY,
                        min_dp=1, workload=WORKLOAD,
                        batch_override=24, seq_override=32,
                        standalone=False,
                        hint_sink=tenant.publish_runtime_hints)
    tenant.attach_trainer(trainer)
    return s, runtime, tenant, trainer


def run(seed: int = 0, n_steps: int = N_STEPS,
        n_servers: int = N_SERVERS) -> Dict:
    n_steps = max(int(n_steps), MIN_STEPS)
    s, runtime, tenant, trainer = build(seed, n_servers)
    horizon = n_steps * SIM_S_PER_STEP

    for frac, cores in ((0.3, WAVE1_CORES), (0.6, WAVE2_CORES)):
        s.engine.at(_event_t(frac, horizon),
                    lambda c=cores: s.capacity_crunch("region-0", c))

    def power_on_leader():
        lead = next((v for v in tenant._order
                     if s.cluster.vms.get(v) is not None
                     and s.cluster.vms[v].server), None)
        if lead is not None:
            s.power_event(s.cluster.vms[lead].server, shed_frac=0.5)
    s.engine.at(_event_t(0.45, horizon), power_on_leader)

    # ticks must cover the tenant's full wait horizon (4x the nominal
    # run): replacements only place on a tick, so a paused trainer could
    # otherwise never recover once ticks end.  Ticks past the actual end
    # of stepping just stay queued.
    s.start(TICK_S, 4.0 * horizon)
    tenant.run(n_steps, SIM_S_PER_STEP)

    ev = s.evictor
    tlog = [t for t in ev.log if t.workload == WORKLOAD]
    early_all = [t for t in ev.log if t.outcome == "early_released"]
    dps = [m["dp"] for m in trainer.metrics_log]
    losses = [m["loss"] for m in trainer.metrics_log]
    i_min = dps.index(min(dps)) if dps else 0
    tm = tenant.telemetry()
    rm = runtime.telemetry()
    trainer_reclaims = sum(1 for t in tlog
                           if t.outcome in ("killed", "early_released"))
    life = s.lifecycle.summary()
    recon = s.lifecycle.reconcile(ev)
    # the bus-derived lifecycle books must agree with the pipeline's own
    assert recon["ok"], recon["diffs"]
    assert life["early_released"] == len(early_all)
    assert life["violations"] == len(ev.violations())
    out = {
        "steps": trainer.step,
        "waves": s.stats.get("capacity_crunches", 0),
        "violations": int(life["violations"]),
        "trainer_early_releases":
            sum(1 for t in tlog if t.outcome == "early_released"),
        "trainer_ladder_kills":
            sum(1 for t in tlog if t.outcome == "killed"),
        "fleet_early_releases": len(early_all) - sum(
            1 for t in tlog if t.outcome == "early_released"),
        "dp0": dps[0] if dps else 0,
        "dp_min": min(dps) if dps else 0,
        "dp_regrown": max(dps[i_min:]) if dps else 0,
        "dp_final": dps[-1] if dps else 0,
        "resizes": sum(1 for e in trainer.events_log
                       if e["kind"] == "resize"),
        "emergency_checkpoints": tm.get("emergency_checkpoints", 0.0),
        "harvest_devices_granted": tm.get("harvest_devices_granted", 0.0),
        "throttles": tm.get("throttle_notices", 0.0),
        "restores": tm.get("restores", 0.0),
        "microbatch_final": trainer.pcfg.microbatch,
        "microbatch_throttled": sum(1 for e in trainer.events_log
                                    if e["kind"] == "throttle"),
        "ack_margin_min_s": tm.get("ack_margin_min_s", float("nan")),
        # the real checkpointed state behind the modeled 4 s write latency
        "ckpt_state_mb": trainer.state_bytes() / 1e6,
        "implied_ckpt_write_gbps":
            trainer.state_bytes() / 1e9 / EMERGENCY_CKPT_S,
        "lost_work_s": tm.get("lost_work_s", 0.0),
        "trainer_reclaims": trainer_reclaims,
        "ckpt_interval_s": CKPT_EVERY * SIM_S_PER_STEP,
        "replacements_placed": rm.get("replacements_placed", 0.0),
        "fleet_lost_work_s_stateless": rm.get("lost_work_s_stateless", 0.0),
        "loss_first3": sum(losses[:3]) / max(len(losses[:3]), 1),
        "loss_last3": sum(losses[-3:]) / max(len(losses[-3:]), 1),
        "losses_finite": all(l == l and abs(l) != float("inf")
                             for l in losses),
        # lifecycle-histogram rollups (reconciled against the pipeline)
        "obs_violations": int(life["violations"]),
        "obs_reconcile_ok": recon["ok"],
        "obs_max_notice_s": life["max_notice_s"],
        "obs_notice_to_ack_p100_s": life["notice_to_ack_s"].get("p100"),
        "obs_kill_lead_p50_s": life["kill_lead_s"].get("p50"),
        "obs_acks_observed": life["notice_to_ack_s"].get("count", 0),
    }
    s.gm.close()        # scenario teardown: release WAL/segment handles
    return out


if __name__ == "__main__":
    n_steps = int(os.environ.get("AI_TRAINING_STEPS", N_STEPS))
    n_servers = int(os.environ.get("AI_TRAINING_SERVERS", N_SERVERS))
    result = run(seed=0, n_steps=n_steps, n_servers=n_servers)
    for k, v in result.items():
        print(f"{k}: {v}")
    print("RESULT " + json.dumps(result))
