"""Scenario: recover the paper's 48.8% average saving from a *live* run.

The analytical reproduction (``sim/provider_scale.py``, benchmark
``f5_savings``) derives the Table-2 savings waterfall in closed form.  This
scenario recovers the same number dynamically: a mixed fleet whose
per-optimization enrollment fractions follow Table 3 (exclusive within each
§6.4 conflict set, shrink-calibrated so the closed-form expectation equals
48.8% — see ``provider_scale.enablement_probs`` / ``fit_enablement_shrink``)
is pushed through the hint-aware scheduler with workload agents attached and
a ``BillingMeter`` listening on the decision bus:

  * every workload's enrollments are derived into deployment hints (plus the
    ``x-enrolled-opts`` extension hint the meter bills from), so each
    enrolled optimization is Table-3 *applicable* by construction;
  * the fleet is placed by the real placer (region-agnostic VMs land in the
    cheap region, oversubscription-eligible VMs pack against p95 headroom,
    availability classes spread);
  * capacity-crunch waves reclaim spot/harvest capacity through the
    eviction pipeline — notices honored, stateless agents ack and get
    early-released, replacements re-enter the pending queue — and
    maintenance power events throttle/evict through ``MADatacenterPolicy``;
  * the periodic policy pass drives rightsizing recommendations,
    under/overclocking offers and auto-scaling (demand-conserving) against
    the live cluster.  Harvest dynamic growth is left off the tick list
    here: harvested spare cores would add discounted core-hours beyond the
    Table-2 nominal accounting the analytical target is defined over.

Invariants: metered saving within ±3pp of the analytical 48.8%; zero
eviction-notice violations; billing meters reconcile with the cluster's own
core-hour integral.
"""
from __future__ import annotations

import random
from typing import Dict, List

from repro import obs
from repro.agents import STATEFUL, STATELESS, AgentPolicy, AgentRuntime
from repro.core.pricing import (ENROLLED_HINT_KEY, BillingMeter,
                                combined_price)
from repro.core.pricing import CONFLICT_SETS, PRICING
from repro.sched import Scheduler
from repro.sim.cluster import VM
from repro.sim.provider_scale import (PAPER_TOTAL_SAVING, enablement_probs,
                                      expected_fleet_saving,
                                      fit_enablement_shrink)

N_WORKLOADS = 400
VMS_PER_WORKLOAD = 3
VM_CORES = 4.0
N_SERVERS_PER_REGION = 72
CORES_PER_SERVER = 48.0
HORIZON_S = 3600.0
TICK_S = 15.0
POLICY_PERIOD_S = 300.0
STORM_WAVES = 4
WAVE_CORES = 260.0
POWER_EVENTS = 4

# Deployment-hint grants that make one optimization Table-3 applicable.
# Merging grants for a workload's enrolled set only ever widens capability,
# so every enrolled optimization stays applicable after the merge.
HINT_GRANTS: Dict[str, Dict] = {
    "auto_scaling": {"scale_out_in": True, "delay_tolerance_ms": 2_000.0},
    "spot": {"preemptibility_pct": 30.0},
    "harvest": {"scale_up_down": True, "preemptibility_pct": 30.0,
                "delay_tolerance_ms": 2_000.0},
    "overclocking": {"scale_up_down": True, "delay_tolerance_ms": 2_000.0},
    "underclocking": {"scale_up_down": True, "delay_tolerance_ms": 2_000.0},
    "non_preprovision": {"deploy_time_ms": 120_000.0},
    "region_agnostic": {"region_independent": True},
    "oversubscription": {"delay_tolerance_ms": 2_000.0},
    "rightsizing": {"availability_nines": 4.0, "scale_up_down": True},
    "ma_datacenters": {"availability_nines": 3.0},
}


def _merge_hints(enrolled) -> Dict:
    """Union of the enrolled optimizations' hint grants.  Bools OR,
    availability tightens downward (a lower nines requirement enables
    more), every other numeric widens upward."""
    out: Dict = {}
    for opt in sorted(enrolled):
        for k, v in HINT_GRANTS[opt].items():
            if k == "availability_nines":
                out[k] = min(out.get(k, 9.0), v)
            elif isinstance(v, bool):
                out[k] = out.get(k, False) or v
            else:
                out[k] = max(out.get(k, 0.0), v)
    return out


def sample_enrollments(n: int, probs: Dict[str, float],
                       rng: random.Random) -> List[set]:
    """Quota-sampled enrollment sets for ``n`` equal-core-mass workloads:
    each optimization enrolls exactly ``round(n * p)`` workloads (low
    sampling variance), conflict-set members partition a shared shuffle so
    they are mutually exclusive within a workload."""
    enrolled: List[set] = [set() for _ in range(n)]
    in_conflict = set()
    for cs in CONFLICT_SETS:
        perm = rng.sample(range(n), n)
        at = 0
        for o in sorted(cs):
            in_conflict.add(o)
            take = round(n * probs[o])
            for i in perm[at:at + take]:
                enrolled[i].add(o)
            at += take
    for o in sorted(PRICING):
        if o in in_conflict:
            continue
        for i in rng.sample(range(n), round(n * probs[o])):
            enrolled[i].add(o)
    return enrolled


def build(seed: int = 0, n_workloads: int = N_WORKLOADS,
          n_servers_per_region: int = N_SERVERS_PER_REGION):
    rng = random.Random(seed)
    # a live registry per scenario run: scheduler phases, agent counters
    # and the bus-fed lifecycle histograms all land in one place, and the
    # reported eviction numbers below are *derived* from it (asserted
    # against the evictor's books)
    registry = obs.MetricsRegistry(enabled=True)
    s = Scheduler(default_notice_s=30.0, policy_period_s=POLICY_PERIOD_S,
                  metrics=registry)
    observer = obs.LifecycleObserver(s.gm.bus, registry=registry)
    # the e2e billing target is defined over nominal allocations, so the
    # harvest grow/shrink tick stays off (see module docstring)
    s.tick_policies = tuple(p for p in s.tick_policies if p != "harvest")
    for r in ("region-0", "region-green"):
        for i in range(n_servers_per_region):
            s.cluster.add_server(f"{r}/s{i}", CORES_PER_SERVER, region=r)

    shrink = fit_enablement_shrink()
    probs = enablement_probs(shrink=shrink)
    enrollments = sample_enrollments(n_workloads, probs, rng)

    expected_sampled = 0.0
    vm_id = 0
    policies: Dict[str, AgentPolicy] = {}
    for i, enrolled in enumerate(enrollments):
        w = f"fleet-{i}"
        hints = _merge_hints(enrolled)
        hints[ENROLLED_HINT_KEY] = sorted(enrolled)
        s.gm.register_workload(w, hints)
        # a fifth of the fleet is stateful: light state checkpoints (and
        # acks) inside the 30 s notice window, heavy state cannot and rides
        # the deadline ladder — so the run exercises both the
        # early-release and the honored-window kill paths
        if i % 5 == 4:
            policies[w] = AgentPolicy(statefulness=STATEFUL,
                                      state_gb=0.5 if i % 10 == 4 else 12.0,
                                      ckpt_gbps=0.2)
        expected_sampled += 1.0 - combined_price(enrolled)
        if "auto_scaling" in enrolled:
            lo, hi = 0.30, 0.55      # inside the autoscaler's stable band
        elif "oversubscription" in enrolled:
            lo, hi = 0.25, 0.60      # oversubscription-eligible p95
        else:
            lo, hi = 0.20, 0.90
        for _ in range(VMS_PER_WORKLOAD):
            s.submit(VM(f"vm{vm_id}", w, "", VM_CORES,
                        util_p95=rng.uniform(lo, hi),
                        spot="spot" in enrolled or "harvest" in enrolled,
                        harvest="harvest" in enrolled))
            vm_id += 1
    expected_sampled /= n_workloads

    # the meter exists before the first placement so it observes every
    # decision record; agents close the bidirectional loop (ack -> early
    # release -> replacement)
    meter = BillingMeter(s.gm, s.cluster)
    runtime = AgentRuntime(s, policies=policies,
                           default_policy=AgentPolicy(
                               statefulness=STATELESS, scale_out_in=True))
    s.schedule_pending()
    return s, meter, runtime, {
        "shrink": shrink,
        "expected_model": expected_fleet_saving(probs),
        "expected_sampled": expected_sampled,
        "observer": observer,
    }


def run(seed: int = 0, n_workloads: int = N_WORKLOADS,
        n_servers_per_region: int = N_SERVERS_PER_REGION,
        horizon_s: float = HORIZON_S) -> Dict[str, float]:
    rng = random.Random(seed + 1)
    s, meter, runtime, model = build(seed, n_workloads, n_servers_per_region)
    placed0 = s.stats["placed"]

    for wave in range(STORM_WAVES):
        region = "region-0" if wave % 2 == 0 else "region-green"
        s.engine.at(600.0 + wave * 700.0,
                    lambda r=region: s.capacity_crunch(r, WAVE_CORES))
    servers = sorted(s.cluster.servers)
    for i in range(POWER_EVENTS):
        srv = rng.choice(servers)
        s.engine.at(900.0 + i * 500.0,
                    lambda sv=srv: s.power_event(sv, shed_frac=0.3))

    s.start(TICK_S, horizon_s)
    s.run_until(horizon_s)

    summary = meter.summary(horizon_s)
    rec = meter.reconcile(horizon_s)
    ev = s.evictor
    observer: obs.LifecycleObserver = model["observer"]
    life = observer.summary()
    recon = observer.reconcile(ev)
    # the bus-derived lifecycle books must match the pipeline's own —
    # the reported eviction numbers below come from the observer
    assert recon["ok"], recon["diffs"]
    assert life["killed"] == ev.stats.get("kills", 0)
    assert life["early_released"] == ev.stats.get("early_releases", 0)
    assert life["cancelled"] == ev.stats.get("cancellations", 0)
    assert life["violations"] == len(ev.violations())
    from repro.sim.provider_scale import evaluate
    analytic = evaluate()
    out = {
        "saving": summary["saving"],
        "paper_saving": PAPER_TOTAL_SAVING,
        # the analytical §6.4 waterfall the live number is checked against
        "analytic_independence": analytic.saving_independence,
        "analytic_calibrated": analytic.saving_calibrated,
        "abs_err_vs_analytic":
            abs(summary["saving"] - analytic.saving_calibrated),
        "expected_model": model["expected_model"],
        "expected_sampled": model["expected_sampled"],
        "shrink": model["shrink"],
        "abs_err_vs_paper": abs(summary["saving"] - PAPER_TOTAL_SAVING),
        "core_hours": summary["core_hours"],
        "cost": summary["cost"],
        "regular_cost": summary["regular_cost"],
        "vms_metered": summary["vms_metered"],
        "placed": placed0,
        # derived from the bus-fed observer (asserted == evictor books)
        "violations": int(life["violations"]),
        "evictions_killed": int(life["killed"]),
        "early_releases": int(life["early_released"]),
        "cancellations": int(life["cancelled"]),
        "replacements_placed":
            runtime.telemetry().get("replacements_placed", 0.0),
        "lost_work_s": runtime.telemetry().get("lost_work_s", 0.0),
        "min_lead_s": (None if ev.min_lead_time_s() == float("inf")
                       else ev.min_lead_time_s()),
        "policy_passes": s.stats.get("policy_passes", 0),
        "hint_migrations": s.stats.get("hint_migrations", 0),
        "defrag_migrations": s.stats.get("defrag_migrations", 0),
        "power_events": s.stats.get("power_events", 0),
        "metered_core_hours": rec["metered_core_hours"],
        "cluster_core_hours": rec["cluster_core_hours"],
        "reconcile_abs_diff": rec["abs_diff"],
        "migration_displaced": s.placer.stats.get("migration_displaced", 0),
        # lifecycle-histogram rollups (CI bench-smoke reconciles these:
        # every ack must land inside the widest hinted notice window)
        "obs_violations": int(life["violations"]),
        "obs_reconcile_ok": recon["ok"],
        "obs_max_notice_s": life["max_notice_s"],
        "obs_notice_to_ack_p50_s": life["notice_to_ack_s"].get("p50"),
        "obs_notice_to_ack_p100_s": life["notice_to_ack_s"].get("p100"),
        "obs_kill_lead_p50_s": life["kill_lead_s"].get("p50"),
        "obs_acks_observed": life["notice_to_ack_s"].get("count", 0),
    }
    s.gm.close()        # scenario teardown: release WAL/segment handles
    return out


if __name__ == "__main__":
    for k, v in run().items():
        print(f"{k}: {v}")
