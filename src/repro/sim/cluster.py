"""Simulated cluster state: servers, VMs, regions — the "view" dict consumed
by optimization managers (see core/optimizations/managers.py docstring) and
driven by the platform scheduler (sched/).

The cluster also owns the pending-VM queue (submitted but not yet placed),
p95-aware headroom accounting for oversubscribed packing, and region
failover (mark a region's servers down and hand back the displaced VMs so
the scheduler can re-place them).

Accounting is *incremental*: per-server ``used`` / ``p95_used`` running
counters plus a vm-id index are maintained in O(1) on every mutation
(place, unplace, kill, harvest grow/shrink, resize), so ``free_cores`` /
``p95_used`` / ``headroom`` are O(1) lookups instead of O(V) scans, and
``view()`` is a cached snapshot patched from dirty-server / dirty-VM deltas
instead of an O(V+S) rebuild per call.  Mutations made directly on ``VM`` /
``Server`` dataclass fields (legacy callers, tests) are intercepted by
``__setattr__`` once the object is registered with a cluster, so the
counters never go stale; ``recompute()`` provides the from-scratch
cross-check that tests pin the incremental books against.

``view()`` returns a live snapshot owned by the cluster: callers must treat
it as read-only and must not hold it across cluster mutations (every caller
in-tree re-requests it per tick).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Set

# VM fields that feed the per-server counters (and the cached view).
_VM_COUNTED = frozenset(("server", "cores", "util_p95", "harvested",
                         "oversubscribed", "alive"))
# VM fields that only feed the cached view entry.
_VM_VIEWED = frozenset(("workload", "spot", "harvest"))
# Server fields that feed the cached view entry.
_SRV_VIEWED = frozenset(("cores", "power_capped", "up"))


@dataclass
class VM:
    vm_id: str
    workload: str
    server: str                     # "" while pending (unplaced)
    cores: float
    util_p95: float = 0.5
    spot: bool = False
    harvest: bool = False
    harvested: float = 0.0          # extra cores currently harvested
    oversubscribed: bool = False
    alive: bool = True

    def __setattr__(self, name, value):
        cl = self.__dict__.get("_cluster")
        if cl is None:
            object.__setattr__(self, name, value)
        elif name in _VM_COUNTED:
            cl._vm_counted_change(self, name, value)
        else:
            object.__setattr__(self, name, value)
            if name in _VM_VIEWED:
                cl._dirty_vms.add(self.vm_id)


@dataclass
class Server:
    server_id: str
    cores: float
    region: str = "region-0"
    power_capped: bool = False
    up: bool = True

    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        cl = self.__dict__.get("_cluster")
        if cl is not None and name in _SRV_VIEWED:
            cl._dirty_servers.add(self.server_id)


@dataclass
class Region:
    name: str
    price: float = 1.0
    carbon_g_kwh: float = 546.0      # §6.4 baseline grid intensity

    def __setattr__(self, name, value):
        object.__setattr__(self, name, value)
        cl = self.__dict__.get("_cluster")
        if cl is not None:
            cl.regions_version += 1


class Cluster:
    def __init__(self):
        self.servers: Dict[str, Server] = {}
        self.vms: Dict[str, VM] = {}
        self.pending: Deque[VM] = deque()
        self.regions: Dict[str, Region] = {}
        self.regions_version = 0        # bumped on any region add/change
        self._by_region: Dict[str, List[str]] = {}
        # -- incremental accounting (the tentpole) --------------------------
        self._used: Dict[str, float] = {}       # nominal + harvested cores
        self._p95: Dict[str, float] = {}        # p95-aware demand
        self._on_server: Dict[str, Set[str]] = {}   # alive placed vm-ids
        # -- core-hour integral (billing reconciliation) --------------------
        # total allocated cores across all servers, integrated over sim time
        # once a clock is attached (Scheduler attaches its engine clock);
        # the BillingMeter cross-checks its per-VM meters against this.
        self.clock = None                       # callable -> sim seconds
        self._used_total = 0.0
        self._core_seconds = 0.0
        self._accrued_t = 0.0
        # -- cached view ----------------------------------------------------
        self._view: Optional[Dict] = None
        self._dirty_vms: Set[str] = set()
        self._dirty_servers: Set[str] = set()
        self._view_regions_version = -1
        # fired (with the VM) right after any kill_vm marks a VM dead —
        # the workload-side agent runtime uses this to detach agents and
        # meter lost work, whatever path performed the kill
        self.kill_listeners: List = []
        # -- unannounced hardware crashes ------------------------------------
        # vm_id -> crash time, populated by crash_vm BEFORE the kill fires
        # so kill listeners can distinguish a crash from an orchestrated
        # kill; entries are pruned when the scheduler's repair loop drains
        # the queue (membership survives until repair, not forever)
        self.crashed_vms: Dict[str, float] = {}
        self._crash_queue: List[tuple] = []     # (VM, crash_t) awaiting repair
        self.crashes_total = 0
        self.add_region(Region("region-0", 1.0, 546.0))
        self.add_region(Region("region-green", 0.78, 267.0))

    # -- topology -----------------------------------------------------------
    def add_region(self, region: Region):
        self.regions[region.name] = region
        region.__dict__["_cluster"] = self
        self.regions_version += 1

    def add_server(self, server_id: str, cores: float, region="region-0"):
        srv = Server(server_id, cores, region)
        srv.__dict__["_cluster"] = self
        self.servers[server_id] = srv
        self._by_region.setdefault(region, []).append(server_id)
        self._used[server_id] = 0.0
        self._p95[server_id] = 0.0
        self._on_server[server_id] = set()
        self._dirty_servers.add(server_id)

    # -- core-hour integral ---------------------------------------------------
    def attach_clock(self, clock):
        """Start integrating allocated core-seconds on ``clock`` (a callable
        returning sim time).  Attaching resets the integration origin to
        the clock's current instant."""
        self.clock = clock
        self._accrued_t = clock()

    def _accrue_used(self, delta: float):
        """Integrate the running total up to now, then apply a change to
        it.  Every mutation of per-server ``used`` flows through here (or
        through ``_bump_used_total`` from the batch placer's flush)."""
        if self.clock is not None:
            t = self.clock()
            if t > self._accrued_t:
                self._core_seconds += self._used_total * (t - self._accrued_t)
                self._accrued_t = t
        self._used_total += delta

    # placement.py's drain loop accumulates per-server deltas in locals and
    # flushes once per server walk; this is its (cheap) total-counter hook
    _bump_used_total = _accrue_used

    def core_hours(self, now: Optional[float] = None) -> float:
        """Allocated core-hours integrated since the clock was attached."""
        self._accrue_used(0.0)
        extra = 0.0
        if now is not None and now > self._accrued_t:
            extra = self._used_total * (now - self._accrued_t)
            self._core_seconds += extra
            self._accrued_t = now
        return self._core_seconds / 3600.0

    # -- accounting internals ------------------------------------------------
    def _account(self, vm: VM, sign: float):
        """Add (sign=+1) or remove (sign=-1) an alive placed VM's demand."""
        sid = vm.server
        nominal = vm.cores + vm.harvested
        self._used[sid] = self._used.get(sid, 0.0) + sign * nominal
        self._accrue_used(sign * nominal)
        p95 = vm.cores * vm.util_p95 if vm.oversubscribed else nominal
        self._p95[sid] = self._p95.get(sid, 0.0) + sign * p95
        on = self._on_server.get(sid)
        if on is None:
            on = self._on_server[sid] = set()
        if sign > 0:
            on.add(vm.vm_id)
        else:
            on.discard(vm.vm_id)
        self._dirty_servers.add(sid)

    def _vm_counted_change(self, vm: VM, name, value):
        """A registered VM's counted field changes: move its contribution."""
        if vm.alive and vm.server:
            self._account(vm, -1.0)
        object.__setattr__(vm, name, value)
        if vm.alive and vm.server:
            self._account(vm, +1.0)
        self._dirty_vms.add(vm.vm_id)

    def recompute(self) -> Dict[str, Dict[str, float]]:
        """From-scratch accounting (the cross-check the incremental books
        are tested against): {"used": {sid: cores}, "p95_used": {sid: ...}}."""
        used: Dict[str, float] = {sid: 0.0 for sid in self.servers}
        p95: Dict[str, float] = {sid: 0.0 for sid in self.servers}
        for v in self.vms.values():
            if not v.alive or not v.server:
                continue
            nominal = v.cores + v.harvested
            used[v.server] = used.get(v.server, 0.0) + nominal
            p95[v.server] = p95.get(v.server, 0.0) + (
                v.cores * v.util_p95 if v.oversubscribed else nominal)
        return {"used": used, "p95_used": p95}

    def assert_consistent(self, tol: float = 1e-6):
        """Raise if the incremental counters drifted from ground truth."""
        truth = self.recompute()
        for sid in self.servers:
            got_u, want_u = self._used.get(sid, 0.0), truth["used"][sid]
            got_p, want_p = self._p95.get(sid, 0.0), truth["p95_used"][sid]
            if abs(got_u - want_u) > tol or abs(got_p - want_p) > tol:
                raise AssertionError(
                    f"{sid}: incremental used={got_u}/p95={got_p} != "
                    f"recomputed used={want_u}/p95={want_p}")
            index = {vid for vid in self._on_server.get(sid, ())
                     if self.vms.get(vid) is not None}
            truth_index = {v.vm_id for v in self.vms.values()
                           if v.alive and v.server == sid}
            if index != truth_index:
                raise AssertionError(f"{sid}: vm index {index} != "
                                     f"{truth_index}")
        want_total = sum(truth["used"].values())
        if abs(self._used_total - want_total) > tol:
            raise AssertionError(f"used_total {self._used_total} != "
                                 f"{want_total}")

    # -- VM registry ---------------------------------------------------------
    def add_vm(self, vm: VM):
        if vm.__dict__.get("_cluster") is self and \
                self.vms.get(vm.vm_id) is vm:
            return                  # already registered; books are current
        old = self.vms.get(vm.vm_id)
        if old is not None and old is not vm:
            self.remove_vm(vm.vm_id)
        self.vms[vm.vm_id] = vm
        vm.__dict__["_cluster"] = self
        if vm.alive and vm.server:
            self._account(vm, +1.0)
        self._dirty_vms.add(vm.vm_id)

    def place_fresh(self, vm: VM, server_id: str, oversubscribed: bool,
                    p95_demand: float):
        """Batch-placer hot path: register + account a VM landing on
        ``server_id`` in one call (equivalent to setting ``vm.server`` /
        ``vm.oversubscribed`` and calling ``add_vm``, with the interception
        machinery bypassed).  ``p95_demand`` is the caller's already-known
        p95 contribution (``cores*util_p95`` if oversubscribed, else
        ``cores+harvested``)."""
        d = vm.__dict__
        if d.get("_cluster") is self and self.vms.get(vm.vm_id) is vm:
            vm.oversubscribed = oversubscribed  # registered: interception
            vm.server = server_id               # keeps the books
            return
        old = self.vms.get(vm.vm_id)
        if old is not None and old is not vm:
            self.remove_vm(vm.vm_id)
        d["server"] = server_id
        d["oversubscribed"] = oversubscribed
        d["_cluster"] = self
        self.vms[vm.vm_id] = vm
        if vm.alive:
            self._used[server_id] += vm.cores + vm.harvested
            self._accrue_used(vm.cores + vm.harvested)
            self._p95[server_id] += p95_demand
            self._on_server[server_id].add(vm.vm_id)
            self._dirty_servers.add(server_id)
        self._dirty_vms.add(vm.vm_id)

    def remove_vm(self, vm_id: str):
        vm = self.vms.pop(vm_id, None)
        if vm is None:
            return
        if vm.alive and vm.server:
            self._account(vm, -1.0)
        vm.__dict__["_cluster"] = None
        self._dirty_vms.add(vm_id)

    def kill_vm(self, vm_id: str):
        vm = self.vms.get(vm_id)
        if vm is not None and vm.alive:
            vm.alive = False        # interception updates the books
            for cb in self.kill_listeners:
                cb(vm)

    # -- pending queue (scheduler feed) -------------------------------------
    def enqueue(self, vm: VM):
        """Submit an unplaced VM for the scheduler to place."""
        vm.server = ""
        self.pending.append(vm)

    def requeue(self, vm: VM):
        """Put a displaced VM at the front of the queue (failover priority)."""
        vm.server = ""
        self.pending.appendleft(vm)

    # -- accounting (O(1) reads) --------------------------------------------
    def free_cores(self, server_id: str) -> float:
        return self.servers[server_id].cores - self._used.get(server_id, 0.0)

    def p95_used(self, server_id: str) -> float:
        """Expected p95 demand: oversubscribed VMs count at p95 utilization,
        everything else reserves its nominal allocation."""
        return self._p95.get(server_id, 0.0)

    def headroom(self, server_id: str) -> float:
        """p95-aware headroom oversubscription-eligible VMs pack against."""
        return self.servers[server_id].cores - self._p95.get(server_id, 0.0)

    def vm_ids_on(self, server_id: str) -> Set[str]:
        """Alive placed vm-ids on a server (the incremental index)."""
        return self._on_server.get(server_id, set())

    def vms_on(self, server_id: str) -> List[VM]:
        return [self.vms[vid] for vid in self._on_server.get(server_id, ())]

    # -- regions ------------------------------------------------------------
    def servers_in_region(self, region: str) -> List[str]:
        return self._by_region.get(region, [])

    def fail_server(self, server_id: str) -> List[VM]:
        """Mark a server down; return its displaced (still-alive) VMs."""
        self.servers[server_id].up = False
        return self.vms_on(server_id)

    def fail_region(self, region: str) -> List[VM]:
        """Region outage: every server down; displaced VMs returned so the
        scheduler can fail them over to surviving regions."""
        displaced: List[VM] = []
        for sid in self.servers_in_region(region):
            displaced.extend(self.fail_server(sid))
        return displaced

    # -- unannounced hardware crashes ----------------------------------------
    def crash_vm(self, vm_id: str) -> bool:
        """Hardware-crash an alive placed VM: no notice, no power event.
        The crash is recorded *before* the kill so kill listeners (billing,
        agent runtime) can see ``vm_id in cluster.crashed_vms``; the
        scheduler's repair loop later drains the queue, closes the books,
        and publishes the failure.  Returns False when the VM is already
        dead or unplaced (a crash racing an eviction kill is a no-op)."""
        vm = self.vms.get(vm_id)
        if vm is None or not vm.alive or not vm.server:
            return False
        t = self.clock() if self.clock is not None else 0.0
        self.crashed_vms[vm_id] = t
        self._crash_queue.append((vm, t))
        self.crashes_total += 1
        self.kill_vm(vm_id)
        return True

    def crash_server(self, server_id: str) -> List[str]:
        """Whole-host hardware failure: the server goes down and every VM
        on it crashes (sorted order for determinism).  Returns the crashed
        vm-ids."""
        srv = self.servers.get(server_id)
        if srv is None:
            return []
        srv.up = False
        victims = sorted(self.vm_ids_on(server_id))
        return [vid for vid in victims if self.crash_vm(vid)]

    def drain_crashed(self) -> List[tuple]:
        """Hand the un-repaired ``(VM, crash_t)`` queue to the repair loop
        and prune the crash-membership map (listeners that needed it have
        already run)."""
        q, self._crash_queue = self._crash_queue, []
        for vm, _ in q:
            self.crashed_vms.pop(vm.vm_id, None)
        return q

    # -- the cached view -----------------------------------------------------
    def _vm_entry(self, v: VM) -> Dict:
        return {"workload": v.workload, "server": v.server,
                "cores": v.cores, "util_p95": v.util_p95,
                "spot": v.spot, "harvest": v.harvest,
                "harvested": v.harvested,
                "oversubscribed": v.oversubscribed}

    def _server_entry(self, s: Server) -> Dict:
        return {"cores": s.cores,
                "free_cores": s.cores - self._used.get(s.server_id, 0.0),
                "power_cap": s.power_capped,
                "region": s.region,
                "up": s.up}

    def view(self) -> Dict:
        """Cached world snapshot; only dirty VMs/servers are re-rendered.
        The returned dict is owned by the cluster — treat as read-only and
        re-request after any mutation."""
        if self._view is None:
            self._view = {
                "vms": {v.vm_id: self._vm_entry(v)
                        for v in self.vms.values() if v.alive},
                "servers": {s.server_id: self._server_entry(s)
                            for s in self.servers.values()},
                "regions": {},
            }
            self._dirty_vms.clear()
            self._dirty_servers.clear()
        else:
            if self._dirty_vms:
                vms_view = self._view["vms"]
                for vid in self._dirty_vms:
                    v = self.vms.get(vid)
                    if v is None or not v.alive:
                        vms_view.pop(vid, None)
                    else:
                        vms_view[vid] = self._vm_entry(v)
                self._dirty_vms.clear()
            if self._dirty_servers:
                srv_view = self._view["servers"]
                for sid in self._dirty_servers:
                    s = self.servers.get(sid)
                    if s is None:
                        srv_view.pop(sid, None)
                    else:
                        srv_view[sid] = self._server_entry(s)
                self._dirty_servers.clear()
        if self._view_regions_version != self.regions_version:
            self._view["regions"] = {
                r.name: {"price": r.price, "carbon_g_kwh": r.carbon_g_kwh}
                for r in self.regions.values()}
            self._view_regions_version = self.regions_version
        return self._view
