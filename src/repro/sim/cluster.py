"""Simulated cluster state: servers, VMs, regions — the "view" dict consumed
by optimization managers (see core/optimizations/managers.py docstring)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class VM:
    vm_id: str
    workload: str
    server: str
    cores: float
    util_p95: float = 0.5
    spot: bool = False
    harvest: bool = False
    harvested: float = 0.0          # extra cores currently harvested
    oversubscribed: bool = False
    alive: bool = True


@dataclass
class Server:
    server_id: str
    cores: float
    region: str = "region-0"
    power_capped: bool = False


@dataclass
class Region:
    name: str
    price: float = 1.0
    carbon_g_kwh: float = 546.0      # §6.4 baseline grid intensity


class Cluster:
    def __init__(self):
        self.servers: Dict[str, Server] = {}
        self.vms: Dict[str, VM] = {}
        self.regions: Dict[str, Region] = {
            "region-0": Region("region-0", 1.0, 546.0),
            "region-green": Region("region-green", 0.78, 267.0),
        }

    def add_server(self, server_id: str, cores: float, region="region-0"):
        self.servers[server_id] = Server(server_id, cores, region)

    def add_vm(self, vm: VM):
        self.vms[vm.vm_id] = vm

    def remove_vm(self, vm_id: str):
        self.vms.pop(vm_id, None)

    def free_cores(self, server_id: str) -> float:
        used = sum(v.cores + v.harvested for v in self.vms.values()
                   if v.server == server_id and v.alive)
        return self.servers[server_id].cores - used

    def view(self) -> Dict:
        return {
            "vms": {v.vm_id: {"workload": v.workload, "server": v.server,
                              "cores": v.cores, "util_p95": v.util_p95,
                              "spot": v.spot, "harvest": v.harvest,
                              "harvested": v.harvested,
                              "oversubscribed": v.oversubscribed}
                    for v in self.vms.values() if v.alive},
            "servers": {s.server_id: {"cores": s.cores,
                                      "free_cores": self.free_cores(
                                          s.server_id),
                                      "power_cap": s.power_capped}
                        for s in self.servers.values()},
            "regions": {r.name: {"price": r.price,
                                 "carbon_g_kwh": r.carbon_g_kwh}
                        for r in self.regions.values()},
        }
