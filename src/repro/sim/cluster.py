"""Simulated cluster state: servers, VMs, regions — the "view" dict consumed
by optimization managers (see core/optimizations/managers.py docstring) and
driven by the platform scheduler (sched/).

The cluster also owns the pending-VM queue (submitted but not yet placed),
p95-aware headroom accounting for oversubscribed packing, and region
failover (mark a region's servers down and hand back the displaced VMs so
the scheduler can re-place them).
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional


@dataclass
class VM:
    vm_id: str
    workload: str
    server: str                     # "" while pending (unplaced)
    cores: float
    util_p95: float = 0.5
    spot: bool = False
    harvest: bool = False
    harvested: float = 0.0          # extra cores currently harvested
    oversubscribed: bool = False
    alive: bool = True


@dataclass
class Server:
    server_id: str
    cores: float
    region: str = "region-0"
    power_capped: bool = False
    up: bool = True


@dataclass
class Region:
    name: str
    price: float = 1.0
    carbon_g_kwh: float = 546.0      # §6.4 baseline grid intensity


class Cluster:
    def __init__(self):
        self.servers: Dict[str, Server] = {}
        self.vms: Dict[str, VM] = {}
        self.pending: Deque[VM] = deque()
        self.regions: Dict[str, Region] = {
            "region-0": Region("region-0", 1.0, 546.0),
            "region-green": Region("region-green", 0.78, 267.0),
        }
        self._by_region: Dict[str, List[str]] = {}

    def add_server(self, server_id: str, cores: float, region="region-0"):
        self.servers[server_id] = Server(server_id, cores, region)
        self._by_region.setdefault(region, []).append(server_id)

    def add_vm(self, vm: VM):
        self.vms[vm.vm_id] = vm

    def remove_vm(self, vm_id: str):
        self.vms.pop(vm_id, None)

    def kill_vm(self, vm_id: str):
        vm = self.vms.get(vm_id)
        if vm is not None:
            vm.alive = False

    # -- pending queue (scheduler feed) -------------------------------------
    def enqueue(self, vm: VM):
        """Submit an unplaced VM for the scheduler to place."""
        vm.server = ""
        self.pending.append(vm)

    def requeue(self, vm: VM):
        """Put a displaced VM at the front of the queue (failover priority)."""
        vm.server = ""
        self.pending.appendleft(vm)

    # -- accounting ---------------------------------------------------------
    def free_cores(self, server_id: str) -> float:
        used = sum(v.cores + v.harvested for v in self.vms.values()
                   if v.server == server_id and v.alive)
        return self.servers[server_id].cores - used

    def p95_used(self, server_id: str) -> float:
        """Expected p95 demand: oversubscribed VMs count at p95 utilization,
        everything else reserves its nominal allocation."""
        used = 0.0
        for v in self.vms.values():
            if v.server != server_id or not v.alive:
                continue
            used += (v.cores * v.util_p95 if v.oversubscribed
                     else v.cores + v.harvested)
        return used

    def headroom(self, server_id: str) -> float:
        """p95-aware headroom oversubscription-eligible VMs pack against."""
        return self.servers[server_id].cores - self.p95_used(server_id)

    def vms_on(self, server_id: str) -> List[VM]:
        return [v for v in self.vms.values()
                if v.server == server_id and v.alive]

    # -- regions ------------------------------------------------------------
    def servers_in_region(self, region: str) -> List[str]:
        return self._by_region.get(region, [])

    def fail_server(self, server_id: str) -> List[VM]:
        """Mark a server down; return its displaced (still-alive) VMs."""
        self.servers[server_id].up = False
        return self.vms_on(server_id)

    def fail_region(self, region: str) -> List[VM]:
        """Region outage: every server down; displaced VMs returned so the
        scheduler can fail them over to surviving regions."""
        displaced: List[VM] = []
        for sid in self.servers_in_region(region):
            displaced.extend(self.fail_server(sid))
        return displaced

    def view(self) -> Dict:
        used: Dict[str, float] = {}
        for v in self.vms.values():
            if v.alive and v.server:
                used[v.server] = used.get(v.server, 0.0) + v.cores + v.harvested
        return {
            "vms": {v.vm_id: {"workload": v.workload, "server": v.server,
                              "cores": v.cores, "util_p95": v.util_p95,
                              "spot": v.spot, "harvest": v.harvest,
                              "harvested": v.harvested,
                              "oversubscribed": v.oversubscribed}
                    for v in self.vms.values() if v.alive},
            "servers": {s.server_id: {"cores": s.cores,
                                      "free_cores":
                                          s.cores - used.get(s.server_id, 0.0),
                                      "power_cap": s.power_capped,
                                      "region": s.region,
                                      "up": s.up}
                        for s in self.servers.values()},
            "regions": {r.name: {"price": r.price,
                                 "carbon_g_kwh": r.carbon_g_kwh}
                        for r in self.regions.values()},
        }
