"""Deterministic discrete-event engine driving the cluster simulator."""
from __future__ import annotations

import heapq
import itertools
from typing import Callable, Optional


class SimClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t


class Engine:
    def __init__(self):
        self.clock = SimClock()
        self._q = []
        self._seq = itertools.count()
        self.dispatched = 0     # events ever run (observability collector)

    def qsize(self) -> int:
        """Events still queued (includes events beyond any past horizon)."""
        return len(self._q)

    def at(self, t: float, fn: Callable[[], None]):
        heapq.heappush(self._q, (t, next(self._seq), fn))

    def after(self, dt: float, fn: Callable[[], None]):
        self.at(self.clock.t + dt, fn)

    def every(self, dt: float, fn: Callable[[], None], until: float):
        def tick():
            fn()
            if self.clock.t + dt <= until:
                self.after(dt, tick)
        self.after(dt, tick)

    def run(self, until: float = float("inf")):
        while self._q and self._q[0][0] <= until:
            t, _, fn = heapq.heappop(self._q)
            self.clock.t = t
            self.dispatched += 1
            fn()
        # A bounded run always ends exactly at the horizon, even when the
        # event queue drained early (events beyond `until` stay queued).
        if until != float("inf"):
            self.clock.t = max(self.clock.t, until)
