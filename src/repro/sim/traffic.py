"""Seeded constant-rate open-loop traffic for the serving tier (wrk2-style).

The serving bar in this repo is *p99 token latency under reclaim storms*,
and a closed-loop client cannot measure that: when the server stalls, a
closed-loop client stalls with it, silently dropping exactly the samples
that would have shown the tail (coordinated omission).  ``OpenLoopTraffic``
therefore schedules arrivals purely from a rate profile on the sim clock —
the next arrival time is ``t + 1/rate(t)`` regardless of whether previous
requests completed, so a drowning fleet accumulates queue instead of
slowing the workload down.

Pieces:

  * rate profiles — ``constant_rate`` (the wrk2 baseline), ``diurnal_rate``
    (cosine day curve, mirroring ``agents.DiurnalProfile``), ``with_spike``
    (multiplier overlay for a flash-crowd window).  Profiles are plain
    ``t -> requests/s`` callables and compose.
  * ``OpenLoopTraffic`` — the generator.  Seeded RNG draws prompt lengths
    and decode budgets, ``submit`` is any callable taking a
    ``serve.engine.Request`` (the tenant router in the fleet case study, an
    engine's ``submit`` in unit tests).  Completions flow back through
    ``observe_completion`` and land in full ``obs`` latency histograms —
    e2e *and* time-to-first-token — so percentiles come from the same
    bucket math the rest of the fleet reports.
"""
from __future__ import annotations

import math
import random
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.serve.engine import Request

RateFn = Callable[[float], float]


def constant_rate(rps: float) -> RateFn:
    """wrk2-style fixed arrival rate."""
    return lambda t: rps


def diurnal_rate(base_rps: float, peak_rps: float, period_s: float,
                 trough_t: float = 0.0) -> RateFn:
    """Cosine day curve: ``base`` at the trough, ``peak`` half a period
    later."""
    mid = (base_rps + peak_rps) / 2.0
    amp = (peak_rps - base_rps) / 2.0

    def rate(t: float) -> float:
        return mid - amp * math.cos(2.0 * math.pi * (t - trough_t)
                                    / period_s)
    return rate


def with_spike(profile: RateFn, at_s: float, dur_s: float,
               mult: float) -> RateFn:
    """Flash-crowd overlay: multiply ``profile`` by ``mult`` inside the
    window ``[at_s, at_s + dur_s)``."""
    def rate(t: float) -> float:
        r = profile(t)
        if at_s <= t < at_s + dur_s:
            return r * mult
        return r
    return rate


class OpenLoopTraffic:
    """Constant-rate open-loop request generator on the sim clock.

    Arrivals self-schedule: each one books the next at ``t + 1/rate(t)``
    via ``engine.at``, never waiting on a completion — the coordinated
    omission guard the module docstring describes.  ``rate(t) <= 0``
    (a profile can model an overnight dead zone) skips forward in
    ``idle_step_s`` probes until the rate recovers.
    """

    def __init__(self, engine, submit: Callable[[Request], Any],
                 rate_fn: RateFn, horizon_s: float, seed: int = 0,
                 prompt_len: Tuple[int, int] = (2, 8),
                 max_new: Tuple[int, int] = (4, 16),
                 registry: Optional[obs.MetricsRegistry] = None,
                 idle_step_s: float = 1.0):
        self.engine = engine
        self.submit = submit
        self.rate_fn = rate_fn
        self.horizon_s = float(horizon_s)
        self.prompt_len = prompt_len
        self.max_new = max_new
        self.idle_step_s = float(idle_step_s)
        self._rng = random.Random(seed)
        self._next_rid = 0
        self.arrivals: List[float] = []
        reg = registry if registry is not None \
            else obs.MetricsRegistry(enabled=True)
        self.registry = reg
        self.metrics = obs.MetricDict(reg, prefix="wi_traffic_")
        for k in ("offered", "completed"):
            self.metrics[k] = 0.0
        self._e2e = reg.histogram(
            "wi_traffic_e2e_latency_s", "submit->done request latency")
        self._ttft = reg.histogram(
            "wi_traffic_ttft_s", "submit->first-token latency")

    # -- arrival chain -------------------------------------------------------
    def start(self):
        """Arm the arrival chain from the current sim time."""
        self._schedule_next(self.engine.clock.t)

    def _schedule_next(self, t_from: float):
        rate = self.rate_fn(t_from)
        if rate <= 0.0:
            t_next = t_from + self.idle_step_s
            fn = lambda: self._schedule_next(self.engine.clock.t)
        else:
            t_next = t_from + 1.0 / rate
            fn = self._arrive
        if t_next <= self.horizon_s:
            self.engine.at(t_next, fn)

    def _arrive(self):
        now = self.engine.clock.t
        self.arrivals.append(now)
        self.metrics["offered"] += 1
        req = self._make_request(now)
        self.submit(req)
        # open loop: the next arrival is booked from the schedule, not
        # from this request's fate
        self._schedule_next(now)

    def _make_request(self, now: float) -> Request:
        rid = self._next_rid
        self._next_rid += 1
        plen = self._rng.randint(*self.prompt_len)
        toks = np.asarray([self._rng.randrange(256) for _ in range(plen)],
                          np.int32)
        req = Request(rid=rid, prompt=toks,
                      max_new=self._rng.randint(*self.max_new))
        req.t_submit = now
        return req

    # -- completion side -----------------------------------------------------
    def observe_completion(self, req: Request):
        """Latency sink for completed requests (wire to the engine's
        ``on_complete`` or the tenant's ``completion_sinks``)."""
        self.metrics["completed"] += 1
        if req.t_submit is None or req.t_done is None:
            return
        self._e2e.observe(max(0.0, req.t_done - req.t_submit))
        if req.t_first_token is not None:
            self._ttft.observe(max(0.0, req.t_first_token - req.t_submit))

    def summary(self) -> Dict[str, float]:
        e2e = self._e2e.summary()
        ttft = self._ttft.summary()
        offered = self.metrics["offered"]
        completed = self.metrics["completed"]
        dur = self.arrivals[-1] - self.arrivals[0] \
            if len(self.arrivals) > 1 else 0.0
        return {
            "offered": offered,
            "completed": completed,
            "goodput_frac": completed / offered if offered else 0.0,
            "offered_rps": (len(self.arrivals) - 1) / dur if dur else 0.0,
            "e2e_p50_s": e2e["p50"], "e2e_p99_s": e2e["p99"],
            "ttft_p50_s": ttft["p50"], "ttft_p99_s": ttft["p99"],
        }
