"""input_specs(): ShapeDtypeStruct stand-ins for every model input.

Weak-type-correct, shardable, no device allocation — the dry-run lowers
against these.  Also provides the matching input PartitionSpecs and the
step-function builders used by both the dry-run and real launches.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import (ModelConfig, ParallelConfig, ShapeConfig,
                                SHAPES)
from repro.models import model as M
from repro.models.model import VIS_EMBED_DIM

A = jax.ShapeDtypeStruct


def batch_pspec(pcfg: ParallelConfig) -> P:
    return P(tuple(pcfg.dp_axes))


def supports_shape(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k only for sub-quadratic archs (see DESIGN.md §4)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "quadratic attention at 524k context"
    return True, ""


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, A]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        sd = S // cfg.enc_seq_ratio
        return {"frames": A((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": A((B, sd + 1), jnp.int32)}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        return {"patches": A((B, nv, VIS_EMBED_DIM), jnp.bfloat16),
                "tokens": A((B, S - nv + 1), jnp.int32)}
    return {"tokens": A((B, S + 1), jnp.int32)}


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, A]:
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        sd = S // cfg.enc_seq_ratio
        return {"frames": A((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": A((B, sd), jnp.int32)}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        return {"patches": A((B, nv, VIS_EMBED_DIM), jnp.bfloat16),
                "tokens": A((B, S - nv), jnp.int32)}
    return {"tokens": A((B, S), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, A]:
    return {"tokens": A((shape.global_batch, 1), jnp.int32)}


def decode_cache_specs(cfg: ModelConfig, shape: ShapeConfig):
    enc_len = shape.seq_len if cfg.family == "encdec" else 0
    return M.init_cache(cfg, shape.global_batch, shape.seq_len, abstract=True,
                        enc_len=enc_len)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, A]:
    """Every input of the step function lowered for this shape cell."""
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)


def input_pspecs(cfg: ModelConfig, shape: ShapeConfig,
                 pcfg: ParallelConfig) -> Dict[str, P]:
    bp = batch_pspec(pcfg)
    specs = input_specs(cfg, shape)
    return {k: P(bp[0]) if v.ndim == 1 else
            P(bp[0], *([None] * (v.ndim - 1))) for k, v in specs.items()}
