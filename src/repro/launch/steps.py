"""Step-function builders + sharding trees shared by dryrun and real launches."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ModelConfig, ParallelConfig, RunConfig,
                                ShapeConfig)
from repro.models import model as M
from repro.models import sharding as SH
from repro.train import optimizer as opt
from repro.train.train_step import make_train_step


def train_shardings(cfg: ModelConfig, pcfg: ParallelConfig, mesh):
    rules = SH.rules_train(cfg, pcfg)
    pshard = SH.tree_shardings(M.param_axes(cfg), mesh, rules)
    oshard = opt.OptState(count=NamedSharding(mesh, P()),
                          m=pshard, v=pshard)
    return pshard, oshard, rules


def decode_shardings(cfg: ModelConfig, pcfg: ParallelConfig, mesh,
                     shape: ShapeConfig = None):
    rules = SH.rules_decode(cfg, pcfg)
    if shape is not None:
        dp = 1
        for a in pcfg.dp_axes:
            dp *= dict(zip(pcfg.axis_names(), pcfg.mesh_shape()))[a]
        if shape.global_batch % dp:
            rules = dict(rules)
            rules["batch"] = None    # e.g. long_500k batch=1: replicate
    pshard = SH.tree_shardings(M.param_axes(cfg), mesh, rules)
    cshard = SH.tree_shardings(M.cache_logical_axes(cfg), mesh, rules)
    return pshard, cshard, rules


def batch_shardings(cfg: ModelConfig, shape: ShapeConfig,
                    pcfg: ParallelConfig, mesh, specs: Dict[str, Any]):
    """Batch-dim sharding; replicate if the batch doesn't divide the axes."""
    dp = 1
    for a in pcfg.dp_axes:
        dp *= dict(zip(pcfg.axis_names(), pcfg.mesh_shape()))[a]
    axes = tuple(pcfg.dp_axes) if shape.global_batch % dp == 0 else None
    return {k: NamedSharding(mesh, P(axes, *([None] * (v.ndim - 1))))
            for k, v in specs.items()}


def build_train_fn(cfg: ModelConfig, pcfg: ParallelConfig, rcfg: RunConfig,
                   mesh):
    step = make_train_step(cfg, pcfg, rcfg, mesh=mesh)

    def train_step(params, opt_state, batch):
        return step(params, opt_state, batch)

    return train_step


def build_prefill_fn(cfg: ModelConfig, pcfg: ParallelConfig):
    def prefill_step(params, batch, cache):
        return M.prefill(cfg, pcfg, params, batch, cache)
    return prefill_step


def build_serve_fn(cfg: ModelConfig, pcfg: ParallelConfig):
    def serve_step(params, cache, tokens):
        return M.decode_step(cfg, pcfg, params, cache, tokens)
    return serve_step
