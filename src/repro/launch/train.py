"""Training launcher: run any assigned architecture under the WI runtime.

    PYTHONPATH=src python -m repro.launch.train --arch minitron-8b --smoke \\
        --steps 50 [--devices 8] [--model-axis 2] [--ckpt-dir /tmp/ck] \\
        [--inject-eviction-at 20] [--batch 16] [--seq 128]

--smoke uses the reduced config (CPU-friendly); without it the full config
is used (requires a real TPU slice — the production mesh shardings come
from launch/steps.py).  ``--devices N`` forces N virtual host devices
(set before jax import, so it must be the launcher, not the library).
"""
import argparse
import os
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--devices", type=int, default=0)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--inject-eviction-at", type=int, default=0)
    ap.add_argument("--inject-harvest-at", type=int, default=0)
    ap.add_argument("--data", default=None, help="tokenized binary file")
    args = ap.parse_args()

    if args.devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.devices}")

    import tempfile
    from repro.configs.archs import ARCHS, smoke_config
    from repro.configs.base import RunConfig
    from repro.core.global_manager import GlobalManager
    from repro.data.pipeline import DataConfig
    from repro.runtime.faults import FaultInjector
    from repro.runtime.trainer import WITrainer

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    rcfg = RunConfig(model=cfg, learning_rate=args.lr,
                     warmup_steps=max(args.steps // 10, 1),
                     total_steps=args.steps)
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    dcfg = (DataConfig(kind="file", path=args.data) if args.data
            else DataConfig())
    tr = WITrainer(rcfg, gm, ckpt_dir=args.ckpt_dir or tempfile.mkdtemp(),
                   model_axis=args.model_axis, ckpt_every=args.ckpt_every,
                   batch_override=args.batch, seq_override=args.seq,
                   data_cfg=dcfg)
    inj = FaultInjector(gm, "train-job")

    def hooks(t):
        if args.inject_eviction_at and t.step == args.inject_eviction_at:
            print(f"[wi] injecting eviction at step {t.step}", flush=True)
            inj.evict(n_devices=t.model_axis)
        if args.inject_harvest_at and t.step == args.inject_harvest_at:
            print(f"[wi] injecting harvest offer at step {t.step}",
                  flush=True)
            inj.offer_capacity(n_devices=t.model_axis)

    tr.run(args.steps, step_callback=hooks)
    for m in tr.metrics_log[:: max(1, args.steps // 20)]:
        print(f"step {m['step']:5d} loss {m['loss']:.4f} dp {m['dp']} "
              f"{m['ms']:.0f} ms")
    print(f"final loss {tr.metrics_log[-1]['loss']:.4f}; "
          f"events: {[e['kind'] for e in tr.events_log]}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
