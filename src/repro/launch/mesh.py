"""Mesh construction.  Functions, not module-level constants — importing this
module never touches jax device state.

``axis_types`` is deliberately not passed: newer jax defaults every axis to
``AxisType.Auto`` already, and older jax (<0.5) has neither the enum nor the
kwarg — omitting it is the one spelling that works everywhere.
"""
from __future__ import annotations

import jax

from repro.configs.base import ParallelConfig


def make_production_mesh(*, multi_pod: bool = False):
    """The production mesh: one v5e pod (16x16) or two pods (2x16x16)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(pcfg: ParallelConfig):
    return jax.make_mesh(pcfg.mesh_shape(), pcfg.axis_names())


def local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (virtual) devices this host exposes."""
    return jax.make_mesh((data, model), ("data", "model"))
