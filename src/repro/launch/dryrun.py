import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes and record memory / cost / collective statistics.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Outputs one JSON per cell under results/dryrun/<mesh>/.
This is the only entry point that forces 512 host devices (see module top —
set before any jax import); smoke tests and benchmarks see 1 device.
"""

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import hlo_stats
from repro.configs.archs import ARCHS
from repro.configs.base import (ParallelConfig, RunConfig, SHAPES,
                                pconfig_replace)
from repro.launch import specs as SP
from repro.launch import steps as ST
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models import sharding as SH
from repro.train import optimizer as opt


# ---------------------------------------------------------------------------
# Per-cell parallel-config overrides (memory fits; see DESIGN.md §5)
# ---------------------------------------------------------------------------

def cell_pcfg(arch: str, shape_name: str, multi_pod: bool,
              optimized: bool = False, **extra) -> ParallelConfig:
    """Baseline per-cell parallel config; ``optimized=True`` applies the
    EXPERIMENTS.md §Perf winners (SP off, MoE capacity-dim sharding)."""
    kind = SHAPES[shape_name].kind
    kw = dict(pod=2 if multi_pod else 1, data=16, model=16,
              attn_impl="flash", loss_chunk=512)
    if kind == "train":
        kw.update(fsdp=True, seq_shard_acts=True)
        if arch == "llama3-405b":
            kw.update(microbatch=8, opt_state_dtype="bfloat16",
                      grad_accum_dtype="bfloat16")
        elif arch in ("gemma2-27b", "internvl2-26b"):
            kw.update(microbatch=2)
    else:
        # serving: replicate weights over the data axis unless they don't fit
        kw.update(fsdp=(arch == "llama3-405b"), seq_shard_acts=False)
    if optimized:
        # §Perf family-aware rule: SP-off wins on attention-dominant archs
        # (-33..79% dominant term) but REGRESSES ssm/hybrid/encdec
        # (+8..60%, measured) — their small d_model activations benefit
        # from staying sequence-sharded. MoE capacity sharding always on.
        fam = ARCHS[arch].family
        kw.update(moe_cap_shard=True)
        if kind == "train" and fam in ("dense", "moe", "vlm"):
            kw.update(seq_shard_acts=False)
    kw.update(extra)
    return ParallelConfig(**kw)


def _abstractify(tree):
    return jax.tree.map(lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               pcfg: ParallelConfig = None, mesh=None):
    """Returns (lowered, meta) for one cell."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, why = SP.supports_shape(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    pcfg = pcfg or cell_pcfg(arch, shape_name, multi_pod)
    mesh = mesh if mesh is not None else make_production_mesh(
        multi_pod=multi_pod)
    rcfg = RunConfig(model=cfg)
    ins = SP.input_specs(cfg, shape)
    bshard = ST.batch_shardings(cfg, shape, pcfg, mesh, ins)

    if shape.kind == "train":
        pshard, oshard, rules = ST.train_shardings(cfg, pcfg, mesh)
        SH.set_mesh(mesh, rules)
        params = M.abstract_params(cfg)
        ostate = opt.init_opt_state(rcfg, params, pcfg, abstract=True)
        fn = ST.build_train_fn(cfg, pcfg, rcfg, mesh)
        jf = jax.jit(fn, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, None),
                     donate_argnums=(0, 1))
        lowered = jf.lower(params, ostate, ins)
    elif shape.kind == "prefill":
        pshard, cshard, rules = ST.decode_shardings(cfg, pcfg, mesh, shape)
        SH.set_mesh(mesh, rules)
        params = M.abstract_params(cfg)
        cache = SP.decode_cache_specs(cfg, shape)
        fn = ST.build_prefill_fn(cfg, pcfg)
        jf = jax.jit(fn, in_shardings=(pshard, bshard, cshard),
                     out_shardings=(None, cshard), donate_argnums=(2,))
        lowered = jf.lower(params, ins, cache)
    else:  # decode
        pshard, cshard, rules = ST.decode_shardings(cfg, pcfg, mesh, shape)
        SH.set_mesh(mesh, rules)
        params = M.abstract_params(cfg)
        cache = SP.decode_cache_specs(cfg, shape)
        fn = ST.build_serve_fn(cfg, pcfg)
        jf = jax.jit(fn, in_shardings=(pshard, cshard, bshard["tokens"]),
                     out_shardings=(None, cshard), donate_argnums=(1,))
        lowered = jf.lower(params, cache, ins["tokens"])

    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "kind": shape.kind,
            "pcfg": dataclasses.asdict(pcfg)}
    return lowered, meta


def run_cell(arch: str, shape_name: str, multi_pod: bool, outdir: Path,
             pcfg=None, mesh=None, tag=""):
    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16", "tag": tag}
    try:
        lowered, meta = lower_cell(arch, shape_name, multi_pod, pcfg=pcfg,
                                   mesh=mesh)
        if lowered is None:
            rec.update(status="skipped", reason=meta["skipped"])
            return _write(rec, outdir)
        rec.update(meta)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        n_dev = 512 if multi_pod else 256
        txt = compiled.as_text()
        hs = hlo_stats.analyze(txt, n_dev)
        rec.update(
            status="ok", t_lower_s=round(t_lower, 2),
            t_compile_s=round(t_compile, 2),
            memory={
                "argument_bytes_per_dev": mem.argument_size_in_bytes,
                "output_bytes_per_dev": mem.output_size_in_bytes,
                "temp_bytes_per_dev": mem.temp_size_in_bytes,
                "alias_bytes_per_dev": mem.alias_size_in_bytes,
                "peak_bytes_per_dev": (mem.argument_size_in_bytes
                                       + mem.temp_size_in_bytes
                                       + mem.output_size_in_bytes
                                       - mem.alias_size_in_bytes),
            },
            cost_analysis={"flops": ca.get("flops", 0.0),
                           "bytes_accessed": ca.get("bytes accessed", 0.0)},
            hlo={"dot_flops_per_dev": hs.dot_flops,
                 "mem_bytes_per_dev": hs.mem_bytes,
                 "collective_wire_bytes_per_dev": hs.collective_wire_bytes,
                 "collective_by_kind": hs.collective_by_kind,
                 "n_collectives": hs.n_collectives,
                 "collective_by_group": {str(k): v for k, v in hs.collective_by_group.items()},
                 "unknown_loops": hs.unknown_loops},
            hlo_chars=len(txt),
        )
    except Exception as e:   # a failing cell is a bug; record it loudly
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    return _write(rec, outdir)


def _write(rec, outdir: Path):
    outdir.mkdir(parents=True, exist_ok=True)
    tag = f"__{rec['tag']}" if rec.get("tag") else ""
    path = outdir / f"{rec['arch']}__{rec['shape']}{tag}.json"
    path.write_text(json.dumps(rec, indent=1, default=str))
    status = rec["status"]
    extra = ""
    if status == "ok":
        gb = rec["memory"]["peak_bytes_per_dev"] / 2 ** 30
        extra = (f" peak={gb:.2f}GiB/dev coll="
                 f"{rec['hlo']['collective_wire_bytes_per_dev']/2**30:.3f}GiB"
                 f" lower={rec['t_lower_s']}s compile={rec['t_compile_s']}s")
    elif status == "error":
        extra = " " + rec["error"][:200]
    elif status == "skipped":
        extra = " " + rec["reason"]
    print(f"[dryrun] {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:8s} "
          f"{status}{extra}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--optimized", action="store_true")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    cells = []
    if args.all:
        for a in ARCHS:
            for s in SHAPES:
                cells.append((a, s))
    else:
        archs = [args.arch] if args.arch else list(ARCHS)
        shapes = [args.shape] if args.shape else list(SHAPES)
        for a in archs:
            for s in shapes:
                cells.append((a, s))

    for mp in meshes:
        mesh = make_production_mesh(multi_pod=mp)
        outdir = Path(args.out) / ("2x16x16" if mp else "16x16")
        for a, s in cells:
            pc = cell_pcfg(a, s, mp, optimized=True) if args.optimized \
                else None
            run_cell(a, s, mp, outdir, mesh=mesh, pcfg=pc,
                     tag="opt" if args.optimized else "")


if __name__ == "__main__":
    main()
