"""Serving launcher: batched decode for any assigned architecture.

    PYTHONPATH=src python -m repro.launch.serve --arch minitron-8b --smoke \\
        --requests 12 --slots 4 --max-new 16

Serves synthetic prompts through the continuous-batching engine and prints
throughput; the engine publishes WI runtime hints (utilization-based
preemptibility) through a local manager, exactly like the training runtime.
"""
import argparse
import sys
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import numpy as np
    from repro.configs.archs import ARCHS, smoke_config
    from repro.configs.base import ParallelConfig
    from repro.core.global_manager import GlobalManager
    from repro.core.local_manager import LocalManager
    from repro.models import model as M
    from repro.serve.engine import Request, ServingEngine

    cfg = smoke_config(args.arch) if args.smoke else ARCHS[args.arch]
    pcfg = ParallelConfig(data=1, model=1, attn_impl="dense", fsdp=False,
                          seq_shard_acts=False)
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("serve-job", {"scale_out_in": True,
                                       "delay_tolerance_ms": 500.0,
                                       "preemptibility_pct": 30.0})
    lm = LocalManager("rack0/srv0", gm.bus, clock=gm.clock,
                      vm_hint_rate_per_s=1e6, vm_hint_burst=1e6)
    ep = lm.attach_vm("vm0", "serve-job")

    eng = ServingEngine(cfg, pcfg, params, batch_slots=args.slots,
                        max_len=args.max_len, seed=args.seed)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=args.prompt_len)
                    .astype(np.int32), max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    for r in reqs:
        eng.submit(r)
    steps = 0
    while (any(eng._active) or eng.queue_depth()) and steps < 100_000:
        eng.step()
        steps += 1
        if steps % 16 == 0:
            ep.set_runtime_hints({
                "preemptibility_pct": 20.0 if eng.utilization() > 0.5
                else 80.0,
                "x-utilization": eng.utilization(),
                "x-queue-depth": eng.queue_depth()})
    dt = time.perf_counter() - t0
    done = sum(r.done for r in reqs)
    toks = sum(len(r.out_tokens) for r in reqs)
    print(f"served {done}/{len(reqs)} requests, {toks} tokens in {dt:.2f}s "
          f"({toks / dt:.1f} tok/s, {steps} engine steps)")
    print(f"engine stats: {eng.stats}; hints forwarded: "
          f"{lm.stats['vm_hints_forwarded']}")
    print("sample:", reqs[0].out_tokens[:10])
    return 0 if done == len(reqs) else 1


if __name__ == "__main__":
    sys.exit(main())
