"""Sub-layer dispatch: params / logical axes / apply for each block kind.

Block kinds: 'attn', 'attn_local', 'mlp', 'moe', 'ssd', 'rglru', 'cross_attn'.
Every sublayer is pre-norm (optionally sandwich post-norm, gemma-2 style) and
residual.  Apply functions return (x, aux, cache_update) so MoE aux losses and
decode-cache updates flow through a uniform interface.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models.layers import basic
from repro.models.layers.attention import (attn_axes, attn_params,
                                           decode_attention_local,
                                           dense_attention, finalize_decode,
                                           qkv)
from repro.models.layers.flash import flash_attention
from repro.models.layers.moe import moe, moe_axes, moe_params
from repro.models.layers.rglru import (rglru_axes, rglru_block, rglru_params,
                                       rglru_init_state)
from repro.models.layers.ssd import (ssd_axes, ssd_block, ssd_params,
                                     ssd_init_state)

A = jax.ShapeDtypeStruct


def _acfg(cfg: ModelConfig, kind: str):
    if kind == "attn_local":
        assert cfg.attn_local is not None
        return cfg.attn_local
    return cfg.attn


def sublayer_params(cfg: ModelConfig, kind: str, dtype, key=None):
    d = cfg.d_model
    norm = {"norm_in": basic.rmsnorm_params(d, dtype, key)}
    if cfg.post_block_norm:
        norm["norm_out"] = basic.rmsnorm_params(d, dtype, key)
    k2 = jax.random.split(key)[1] if key is not None else None
    if kind in ("attn", "attn_local", "cross_attn"):
        core = attn_params(d, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype, k2)
    elif kind == "mlp":
        core = basic.mlp_params(d, cfg.d_ff, dtype, k2)
    elif kind == "moe":
        core = moe_params(d, cfg.moe, dtype, k2)
    elif kind == "ssd":
        core = ssd_params(d, cfg.ssd, dtype, k2)
    elif kind == "rglru":
        core = rglru_params(d, cfg.rglru, dtype, k2)
    else:
        raise ValueError(kind)
    return {**norm, "core": core}


def sublayer_axes(cfg: ModelConfig, kind: str):
    norm = {"norm_in": basic.rmsnorm_axes()}
    if cfg.post_block_norm:
        norm["norm_out"] = basic.rmsnorm_axes()
    if kind in ("attn", "attn_local", "cross_attn"):
        core = attn_axes()
    elif kind == "mlp":
        core = basic.mlp_axes()
    elif kind == "moe":
        core = moe_axes()
    elif kind == "ssd":
        core = ssd_axes()
    elif kind == "rglru":
        core = rglru_axes()
    else:
        raise ValueError(kind)
    return {**norm, "core": core}


# ---------------------------------------------------------------------------
# apply — full-sequence (train / prefill)
# ---------------------------------------------------------------------------

def apply_sublayer(cfg: ModelConfig, pcfg: ParallelConfig, kind: str, p, x,
                   positions, enc_out=None, cache=None, decode_index=None):
    """Returns (x_new, aux_loss, new_cache_entry)."""
    acfg = _acfg(cfg, kind)
    h = basic.rmsnorm(p["norm_in"], x, cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    new_cache = cache

    if kind in ("attn", "attn_local"):
        if decode_index is None:
            q, k, v = qkv(p["core"], h, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, positions, cfg.rope_theta)
            if cache is not None:   # prefill: also populate the cache
                new_cache = dict(cache)
                new_cache["k"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"].astype(k.dtype), k, 0, axis=1)
                new_cache["v"] = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"].astype(v.dtype), v, 0, axis=1)
            if pcfg.attn_impl == "dense":
                o = dense_attention(q, k, v, acfg)
            else:
                o = flash_attention(q, k, v, acfg, pcfg.flash_q_chunk,
                                    pcfg.flash_kv_chunk, pcfg.flash_causal_skip)
            o = o.reshape(*h.shape[:2], cfg.n_heads * cfg.head_dim)
            h = o @ p["core"]["wo"]
        else:                       # single-token decode against the cache
            q, k, v = qkv(p["core"], h, cfg.n_heads, cfg.n_kv_heads,
                          cfg.head_dim, positions, cfg.rope_theta)
            idx = jnp.broadcast_to(jnp.asarray(decode_index), (h.shape[0],))
            upd = jax.vmap(lambda c, u, s: jax.lax.dynamic_update_slice(
                c, u, (s, 0, 0)))
            new_cache = dict(cache)
            new_cache["k"] = upd(cache["k"], k.astype(cache["k"].dtype), idx)
            new_cache["v"] = upd(cache["v"], v.astype(cache["v"].dtype), idx)
            num, den, m = decode_attention_local(
                q, new_cache["k"], new_cache["v"], idx + 1, acfg)
            o = finalize_decode(num, den, m).astype(h.dtype)
            o = o.reshape(h.shape[0], 1, cfg.n_heads * cfg.head_dim)
            h = o @ p["core"]["wo"]

    elif kind == "cross_attn":
        if decode_index is None:
            # training / prefill: compute cross K/V from encoder output
            B, Se, _ = enc_out.shape
            k = (enc_out @ p["core"]["wk"]).reshape(B, Se, cfg.n_kv_heads,
                                                    cfg.head_dim)
            v = (enc_out @ p["core"]["wv"]).reshape(B, Se, cfg.n_kv_heads,
                                                    cfg.head_dim)
            if cache is not None:
                new_cache = {"k": k.astype(cache["k"].dtype),
                             "v": v.astype(cache["v"].dtype)}
        else:
            k, v = cache["k"], cache["v"]
            new_cache = cache
        B, Sd, _ = h.shape
        q = (h @ p["core"]["wq"]).reshape(B, Sd, cfg.n_heads, cfg.head_dim)
        from repro.configs.base import AttnConfig
        xacfg = AttnConfig(causal=False)
        if decode_index is None and pcfg.attn_impl != "dense" and Sd > 1:
            o = flash_attention(q, k.astype(h.dtype), v.astype(h.dtype), xacfg,
                                pcfg.flash_q_chunk, pcfg.flash_kv_chunk, False)
        else:
            o = dense_attention(q, k.astype(h.dtype), v.astype(h.dtype), xacfg)
        h = o.reshape(B, Sd, cfg.n_heads * cfg.head_dim) @ p["core"]["wo"]

    elif kind == "mlp":
        h = basic.mlp(p["core"], h)

    elif kind == "moe":
        h, aux = moe(p["core"], h, cfg.moe, cap_shard=pcfg.moe_cap_shard)

    elif kind == "ssd":
        st = None if cache is None or decode_index is None else cache["state"]
        cv = None if cache is None or decode_index is None else cache["conv"]
        h, (new_st, new_cv) = ssd_block(p["core"], h, cfg.ssd, cfg.d_model,
                                        state=st, conv_state=cv,
                                        rms_eps=cfg.rms_eps)
        if cache is not None:
            new_cache = {"state": new_st, "conv": new_cv}

    elif kind == "rglru":
        st = None if cache is None or decode_index is None else cache["state"]
        cv = None if cache is None or decode_index is None else cache["conv"]
        h, (new_st, new_cv) = rglru_block(p["core"], h, cfg.rglru,
                                          state=st, conv_state=cv)
        if cache is not None:
            new_cache = {"state": new_st, "conv": new_cv}
    else:
        raise ValueError(kind)

    if cfg.post_block_norm:
        h = basic.rmsnorm(p["norm_out"], h, cfg.rms_eps)
    return (x + h).astype(x.dtype), aux, new_cache


def sublayer_cache(cfg: ModelConfig, kind: str, batch, max_len, cache_dtype,
                   abstract=False, enc_len=0):
    """Abstract/zero cache entry for one sublayer (None if stateless)."""
    if kind in ("attn", "attn_local"):
        shp = (batch, max_len, cfg.n_kv_heads, cfg.head_dim)
        if abstract:
            return {"k": A(shp, cache_dtype), "v": A(shp, cache_dtype)}
        return {"k": jnp.zeros(shp, cache_dtype), "v": jnp.zeros(shp, cache_dtype)}
    if kind == "cross_attn":
        shp = (batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
        if abstract:
            return {"k": A(shp, cache_dtype), "v": A(shp, cache_dtype)}
        return {"k": jnp.zeros(shp, cache_dtype), "v": jnp.zeros(shp, cache_dtype)}
    if kind == "ssd":
        return ssd_init_state(batch, cfg.d_model, cfg.ssd, cache_dtype, abstract)
    if kind == "rglru":
        return rglru_init_state(batch, cfg.d_model, cfg.rglru, cache_dtype,
                                abstract)
    return None


def cache_axes(kind: str):
    """Logical axes for a sublayer cache entry (leading scan dim added later)."""
    if kind in ("attn", "attn_local"):
        return {"k": ("batch", "kv_seq", None, None),
                "v": ("batch", "kv_seq", None, None)}
    if kind == "cross_attn":
        return {"k": ("batch", "kv_seq", None, None),
                "v": ("batch", "kv_seq", None, None)}
    if kind == "ssd":
        return {"state": ("batch", "ssm_heads", None, None),
                "conv": ("batch", None, "inner")}
    if kind == "rglru":
        return {"state": ("batch", "inner"), "conv": ("batch", None, "inner")}
    return None
