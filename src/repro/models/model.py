"""Unified model assembly for all assigned architectures.

A model is: embedding (+ optional modality frontend) -> a stack of scanned
*groups* -> final norm -> (un)embedding.  Each group repeats a block
``pattern`` R times via ``lax.scan`` over stacked parameters, with
``jax.remat`` inside the body (compile-time and memory control: the 126-layer
llama3-405b train step lowers+compiles in seconds).

Entry points:
  abstract_params / init_params / param_axes
  loss_and_aux (train), prefill, decode_step, init_cache
  count_params (analytic, cross-checked against the tree in tests)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import blocks
from repro.models.layers import basic
from repro.models.sharding import constrain

A = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# Stack structure
# ---------------------------------------------------------------------------

def stack_groups(cfg: ModelConfig, n_layers=None) -> List[Tuple[Tuple, int]]:
    """[(pattern, repeats), ...] covering n_layers total layers."""
    n = cfg.n_layers if n_layers is None else n_layers
    u = len(cfg.pattern)
    groups = []
    if n // u:
        groups.append((cfg.pattern, n // u))
    if n % u:
        groups.append((cfg.pattern[: n % u], 1))
    return groups


def _unit_params(cfg, pattern, dtype, key=None):
    import zlib
    out = {}
    for i, layer_kinds in enumerate(pattern):
        for kind in layer_kinds:
            k = (jax.random.fold_in(key, zlib.crc32(f"{i}.{kind}".encode()))
                 if key is not None else None)
            out[f"{i}.{kind}"] = blocks.sublayer_params(cfg, kind, dtype, k)
    return out


def _stack(tree, r):
    return jax.tree.map(
        lambda l: A((r,) + l.shape, l.dtype) if isinstance(l, A)
        else jnp.broadcast_to(l, (r,) + l.shape), tree)


def _params(cfg: ModelConfig, key=None) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.act_dtype)
    ks = jax.random.split(key, 8) if key is not None else [None] * 8
    p: Dict[str, Any] = {
        "embed": basic.embed_params(cfg.padded_vocab, cfg.d_model, dtype, ks[0],
                                    tie=cfg.tie_embeddings),
        "final_norm": basic.rmsnorm_params(cfg.d_model, dtype, ks[1]),
    }
    groups = []
    for gi, (pattern, r) in enumerate(stack_groups(cfg)):
        if key is None:
            unit = _unit_params(cfg, pattern, dtype, None)
            groups.append(_stack(unit, r))
        else:
            kr = jax.random.split(jax.random.fold_in(ks[2], gi), r)
            groups.append(jax.vmap(
                lambda k: _unit_params(cfg, pattern, dtype, k))(kr))
    p["groups"] = groups
    if cfg.family == "encdec":
        enc_groups = []
        enc_cfg = _encoder_cfg(cfg)
        for gi, (pattern, r) in enumerate(stack_groups(enc_cfg)):
            if key is None:
                enc_groups.append(_stack(_unit_params(enc_cfg, pattern, dtype,
                                                      None), r))
            else:
                kr = jax.random.split(jax.random.fold_in(ks[3], gi), r)
                enc_groups.append(jax.vmap(
                    lambda k: _unit_params(enc_cfg, pattern, dtype, k))(kr))
        p["enc_groups"] = enc_groups
        p["enc_norm"] = basic.rmsnorm_params(cfg.d_model, dtype, ks[4])
    if cfg.family == "vlm":
        p["vis_proj"] = basic._leaf((VIS_EMBED_DIM, cfg.d_model), dtype, ks[5],
                                    "normal")
    return p


VIS_EMBED_DIM = 3200  # InternViT-6B hidden size (frontend stub output)


def abstract_params(cfg):
    return _params(cfg, None)


def init_params(cfg, key):
    return _params(cfg, key)


def _encoder_cfg(cfg: ModelConfig) -> ModelConfig:
    from repro.configs.base import AttnConfig, mconfig_replace
    return mconfig_replace(cfg, n_layers=cfg.enc_layers,
                           pattern=(("attn", "mlp"),),
                           attn=AttnConfig(causal=False))


def param_axes(cfg: ModelConfig):
    """Tree of logical-axis tuples matching abstract_params (scan dim first)."""
    def unit_axes(c, pattern):
        out = {}
        for i, layer_kinds in enumerate(pattern):
            for kind in layer_kinds:
                sub = blocks.sublayer_axes(c, kind)
                out[f"{i}.{kind}"] = jax.tree.map(
                    lambda ax: ("layers",) + ax, sub,
                    is_leaf=lambda v: isinstance(v, tuple))
        return out

    axes: Dict[str, Any] = {
        "embed": basic.embed_axes(tie=cfg.tie_embeddings),
        "final_norm": basic.rmsnorm_axes(),
        "groups": [unit_axes(cfg, pat) for pat, _ in stack_groups(cfg)],
    }
    if cfg.family == "encdec":
        ec = _encoder_cfg(cfg)
        axes["enc_groups"] = [unit_axes(ec, pat) for pat, _ in stack_groups(ec)]
        axes["enc_norm"] = basic.rmsnorm_axes()
    if cfg.family == "vlm":
        axes["vis_proj"] = (None, "embed")
    return axes


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------

def _fsdp_gather_axes(cfg, pattern):
    """Per-unit logical axes with the FSDP-mapped axes dropped (scan-slice
    view, no leading 'layers').  Constraining the sliced weights to these
    axes *inside* the scan body makes GSPMD all-gather one layer per
    iteration instead of resharding the whole stacked array before the loop
    (measured: 18.5 -> ~2 GiB/device fwd temp on llama3-405b)."""
    out = {}
    for i, layer_kinds in enumerate(pattern):
        for kind in layer_kinds:
            sub = blocks.sublayer_axes(cfg, kind)
            out[f"{i}.{kind}"] = jax.tree.map(
                lambda ax: tuple(None if a in ("embed", "inner_in") else a
                                 for a in ax),
                sub, is_leaf=lambda v: isinstance(v, tuple))
    return out


def _run_groups(cfg, pcfg, groups_p, patterns, x, positions, enc_out=None,
                caches=None, decode_index=None, remat=True):
    """Scan every group.  Returns (x, aux_sum, new_caches)."""
    aux_total = jnp.zeros((), jnp.float32)
    new_caches = [] if caches is not None else None

    for gi, (pattern, _) in enumerate(patterns):
        unit_p = groups_p[gi]
        cache_g = caches[gi] if caches is not None else None
        gather_axes = _fsdp_gather_axes(cfg, pattern) if pcfg.fsdp else None

        def body(carry, xs, _pattern=pattern, _gather=gather_axes):
            xx, aux = carry
            up, uc = xs
            if _gather is not None:
                up = jax.tree.map(lambda w, ax: constrain(w, ax), up, _gather)
                if pcfg.gather_barrier:
                    # pin the gathered weights here: without the barrier XLA
                    # sinks the all-gathers into the flash-attention inner
                    # loops and re-gathers per chunk (measured 20x wire
                    # bytes on llama3-405b/train_4k — §Perf iteration 1)
                    up = jax.lax.optimization_barrier(up)
            ncache = {} if uc is not None else None
            for i, layer_kinds in enumerate(_pattern):
                for kind in layer_kinds:
                    key = f"{i}.{kind}"
                    c_in = uc.get(key) if uc is not None else None
                    c_in = c_in if c_in else None  # {} placeholder -> None
                    xx, a, c_out = blocks.apply_sublayer(
                        cfg, pcfg, kind, up[key], xx, positions,
                        enc_out=enc_out, cache=c_in, decode_index=decode_index)
                    if pcfg.seq_shard_acts and decode_index is None:
                        xx = constrain(xx, ("batch", "seq", None))
                    aux = aux + a
                    if ncache is not None:
                        ncache[key] = c_out if c_out is not None else {}
            return (xx, aux), ncache

        if remat and decode_index is None and pcfg.remat != "none":
            policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                      if pcfg.remat == "dots" else None)
            fn = jax.remat(body, policy=policy)
        else:
            fn = body
        xs = (unit_p, cache_g if cache_g is not None
              else jax.tree.map(lambda v: v, {k: {} for k in unit_p}))
        (x, aux_total), ys = jax.lax.scan(fn, (x, aux_total), xs)
        if new_caches is not None:
            new_caches.append(ys)
    return x, aux_total, new_caches


def _embed_inputs(cfg, params, batch, for_decode=False):
    """Returns (x, positions, labels, loss_mask, enc_in)."""
    tokens = batch["tokens"]
    if not for_decode:
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
    else:
        inputs, labels = tokens, None
    x = basic.embed(params["embed"], inputs,
                    scale_by_sqrt_dim=cfg.emb_scale_by_sqrt_dim)
    mask = jnp.ones(x.shape[:2], jnp.float32) if labels is not None else None
    if cfg.family == "vlm" and "patches" in batch:
        vis = (batch["patches"] @ params["vis_proj"]).astype(x.dtype)
        x = jnp.concatenate([vis, x], axis=1)
        if mask is not None:
            mask = jnp.concatenate(
                [jnp.zeros(vis.shape[:2], jnp.float32), mask], axis=1)
            labels = jnp.concatenate(
                [jnp.zeros(vis.shape[:2], jnp.int32), labels], axis=1)
    positions = jnp.arange(x.shape[1])[None, :] + jnp.zeros(
        (x.shape[0], 1), jnp.int32)
    return x, positions, labels, mask


def encode(cfg, pcfg, params, frames):
    """Whisper encoder over (stubbed) frame embeddings [B, Se, D]."""
    ec = _encoder_cfg(cfg)
    pos = jnp.arange(frames.shape[1])[None, :] + jnp.zeros(
        (frames.shape[0], 1), jnp.int32)
    x = frames.astype(jnp.dtype(cfg.act_dtype))
    x, _, _ = _run_groups(ec, pcfg, params["enc_groups"], stack_groups(ec),
                          x, pos)
    return basic.rmsnorm(params["enc_norm"], x, cfg.rms_eps)


def loss_and_aux(cfg: ModelConfig, pcfg: ParallelConfig, params, batch):
    """Scalar LM loss (+MoE aux).  batch['tokens'] is [B, S+1]."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, pcfg, params, batch["frames"])
    x, positions, labels, mask = _embed_inputs(cfg, params, batch)
    x = constrain(x, ("batch", "seq", None))
    x, aux, _ = _run_groups(cfg, pcfg, params["groups"], stack_groups(cfg), x,
                            positions, enc_out=enc_out)
    x = basic.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    loss = _xent(cfg, pcfg, params, x, labels, mask)
    return loss + aux, {"xent": loss, "aux": aux}


def _xent(cfg, pcfg, params, x, labels, mask):
    """Chunked cross-entropy (avoids materializing [B,S,V] f32)."""
    B, S, D = x.shape
    chunk = pcfg.loss_chunk or S
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    @jax.remat   # recompute per-chunk logits in backward (memory control)
    def chunk_loss(carry, idx):
        xs = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, 1)
        ls = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, 1)
        ms = jax.lax.dynamic_slice_in_dim(mask, idx * chunk, chunk, 1)
        logits = basic.unembed_logits(params["embed"], xs,
                                      cfg.final_logit_softcap,
                                      n_valid=cfg.vocab_size)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ls[..., None], axis=-1)[..., 0]
        return carry + jnp.sum((lse - gold) * ms), None

    total, _ = jax.lax.scan(chunk_loss, jnp.zeros((), jnp.float32),
                            jnp.arange(nc))
    return total / jnp.maximum(mask.sum(), 1.0)


# ---------------------------------------------------------------------------
# Serving: cache init / prefill / decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch_size, max_len, abstract=False,
               cache_dtype=jnp.bfloat16, enc_len=0):
    def unit_cache(c, pattern, r):
        out = {}
        for i, layer_kinds in enumerate(pattern):
            for kind in layer_kinds:
                e = blocks.sublayer_cache(c, kind, batch_size, max_len,
                                          cache_dtype, abstract=False,
                                          enc_len=enc_len)
                out[f"{i}.{kind}"] = (jax.tree.map(
                    lambda l: jnp.zeros((r,) + l.shape, l.dtype), e)
                    if e is not None else {})
        return out

    def a_unit_cache(c, pattern, r):
        out = {}
        for i, layer_kinds in enumerate(pattern):
            for kind in layer_kinds:
                e = blocks.sublayer_cache(c, kind, batch_size, max_len,
                                          cache_dtype, abstract=True,
                                          enc_len=enc_len)
                out[f"{i}.{kind}"] = (jax.tree.map(
                    lambda l: A((r,) + l.shape, l.dtype), e)
                    if e is not None else {})
        return out

    mk = a_unit_cache if abstract else unit_cache
    cache = {"groups": [mk(cfg, pat, r) for pat, r in stack_groups(cfg)],
             "index": (A((batch_size,), jnp.int32) if abstract
                       else jnp.zeros((batch_size,), jnp.int32))}
    if cfg.family == "encdec":
        # encoder output replayed through cross-attn caches (per group entry)
        pass  # cross entries already sized via enc_len above
    return cache


def cache_logical_axes(cfg: ModelConfig):
    def unit(c, pattern):
        out = {}
        for i, layer_kinds in enumerate(pattern):
            for kind in layer_kinds:
                ax = blocks.cache_axes(kind)
                out[f"{i}.{kind}"] = (jax.tree.map(
                    lambda t: ("layers",) + t, ax,
                    is_leaf=lambda v: isinstance(v, tuple))
                    if ax is not None else {})
        return out
    return {"groups": [unit(cfg, pat) for pat, _ in stack_groups(cfg)],
            "index": ("batch",)}


def prefill(cfg, pcfg, params, batch, cache):
    """Populate cache from a prompt; returns (last-position logits, cache)."""
    enc_out = None
    if cfg.family == "encdec":
        enc_out = encode(cfg, pcfg, params, batch["frames"])
    x, positions, _, _ = _embed_inputs(cfg, params, batch, for_decode=True)
    x = constrain(x, ("batch", "seq", None))
    x, _, new_caches = _run_groups(cfg, pcfg, params["groups"],
                                   stack_groups(cfg), x, positions,
                                   enc_out=enc_out, caches=cache["groups"],
                                   remat=False)
    x = basic.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = basic.unembed_logits(params["embed"], x[:, -1:],
                                  cfg.final_logit_softcap,
                                  n_valid=cfg.vocab_size)
    return logits, {"groups": new_caches,
                    "index": jnp.full((x.shape[0],), x.shape[1], jnp.int32)}


def decode_step(cfg, pcfg, params, cache, tokens):
    """One token for every sequence.  tokens [B, 1] -> (logits [B,1,V], cache).

    cache['index'] is per-sequence [B] — slots may be at different positions
    (continuous batching in serve/engine.py)."""
    idx = cache["index"]
    x = basic.embed(params["embed"], tokens,
                    scale_by_sqrt_dim=cfg.emb_scale_by_sqrt_dim)
    positions = idx[:, None]
    x, _, new_caches = _run_groups(cfg, pcfg, params["groups"],
                                   stack_groups(cfg), x, positions,
                                   caches=cache["groups"], decode_index=idx,
                                   remat=False)
    x = basic.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    logits = basic.unembed_logits(params["embed"], x, cfg.final_logit_softcap,
                                  n_valid=cfg.vocab_size)
    return logits, {"groups": new_caches, "index": idx + 1}


# ---------------------------------------------------------------------------
# Param counting (analytic)
# ---------------------------------------------------------------------------

def count_params(cfg: ModelConfig, active_only=False) -> int:
    tree = abstract_params(cfg)
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        n = int(np.prod(leaf.shape))
        keys = [getattr(k, "key", getattr(k, "idx", "")) for k in path]
        if active_only and any(str(k).endswith(".moe") for k in keys) \
                and str(keys[-1]) in ("w_gate", "w_up", "w_down"):
            n = n * cfg.moe.top_k // cfg.moe.n_experts
        total += n
    return total
