"""Logical-axis -> mesh-axis sharding rules (MaxText-style).

Params and caches carry *logical* axis names (see ``*_axes`` functions);
``rules_train``/``rules_decode`` map them onto the physical mesh axes.  The
mapping adapts per architecture (e.g. experts go to the model axis only when
the expert count divides it) and per parallel config (FSDP on/off).

``set_mesh``/``constrain`` provide activation sharding constraints inside
model code without threading the mesh through every call.
"""
from __future__ import annotations

import threading
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

_STATE = threading.local()


def set_mesh(mesh: Optional[Mesh], rules: Optional[Dict] = None):
    _STATE.mesh = mesh
    _STATE.rules = rules


def get_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def rules_train(cfg: ModelConfig, pcfg: ParallelConfig) -> Dict[str, object]:
    """Logical axis -> mesh axis (or tuple of axes, or None)."""
    fsdp_axis = "data" if pcfg.fsdp else None
    ep_ok = cfg.moe is not None and cfg.moe.n_experts % pcfg.model == 0
    return {
        "vocab": "model",
        "embed": fsdp_axis,
        "heads": "model",
        "kv_heads": "model",
        "ffn": "model",
        "expert": "model" if ep_ok else None,
        "expert_ffn": None if ep_ok else "model",
        "inner": "model",
        "inner_in": fsdp_axis,
        "ssm_heads": None,
        "layers": None,
        # activations
        "batch": tuple(pcfg.dp_axes),
        "seq": "model" if pcfg.seq_shard_acts else None,
        "kv_seq": "model",
        None: None,
    }


def rules_decode(cfg: ModelConfig, pcfg: ParallelConfig) -> Dict[str, object]:
    r = rules_train(cfg, pcfg)
    r = dict(r)
    r["embed"] = None          # no FSDP for serving weights
    r["inner_in"] = None
    r["seq"] = None
    r["kv_seq"] = "model"      # sequence-sharded KV cache (flash-decode)
    return r


def logical_to_pspec(axes: Tuple, rules: Dict) -> P:
    spec = []
    used = set()
    for ax in axes:
        m = rules.get(ax)
        if isinstance(m, tuple):
            m = tuple(x for x in m if x not in used) or None
        if m is None or m in used:
            spec.append(None)
        else:
            spec.append(m)
            used.add(m) if not isinstance(m, tuple) else used.update(m)
    return P(*spec)


def tree_pspecs(axes_tree, rules):
    return jax.tree.map(lambda ax: logical_to_pspec(ax, rules), axes_tree,
                        is_leaf=lambda v: isinstance(v, tuple))


def tree_shardings(axes_tree, mesh, rules):
    return jax.tree.map(lambda ax: NamedSharding(mesh,
                                                 logical_to_pspec(ax, rules)),
                        axes_tree, is_leaf=lambda v: isinstance(v, tuple))


def constrain(x, logical_axes: Tuple):
    """with_sharding_constraint if a mesh is active; no-op otherwise.

    Axes that are *manual* in the current tracing context (inside a
    shard_map, e.g. the pod axis in the int8-ring gradient path) are
    stripped from the spec — mixing manual and auto axes in one
    PartitionSpec is rejected by JAX.
    """
    mesh = get_mesh()
    rules = getattr(_STATE, "rules", None)
    if mesh is None or rules is None:
        return x
    spec = logical_to_pspec(logical_axes, rules)
    try:
        am = jax.sharding.get_abstract_mesh()
        manual = {n for n, t in zip(am.axis_names, am.axis_types)
                  if "Manual" in str(t)}
    except Exception:   # noqa: BLE001 — no tracing context
        manual = set()
    if manual:
        def strip(entry):
            if entry is None:
                return None
            if isinstance(entry, tuple):
                kept = tuple(a for a in entry if a not in manual)
                return kept or None
            return None if entry in manual else entry
        spec = P(*(strip(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
