"""Griffin / RecurrentGemma RG-LRU recurrent block  [arXiv:2402.19427].

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    log a_t = -c * softplus(Lambda) * r_t   # c = 8.0
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill evaluate the linear recurrence with
``jax.lax.associative_scan`` (log-space decays, f32); decode is the single
recurrent step.  The surrounding block follows the paper: linear in-proj with
a gated branch, short depthwise conv, RG-LRU, then out-proj.
``repro.kernels.rglru`` is the Pallas twin of ``rglru_scan``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.models.layers.basic import _leaf

A = jax.ShapeDtypeStruct


def rglru_params(d_model, rcfg: RGLRUConfig, dtype, key=None):
    w = rcfg.lru_width or d_model
    W = rcfg.conv_width
    ks = jax.random.split(key, 8) if key is not None else (None,) * 8
    return {
        "in_x": _leaf((d_model, w), dtype, ks[0], "normal"),
        "in_gate": _leaf((d_model, w), dtype, ks[1], "normal"),
        "conv_w": _leaf((W, w), dtype, ks[2], "normal"),
        "conv_b": _leaf((w,), dtype, ks[3], "zeros"),
        "wa": _leaf((w, w), dtype, ks[4], "normal"),
        "wx": _leaf((w, w), dtype, ks[5], "normal"),
        "a_param": _leaf((w,), jnp.float32, ks[6], "ones"),   # Lambda
        "out": _leaf((w, d_model), dtype, ks[7], "normal"),
    }


def rglru_axes():
    return {"in_x": ("embed", "inner"), "in_gate": ("embed", "inner"),
            "conv_w": (None, "inner"), "conv_b": ("inner",),
            "wa": ("inner", "inner_in"), "wx": ("inner", "inner_in"),
            "a_param": ("inner",), "out": ("inner", "embed")}


def rglru_scan(x, log_a, init_h=None):
    """Associative-scan linear recurrence.

    x [B,S,W] (already input-gated), log_a [B,S,W] (log decay, <= 0).
    h_t = a_t h_{t-1} + sqrt(1-a_t^2) x_t.
    Returns (h [B,S,W] f32, final_h [B,W]).
    """
    xf = x.astype(jnp.float32)
    a2 = jnp.exp(2.0 * log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-12)) * xf
    if init_h is not None:
        # fold the initial state in as a virtual first element
        log_a = jnp.concatenate([jnp.zeros_like(log_a[:, :1]), log_a], 1)
        b = jnp.concatenate([init_h.astype(jnp.float32)[:, None], b], 1)

    def combine(left, right):
        la, lb = left
        ra, rb = right
        return la + ra, lb * jnp.exp(ra) + rb

    la, h = jax.lax.associative_scan(combine, (log_a, b), axis=1)
    if init_h is not None:
        h = h[:, 1:]
    return h, h[:, -1]


def rglru_step(h, xt, log_at):
    """One decode step: h [B,W] f32, xt [B,W] (input-gated), log_at [B,W]."""
    a = jnp.exp(log_at)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    new = a * h + b * xt.astype(jnp.float32)
    return new, new


def _causal_conv(x, w, b, state=None):
    W = w.shape[0]
    pad = (jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
           if state is None else state)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    return out + b, xp[:, -(W - 1):, :]


def rglru_block(p, x, rcfg: RGLRUConfig, state=None, conv_state=None):
    """x [B,S,D] -> (out [B,S,D], (h_state [B,W] f32, conv_state))."""
    gate = jax.nn.gelu((x @ p["in_gate"]).astype(jnp.float32), approximate=True)
    xr = x @ p["in_x"]
    xr, new_conv = _causal_conv(xr, p["conv_w"], p["conv_b"], conv_state)
    r = jax.nn.sigmoid((xr @ p["wa"]).astype(jnp.float32))
    i = jax.nn.sigmoid((xr @ p["wx"]).astype(jnp.float32))
    log_a = -rcfg.c * jax.nn.softplus(p["a_param"]) * r          # [B,S,W]
    gated = i * xr.astype(jnp.float32)
    if state is None:
        h, fin = rglru_scan(gated.astype(x.dtype), log_a, None)
    else:
        fin, _ = rglru_step(state, gated[:, 0], log_a[:, 0])
        h = fin[:, None]
    y = (h * gate).astype(x.dtype)
    return y @ p["out"], (fin, new_conv)


def rglru_init_state(batch, d_model, rcfg: RGLRUConfig, dtype=jnp.bfloat16,
                     abstract=False):
    w = rcfg.lru_width or d_model
    shapes = {"state": (batch, w), "conv": (batch, rcfg.conv_width - 1, w)}
    if abstract:
        return {"state": A(shapes["state"], jnp.float32),
                "conv": A(shapes["conv"], dtype)}
    return {"state": jnp.zeros(shapes["state"], jnp.float32),
            "conv": jnp.zeros(shapes["conv"], dtype)}
