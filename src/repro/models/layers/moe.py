"""Mixture-of-Experts FFN (granite-moe family).

Capacity-based scatter dispatch (GShard/Switch semantics without materializing
the [T, E, C] one-hot): tokens are ranked within their chosen expert via a
one-hot cumsum, scattered into a per-expert [E, C, D] buffer (overflow tokens
drop, standard capacity behaviour), run through the expert FFN as one batched
matmul, and gathered back with router-weight combine.

Sharding: expert tensors carry the leading 'expert' logical axis.  When
n_experts divides the model-axis width the rules map it to the mesh model axis
(expert parallelism, all-to-all dispatch inserted by GSPMD); otherwise the
expert FFN dim maps to the model axis (TP-inside-experts, e.g. granite-3b's
40 experts on a 16-wide axis).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models.layers.basic import _leaf


def moe_params(d, mcfg: MoEConfig, dtype, key=None):
    e, f = mcfg.n_experts, mcfg.expert_d_ff
    ks = jax.random.split(key, 4) if key is not None else (None,) * 4
    return {
        "router": _leaf((d, e), dtype, ks[0], "normal"),
        "w_gate": _leaf((e, d, f), dtype, ks[1], "normal"),
        "w_up": _leaf((e, d, f), dtype, ks[2], "normal"),
        "w_down": _leaf((e, f, d), dtype, ks[3], "normal"),
    }


def moe_axes():
    return {"router": ("embed", None),
            "w_gate": ("expert", "embed", "expert_ffn"),
            "w_up": ("expert", "embed", "expert_ffn"),
            "w_down": ("expert", "expert_ffn", "embed")}


def moe_capacity(n_tokens, mcfg: MoEConfig):
    c = int(np.ceil(n_tokens * mcfg.top_k * mcfg.capacity_factor / mcfg.n_experts))
    return max(8, int(np.ceil(c / 8)) * 8)  # pad to a lane-friendly multiple


def moe(p, x, mcfg: MoEConfig, cap_shard=False):
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar f32).

    cap_shard: constrain the [E, C, D] dispatch buffers so the capacity dim
    is data-sharded — dispatch becomes an all-to-all instead of a full
    token all-gather (§Perf lever for the EP-less granite configs)."""
    B, S, D = x.shape
    T = B * S
    E, K = mcfg.n_experts, mcfg.top_k
    C = moe_capacity(T, mcfg)
    xt = x.reshape(T, D)

    logits = (xt @ p["router"]).astype(jnp.float32)          # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # [T, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # position of each (token, k) within its expert: cumsum over the flattened
    # (k-major) assignment sequence so k=0 choices rank before k=1 (GShard).
    flat_idx = gate_idx.T.reshape(-1)                        # [K*T], k-major
    onehot = jax.nn.one_hot(flat_idx, E, dtype=jnp.int32)    # [K*T, E]
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot - 1       # [K*T, E]
    pos = pos_in_e.max(-1)                                   # [K*T]
    keep = pos < C
    slot = jnp.where(keep, flat_idx * C + pos, E * C)        # drop -> scratch row

    # scatter tokens into [E*C+1, D] buffer (last row = dropped scratch)
    buf = jnp.zeros((E * C + 1, D), x.dtype)
    tok_of = jnp.tile(jnp.arange(T), K)                      # token for each (k,t)
    buf = buf.at[slot].set(xt[tok_of], mode="drop")
    eb = buf[: E * C].reshape(E, C, D)
    if cap_shard:
        from repro.models.sharding import constrain
        eb = constrain(eb, (None, "batch", None))

    # expert FFN, batched over E
    g = jnp.einsum("ecd,edf->ecf", eb, p["w_gate"])
    h = jax.nn.silu(g) * jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    out_ecd = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    if cap_shard:
        from repro.models.sharding import constrain
        out_ecd = constrain(out_ecd, (None, "batch", None))
    out_e = out_ecd.reshape(E * C, D)
    out_e = jnp.concatenate([out_e, jnp.zeros((1, D), out_e.dtype)], 0)

    # gather back + weighted combine over the K choices
    got = out_e[slot].reshape(K, T, D)                       # dropped -> zeros row
    w = (gate_vals.T * keep.reshape(K, T)).astype(jnp.float32)
    out = jnp.einsum("kt,ktd->td", w, got.astype(jnp.float32))

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(0)                                        # [E]
    ce = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32).sum((0, 1)) / (T * K)
    aux = mcfg.aux_loss_coef * E * jnp.sum(me * ce)
    return out.reshape(B, S, D).astype(x.dtype), aux


def moe_dense_oracle(p, x, mcfg: MoEConfig):
    """No-capacity oracle: every token visits its top-k experts exactly.

    O(T·E·D·F) — test-only reference for the dispatch implementation.
    """
    B, S, D = x.shape
    xt = x.reshape(-1, D)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, mcfg.top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    g = jnp.einsum("td,edf->tef", xt, p["w_gate"])
    h = jax.nn.silu(g) * jnp.einsum("td,edf->tef", xt, p["w_up"])
    all_out = jnp.einsum("tef,efd->ted", h, p["w_down"])     # [T, E, D]
    sel = jnp.take_along_axis(all_out, gate_idx[:, :, None], axis=1)
    out = jnp.einsum("tk,tkd->td", gate_vals, sel.astype(jnp.float32))
    return out.reshape(B, S, D).astype(x.dtype)
