"""Basic layers: norms, RoPE, gated MLP, embeddings.

All layers are purely functional: ``*_params`` returns a ShapeDtypeStruct tree
(abstract) or an initialized tree (concrete), ``*_axes`` returns the matching
tree of logical-axis name tuples consumed by models/sharding.py, and the apply
functions take (params, inputs).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

A = jax.ShapeDtypeStruct


def _leaf(shape, dtype, key, init, scale=1.0):
    """Abstract leaf when key is None, else initialized."""
    if key is None:
        return A(shape, dtype)
    if init == "zeros":
        return jnp.zeros(shape, dtype)
    if init == "ones":
        return jnp.ones(shape, dtype)
    if init == "normal":
        fan_in = shape[0] if len(shape) >= 2 else 1
        std = scale / np.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)
    raise ValueError(init)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def rmsnorm_params(d, dtype, key=None):
    return {"scale": _leaf((d,), dtype, key, "zeros")}  # gemma-style (1+scale)


def rmsnorm_axes():
    return {"scale": ("embed",)}


def rmsnorm(p, x, eps=1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + p["scale"].astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    return theta ** (-np.arange(0, head_dim // 2, dtype=np.float32) * 2 / head_dim)


def apply_rope(x, positions, theta=10_000.0):
    """x: [..., S, H, hd]; positions: [..., S]."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta))
    ang = positions.astype(jnp.float32)[..., None] * freqs       # [..., S, hd/2]
    ang = ang[..., None, :]                                       # [..., S, 1, hd/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU / GeGLU)
# ---------------------------------------------------------------------------

def mlp_params(d, f, dtype, key=None):
    ks = jax.random.split(key, 3) if key is not None else (None,) * 3
    return {
        "w_gate": _leaf((d, f), dtype, ks[0], "normal"),
        "w_up": _leaf((d, f), dtype, ks[1], "normal"),
        "w_down": _leaf((f, d), dtype, ks[2], "normal"),
    }


def mlp_axes():
    return {"w_gate": ("embed", "ffn"), "w_up": ("embed", "ffn"),
            "w_down": ("ffn", "embed")}


def mlp(p, x, act="silu"):
    g = x @ p["w_gate"]
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    h = g * (x @ p["w_up"])
    return (h @ p["w_down"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_params(vocab, d, dtype, key=None, tie=True):
    ks = jax.random.split(key, 2) if key is not None else (None, None)
    # std = 1/sqrt(d): tied unembedding keeps logits O(1) (gemma rescales the
    # embedding path by sqrt(d) separately).
    p = {"tok": _leaf((vocab, d), dtype, ks[0], "normal",
                      scale=np.sqrt(vocab / d))}
    if not tie:
        p["unembed"] = _leaf((d, vocab), dtype, ks[1], "normal")
    return p


def embed_axes(tie=True):
    a = {"tok": ("vocab", "embed")}
    if not tie:
        a["unembed"] = ("embed", "vocab")
    return a


def embed(p, tokens, scale_by_sqrt_dim=False):
    x = p["tok"][tokens]
    if scale_by_sqrt_dim:
        x = (x.astype(jnp.float32) * np.sqrt(p["tok"].shape[1])).astype(x.dtype)
    return x


def unembed_logits(p, x, softcap=None, n_valid=None):
    """x: [..., D] -> logits [..., V_padded] in f32 (padded ids masked)."""
    w = p.get("unembed")
    if w is None:
        w = p["tok"].T
    logits = (x @ w).astype(jnp.float32)
    if softcap:
        logits = softcap * jnp.tanh(logits / softcap)
    if n_valid is not None and n_valid < logits.shape[-1]:
        ids = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1)
        logits = jnp.where(ids < n_valid, logits, -1e30)
    return logits
