"""Mamba-2 SSD (state-space duality) block  [arXiv:2405.21060].

Block layout follows the Mamba-2 paper: one input projection produces
(z, x, B, C, dt); a short depthwise conv over (x, B, C); the SSD mixer; a
gated RMSNorm; and an output projection.

The SSD mixer itself is the chunked algorithm (Listing 1 of the paper):
  * intra-chunk: quadratic attention-like term with decay L-matrix,
  * inter-chunk: a sequential ``lax.scan`` over per-chunk states
    [B, H, P, N] (nheads × headdim × dstate).
Training/prefill use the chunked path; decode uses the recurrent step.
``repro.kernels.ssd`` holds the Pallas TPU version of the chunked kernel and
must match ``ssd_chunked`` (its ref.py re-exports the functions here).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SSDConfig
from repro.models.layers.basic import _leaf, rmsnorm

A = jax.ShapeDtypeStruct


def ssd_dims(d_model, scfg: SSDConfig):
    d_inner = scfg.expand * d_model
    n_heads = d_inner // scfg.head_dim
    return d_inner, n_heads


def ssd_params(d_model, scfg: SSDConfig, dtype, key=None):
    d_inner, H = ssd_dims(d_model, scfg)
    G, N, W = scfg.n_groups, scfg.d_state, scfg.conv_width
    conv_dim = d_inner + 2 * G * N
    ks = jax.random.split(key, 7) if key is not None else (None,) * 7
    return {
        # in_proj -> [z (d_inner), x (d_inner), B (G*N), C (G*N), dt (H)]
        "in_proj": _leaf((d_model, 2 * d_inner + 2 * G * N + H), dtype, ks[0], "normal"),
        "conv_w": _leaf((W, conv_dim), dtype, ks[1], "normal"),
        "conv_b": _leaf((conv_dim,), dtype, ks[2], "zeros"),
        "a_log": _leaf((H,), jnp.float32, ks[3], "ones"),
        "dt_bias": _leaf((H,), jnp.float32, ks[4], "zeros"),
        "d_skip": _leaf((H,), jnp.float32, ks[5], "ones"),
        "norm_scale": _leaf((d_inner,), dtype, ks[6], "zeros"),
        "out_proj": _leaf((d_inner, d_model), dtype,
                          jax.random.split(ks[0])[0] if key is not None else None,
                          "normal"),
    }


def ssd_axes():
    return {"in_proj": ("embed", "inner"), "conv_w": (None, "inner"),
            "conv_b": ("inner",), "a_log": ("ssm_heads",),
            "dt_bias": ("ssm_heads",), "d_skip": ("ssm_heads",),
            "norm_scale": ("inner",), "out_proj": ("inner", "embed")}


def _split_proj(proj, d_inner, G, N, H):
    z = proj[..., :d_inner]
    x = proj[..., d_inner:2 * d_inner]
    Bm = proj[..., 2 * d_inner:2 * d_inner + G * N]
    Cm = proj[..., 2 * d_inner + G * N:2 * d_inner + 2 * G * N]
    dt = proj[..., 2 * d_inner + 2 * G * N:]
    return z, x, Bm, Cm, dt


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv. x [B,S,C], w [W,C]. state [B,W-1,C] for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(out + b), new_state


def ssd_chunked(x, dt, a_log, Bm, Cm, chunk, init_state=None):
    """Chunked SSD.  x [B,S,H,P], dt [B,S,H] (post-softplus), a_log [H],
    Bm/Cm [B,S,G,N].  Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    rep = H // G
    nc = S // chunk
    a = -jnp.exp(a_log)                                     # [H] negative
    dA = dt * a                                             # [B,S,H] log-decay
    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    dAc = dA.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, G, N)
    Cc = Cm.reshape(Bsz, nc, chunk, G, N)

    seg = jnp.cumsum(dAc, axis=2)                           # [B,nc,L,H]
    total = seg[:, :, -1, :]                                # [B,nc,H]

    # intra-chunk (diagonal blocks): y_intra[t] = sum_{s<=t} C_t·B_s exp(seg_t-seg_s) dt_s x_s
    Cg = Cc.reshape(Bsz, nc, chunk, G, 1, N)
    Bg = Bc.reshape(Bsz, nc, chunk, G, 1, N)
    scores = jnp.einsum("bclgrn,bcsgrn->bcglrs",
                        jnp.broadcast_to(Cg, (Bsz, nc, chunk, G, rep, N)),
                        jnp.broadcast_to(Bg, (Bsz, nc, chunk, G, rep, N)),
                        preferred_element_type=jnp.float32)  # [B,nc,G,l,rep,s]
    # decay L matrix per head: L[l,s] = exp(seg[l] - seg[s]), causal-masked
    segh = seg.reshape(Bsz, nc, chunk, G, rep)
    segl = segh.transpose(0, 1, 3, 4, 2)                    # [B,nc,G,rep,L]
    dmat = segl[..., :, None] - segl[..., None, :]          # [B,nc,G,rep,l,s]
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    lmat = jnp.where(causal, jnp.exp(dmat), 0.0)
    dtl = dtc.reshape(Bsz, nc, chunk, G, rep).transpose(0, 1, 3, 4, 2)
    sc = scores.transpose(0, 1, 2, 4, 3, 5)                 # [B,nc,G,rep,l,s]
    w = sc * lmat * dtl[..., None, :]
    xh = xc.reshape(Bsz, nc, chunk, G, rep, P)
    y_intra = jnp.einsum("bcgrls,bcsgrp->bclgrp", w.astype(x.dtype), xh)

    # per-chunk input state: state_c = sum_s exp(total - seg_s) dt_s B_s x_s
    decay_in = jnp.exp(total[:, :, None, :] - seg)          # [B,nc,L,H]
    contrib = (dtc * decay_in).reshape(Bsz, nc, chunk, G, rep)
    states = jnp.einsum("bcsgr,bcsgn,bcsgrp->bcgrpn", contrib,
                        Bc, xh, preferred_element_type=jnp.float32)

    # inter-chunk recurrence over chunk states
    def step(carry, inp):
        st_in, tot = inp                                    # [B,G,rep,P,N], [B,H]
        toth = jnp.exp(tot).reshape(Bsz, G, rep)[..., None, None]
        new = carry * toth + st_in
        return new, carry                                   # emit state *before* chunk

    init = (jnp.zeros((Bsz, G, rep, P, N), jnp.float32) if init_state is None
            else init_state.reshape(Bsz, G, rep, P, N).astype(jnp.float32))
    final, prev_states = jax.lax.scan(
        step, init, (states.transpose(1, 0, 2, 3, 4, 5), total.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4, 5)   # [B,nc,G,rep,P,N]

    # inter-chunk output: y_inter[t] = C_t · (exp(seg_t) * state_prev)
    outdec = jnp.exp(seg).reshape(Bsz, nc, chunk, G, rep)
    y_inter = jnp.einsum("bclgn,bcgrpn,bclgr->bclgrp", Cc,
                         prev_states.astype(jnp.float32), outdec)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype), final.reshape(Bsz, H, P, N)


def ssd_recurrent_step(state, xt, dtt, a_log, Bt, Ct):
    """One decode step. state [B,H,P,N]; xt [B,H,P]; dtt [B,H];
    Bt/Ct [B,G,N] -> (y [B,H,P], new_state)."""
    Bsz, H, P, N = state.shape
    G = Bt.shape[1]
    rep = H // G
    a = -jnp.exp(a_log)
    dA = jnp.exp(dtt * a)                                    # [B,H]
    Bh = jnp.repeat(Bt, rep, axis=1)                         # [B,H,N]
    Ch = jnp.repeat(Ct, rep, axis=1)
    upd = jnp.einsum("bh,bhp,bhn->bhpn", dtt, xt.astype(jnp.float32),
                     Bh.astype(jnp.float32))
    new = state * dA[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch.astype(jnp.float32))
    return y.astype(xt.dtype), new


def ssd_block(p, x, scfg: SSDConfig, d_model, state=None, conv_state=None,
              rms_eps=1e-6):
    """Full Mamba-2 block.  x [B,S,D].

    Train/prefill: state/conv_state None -> chunked path, returns (y, None).
    Decode: S==1 with states -> recurrent path, returns (y, (state, conv)).
    """
    d_inner, H = ssd_dims(d_model, scfg)
    G, N, P = scfg.n_groups, scfg.d_state, scfg.head_dim
    proj = x @ p["in_proj"]
    z, xr, Bm, Cm, dt = _split_proj(proj, d_inner, G, N, H)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    conv_in = jnp.concatenate([xr, Bm, Cm], axis=-1)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"], conv_state)
    xr, Bm, Cm = (conv_out[..., :d_inner],
                  conv_out[..., d_inner:d_inner + G * N],
                  conv_out[..., d_inner + G * N:])
    Bsz, S = x.shape[0], x.shape[1]
    xh = xr.reshape(Bsz, S, H, P)
    Bm = Bm.reshape(Bsz, S, G, N)
    Cm = Cm.reshape(Bsz, S, G, N)
    if state is None:
        chunk = min(scfg.chunk_size, S)
        y, fin = ssd_chunked(xh, dt, p["a_log"], Bm, Cm, chunk)
        new_state = fin
    else:
        y, new_state = ssd_recurrent_step(
            state, xh[:, 0], dt[:, 0], p["a_log"], Bm[:, 0], Cm[:, 0])
        y = y[:, None]
    y = y + (xh.astype(jnp.float32)
             * p["d_skip"][None, None, :, None]).astype(y.dtype)
    y = y.reshape(Bsz, S, d_inner)
    y = rmsnorm({"scale": p["norm_scale"]}, y, rms_eps) * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out.astype(x.dtype), (new_state, new_conv)


def ssd_init_state(batch, d_model, scfg: SSDConfig, dtype=jnp.float32,
                   abstract=False):
    d_inner, H = ssd_dims(d_model, scfg)
    conv_dim = d_inner + 2 * scfg.n_groups * scfg.d_state
    shapes = {
        "state": (batch, H, scfg.head_dim, scfg.d_state),
        "conv": (batch, scfg.conv_width - 1, conv_dim),
    }
    if abstract:
        return {"state": A(shapes["state"], jnp.float32),
                "conv": A(shapes["conv"], dtype)}
    return {"state": jnp.zeros(shapes["state"], jnp.float32),
            "conv": jnp.zeros(shapes["conv"], dtype)}
