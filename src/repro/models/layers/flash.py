"""Chunked flash attention in pure JAX with a custom VJP.

This is the XLA-compilable twin of the Pallas kernel in
``repro.kernels.flash_attention``: same math (online softmax, GQA, sliding
window, logit softcap), but expressed with ``lax.scan``/``lax.map`` so it
lowers on any backend (the multi-pod dry-run compiles on the CPU host).

Memory behaviour is the whole point: the forward saves only (q, k, v, out,
lse); the backward recomputes scores blockwise.  A naive differentiated scan
would stash every [CQ, CK] probability block and blow past HBM (measured
1.2 TB/device on llama3-405b/train_4k before this existed).

Sliding-window attention statically restricts the kv-chunk range (no wasted
blocks).  For purely causal attention the baseline scans all kv chunks with
masking; ``causal_skip=True`` switches to a balanced two-chunk schedule that
halves the block count (hillclimb optimization, see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig

NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def _block_mask(q_pos, k_pos, causal, window):
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def _scores(qi, kj, scale, softcap):
    # qi [B,K,R,CQ,hd], kj [B,CK,K,hd] -> [B,K,R,CQ,CK] f32
    s = jnp.einsum("bkrqd,bskd->bkrqs", qi, kj,
                   preferred_element_type=jnp.float32) * scale
    if softcap:
        s = softcap * jnp.tanh(s / softcap)
    return s


def _kv_chunk_range(i, cq, ck, nk, window, causal):
    """Static number of kv chunks to visit for q chunk i, plus start index.

    For window attention the range is static length ``nw``; for global
    attention it is all nk chunks (masking handles causality).
    """
    if window is not None:
        nw = (window + cq) // ck + 1
        nw = min(nw, nk)
        start = jnp.clip(((i * cq - window) // ck), 0, nk - nw)
        return start, nw
    return jnp.int32(0), nk


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def flash_attention(q, k, v, cfg: AttnConfig, q_chunk=512, kv_chunk=512,
                    causal_skip=False):
    """q [B,Sq,H,hd], k/v [B,Sk,K,hd] -> out [B,Sq,H,hd]."""
    out, _ = _flash_fwd(q, k, v, cfg, q_chunk, kv_chunk, causal_skip)
    return out


def _prep(q, k, cfg, q_chunk, kv_chunk):
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    R = H // K
    cq = min(q_chunk, Sq)
    ck = min(kv_chunk, k.shape[1])
    assert Sq % cq == 0 and k.shape[1] % ck == 0, (Sq, cq, k.shape[1], ck)
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / np.sqrt(hd)
    return B, Sq, H, hd, K, R, cq, ck, Sq // cq, k.shape[1] // ck, scale


def _flash_fwd(q, k, v, cfg, q_chunk, kv_chunk, causal_skip):
    B, Sq, H, hd, K, R, cq, ck, nq, nk, scale = _prep(q, k, cfg, q_chunk, kv_chunk)
    qr = q.reshape(B, nq, cq, K, R, hd).transpose(1, 0, 3, 4, 2, 5)  # [nq,B,K,R,cq,hd]
    kc = k.reshape(B, nk, ck, K, hd).transpose(1, 0, 2, 3, 4)        # [nk,B,ck,K,hd]
    vc = v.reshape(B, nk, ck, K, hd).transpose(1, 0, 2, 3, 4)

    def one_q_chunk(args):
        qi, i = args                                   # [B,K,R,cq,hd]
        start, span = _kv_chunk_range(i, cq, ck, nk, cfg.window, cfg.causal)

        def kv_step(carry, t):
            m, l, acc = carry
            j = start + t
            kj = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
            s = _scores(qi, kj, scale, cfg.logit_softcap)
            qp = i * cq + jnp.arange(cq)
            kp = j * ck + jnp.arange(ck)
            msk = _block_mask(qp, kp, cfg.causal, cfg.window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            mnew = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - mnew[..., None])
            corr = jnp.exp(m - mnew)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bskd->bkrqd", p.astype(vj.dtype), vj,
                preferred_element_type=jnp.float32)
            return (mnew, l, acc), None

        init = (jnp.full((B, K, R, cq), NEG_INF, jnp.float32),
                jnp.zeros((B, K, R, cq), jnp.float32),
                jnp.zeros((B, K, R, cq, hd), jnp.float32))
        if causal_skip and cfg.causal and cfg.window is None:
            # visit only chunks 0..i (static upper bound nk; masked scan with
            # early bound via fori over dynamic trip count)
            (m, l, acc), _ = jax.lax.scan(
                lambda c, t: jax.lax.cond(t <= i, lambda: kv_step(c, t),
                                          lambda: (c, None)),
                init, jnp.arange(nk))
        else:
            (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(span))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        lse = m + jnp.log(jnp.maximum(l, 1e-30))
        return out.astype(q.dtype), lse

    outs, lses = jax.lax.map(one_q_chunk, (qr, jnp.arange(nq)))
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    lse = lses.transpose(1, 2, 3, 0, 4).reshape(B, K, R, Sq)
    return out, (q, k, v, out, lse)


def _flash_bwd(cfg, q_chunk, kv_chunk, causal_skip, res, dout):
    q, k, v, out, lse = res
    B, Sq, H, hd, K, R, cq, ck, nq, nk, scale = _prep(q, k, cfg, q_chunk, kv_chunk)
    qr = q.reshape(B, nq, cq, K, R, hd).transpose(1, 0, 3, 4, 2, 5)
    kc = k.reshape(B, nk, ck, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nk, ck, K, hd).transpose(1, 0, 2, 3, 4)
    do = dout.reshape(B, nq, cq, K, R, hd).transpose(1, 0, 3, 4, 2, 5)
    lse_r = lse.reshape(B, K, R, nq, cq).transpose(3, 0, 1, 2, 4)   # [nq,B,K,R,cq]
    # D_i = rowsum(dO * O)
    delta = jnp.einsum("bshd,bshd->bhs", dout.astype(jnp.float32),
                       out.astype(jnp.float32))
    delta = delta.reshape(B, K, R, nq, cq).transpose(3, 0, 1, 2, 4)

    def p_and_ds(qi, kj, i, j, lse_i, do_i, vj, d_i):
        s_raw = jnp.einsum("bkrqd,bskd->bkrqs", qi, kj,
                           preferred_element_type=jnp.float32) * scale
        if cfg.logit_softcap:
            t = jnp.tanh(s_raw / cfg.logit_softcap)
            s = cfg.logit_softcap * t
        else:
            s = s_raw
        qp = i * cq + jnp.arange(cq)
        kp = j * ck + jnp.arange(ck)
        msk = _block_mask(qp, kp, cfg.causal, cfg.window)[None, None, None]
        s = jnp.where(msk, s, NEG_INF)
        p = jnp.exp(s - lse_i[..., None])
        p = jnp.where(msk, p, 0.0)
        dp = jnp.einsum("bkrqd,bskd->bkrqs", do_i, vj,
                        preferred_element_type=jnp.float32)
        ds = p * (dp - d_i[..., None])
        if cfg.logit_softcap:
            ds = ds * (1.0 - t * t)
        ds = jnp.where(msk, ds, 0.0)
        return p, ds

    # pass 1: dQ — map over q chunks, scan kv chunks
    def dq_chunk(args):
        qi, i, lse_i, do_i, d_i = args
        start, span = _kv_chunk_range(i, cq, ck, nk, cfg.window, cfg.causal)

        def kv_step(dq, t):
            j = start + t
            kj = jax.lax.dynamic_index_in_dim(kc, j, 0, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, j, 0, keepdims=False)
            _, ds = p_and_ds(qi, kj, i, j, lse_i, do_i, vj, d_i)
            dq = dq + jnp.einsum("bkrqs,bskd->bkrqd", ds.astype(kj.dtype), kj,
                                 preferred_element_type=jnp.float32)
            return dq, None

        dq0 = jnp.zeros((B, K, R, cq, hd), jnp.float32)
        if causal_skip and cfg.causal and cfg.window is None:
            dq, _ = jax.lax.scan(
                lambda c, t: jax.lax.cond(t <= i, lambda: kv_step(c, t),
                                          lambda: (c, None)),
                dq0, jnp.arange(nk))
        else:
            dq, _ = jax.lax.scan(kv_step, dq0, jnp.arange(span))
        return (dq * scale).astype(q.dtype)

    dq = jax.lax.map(dq_chunk, (qr, jnp.arange(nq), lse_r, do, delta))
    dq = dq.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)

    # pass 2: dK, dV — map over kv chunks, scan q chunks
    def dkv_chunk(args):
        kj, vj, j = args

        def q_step(carry, i):
            dk, dv = carry
            qi = jax.lax.dynamic_index_in_dim(qr, i, 0, keepdims=False)
            lse_i = jax.lax.dynamic_index_in_dim(lse_r, i, 0, keepdims=False)
            do_i = jax.lax.dynamic_index_in_dim(do, i, 0, keepdims=False)
            d_i = jax.lax.dynamic_index_in_dim(delta, i, 0, keepdims=False)
            p, ds = p_and_ds(qi, kj, i, j, lse_i, do_i, vj, d_i)
            dv = dv + jnp.einsum("bkrqs,bkrqd->bskd", p.astype(do_i.dtype), do_i,
                                 preferred_element_type=jnp.float32)
            dk = dk + jnp.einsum("bkrqs,bkrqd->bskd", ds.astype(qi.dtype), qi,
                                 preferred_element_type=jnp.float32)
            return (dk, dv), None

        z = jnp.zeros((B, ck, K, hd), jnp.float32)
        (dk, dv), _ = jax.lax.scan(q_step, (z, z), jnp.arange(nq))
        return (dk * scale).astype(k.dtype), dv.astype(v.dtype)

    dks, dvs = jax.lax.map(dkv_chunk, (kc, vc, jnp.arange(nk)))
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, K, hd)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, nk * ck, K, hd)
    return dq, dk, dv


def _fwd_rule(q, k, v, cfg, q_chunk, kv_chunk, causal_skip):
    return _flash_fwd(q, k, v, cfg, q_chunk, kv_chunk, causal_skip)


flash_attention.defvjp(_fwd_rule, _flash_bwd)
