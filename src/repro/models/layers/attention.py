"""Attention: projections, dense reference attention, and decode attention.

Dense attention is the oracle used by smoke tests and by tiny configs; the
chunked flash implementation (layers/flash.py) and the Pallas kernel
(repro.kernels.flash_attention) must match it.

Decode attention supports a *sequence-sharded* KV cache: on the production
mesh the cache sequence dimension lives on the "model" axis; each device
computes partial attention over its sequence shard and shards are combined
with a numerically-stable log-sum-exp ``psum`` inside ``shard_map`` (a
flash-decode pattern — the TPU-native answer to GQA head counts that do not
divide the TP width).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig
from repro.models.layers.basic import _leaf, apply_rope

A = jax.ShapeDtypeStruct
NEG_INF = -0.7 * float(np.finfo(np.float32).max)


def attn_params(d, n_heads, n_kv, head_dim, dtype, key=None):
    ks = jax.random.split(key, 4) if key is not None else (None,) * 4
    return {
        "wq": _leaf((d, n_heads * head_dim), dtype, ks[0], "normal"),
        "wk": _leaf((d, n_kv * head_dim), dtype, ks[1], "normal"),
        "wv": _leaf((d, n_kv * head_dim), dtype, ks[2], "normal"),
        "wo": _leaf((n_heads * head_dim, d), dtype, ks[3], "normal"),
    }


def attn_axes():
    return {"wq": ("embed", "heads"), "wk": ("embed", "kv_heads"),
            "wv": ("embed", "kv_heads"), "wo": ("heads", "embed")}


def qkv(p, x, n_heads, n_kv, head_dim, positions, rope_theta):
    """Project and rope. Returns q [B,S,H,hd], k/v [B,S,Khv,hd]."""
    B, S, _ = x.shape
    q = (x @ p["wq"]).reshape(B, S, n_heads, head_dim)
    k = (x @ p["wk"]).reshape(B, S, n_kv, head_dim)
    v = (x @ p["wv"]).reshape(B, S, n_kv, head_dim)
    q = apply_rope(q, positions, rope_theta)
    k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _mask(q_pos, k_pos, causal, window):
    """[Sq, Sk] bool mask: True = attend."""
    m = jnp.ones((q_pos.shape[-1], k_pos.shape[-1]), bool)
    if causal:
        m &= q_pos[:, None] >= k_pos[None, :]
    if window is not None:
        m &= q_pos[:, None] - k_pos[None, :] < window
    return m


def dense_attention(q, k, v, cfg: AttnConfig, q_offset=0):
    """Reference attention. q [B,Sq,H,hd], k/v [B,Sk,K,hd]."""
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    rep = H // K
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / np.sqrt(hd)
    qh = q.reshape(B, Sq, K, rep, hd)
    s = jnp.einsum("bqkrd,bskd->bkrqs", qh, k).astype(jnp.float32) * scale
    if cfg.logit_softcap:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(k.shape[1])
    m = _mask(q_pos, k_pos, cfg.causal, cfg.window)
    s = jnp.where(m[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bkrqs,bskd->bqkrd", p, v)
    return o.reshape(B, Sq, H, hd)


# ---------------------------------------------------------------------------
# Decode attention over a (possibly sequence-sharded) KV cache
# ---------------------------------------------------------------------------

def decode_attention_local(q, k_cache, v_cache, valid_len, cfg: AttnConfig,
                           kv_offset=0):
    """Partial decode attention over a local KV-cache shard.

    q        [B, 1, H, hd]
    k/v      [B, Sc, K, hd]   (this device's shard of the cache)
    valid_len scalar or [B]   (valid cache positions, per sequence)
    kv_offset scalar          (global position of this shard's first slot)

    Returns (numerator [B,1,H,hd] f32, denominator [B,1,H] f32, max [B,1,H]).
    Combine shards with combine_decode_partials (LSE merge).
    """
    B, _, H, hd = q.shape
    K = k_cache.shape[2]
    rep = H // K
    scale = cfg.query_scale if cfg.query_scale is not None else 1.0 / np.sqrt(hd)
    qh = q.reshape(B, K, rep, hd)
    s = jnp.einsum("bkrd,bskd->bkrs", qh, k_cache).astype(jnp.float32) * scale
    if cfg.logit_softcap:
        s = cfg.logit_softcap * jnp.tanh(s / cfg.logit_softcap)
    pos = kv_offset + jnp.arange(k_cache.shape[1])
    vl = jnp.asarray(valid_len)
    if vl.ndim == 0:
        vl = jnp.broadcast_to(vl, (B,))
    ok = pos[None, :] < vl[:, None]                            # [B, Sc]
    if cfg.window is not None:
        ok &= pos[None, :] >= (vl[:, None] - cfg.window)
    okb = ok[:, None, None, :]
    s = jnp.where(okb, s, NEG_INF)
    m = jnp.max(s, axis=-1)                                    # [B,K,rep]
    p = jnp.exp(s - m[..., None])
    p = jnp.where(okb, p, 0.0)
    den = jnp.sum(p, axis=-1)
    num = jnp.einsum("bkrs,bskd->bkrd", p.astype(v_cache.dtype), v_cache)
    num = num.astype(jnp.float32)
    return (num.reshape(B, 1, H, hd), den.reshape(B, 1, H), m.reshape(B, 1, H))


def combine_decode_partials(num, den, m, axis_name):
    """LSE-combine decode partials across a mesh axis (inside shard_map)."""
    g_m = jax.lax.pmax(m, axis_name)
    corr = jnp.exp(m - g_m)
    num = jax.lax.psum(num * corr[..., None], axis_name)
    den = jax.lax.psum(den * corr, axis_name)
    return num / jnp.maximum(den, 1e-30)[..., None]


def finalize_decode(num, den, m):
    """Single-shard finalization (no mesh axis)."""
    return num / jnp.maximum(den, 1e-30)[..., None]
