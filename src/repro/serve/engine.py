"""Batched serving engine: request queue -> prefill -> decode loop.

Slot-based continuous batching lite: a fixed-size batch of decode slots;
finished sequences free their slot, queued requests prefill into free slots.
The engine is a WI *workload*: it publishes runtime hints (utilization-based
preemptibility, scale-out pressure) and reacts to platform hints (eviction
notice -> drain; harvest offer -> grow slots) via the runtime adapter.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import model as M


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


def sample(logits: jax.Array, temperature: float, key) -> jax.Array:
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ServingEngine:
    """Single-host engine (tests + examples); the distributed variant runs
    the same logic with pjit'd prefill/decode (launch/serve.py)."""

    def __init__(self, cfg: ModelConfig, pcfg: ParallelConfig, params,
                 batch_slots: int = 4, max_len: int = 256, seed: int = 0):
        self.cfg, self.pcfg, self.params = cfg, pcfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._active: List[Optional[Request]] = [None] * batch_slots
        self._key = jax.random.PRNGKey(seed)
        self._cache = M.init_cache(cfg, batch_slots, max_len)
        self._decode = jax.jit(
            lambda p, c, t: M.decode_step(cfg, pcfg, p, c, t))
        self.stats = {"requests": 0, "tokens": 0, "batches": 0}

    # -- API -----------------------------------------------------------------
    def submit(self, req: Request):
        self._queue.put(req)
        self.stats["requests"] += 1

    def utilization(self) -> float:
        return sum(r is not None for r in self._active) / self.slots

    def queue_depth(self) -> int:
        return self._queue.qsize()

    # -- loop ----------------------------------------------------------------
    def _admit(self):
        """Fill free slots.  The prompt is fed token-by-token through the
        batched decode step (slot-level prefill interleaves with other
        slots' generation — continuous batching)."""
        for i in range(self.slots):
            if self._active[i] is None and not self._queue.empty():
                req = self._queue.get()
                req._pending = list(int(t) for t in req.prompt)
                req._last = req._pending[-1]
                self._active[i] = req
                self._reset_slot(i)

    def _reset_slot(self, i: int):
        def zero_rows(c):
            def z(leaf):
                return leaf.at[:, i].set(jnp.zeros_like(leaf[:, i])) \
                    if leaf.ndim >= 2 else leaf
            return jax.tree.map(z, c)
        self._cache = {
            "groups": [zero_rows(g) for g in self._cache["groups"]],
            "index": self._cache["index"].at[i].set(0),
        }

    def step(self) -> int:
        """One batched decode step across all active slots (per-slot cache
        positions diverge; cache['index'] is a per-slot vector)."""
        self._admit()
        live = [i for i, r in enumerate(self._active) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            r = self._active[i]
            toks[i, 0] = r._pending[0] if r._pending else r._last
        logits, self._cache = self._decode(self.params, self._cache,
                                           jnp.asarray(toks))
        self._key, sub = jax.random.split(self._key)
        nxt = np.asarray(sample(logits[:, 0], 0.0, sub))
        idx = np.asarray(self._cache["index"])
        for i in live:
            r = self._active[i]
            emit = False
            if r._pending:
                r._pending.pop(0)
                emit = not r._pending   # prompt consumed: first real token
            else:
                emit = True
            if emit:
                r.out_tokens.append(int(nxt[i]))
                r._last = int(nxt[i])
            self.stats["tokens"] += 1
            if len(r.out_tokens) >= r.max_new or idx[i] >= self.max_len - 1:
                r.done = True
                self._active[i] = None
        self.stats["batches"] += 1
        return len(live)

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (any(self._active) or not self._queue.empty()) \
                and steps < max_steps:
            self.step()
            steps += 1
        return steps
