"""Batched serving engine: request queue -> prefill -> decode loop.

Slot-based continuous batching lite: a fixed-size batch of decode slots;
finished sequences free their slot, queued requests prefill into free slots
FIFO.  The engine is a WI *workload* with a public elastic surface the
serving tenant (``repro.agents.serving_agent``) drives:

  * ``drain()`` — stop admitting, reject new submits, hand queued requests
    back for re-routing; in-flight decodes run to completion.
  * ``resize_slots(n)`` — grow immediately (harvest ``SCALE_UP_OFFER``);
    shrink is *deferred* until the active set fits, then the surviving
    sequences are compacted into the smaller batch (throttle = compute
    shed: the batch shrinks, demand hints stay put).
  * ``step_once()`` — one batched decode step, the unit the tenant's pump
    loop and the trainer-style ``run()`` interleave with sim time.

Time is injected (``now=``, defaulting to ``time.time`` for standalone
use) so latency accounting works under the sim clock, and stats live in an
``obs.MetricDict`` with per-engine collectors (queue depth, active slots,
tokens/s) plus token/request latency histograms on the injected registry.

Two decode backends share every bit of the admission/slot/drain logic:

  * **real** (``params`` given) — jit-compiled batched decode over a jax
    KV cache (per-slot positions diverge; ``cache['index']`` is a vector);
  * **synthetic** (``params is None``) — a deterministic pure-python
    next-token function and per-slot position counters.  No jax import
    anywhere on this path, so the scheduler-tenant case studies and the
    choreography tests serve "tokens" at simulation speed.
"""
from __future__ import annotations

import dataclasses
import queue
import time
from typing import Callable, List, Optional

import numpy as np

from repro import obs

_SYNTH_VOCAB = 256      # synthetic-mode token space


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # [S] int32
    max_new: int = 16
    temperature: float = 0.0
    out_tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    # latency stamps (engine ``now()`` timebase; submit may pre-stamp)
    t_submit: Optional[float] = None
    t_first_token: Optional[float] = None
    t_done: Optional[float] = None


def sample(logits, temperature: float, key):
    import jax
    import jax.numpy as jnp
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)
    return jax.random.categorical(key, logits / temperature, axis=-1)


class ServingEngine:
    """Single-host engine (tests + examples + the serving tenant); the
    distributed variant runs the same logic with pjit'd prefill/decode
    (launch/serve.py)."""

    def __init__(self, cfg, pcfg, params, batch_slots: int = 4,
                 max_len: int = 256, seed: int = 0,
                 now: Optional[Callable[[], float]] = None,
                 registry: Optional[obs.MetricsRegistry] = None,
                 name: str = "engine",
                 on_complete: Optional[Callable[[Request], None]] = None):
        self.cfg, self.pcfg, self.params = cfg, pcfg, params
        self.slots = batch_slots
        self.max_len = max_len
        self.name = name
        self._now = now if now is not None else time.time
        self._on_complete = on_complete
        self._queue: "queue.Queue[Request]" = queue.Queue()
        self._active: List[Optional[Request]] = [None] * batch_slots
        self._last_emit: List[Optional[float]] = [None] * batch_slots
        self._draining = False
        self._target_slots: Optional[int] = None    # pending deferred shrink
        self._synthetic = params is None
        if self._synthetic:
            self._pos = [0] * batch_slots
        else:
            import jax
            from repro.models import model as M
            self._key = jax.random.PRNGKey(seed)
            self._cache = M.init_cache(cfg, batch_slots, max_len)
            self._decode = jax.jit(
                lambda p, c, t: M.decode_step(cfg, pcfg, p, c, t))
        reg = registry if registry is not None \
            else obs.MetricsRegistry(enabled=False)
        self._registry = reg
        self._t0 = self._now()
        # defaultdict(float)-compatible stats, mirrored into registry gauges
        self.stats = obs.MetricDict(reg, prefix="wi_serving_", replica=name)
        for k in ("requests", "tokens", "batches"):
            self.stats[k] = 0
        # latency distributions are shared series (no replica label) so one
        # percentile read covers the whole fleet
        self._tok_lat = reg.histogram(
            "wi_serving_token_latency_s",
            "submit/last-emit to token emit (includes queue wait)")
        self._req_lat = reg.histogram(
            "wi_serving_request_latency_s", "submit to final token")
        reg.add_collector(f"serving.{name}", self._collect)

    def _collect(self):
        dt = max(self._now() - self._t0, 1e-9)
        return {"queue_depth": self.queue_depth(),
                "active_slots": self.active_count(),
                "slots": self.slots,
                "tokens_per_s": self.stats["tokens"] / dt}

    # -- API -----------------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Queue a request; a draining engine rejects it (the router must
        send it elsewhere)."""
        if self._draining:
            self.stats["rejected"] += 1
            return False
        if req.t_submit is None:
            req.t_submit = self._now()
        self._queue.put(req)
        self.stats["requests"] += 1
        return True

    def utilization(self) -> float:
        return self.active_count() / self.slots

    def queue_depth(self) -> int:
        return self._queue.qsize()

    def active_count(self) -> int:
        return sum(r is not None for r in self._active)

    @property
    def admitting(self) -> bool:
        return not self._draining

    def p99_token_latency(self) -> float:
        """Bucket-estimated p99 of the shared token-latency series (NaN
        until anything was observed or when the registry is disabled)."""
        if getattr(self._tok_lat, "count", 0) == 0:
            return float("nan")
        return self._tok_lat.percentile(99)

    @staticmethod
    def _steps_left(r: Request) -> int:
        """Upper bound on decode steps to finish ``r`` (prompt feed-through
        plus remaining generation; the max_len cap can only end earlier)."""
        return len(getattr(r, "_pending", ())) + \
            max(0, r.max_new - len(r.out_tokens))

    # -- elastic surface -----------------------------------------------------
    def drain(self):
        """Eviction notice: stop admitting and reject new submits.  Returns
        ``(steps_left, requeued)`` — the worst-case decode steps to finish
        every in-flight sequence (the tenant converts that to the modeled
        ack latency) and the queued-but-unstarted requests, handed back so
        the router re-routes them to surviving replicas."""
        self._draining = True
        requeued: List[Request] = []
        while not self._queue.empty():
            requeued.append(self._queue.get())
        steps = max((self._steps_left(r) for r in self._active
                     if r is not None), default=0)
        self.stats["drains"] += 1
        self.stats["drain_requeued"] += len(requeued)
        return steps, requeued

    def resize_slots(self, n: int) -> int:
        """Grow/shrink the decode batch.  Grows apply immediately (new
        slots admit from the queue on the next step); shrinks defer until
        the active set fits, then compact surviving sequences — an active
        sequence is never dropped by a resize.  Returns the batch size in
        effect right now (the target, once a pending shrink lands)."""
        n = max(1, int(n))
        if n >= self.slots:
            if n > self.slots:
                self._grow(n)
            self._target_slots = None
            return self.slots
        self._target_slots = n
        self._maybe_apply_shrink()
        return self.slots if self._target_slots is None else n

    def _grow(self, n: int):
        old = self.slots
        self._active.extend([None] * (n - old))
        self._last_emit.extend([None] * (n - old))
        if self._synthetic:
            self._pos.extend([0] * (n - old))
        else:
            import jax
            from repro.models import model as M
            new_cache = M.init_cache(self.cfg, n, self.max_len)

            def cp(o, nl):
                return nl.at[:, :o.shape[1]].set(o) if nl.ndim >= 2 else nl
            self._cache = {
                "groups": [jax.tree.map(cp, og, ng) for og, ng in
                           zip(self._cache["groups"], new_cache["groups"])],
                "index": new_cache["index"].at[:old].set(
                    self._cache["index"]),
            }
        self.slots = n
        self.stats["resizes"] += 1

    def _maybe_apply_shrink(self):
        n = self._target_slots
        if n is None:
            return
        keep = [i for i, r in enumerate(self._active) if r is not None]
        if len(keep) > n:
            return          # still too many in flight: stay deferred
        # surviving sequences first, then free rows to pad out the batch
        perm = keep + [i for i in range(self.slots)
                       if self._active[i] is None][:n - len(keep)]
        self._active = [self._active[i] for i in perm]
        self._last_emit = [self._last_emit[i] for i in perm]
        if self._synthetic:
            self._pos = [self._pos[i] for i in perm]
        else:
            import jax
            import jax.numpy as jnp
            idx = jnp.asarray(perm)

            def take(leaf):
                return leaf[:, idx] if leaf.ndim >= 2 else leaf
            self._cache = {
                "groups": [jax.tree.map(take, g)
                           for g in self._cache["groups"]],
                "index": self._cache["index"][idx],
            }
        self.slots = n
        self._target_slots = None
        self.stats["resizes"] += 1

    # -- loop ----------------------------------------------------------------
    def _admit(self):
        """Fill free slots FIFO from the queue.  The prompt is fed
        token-by-token through the batched decode step (slot-level prefill
        interleaves with other slots' generation — continuous batching).
        A pending shrink caps admissions at the target batch size."""
        cap = self._target_slots if self._target_slots is not None \
            else self.slots
        n_active = self.active_count()
        for i in range(self.slots):
            if n_active >= cap or self._queue.empty():
                break
            if self._active[i] is None:
                req = self._queue.get()
                req._pending = list(int(t) for t in req.prompt)
                req._last = req._pending[-1]
                self._active[i] = req
                self._last_emit[i] = None
                self._reset_slot(i)
                n_active += 1

    def _reset_slot(self, i: int):
        if self._synthetic:
            self._pos[i] = 0
            return
        import jax
        import jax.numpy as jnp

        def zero_rows(c):
            def z(leaf):
                return leaf.at[:, i].set(jnp.zeros_like(leaf[:, i])) \
                    if leaf.ndim >= 2 else leaf
            return jax.tree.map(z, c)
        self._cache = {
            "groups": [zero_rows(g) for g in self._cache["groups"]],
            "index": self._cache["index"].at[i].set(0),
        }

    def step_once(self) -> int:
        """One batched decode step across all active slots (per-slot cache
        positions diverge; cache['index'] is a per-slot vector)."""
        self._maybe_apply_shrink()
        self._admit()
        live = [i for i, r in enumerate(self._active) if r is not None]
        if not live:
            return 0
        now = self._now()
        toks = np.zeros((self.slots, 1), np.int32)
        for i in live:
            r = self._active[i]
            toks[i, 0] = r._pending[0] if r._pending else r._last
        if self._synthetic:
            # deterministic pure-python "greedy decode": the next token is
            # a fixed function of the fed token, independent of co-batched
            # slots — same determinism contract as the jax path
            nxt = (5 * toks[:, 0] + 7) % _SYNTH_VOCAB
            for i in live:
                self._pos[i] += 1
            idx = np.asarray(self._pos)
        else:
            import jax
            import jax.numpy as jnp
            logits, self._cache = self._decode(self.params, self._cache,
                                               jnp.asarray(toks))
            self._key, sub = jax.random.split(self._key)
            nxt = np.asarray(sample(logits[:, 0], 0.0, sub))
            idx = np.asarray(self._cache["index"])
        for i in live:
            r = self._active[i]
            emit = False
            if r._pending:
                r._pending.pop(0)
                emit = not r._pending   # prompt consumed: first real token
            else:
                emit = True
            if emit:
                r.out_tokens.append(int(nxt[i]))
                r._last = int(nxt[i])
                # token latency: gap since the previous emit, or the full
                # queue-included wait for the first token
                prev = self._last_emit[i]
                if prev is None:
                    r.t_first_token = now
                    prev = r.t_submit if r.t_submit is not None else now
                self._tok_lat.observe(max(0.0, now - prev))
                self._last_emit[i] = now
            self.stats["tokens"] += 1
            if len(r.out_tokens) >= r.max_new or idx[i] >= self.max_len - 1:
                r.done = True
                r.t_done = now
                self._active[i] = None
                self._last_emit[i] = None
                self.stats["completed"] += 1
                self.stats["tokens_out"] += len(r.out_tokens)
                if r.t_submit is not None:
                    self._req_lat.observe(max(0.0, now - r.t_submit))
                if self._on_complete is not None:
                    self._on_complete(r)
        self.stats["batches"] += 1
        return len(live)

    # legacy name: step_once is the tenant-facing spelling
    def step(self) -> int:
        return self.step_once()

    def run_until_drained(self, max_steps: int = 10_000):
        steps = 0
        while (any(self._active) or not self._queue.empty()) \
                and steps < max_steps:
            self.step_once()
            steps += 1
        return steps
