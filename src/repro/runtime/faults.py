"""Back-compat shim: ``FaultInjector`` moved to ``repro.chaos.injector``.

This module keeps the old import path working for
``tests/test_runtime_elastic.py`` and the examples.  For real fault
injection — seeded channel faults, unannounced hardware crashes,
misbehaving guests — use ``repro.chaos`` (FaultPlan / ChaosBus /
CrashInjector) against the scheduler substrate; see docs/RESILIENCE.md.
"""
from repro.chaos.injector import FaultInjector

__all__ = ["FaultInjector"]
