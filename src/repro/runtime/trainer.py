"""WI-integrated elastic trainer.

The training job is a WI *workload*:
  * at deployment it publishes hints derived from its own config — elastic
    width => scale_out_in, checkpoint cadence => preemptibility, restart
    latency => deploy_time,
  * at runtime the per-host local manager publishes x-step-time (straggler
    telemetry) and flips preemptibility low while a checkpoint is stale,
  * it subscribes to platform hints and reacts:
      EVICTION_NOTICE / SCALE_DOWN_NOTICE -> emergency checkpoint, shrink the
        data-parallel width (drop the evicted hosts), re-jit, reshard, resume;
      SCALE_UP_OFFER -> grow DP width onto offered hosts;
      THROTTLE_NOTICE / UNDERCLOCK_NOTICE -> halve microbatch (less compute
        per unit time) until the event clears.

Elasticity is real: the mesh is rebuilt over the surviving device set and
params/opt state are resharded with device_put.  The data pipeline is
stateless-per-step, so no sample is lost or repeated across resizes.

The trainer runs in one of two modes:

  * **standalone** (default, ``standalone=True``) — it owns a
    ``LocalManager``/``VMEndpoint`` pair for a single synthetic VM and
    drains platform events itself.  This is the unit-test path driven by
    ``repro.chaos.FaultInjector``.
  * **scheduler tenant** (``standalone=False``) — the training job's VMs
    are placed, noticed, and killed by the real platform scheduler
    (``repro.sched``), and ``repro.agents.trainer_agent.TrainerTenant``
    owns the endpoints (one per placed VM, through the agent runtime) and
    the VM->device mapping.  The tenant calls the public elastic surface
    below (``emergency_checkpoint`` / ``resize_to_devices`` /
    ``set_throttled`` / ``step_once``); runtime hints flow out through
    ``hint_sink`` (wired to the leader agent's guest channel).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.ckpt.checkpoint import Checkpointer, CheckpointCorruptError
from repro.configs.base import (ModelConfig, ParallelConfig, RunConfig,
                                pconfig_replace)
from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.local_manager import LocalManager, VMEndpoint
from repro.data.pipeline import make_dataset, DataConfig
from repro.launch import steps as ST
from repro.models import model as Mdl
from repro.models import sharding as SH
from repro.runtime.straggler import StragglerDetector
from repro.train import optimizer as opt


def deployment_hints_from(rcfg: RunConfig, ckpt_every: int,
                          elastic: bool) -> Dict:
    """The WI mapping for training jobs (DESIGN.md §2 table)."""
    return {
        "scale_out_in": bool(elastic),
        "scale_up_down": bool(elastic),
        # a job that checkpoints every N steps tolerates losing < N steps:
        # high preemptibility, bounded by how much compute a restart wastes
        "preemptibility_pct": 80.0 if elastic else 20.0,
        "delay_tolerance_ms": 60_000.0,
        "deploy_time_ms": 300_000.0,      # tolerant restart latency
        "availability_nines": 2.0,
        "region_independent": True,
    }


class WITrainer:
    def __init__(self, rcfg: RunConfig, gm: GlobalManager,
                 ckpt_dir: str, devices: Optional[Sequence] = None,
                 model_axis: int = 1, ckpt_every: int = 20,
                 min_dp: int = 1, data_cfg: DataConfig = DataConfig(),
                 workload: str = "train-job", server: str = "rack0/host0",
                 batch_override: Optional[int] = None,
                 seq_override: Optional[int] = None,
                 standalone: bool = True,
                 hint_sink: Optional[Callable[[Dict], None]] = None):
        self.rcfg, self.gm = rcfg, gm
        self.cfg: ModelConfig = rcfg.model
        self.workload = workload
        self.ckpt = Checkpointer(ckpt_dir)
        self.ckpt_every = ckpt_every
        self.min_dp = min_dp
        self.model_axis = model_axis
        self.detector = StragglerDetector()
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.excluded: List = []
        self.batch = batch_override or 8
        self.seq = seq_override or 64
        self.data = make_dataset(self.cfg, self.batch, self.seq, data_cfg)
        self.metrics_log: List[Dict] = []
        self.events_log: List[Dict] = []
        self.step = 0
        self._throttled = False

        self.hint_sink = hint_sink
        self.local: Optional[LocalManager] = None
        self.endpoint: Optional[VMEndpoint] = None
        if standalone:
            # legacy single-VM mode: the trainer owns its guest channel
            gm.register_workload(workload, deployment_hints_from(
                rcfg, ckpt_every, elastic=True))
            self.local = LocalManager(server, gm.bus, clock=gm.clock,
                                      vm_hint_rate_per_s=1e6,
                                      vm_hint_burst=1e6)
            self.endpoint = self.local.attach_vm("vm0", workload)
            self.endpoint.on_event(self._on_platform_event)
        self._pending_events: List[Dict] = []

        self._build(self.devices)
        self._init_state()

    # -- mesh / jit lifecycle --------------------------------------------------
    def _build(self, devices: Sequence):
        dp = max(self.min_dp, len(devices) // self.model_axis)
        devices = list(devices)[: dp * self.model_axis]
        self.active_devices = devices
        dev_array = np.asarray(devices).reshape(dp, self.model_axis)
        self.mesh = Mesh(dev_array, ("data", "model"))
        self.pcfg = ParallelConfig(
            pod=1, data=dp, model=self.model_axis, fsdp=False,
            seq_shard_acts=False, attn_impl="dense", remat="none",
            microbatch=2 if self._throttled else 0)
        self.pshard, self.oshard, rules = ST.train_shardings(
            self.cfg, self.pcfg, self.mesh)
        SH.set_mesh(self.mesh, rules)
        fn = ST.build_train_fn(self.cfg, self.pcfg, self.rcfg, self.mesh)
        self.bshard = {
            k: NamedSharding(self.mesh, P("data", *([None] * (v.ndim - 1))))
            for k, v in self.data.batch_at(0).items()}
        self._train_step = jax.jit(
            fn, in_shardings=(self.pshard, self.oshard, self.bshard),
            out_shardings=(self.pshard, self.oshard, None),
            donate_argnums=(0, 1))
        self.dp = dp

    def _init_state(self):
        # newest committed checkpoint first; a corrupt/torn one (crash mid
        # emergency checkpoint) falls back to the previous durable
        # generation — lost work is bounded by the checkpoint interval, the
        # job never bricks on a bad restore
        for ck_step in reversed(self.ckpt.committed_steps()):
            try:
                self._restore(ck_step)
                return
            except CheckpointCorruptError:
                self.events_log.append({"kind": "corrupt_checkpoint_skipped",
                                        "step": ck_step})
        self.params = jax.device_put(
            Mdl.init_params(self.cfg, jax.random.PRNGKey(self.rcfg.seed)),
            self.pshard)
        self.opt_state = jax.device_put(
            opt.init_opt_state(self.rcfg, self.params, self.pcfg),
            self.oshard)

    def _restore(self, ck_step: int):
        like_p = Mdl.abstract_params(self.cfg)
        like_o = opt.init_opt_state(self.rcfg, like_p, self.pcfg,
                                    abstract=True)
        tree = self.ckpt.restore(
            ck_step, {"params": like_p, "opt": like_o},
            {"params": self.pshard, "opt": self.oshard})
        self.params, self.opt_state = tree["params"], tree["opt"]
        self.step = self.ckpt.metadata(ck_step).get("step", ck_step)

    def _checkpoint(self, sync=False):
        tree = {"params": self.params, "opt": self.opt_state}
        md = {"step": self.step, "dp": self.dp}
        if sync:
            self.ckpt.save(self.step, tree, md)
        else:
            self.ckpt.save_async(self.step, tree, md)
        self.events_log.append({"kind": "checkpoint", "step": self.step,
                                "sync": sync})

    # -- WI event handling -----------------------------------------------------
    def _on_platform_event(self, event: Dict):
        self._pending_events.append(event)

    def _drain_events(self):
        evs, self._pending_events = self._pending_events, []
        for e in evs:
            kind = e.get("event")
            self.events_log.append({"kind": kind, "step": self.step,
                                    "payload": e.get("payload", {})})
            if kind in (H.PlatformEvent.EVICTION_NOTICE.value,
                        H.PlatformEvent.SCALE_DOWN_NOTICE.value):
                n_lost = int(e.get("payload", {}).get("n_devices", 0)) or \
                    self.model_axis
                self._resize(len(self.active_devices) - n_lost)
                self.endpoint.ack_event(e.get("seq", 0))
            elif kind == H.PlatformEvent.SCALE_UP_OFFER.value:
                n_new = int(e.get("payload", {}).get("n_devices", 0)) or \
                    self.model_axis
                target = min(len(self.devices),
                             len(self.active_devices) + n_new)
                self._resize(target)
                self.endpoint.ack_event(e.get("seq", 0))
            elif kind in (H.PlatformEvent.THROTTLE_NOTICE.value,
                          H.PlatformEvent.UNDERCLOCK_NOTICE.value):
                self.set_throttled(True)
            elif kind == H.PlatformEvent.OVERCLOCK_OFFER.value:
                self.set_throttled(False)

    def _rebuild_same_devices(self):
        self._checkpoint(sync=True)
        self.ckpt.wait()
        self._build(self.active_devices)
        self._reshard()

    def _resize(self, n_devices: int):
        """Elastic resize to n_devices (floor at min_dp x model_axis)."""
        n_devices = max(self.min_dp * self.model_axis,
                        (n_devices // self.model_axis) * self.model_axis)
        if n_devices == len(self.active_devices):
            return
        self._checkpoint(sync=True)
        self.ckpt.wait()
        usable = [d for d in self.devices if d not in self.excluded]
        self._build(usable[:n_devices])
        self._reshard()
        self.events_log.append({"kind": "resize", "step": self.step,
                                "dp": self.dp,
                                "devices": len(self.active_devices)})

    def _reshard(self):
        self.params = jax.device_put(
            jax.tree.map(np.asarray, self.params), self.pshard)
        self.opt_state = jax.device_put(
            jax.tree.map(np.asarray, self.opt_state), self.oshard)

    # -- public elastic surface (scheduler-tenant mode) ----------------------
    def emergency_checkpoint(self):
        """Eviction notice: make the state durable *now* (sync save + join)
        so the guest can ack the notice and hand the VM back early."""
        self._checkpoint(sync=True)
        self.ckpt.wait()
        self.events_log.append({"kind": "emergency_checkpoint",
                                "step": self.step})

    def resize_to_devices(self, devices: Sequence) -> bool:
        """Elastic resize onto an explicit device set (the tenant's VM ->
        device mapping after a kill / replacement / harvest grant).  Returns
        False — and leaves the current mesh untouched — when the set is too
        small for even the minimum mesh; the caller pauses stepping until
        capacity returns."""
        devices = list(devices)
        if len(devices) < self.min_dp * self.model_axis:
            return False
        # _build floors the mesh to dp*model_axis devices, so compare the
        # usable prefix — an odd-sized set must not re-jit an identical mesh
        dp = max(self.min_dp, len(devices) // self.model_axis)
        if devices[: dp * self.model_axis] == self.active_devices:
            return True
        self._checkpoint(sync=True)
        self.ckpt.wait()
        self._build(devices)
        self._reshard()
        self.events_log.append({"kind": "resize", "step": self.step,
                                "dp": self.dp,
                                "devices": len(self.active_devices)})
        return True

    def set_throttled(self, on: bool):
        """Platform throttle/underclock notice (or its clearing): halve the
        microbatch (less compute per unit time) until the event clears."""
        if bool(on) == self._throttled:
            return
        self._throttled = bool(on)
        self._rebuild_same_devices()
        self.events_log.append({"kind": "throttle" if on else "restore",
                                "step": self.step})

    def state_bytes(self) -> int:
        """Checkpointable state size (params + optimizer), for modeling
        checkpoint write latency in simulated time."""
        leaves = jax.tree.leaves({"params": self.params,
                                  "opt": self.opt_state})
        return int(sum(np.asarray(l).nbytes for l in leaves))

    # -- runtime hints -----------------------------------------------------------
    def _publish_runtime_hints(self, step_ms: float):
        fresh = (self.step % self.ckpt_every) < max(1, self.ckpt_every // 4)
        hints = {
            "preemptibility_pct": 90.0 if fresh else 40.0,
            "x-step-time-ms": step_ms,
            "x-dp-width": self.dp,
        }
        if self.endpoint is not None:
            self.endpoint.set_runtime_hints(hints)
        elif self.hint_sink is not None:
            self.hint_sink(hints)
        self.detector.record(f"host-dp{self.step % max(self.dp, 1)}", step_ms)

    # -- main loop -----------------------------------------------------------
    def step_once(self) -> Dict:
        """One training step on the current mesh (the tenant interleaves
        these with the platform's simulated clock)."""
        batch = {k: jax.device_put(v, self.bshard[k])
                 for k, v in self.data.batch_at(self.step).items()}
        t0 = time.perf_counter()
        self.params, self.opt_state, metrics = self._train_step(
            self.params, self.opt_state, batch)
        loss = float(metrics["loss"])
        dt_ms = (time.perf_counter() - t0) * 1e3
        self.step += 1
        rec = {"step": self.step, "loss": loss, "dp": self.dp, "ms": dt_ms}
        self.metrics_log.append(rec)
        self._publish_runtime_hints(dt_ms)
        if self.step % self.ckpt_every == 0:
            self._checkpoint()
        return rec

    def run(self, n_steps: int, step_callback: Optional[Callable] = None):
        while self.step < n_steps:
            self._drain_events()
            self.step_once()
            if step_callback:
                step_callback(self)
        self.ckpt.wait()
        return self.metrics_log
