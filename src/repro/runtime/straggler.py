"""Straggler detection + mitigation policy.

Hosts report per-step durations (via WI runtime hints, key
``x-step-time-ms``).  The detector keeps an EWMA per host and flags hosts
whose smoothed step time exceeds ``threshold`` x the fleet median.  The
mitigation policy is the WI loop's job: publish an ``x-straggler`` hint so
the platform can rightsize/migrate, and (if the job is elastic) exclude the
host at the next checkpoint boundary.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class HostStat:
    ewma_ms: float = 0.0
    n: int = 0


class StragglerDetector:
    def __init__(self, alpha: float = 0.3, threshold: float = 1.5,
                 min_samples: int = 5):
        self.alpha, self.threshold, self.min_samples = (alpha, threshold,
                                                        min_samples)
        self._hosts: Dict[str, HostStat] = {}

    def record(self, host: str, step_ms: float):
        st = self._hosts.setdefault(host, HostStat())
        st.ewma_ms = (step_ms if st.n == 0
                      else (1 - self.alpha) * st.ewma_ms
                      + self.alpha * step_ms)
        st.n += 1

    def median_ewma(self) -> Optional[float]:
        vals = sorted(s.ewma_ms for s in self._hosts.values()
                      if s.n >= self.min_samples)
        return vals[len(vals) // 2] if vals else None

    def stragglers(self) -> List[str]:
        med = self.median_ewma()
        if med is None or med <= 0:
            return []
        return [h for h, s in self._hosts.items()
                if s.n >= self.min_samples
                and s.ewma_ms > self.threshold * med]

    def slowdown(self, host: str) -> float:
        med = self.median_ewma()
        st = self._hosts.get(host)
        if not med or not st or st.n < self.min_samples:
            return 1.0
        return st.ewma_ms / med
