"""Data pipeline: deterministic synthetic LM streams + a binary-file reader.

Elastic-friendly by construction: ``batch_at(step)`` is a pure function of
(seed, step, shape), so a job that restarts — possibly with a different
data-parallel width — consumes exactly the global batch sequence it would
have seen, with no skipped or repeated tokens (the WI elastic-resize story
depends on this).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.model import VIS_EMBED_DIM


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 0
    kind: str = "synthetic"        # synthetic | file
    path: Optional[str] = None     # for kind=file: tokenized uint16/32 binary


class SyntheticLM:
    """Structured-random tokens (zipfian unigram + short-range repeats) —
    learnable enough that a ~100M model shows loss descent in the examples."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dcfg: DataConfig = DataConfig()):
        self.cfg, self.batch, self.seq, self.dcfg = cfg, batch, seq, dcfg
        v = cfg.vocab_size
        rng = np.random.default_rng(dcfg.seed)
        probs = 1.0 / np.arange(1, v + 1) ** 1.1
        self._probs = probs / probs.sum()
        self._perm = rng.permutation(v)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng((self.dcfg.seed, step))
        toks = rng.choice(self.cfg.vocab_size, size=(self.batch, self.seq + 1),
                          p=self._probs)
        toks = self._perm[toks]
        # short-range structure: copy spans forward so context helps
        span = max(4, self.seq // 64)
        hi = max(1, self.seq + 1 - 2 * span)
        for row in toks:
            starts = rng.integers(0, hi, size=3)
            for s in starts:
                row[s + span:s + 2 * span] = row[s:s + span]
        out = {"tokens": toks.astype(np.int32)}
        if self.cfg.family == "encdec":
            out["frames"] = rng.standard_normal(
                (self.batch, self.seq, self.cfg.d_model)).astype(np.float32)
            out["tokens"] = toks[:, : self.seq // self.cfg.enc_seq_ratio + 1]
        if self.cfg.family == "vlm":
            nv = self.cfg.n_vision_tokens
            out["patches"] = rng.standard_normal(
                (self.batch, nv, VIS_EMBED_DIM)).astype(np.float32)
            out["tokens"] = toks[:, : max(2, self.seq - nv) + 1]
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class FileLM:
    """Memory-mapped token file: contiguous uint16/uint32 token ids.

    ``batch_at(step)`` deterministically strides disjoint windows across the
    file (wrap-around), matching the SyntheticLM contract.
    """

    def __init__(self, cfg: ModelConfig, batch: int, seq: int,
                 dcfg: DataConfig):
        self.cfg, self.batch, self.seq = cfg, batch, seq
        path = Path(dcfg.path)
        dtype = np.uint16 if cfg.vocab_size < 65_536 else np.uint32
        self._data = np.memmap(path, dtype=dtype, mode="r")
        assert len(self._data) > (seq + 1), "token file too small"
        self.dcfg = dcfg

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        n = len(self._data)
        out = np.empty((self.batch, self.seq + 1), np.int32)
        base = step * self.batch * (self.seq + 1)
        for b in range(self.batch):
            start = (base + b * (self.seq + 1)) % (n - self.seq - 1)
            out[b] = self._data[start:start + self.seq + 1]
        return {"tokens": np.clip(out, 0, self.cfg.vocab_size - 1)}


def make_dataset(cfg: ModelConfig, batch: int, seq: int,
                 dcfg: DataConfig = DataConfig()):
    if dcfg.kind == "file":
        return FileLM(cfg, batch, seq, dcfg)
    return SyntheticLM(cfg, batch, seq, dcfg)


def shard_batch(batch: Dict[str, np.ndarray], shardings: Dict):
    """device_put a host batch with the step function's input shardings."""
    return {k: jax.device_put(v, shardings[k]) if k in shardings
            else jax.numpy.asarray(v) for k, v in batch.items()}
