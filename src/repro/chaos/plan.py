"""Seeded fault plans: the single source of truth for injected chaos.

A ``FaultPlan`` describes everything a chaos run does to the system —
per-topic channel faults (drop / duplicate / delay / reorder), scheduled
hardware crashes, and per-workload guest misbehavior — from one seed, so
every run is exactly reproducible.  The plan is *data*; the machinery that
acts on it lives next door (``ChaosBus`` for channels, ``CrashInjector``
for hardware, ``misbehaving_factory`` for guests).

Delivery contract (docs/RESILIENCE.md): the scheduler-authoritative topics
``wi.sched.decisions`` / ``wi.sched.evictions`` / ``wi.sched.failures``
are transactional — they are the platform's own books and may never be
faulted; a plan that names one raises at construction.  Guest-facing
channels (platform hints, acks, runtime hints, leases, deploy hints) are
best-effort, matching the paper's framing of hints as advisory — the
hardened endpoints must survive loss, duplication, and reordering there.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from repro.core import hints as H

# Topics the platform relies on transactionally: its own decision /
# eviction / failure streams.  Faulting these would corrupt the books the
# chaos soak exists to validate, so plans refuse them outright.
PROTECTED_TOPICS = frozenset({
    H.TOPIC_SCHED_DECISIONS,
    H.TOPIC_EVICTIONS,
    H.TOPIC_FAILURES,
})

# Guest misbehavior modes (see chaos/guests.py)
GUEST_NEVER_ACK = "never_ack"
GUEST_SLOW_ACK = "slow_ack"
GUEST_CRASH_MID_CKPT = "crash_mid_ckpt"
GUEST_HINT_SPAM = "hint_spam"
GUEST_MODES = frozenset({GUEST_NEVER_ACK, GUEST_SLOW_ACK,
                         GUEST_CRASH_MID_CKPT, GUEST_HINT_SPAM})


@dataclass(frozen=True)
class ChannelFaults:
    """Per-topic fault rates.  Fates are mutually exclusive per record
    (drop XOR delay XOR reorder XOR clean delivery); duplication is decided
    independently of the primary fate, so a delayed record may also arrive
    twice."""
    drop_p: float = 0.0         # record silently lost (all consumers)
    dup_p: float = 0.0          # record delivered again immediately
    delay_p: float = 0.0        # record held for U(0, delay_max_s]
    delay_max_s: float = 5.0
    reorder_p: float = 0.0      # record held back past its successor
    reorder_hold_s: float = 2.0  # safety flush if no successor arrives

    def any(self) -> bool:
        return (self.drop_p > 0.0 or self.dup_p > 0.0 or
                self.delay_p > 0.0 or self.reorder_p > 0.0)


@dataclass
class FaultPlan:
    """One deterministic chaos schedule.

    ``channels`` maps topic -> ``ChannelFaults``; ``server_crashes`` /
    ``vm_crashes`` are ``(t, id)`` schedules armed on the engine by
    ``CrashInjector``; ``guest_modes`` maps workload -> one of
    ``GUEST_MODES``.  Randomness is derived per-topic from the seed alone
    (``random.Random(f"{seed}:{topic}")``), independent of
    ``PYTHONHASHSEED`` and of how many other topics are faulted.
    """
    seed: int = 0
    channels: Dict[str, ChannelFaults] = field(default_factory=dict)
    server_crashes: List[Tuple[float, str]] = field(default_factory=list)
    vm_crashes: List[Tuple[float, str]] = field(default_factory=list)
    guest_modes: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self):
        for topic, ch in self.channels.items():
            if topic in PROTECTED_TOPICS and ch.any():
                raise ValueError(
                    f"topic {topic!r} is transactional (platform books); "
                    f"a FaultPlan may not fault it")
        for w, mode in self.guest_modes.items():
            if mode not in GUEST_MODES:
                raise ValueError(f"unknown guest mode {mode!r} for {w!r}")
        self._rngs: Dict[str, random.Random] = {}

    def channel(self, topic: str) -> Optional[ChannelFaults]:
        """The faults for a topic, or None when the topic is clean (the
        pass-through fast path in ``ChaosBus``)."""
        ch = self.channels.get(topic)
        return ch if ch is not None and ch.any() else None

    def rng(self, topic: str) -> random.Random:
        r = self._rngs.get(topic)
        if r is None:
            r = self._rngs[topic] = random.Random(f"{self.seed}:{topic}")
        return r


def lossy_guest_plan(seed: int = 0, drop_p: float = 0.05,
                     dup_p: float = 0.05, delay_p: float = 0.05,
                     delay_max_s: float = 3.0, reorder_p: float = 0.05,
                     **kw) -> FaultPlan:
    """Convenience: fault every guest-facing channel uniformly (platform
    hints, acks, runtime hints) — the standard chaos-soak configuration."""
    ch = ChannelFaults(drop_p=drop_p, dup_p=dup_p, delay_p=delay_p,
                       delay_max_s=delay_max_s, reorder_p=reorder_p)
    return FaultPlan(seed=seed, channels={
        H.TOPIC_PLATFORM_HINTS: ch,
        H.TOPIC_EVENT_ACKS: ch,
        H.TOPIC_RUNTIME_HINTS: ChannelFaults(drop_p=drop_p),
    }, **kw)
