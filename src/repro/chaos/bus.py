"""ChaosBus: a fault-injecting wrapper around ``core.bus.Bus``.

Same producer/consumer API as the real bus; publishes on topics named in
the ``FaultPlan`` may be dropped, delayed, duplicated, or reordered before
they reach the inner bus.  Faults are applied on the *publish* side — a
dropped record is lost for every consumer, matching a producer-side send
failure — which keeps the model simple and the books checkable (see
docs/RESILIENCE.md).  Topics without faults in the plan take a strict
pass-through path: with an all-zero plan the wrapper is behaviorally
identical to the inner bus, so existing benchmarks reproduce their bars
unchanged.

Fault semantics per publish on a faulted topic:
  * **drop** — the record never reaches the inner bus; the caller gets a
    synthetic ``(0, -1)`` ack (producers in this codebase ignore acks).
  * **delay** — delivery deferred by U(0, delay_max_s] sim-seconds via the
    engine; requires an engine.
  * **reorder** — the record is held back until the *next* publish on the
    topic lands first (or a safety timer flushes it), i.e. two adjacent
    records swap; at most one record is held per topic at a time.
  * **duplicate** — decided independently of the primary fate: the record
    is appended twice back-to-back (or twice after its delay).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.chaos.plan import ChannelFaults, FaultPlan


class ChaosBus:
    def __init__(self, inner, plan: Optional[FaultPlan] = None, engine=None):
        self.inner = inner
        self.plan = plan or FaultPlan()
        self.engine = engine
        # topic -> the one held-back (key, value, dup) awaiting a successor
        self._held: Dict[str, tuple] = {}
        self.stats: Dict[str, int] = {
            "dropped": 0, "delayed": 0, "duplicated": 0, "reordered": 0}
        needs_engine = any(
            ch.delay_p > 0.0 or ch.reorder_p > 0.0
            for ch in self.plan.channels.values())
        if needs_engine and engine is None:
            raise ValueError("FaultPlan uses delay/reorder: ChaosBus needs "
                             "an engine to defer deliveries")

    # -- faulted producer path ----------------------------------------------
    def _deliver(self, topic: str, value, key, dup: bool) -> Tuple[int, int]:
        ack = self.inner.publish(topic, value, key=key)
        if dup:
            self.inner.publish(topic, value, key=key)
            self.stats["duplicated"] += 1
        return ack

    def _flush_held(self, topic: str, entry):
        """Deliver a held-back record (successor landed, or safety timer)."""
        if self._held.get(topic) is entry:
            del self._held[topic]
            key, value, dup = entry
            self._deliver(topic, value, key, dup)

    def publish(self, topic: str, value, key=None) -> Tuple[int, int]:
        ch = self.plan.channel(topic)
        if ch is None:
            return self.inner.publish(topic, value, key=key)
        rng = self.plan.rng(topic)
        fate = rng.random()
        dup = rng.random() < ch.dup_p
        held = self._held.get(topic)
        if (held is None and
                ch.drop_p + ch.delay_p <= fate
                < ch.drop_p + ch.delay_p + ch.reorder_p):
            entry = (key, value, dup)
            self._held[topic] = entry
            self.stats["reordered"] += 1
            self.engine.after(ch.reorder_hold_s,
                              lambda: self._flush_held(topic, entry))
            return 0, -1
        ack: Tuple[int, int] = (0, -1)
        if fate < ch.drop_p:
            self.stats["dropped"] += 1
        elif fate < ch.drop_p + ch.delay_p:
            d = rng.uniform(0.0, ch.delay_max_s)
            self.stats["delayed"] += 1
            self.engine.after(d, lambda: self._deliver(topic, value, key, dup))
        else:
            ack = self._deliver(topic, value, key, dup)
        if held is not None:    # the successor has gone by: swap complete
            self._flush_held(topic, held)
        return ack

    def publish_batch(self, topic: str, items) -> List[Tuple[int, int]]:
        if self.plan.channel(topic) is None:
            return self.inner.publish_batch(topic, items)
        return [self.publish(topic, v, key=k) for k, v in items]

    # -- everything else delegates -------------------------------------------
    def subscribe(self, topic, callback):
        return self.inner.subscribe(topic, callback)

    def poll(self, topic, group, max_records: int = 100):
        return self.inner.poll(topic, group, max_records)

    def commit(self, topic, group, partition, offset):
        return self.inner.commit(topic, group, partition, offset)

    def seek_to_beginning(self, topic, group):
        return self.inner.seek_to_beginning(topic, group)

    def topics(self):
        return self.inner.topics()

    def end_offsets(self, topic):
        return self.inner.end_offsets(topic)

    def lag(self, topic, group):
        return self.inner.lag(topic, group)

    def close(self):
        return self.inner.close()

    @property
    def published(self) -> int:
        return self.inner.published

    @property
    def _clock(self):
        return self.inner._clock
