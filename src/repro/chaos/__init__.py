"""repro.chaos — deterministic fault injection for the WI reproduction.

Everything chaotic flows from one seeded ``FaultPlan``:

  * ``ChaosBus`` wraps the WI bus and drops / delays / duplicates /
    reorders records on guest-facing topics (platform books stay
    transactional — see ``PROTECTED_TOPICS``);
  * ``CrashInjector`` arms unannounced hardware crashes on the engine;
  * ``misbehaving_factory`` / ``install_guest_modes`` swap rogue agents
    into workload policies (never-ack, slow-ack, crash-mid-checkpoint,
    hint-spam);
  * ``FaultInjector`` is the single-process unit-test shim (publishes
    platform events straight through a GlobalManager, no scheduler).

The chaos soak (``sim/casestudies/chaos_soak.py``) composes all of these;
docs/RESILIENCE.md documents the failure model and the hardening it
exercises.
"""
from repro.chaos.bus import ChaosBus
from repro.chaos.crashes import CrashInjector
from repro.chaos.guests import (MisbehavingAgent, install_guest_modes,
                                misbehaving_factory)
from repro.chaos.injector import FaultInjector
from repro.chaos.plan import (GUEST_CRASH_MID_CKPT, GUEST_HINT_SPAM,
                              GUEST_MODES, GUEST_NEVER_ACK, GUEST_SLOW_ACK,
                              PROTECTED_TOPICS, ChannelFaults, FaultPlan,
                              lossy_guest_plan)

__all__ = [
    "ChaosBus", "ChannelFaults", "CrashInjector", "FaultInjector",
    "FaultPlan", "GUEST_CRASH_MID_CKPT", "GUEST_HINT_SPAM", "GUEST_MODES",
    "GUEST_NEVER_ACK", "GUEST_SLOW_ACK", "MisbehavingAgent",
    "PROTECTED_TOPICS", "install_guest_modes", "lossy_guest_plan",
    "misbehaving_factory",
]
