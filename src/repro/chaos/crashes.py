"""CrashInjector: arms a plan's hardware-crash schedule on the engine.

Crashes are *unannounced*: they call ``Cluster.crash_vm`` /
``Cluster.crash_server`` directly — no eviction notice, no power event, no
bus record — so the platform only learns about them when the scheduler's
repair loop drains the cluster's crash queue on its next tick.  The
injector can also sample extra crashes at a rate, deterministically from
the plan seed.
"""
from __future__ import annotations

import random
from typing import List, Tuple

from repro.chaos.plan import FaultPlan


class CrashInjector:
    def __init__(self, cluster, engine, plan: FaultPlan):
        self.cluster, self.engine, self.plan = cluster, engine, plan
        self.stats = {"vm_crashes": 0, "server_crashes": 0, "misses": 0}
        self._rng = random.Random(f"{plan.seed}:crashes")

    def arm(self):
        """Schedule every crash in the plan on the engine."""
        for t, vm_id in self.plan.vm_crashes:
            self.engine.at(t, lambda v=vm_id: self.crash_vm(v))
        for t, sid in self.plan.server_crashes:
            self.engine.at(t, lambda s=sid: self.crash_server(s))
        return self

    def arm_random_vm_crashes(self, rate_per_s: float, until: float,
                              period_s: float = 10.0):
        """Poisson-ish background VM crashes: every ``period_s`` each tick
        crashes one uniformly chosen live VM with probability
        ``rate_per_s * period_s`` (clamped).  Victim choice is seeded and
        sorted, so runs are reproducible.  The crash instant is jittered
        *within* the period so it never lands exactly on a scheduler tick
        boundary — otherwise detection latency would measure as a free
        zero instead of the honest crash->next-tick gap."""
        p = min(1.0, rate_per_s * period_s)

        def crash_one():
            live = sorted(v.vm_id for v in self.cluster.vms.values()
                          if v.alive and v.server)
            if live:
                self.crash_vm(self._rng.choice(live))

        def tick():
            if self._rng.random() >= p:
                return
            self.engine.after(self._rng.uniform(0.1, 0.9) * period_s,
                              crash_one)
        self.engine.every(period_s, tick, until)
        return self

    def crash_vm(self, vm_id: str) -> bool:
        ok = self.cluster.crash_vm(vm_id)
        self.stats["vm_crashes" if ok else "misses"] += 1
        return ok

    def crash_server(self, server_id: str) -> List[str]:
        victims = self.cluster.crash_server(server_id)
        self.stats["server_crashes"] += 1
        self.stats["vm_crashes"] += len(victims)
        return victims
