"""Single-process platform-event injector (the documented unit-test shim).

Drives the same platform-hint *topic* the real optimization policies use —
the injector publishes EVICTION_NOTICE / SCALE_UP_OFFER / THROTTLE_NOTICE
through the global manager and the standalone-mode ``WITrainer`` reacts to
them — but nothing here books eviction tickets, honors notice windows, or
frees capacity.  The REAL fault path is the rest of ``repro.chaos``: a
seeded ``FaultPlan`` driving ``ChaosBus`` channel faults, ``CrashInjector``
hardware crashes, and misbehaving-guest agents against the full scheduler
substrate (see ``sim/casestudies/chaos_soak.py`` and docs/RESILIENCE.md).
Keep this class for fast single-process tests
(``tests/test_runtime_elastic.py``) and examples only.
"""
from __future__ import annotations

from repro.core import hints as H
from repro.core.global_manager import GlobalManager


class FaultInjector:
    def __init__(self, gm: GlobalManager, workload: str,
                 resource: str = "rack0/host0/vm0"):
        self.gm, self.workload, self.resource = gm, workload, resource

    def _emit(self, event: H.PlatformEvent, deadline_s=0.0, **payload):
        ok = self.gm.publish_platform_hint(H.PlatformHint(
            event=event.value, workload=self.workload, resource=self.resource,
            deadline_s=deadline_s, payload=payload, source_opt="fault-inject"))
        assert ok, "platform hint rate limited during fault injection"

    def evict(self, n_devices: int, deadline_s: float = 30.0):
        self._emit(H.PlatformEvent.EVICTION_NOTICE, deadline_s,
                   n_devices=n_devices)

    def offer_capacity(self, n_devices: int):
        self._emit(H.PlatformEvent.SCALE_UP_OFFER, n_devices=n_devices)

    def throttle(self, frac: float = 0.5):
        self._emit(H.PlatformEvent.THROTTLE_NOTICE, frac=frac)

    def unthrottle(self):
        self._emit(H.PlatformEvent.OVERCLOCK_OFFER, boost_frac=0.0)

    def maintenance(self, deadline_s: float = 60.0):
        self._emit(H.PlatformEvent.MAINTENANCE, deadline_s)
