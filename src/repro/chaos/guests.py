"""Misbehaving guests: agent fault modes driven from a ``FaultPlan``.

The platform must keep its invariants no matter what runs inside the VM
(§4.3: hints are untrusted input).  ``MisbehavingAgent`` subclasses the
normal ``WorkloadAgent`` with one of four rogue behaviors:

  * ``never_ack`` — goes completely silent: no heartbeats, no acks.  The
    local manager's lease expires, the scheduler marks the guest silent
    (stopping notice redelivery), and the eviction ladder kills at the
    deadline — a notice violation must NOT result.
  * ``slow_ack`` — checkpoints far slower than any notice window, so the
    deadline always wins and the un-checkpointed work is metered lost.
  * ``crash_mid_ckpt`` — the VM hardware-crashes halfway through its
    emergency checkpoint (an unannounced failure racing the ladder).
  * ``hint_spam`` — floods the guest hint channel; the local manager's
    per-VM rate limiter must absorb it without starving other guests.

``install_guest_modes`` wires the plan's ``guest_modes`` map into a
policies dict before the ``AgentRuntime`` is built.
"""
from __future__ import annotations

from typing import Any, Dict

from repro.agents.agent import WorkloadAgent
from repro.chaos import plan as P

SPAM_BURSTS = 20
SPAM_PERIOD_S = 15.0
SPAM_PER_BURST = 25
SLOW_FACTOR = 3.0


class MisbehavingAgent(WorkloadAgent):
    def __init__(self, vm, endpoint, runtime, policy, mode: str):
        super().__init__(vm, endpoint, runtime, policy)
        self.mode = mode
        self._crashed_self = False
        if mode == P.GUEST_NEVER_ACK:
            self.unresponsive = True        # lease loop stops heartbeating
        elif mode == P.GUEST_HINT_SPAM:
            self._spam_left = SPAM_BURSTS
            runtime.engine.after(SPAM_PERIOD_S, self._spam)

    # -- never_ack ----------------------------------------------------------
    def _on_eviction(self, event: Dict[str, Any]):
        if self.mode == P.GUEST_NEVER_ACK:
            if not self.draining:
                self.draining = True        # saw it; will never answer
                self.rt.metrics["eviction_notices_seen"] += 1
                self.rt.metrics["rogue_notices_ignored"] += 1
            return
        super()._on_eviction(event)

    # -- slow_ack / crash_mid_ckpt ------------------------------------------
    def _begin_checkpoint(self, event: Dict[str, Any]) -> float:
        lat = super()._begin_checkpoint(event)
        if self.mode == P.GUEST_SLOW_ACK:
            notice = float(event.get("payload", {}).get(
                "notice_s", event.get("deadline_s", 30.0)))
            return max(lat, notice * SLOW_FACTOR)   # the deadline always wins
        if self.mode == P.GUEST_CRASH_MID_CKPT and lat > 0.0 \
                and not self._crashed_self:
            self._crashed_self = True
            self.rt.engine.after(lat * 0.5, self._crash_self)
        return lat

    def _crash_self(self):
        if not self.dead and self.rt.cluster.crash_vm(self.vm.vm_id):
            self.rt.metrics["rogue_self_crashes"] += 1

    # -- hint_spam ----------------------------------------------------------
    def _spam(self):
        if self.dead or self._spam_left <= 0:
            return
        self._spam_left -= 1
        accepted = 0
        for i in range(SPAM_PER_BURST):
            if self.ep.set_runtime_hints({"x-spam": float(i)}):
                accepted += 1
        self.rt.metrics["spam_hints_sent"] += SPAM_PER_BURST
        self.rt.metrics["spam_hints_accepted"] += accepted
        self.rt.engine.after(SPAM_PERIOD_S, self._spam)


def misbehaving_factory(mode: str):
    """An ``AgentPolicy.agent_factory`` that builds rogue agents."""
    def factory(vm, endpoint, runtime, policy):
        return MisbehavingAgent(vm, endpoint, runtime, policy, mode=mode)
    return factory


def install_guest_modes(plan: P.FaultPlan, policies: Dict[str, Any]):
    """Point each plan-named workload's policy at a rogue agent factory
    (call before constructing the AgentRuntime)."""
    for workload, mode in plan.guest_modes.items():
        pol = policies.get(workload)
        if pol is not None:
            pol.agent_factory = misbehaving_factory(mode)
    return policies
