"""Gradient compression: int8 ring all-reduce over a mesh axis.

The cross-pod (DCN) gradient synchronization is the bandwidth-critical
collective at multi-pod scale.  ``ring_allreduce_q`` implements a ring
reduce-scatter + all-gather with blockwise int8 quantization per hop via
``jax.lax.ppermute`` — 4x fewer bytes on the wire than an f32 all-reduce,
visible directly in the dry-run's collective-bytes term (§Perf lever).

Error feedback: quantization residue of the *local* contribution is returned
so the caller can fold it into the next step's gradients (Karimireddy et al.,
"Error Feedback Fixes SignSGD").
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_int8(x, block=256):
    """Blockwise symmetric int8 quantization.  Returns (q, scales)."""
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, block)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_int8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def ring_allreduce_q(x, axis_name, axis_size, block=256):
    """Quantized ring all-reduce (sum) of ``x`` over ``axis_name``.

    Must run inside shard_map with ``axis_name`` manual.  Wire format per hop
    is (int8 payload, f32 blockwise scales) — scales add 4/block overhead
    (1.6% at block=256).
    """
    if axis_size == 1:
        return x, jnp.zeros_like(x)
    # reduce-scatter phase: each rank accumulates one segment
    n = axis_size
    idx = jax.lax.axis_index(axis_name)
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % (n * block)
    flat = jnp.pad(flat, (0, pad))
    segs = flat.reshape(n, -1)                       # [n, seg]
    perm = [(i, (i + 1) % n) for i in range(n)]

    acc = segs
    err_total = jnp.zeros_like(segs)

    def hop(carry, h):
        acc, err = carry
        # send segment (idx - h - 1) mod n, quantized
        send_ix = (idx - h - 1) % n
        payload = acc[send_ix]
        q, sc = quantize_int8(payload, block)
        deq = dequantize_int8(q, sc, payload.shape)
        err = err.at[send_ix].add(payload - deq)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        sc_r = jax.lax.ppermute(sc, axis_name, perm)
        recv = dequantize_int8(q_r, sc_r, payload.shape)
        recv_ix = (idx - h - 2) % n
        acc = acc.at[recv_ix].add(recv)
        return (acc, err), None

    (acc, err_total), _ = jax.lax.scan(hop, (acc, err_total), jnp.arange(n - 1))

    # all-gather phase: circulate the fully-reduced segment
    def gather_hop(carry, h):
        acc = carry
        send_ix = (idx - h) % n
        payload = acc[send_ix]
        q, sc = quantize_int8(payload, block)
        q_r = jax.lax.ppermute(q, axis_name, perm)
        sc_r = jax.lax.ppermute(sc, axis_name, perm)
        recv = dequantize_int8(q_r, sc_r, payload.shape)
        recv_ix = (idx - h - 1) % n
        acc = acc.at[recv_ix].set(recv)
        return acc, None

    acc, _ = jax.lax.scan(gather_hop, acc, jnp.arange(n - 1))
    out = acc.reshape(-1)[: x.size].reshape(x.shape).astype(x.dtype)
    err = err_total.reshape(-1)[: x.size].reshape(x.shape)
    return out, err
