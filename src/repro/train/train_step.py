"""Train-step builder: microbatched gradient accumulation, clipping, optional
cross-pod int8 gradient compression, optimizer update.

The returned function is pure and jit-able; the launcher supplies shardings.
DP gradient reduction is implicit in the mean loss under jit-auto; the
compressed path peels the pod axis out with shard_map and runs the int8 ring
explicitly (multi-pod DCN lever).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig, RunConfig
from repro.models import model as M
from repro.train import optimizer as opt
from repro.train.compression import ring_allreduce_q


def _microbatches(batch: Dict[str, jax.Array], n: int):
    return jax.tree.map(
        lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)


def _constrain_like_params(cfg, tree):
    """Pin a param-shaped tree (e.g. gradients) to the param shardings.

    Without this the microbatch-scan carry holds *replicated* cotangents —
    measured +40 GiB/device on llama3-405b/train_4k from the f32 [V, D]
    embedding gradient alone.
    """
    from repro.models.sharding import constrain
    axes = M.param_axes(cfg)
    return jax.tree.map(lambda x, ax: constrain(x, ax), tree, axes,
                        is_leaf=lambda v: isinstance(v, tuple))


def grads_fn(cfg: ModelConfig, pcfg: ParallelConfig, params, batch):
    """Mean-loss gradients with optional microbatch accumulation."""
    def loss(p, b):
        l, parts = M.loss_and_aux(cfg, pcfg, p, b)
        return l, parts

    nm = pcfg.microbatch
    if nm <= 1:
        (l, parts), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
        return l, parts, _constrain_like_params(cfg, g)

    mb = _microbatches(batch, nm)
    acc_dt = jnp.dtype(pcfg.grad_accum_dtype)

    def step(carry, b):
        gacc, lacc = carry
        (l, _), g = jax.value_and_grad(loss, has_aux=True)(params, b)
        g = _constrain_like_params(cfg, g)
        gacc = jax.tree.map(lambda a, x: a + x.astype(acc_dt), gacc, g)
        gacc = _constrain_like_params(cfg, gacc)
        return (gacc, lacc + l), None

    g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, acc_dt), params)
    g0 = _constrain_like_params(cfg, g0)
    (gsum, lsum), _ = jax.lax.scan(step, (g0, jnp.zeros((), jnp.float32)), mb)
    g = jax.tree.map(lambda x: (x / nm).astype(jnp.float32), gsum)
    l = lsum / nm
    return l, {"xent": l, "aux": jnp.zeros((), jnp.float32)}, g


def make_train_step(cfg: ModelConfig, pcfg: ParallelConfig, rcfg: RunConfig,
                    mesh=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    When ``pcfg.grad_compression == 'int8'`` and the mesh has a pod axis, the
    per-pod gradients are synchronized with the quantized ring instead of the
    implicit DCN all-reduce.
    """
    use_ring = (pcfg.grad_compression == "int8" and pcfg.pod > 1
                and mesh is not None)

    def compute_grads(params, batch):
        if not use_ring:
            return grads_fn(cfg, pcfg, params, batch)

        from jax.sharding import PartitionSpec as P
        # partial-manual shard_map: only the pod axis is manual, so specs
        # mention only 'pod'; data/model shardings flow through as auto.
        pspec = jax.tree.map(lambda _: P(), M.abstract_params(cfg))

        @functools.partial(
            jax.shard_map, mesh=mesh,
            in_specs=(pspec, P("pod")), out_specs=(P(), P(), pspec),
            check_vma=False, axis_names={"pod"})
        def sharded(p, b):
            # constrain() strips manual axes (pod) from specs in here
            l, parts, g = grads_fn(cfg, pcfg, p, b)
            flat, td = jax.tree_util.tree_flatten(g)
            summed = []
            for leaf in flat:
                s, _err = ring_allreduce_q(leaf, "pod", pcfg.pod)
                summed.append(s / pcfg.pod)
            g = jax.tree_util.tree_unflatten(td, summed)
            l = jax.lax.pmean(l, "pod")
            return l, parts["xent"], g

        l, xent, g = sharded(params, batch)
        return l, {"xent": xent, "aux": jnp.zeros((), jnp.float32)}, g

    def train_step(params, opt_state, batch):
        l, parts, g = compute_grads(params, batch)
        g, gnorm = opt.clip_by_global_norm(g, rcfg.grad_clip)
        lr = opt.lr_schedule(rcfg, opt_state.count)
        params, opt_state = opt.apply_update(rcfg, lr, params, g, opt_state)
        metrics = {"loss": l, "xent": parts["xent"], "aux": parts["aux"],
                   "grad_norm": gnorm, "lr": lr}
        return params, opt_state, metrics

    return train_step
