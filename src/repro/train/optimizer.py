"""Optimizers (no optax offline): AdamW and Adafactor, sharding-transparent.

Optimizer state mirrors the parameter tree, so the same NamedShardings apply
(ZeRO-style: with FSDP the moments are sharded exactly like the weights).
``opt_state_dtype`` trades moment precision for memory (llama3-405b on
v5e-256 uses bf16 moments; see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig


class OptState(NamedTuple):
    count: jax.Array
    m: Any
    v: Any          # adamw: per-leaf; adafactor: {row, col or full}


def adamw_init(params, dtype=jnp.float32, abstract=False):
    def z(l):
        if abstract:
            return jax.ShapeDtypeStruct(l.shape, dtype)
        return jnp.zeros(l.shape, dtype)
    mk = (lambda: jax.ShapeDtypeStruct((), jnp.int32)) if abstract \
        else (lambda: jnp.zeros((), jnp.int32))
    return OptState(count=mk(), m=jax.tree.map(z, params),
                    v=jax.tree.map(z, params))


def opt_state_axes(param_axes_tree):
    """Logical axes for the optimizer state (mirrors params)."""
    return OptState(count=(), m=param_axes_tree, v=param_axes_tree)


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale)
                        .astype(g.dtype), grads), norm


def adamw_update(rcfg: RunConfig, lr, params, grads, state: OptState):
    b1, b2, eps = rcfg.beta1, rcfg.beta2, 1e-8
    cnt = state.count + 1
    bc1 = 1.0 - b1 ** cnt.astype(jnp.float32)
    bc2 = 1.0 - b2 ** cnt.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
        step = (m32 / bc1) / (jnp.sqrt(v32 / bc2) + eps)
        if p.ndim >= 2:   # decoupled weight decay on matrices only
            step = step + rcfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    newp = jax.tree.map(lambda o: o[0], out, is_leaf=lambda x: isinstance(x, tuple))
    newm = jax.tree.map(lambda o: o[1], out, is_leaf=lambda x: isinstance(x, tuple))
    newv = jax.tree.map(lambda o: o[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return newp, OptState(count=cnt, m=newm, v=newv)


# ---------------------------------------------------------------------------
# Adafactor (factored second moments — the memory-tight option)
# ---------------------------------------------------------------------------

def _factored(shape):
    return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1


def adafactor_init(params, abstract=False):
    def z(shape, dtype=jnp.float32):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    def per_leaf(l):
        if _factored(l.shape):
            return {"row": z(l.shape[:-1]), "col": z(l.shape[:-2] + l.shape[-1:])}
        return {"full": z(l.shape)}

    mk = (lambda: jax.ShapeDtypeStruct((), jnp.int32)) if abstract \
        else (lambda: jnp.zeros((), jnp.int32))
    return OptState(count=mk(), m=None,
                    v=jax.tree.map(per_leaf, params))


def adafactor_update(rcfg: RunConfig, lr, params, grads, state: OptState):
    cnt = state.count + 1
    decay = 1.0 - cnt.astype(jnp.float32) ** -0.8
    eps = 1e-30

    def upd(p, g, v):
        g32 = g.astype(jnp.float32)
        g2 = g32 * g32 + eps
        if "row" in v:
            row = v["row"] * decay + g2.mean(-1) * (1 - decay)
            col = v["col"] * decay + g2.mean(-2) * (1 - decay)
            rfac = row / jnp.maximum(row.mean(-1, keepdims=True), eps)
            step = g32 / (jnp.sqrt(rfac)[..., None] * jnp.sqrt(col)[..., None, :]
                          + 1e-9)
            nv = {"row": row, "col": col}
        else:
            full = v["full"] * decay + g2 * (1 - decay)
            step = g32 / (jnp.sqrt(full) + 1e-9)
            nv = {"full": full}
        clip = jnp.maximum(1.0, global_norm([step]) /
                           (1.0 * jnp.sqrt(jnp.asarray(step.size, jnp.float32))))
        step = step / clip
        if p.ndim >= 2:
            step = step + rcfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype), nv

    leaves = jax.tree_util.tree_structure(params)
    flat_p, flat_g = jax.tree.leaves(params), jax.tree.leaves(grads)
    flat_v = jax.tree.leaves(state.v, is_leaf=lambda x: isinstance(x, dict)
                             and ("row" in x or "full" in x))
    news = [upd(p, g, v) for p, g, v in zip(flat_p, flat_g, flat_v)]
    newp = jax.tree_util.tree_unflatten(leaves, [n[0] for n in news])
    newv = jax.tree_util.tree_unflatten(leaves, [n[1] for n in news])
    return newp, OptState(count=cnt, m=None, v=newv)


def lr_schedule(rcfg: RunConfig, step):
    """Linear warmup + cosine decay."""
    warm = jnp.minimum(1.0, (step + 1) / max(rcfg.warmup_steps, 1))
    prog = jnp.clip((step - rcfg.warmup_steps)
                    / max(rcfg.total_steps - rcfg.warmup_steps, 1), 0.0, 1.0)
    return rcfg.learning_rate * warm * 0.5 * (1 + jnp.cos(jnp.pi * prog))


def init_opt_state(rcfg: RunConfig, params, pcfg=None, abstract=False):
    dtype = jnp.dtype(pcfg.opt_state_dtype) if pcfg else jnp.float32
    if rcfg.optimizer == "adafactor":
        return adafactor_init(params, abstract=abstract)
    return adamw_init(params, dtype=dtype, abstract=abstract)


def apply_update(rcfg: RunConfig, lr, params, grads, state):
    if rcfg.optimizer == "adafactor":
        return adafactor_update(rcfg, lr, params, grads, state)
    return adamw_update(rcfg, lr, params, grads, state)
