"""Fleet observability: metrics registry, tick-phase tracing, lifecycle
latency histograms (docs/OBSERVABILITY.md).

Three zero-dependency pillars:

  * :mod:`repro.obs.metrics` — ``MetricsRegistry`` (counters, gauges,
    fixed-bucket histograms, labels, Prometheus text exposition);
  * :mod:`repro.obs.trace` — ``Tracer``, a ring-buffer flight recorder
    with Chrome/Perfetto ``trace_event`` export and per-phase breakdowns;
  * :mod:`repro.obs.lifecycle` — ``LifecycleObserver``, bus-fed
    notice→ack / ack→release / kill-lead-time histograms reconciled
    against the eviction pipeline's books.

The scheduler and eviction pipeline instrument against the *process-wide
defaults* below, both of which start **disabled** (shared no-op
instruments, no allocation), so the hot path costs nothing until a
scenario or ``benchmarks/run.py --profile`` opts in via
``set_default_tracer`` / ``set_default_registry`` — or passes explicit
``tracer=`` / ``metrics=`` arguments.
"""
from __future__ import annotations

from repro.obs.metrics import (DEFAULT_BUCKETS, Counter, Gauge, Histogram,
                               MetricDict, MetricsRegistry, NULL_INSTRUMENT)
from repro.obs.trace import NULL_SPAN, Tracer
from repro.obs.lifecycle import (LIFECYCLE_BUCKETS, LifecycleObserver,
                                 default_classify)

_default_registry = MetricsRegistry(enabled=False)
_default_tracer = Tracer(capacity=1, enabled=False)


def default_registry() -> MetricsRegistry:
    """The process-wide registry (disabled unless a scenario swapped it)."""
    return _default_registry


def set_default_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-wide registry; returns the previous one.  Only
    schedulers constructed *after* the swap pick it up (instruments are
    bound at construction)."""
    global _default_registry
    prev, _default_registry = _default_registry, registry
    return prev


def default_tracer() -> Tracer:
    """The process-wide tracer (disabled unless profiling swapped it)."""
    return _default_tracer


def set_default_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-wide tracer; returns the previous one."""
    global _default_tracer
    prev, _default_tracer = _default_tracer, tracer
    return prev


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricDict", "MetricsRegistry",
    "Tracer", "LifecycleObserver", "default_classify",
    "DEFAULT_BUCKETS", "LIFECYCLE_BUCKETS", "NULL_INSTRUMENT", "NULL_SPAN",
    "default_registry", "set_default_registry",
    "default_tracer", "set_default_tracer",
]
