"""Bus-fed lifecycle latency histograms for the eviction protocol.

Observability pillar 3 (see docs/OBSERVABILITY.md).  A ``LifecycleObserver``
subscribes to the authoritative record streams —

  * ``wi.sched.evictions`` — notice / evicted / early_released / cancelled /
    already_gone records from the ``EvictionPipeline``;
  * ``wi.events.acks`` — guest acks fanned in by local managers;
  * ``wi.sched.decisions`` — batched placement/migration/defrag records —

and derives, per workload class (labels from a pluggable classifier,
default: strip the trailing replica index, so ``web-3`` and ``web-7`` are
both class ``web``):

  * ``wi_lifecycle_notice_to_ack_s``   — notice issued -> guest ack;
  * ``wi_lifecycle_ack_to_release_s``  — guest ack -> early release enacted;
  * ``wi_lifecycle_kill_lead_s``       — achieved lead time of ladder kills;
  * outcome counters (``wi_lifecycle_events_total{event=...}``), a
    late-ack / notice-window-violation counter, and queue-depth gauges
    (notices outstanding, decision-batch backlog).

The observer is *derived* truth reconciled against the pipeline's own
books — ``reconcile(pipeline)`` diffs its counters against
``EvictionPipeline.stats`` / ``violations()`` and must come back clean
(asserted by the scenario runs, tests, and the CI bench-smoke job) — so
the histograms are cross-checked, not a second opinion.

Purely bus-fed: attaching one to a live scheduler costs its subscribers
one dict dispatch per record, nothing on the placement hot path (decision
records are already batched: one record per drain).
"""
from __future__ import annotations

import re
from typing import Any, Callable, Dict, Optional

from repro.core import hints as H

from repro.obs.metrics import MetricsRegistry

# "web-3" -> "web", "bigdata-0.r12" -> "bigdata", "fleet-17.as2" -> "fleet"
_CLASS_RE = re.compile(r"([.-]\d+|\.(r|as)\d+)+$")

# Buckets sized for protocol latencies: sub-second ack turnarounds up
# through the multi-minute notice windows.
LIFECYCLE_BUCKETS = (0.5, 1.0, 2.0, 5.0, 10.0, 20.0, 30.0, 45.0, 60.0,
                     90.0, 120.0, 180.0, 300.0, 600.0)


def default_classify(workload: str) -> str:
    """Workload name -> workload class (replica/clone suffixes stripped)."""
    return _CLASS_RE.sub("", workload) or workload


class LifecycleObserver:
    def __init__(self, bus, registry: Optional[MetricsRegistry] = None,
                 classify: Callable[[str], str] = default_classify):
        self.registry = registry if registry is not None \
            else MetricsRegistry(enabled=True)
        self.classify = classify
        # vm -> [t_notice, notice_s, workload_class, acked]; live notices
        self._notices: Dict[str, list] = {}
        self._acks: Dict[str, float] = {}       # vm -> t_ack (latest)
        # release records can beat their own ack record to this observer
        # (the scheduler's ack subscriber runs first and publishes the
        # early_released record mid-dispatch): vm -> (t_release, note)
        self._pending_release: Dict[str, tuple] = {}
        self.max_notice_s = 0.0                 # widest hinted window seen
        self.min_ack_margin_s = float("inf")    # notice_s - notice_to_ack
        r = self.registry
        self._outstanding = r.gauge(
            "wi_lifecycle_notices_outstanding",
            "eviction notices issued and not yet resolved")
        self._backlog = r.gauge(
            "wi_sched_decision_batch_n",
            "size of the most recent decision batch per kind")
        # crashed vm -> crash_t, awaiting its replacement placement (MTTR)
        self._crashes: Dict[str, float] = {}
        self._unsubs = [
            bus.subscribe(H.TOPIC_EVICTIONS, self._on_eviction),
            bus.subscribe(H.TOPIC_EVENT_ACKS, self._on_ack),
            bus.subscribe(H.TOPIC_SCHED_DECISIONS, self._on_decisions),
            bus.subscribe(H.TOPIC_FAILURES, self._on_failure),
        ]

    def close(self) -> None:
        for unsub in self._unsubs:
            try:
                unsub()
            except ValueError:
                pass
        self._unsubs = []

    # -- instruments ---------------------------------------------------------
    def _hist(self, name: str, help: str, cls: str):
        return self.registry.histogram(name, help,
                                       buckets=LIFECYCLE_BUCKETS,
                                       workload_class=cls)

    def _count(self, event: str, cls: str):
        self.registry.counter(
            "wi_lifecycle_events_total",
            "eviction-protocol records by event and workload class",
            event=event, workload_class=cls).inc()

    # -- bus handlers --------------------------------------------------------
    def _on_eviction(self, rec) -> None:
        d = rec.value
        if not isinstance(d, dict):
            return
        event = d.get("event")
        vm = d.get("vm", "")
        cls = self.classify(d.get("workload", ""))
        if event == "notice":
            t = float(d.get("t", 0.0))
            notice_s = float(d.get("notice_s", 0.0))
            self._notices[vm] = [t, notice_s, cls, False]
            if notice_s > self.max_notice_s:
                self.max_notice_s = notice_s
            self._outstanding.inc()
            self.registry.gauge("wi_lifecycle_notices_outstanding",
                                workload_class=cls).inc()
            self._count("notice", cls)
            # an ack that raced ahead of the authoritative ticket (guest
            # answered the manager's advisory notice) resolves at the same
            # instant the ticket is booked
            t_ack = self._acks.get(vm)
            if t_ack is not None and t_ack >= t - 1e-9:
                self._observe_ack(vm, t_ack)
            return
        if event in ("evicted", "early_released", "cancelled",
                     "already_gone", "crashed"):
            self._count(event, cls)
            note = self._notices.pop(vm, None)
            if note is not None:
                self._outstanding.dec()
                self.registry.gauge("wi_lifecycle_notices_outstanding",
                                    workload_class=note[2]).dec()
            if event == "evicted":
                lead = float(d.get("lead_time_s", -1.0))
                notice_s = float(d.get("notice_s", 0.0))
                self._hist("wi_lifecycle_kill_lead_s",
                           "achieved eviction lead time (ladder kills)",
                           cls).observe(lead)
                if lead < notice_s - 1e-9:
                    self.registry.counter(
                        "wi_lifecycle_violations_total",
                        "kills whose lead time undercut the hinted window",
                        workload_class=cls).inc()
            elif event == "early_released":
                t_ack = self._acks.get(vm)
                if t_ack is not None:
                    self._hist("wi_lifecycle_ack_to_release_s",
                               "guest ack -> early release enacted",
                               cls).observe(
                                   max(0.0, float(d.get("t", 0.0)) - t_ack))
                elif note is not None and not note[3]:
                    # the triggering ack record is still in flight behind
                    # this release record: finish both histograms when it
                    # lands (_on_ack)
                    self._pending_release[vm] = (float(d.get("t", 0.0)),
                                                 note)
            self._acks.pop(vm, None)

    def _on_ack(self, rec) -> None:
        d = rec.value
        if not isinstance(d, dict):
            return
        if d.get("event") != H.PlatformEvent.EVICTION_NOTICE.value:
            return
        vm = d.get("vm", "")
        t_ack = float(d.get("t", 0.0))
        pending = self._pending_release.pop(vm, None)
        if pending is not None:
            t_release, note = pending
            self._observe_ack_note(note, t_ack)
            self._hist("wi_lifecycle_ack_to_release_s",
                       "guest ack -> early release enacted",
                       note[2]).observe(max(0.0, t_release - t_ack))
            return
        self._acks[vm] = t_ack
        if vm in self._notices:
            self._observe_ack(vm, t_ack)

    def _observe_ack(self, vm: str, t_ack: float) -> None:
        self._observe_ack_note(self._notices[vm], t_ack)

    def _observe_ack_note(self, note: list, t_ack: float) -> None:
        t_notice, notice_s, cls, acked = note
        if acked:               # duplicate ack for the same ticket
            return
        note[3] = True
        dt = max(0.0, t_ack - t_notice)
        self._hist("wi_lifecycle_notice_to_ack_s",
                   "eviction notice issued -> guest ack", cls).observe(dt)
        margin = notice_s - dt
        if margin < self.min_ack_margin_s:
            self.min_ack_margin_s = margin
        if margin < -1e-9:
            self.registry.counter(
                "wi_lifecycle_late_acks_total",
                "acks that arrived after the notice window expired",
                workload_class=cls).inc()

    def _on_failure(self, rec) -> None:
        """Unannounced hardware failure published by the repair loop:
        count it, observe how long the crash sat undetected, and open an
        MTTR window that the crashed VM's replacement placement closes."""
        d = rec.value
        if not isinstance(d, dict) or d.get("event") != "crashed":
            return
        cls = self.classify(d.get("workload", ""))
        self._count("crashed_vm", cls)
        crash_t = float(d.get("crash_t", d.get("t", 0.0)))
        self._hist("wi_lifecycle_crash_detect_s",
                   "crash instant -> repair-loop detection", cls).observe(
                       max(0.0, float(d.get("t", 0.0)) - crash_t))
        self._crashes[d.get("vm", "")] = crash_t

    # replacements are named "<original>.r<seq>"; strip ONE replacement
    # suffix so a replacement-of-a-replacement resolves to its immediate
    # parent (whose own crash opened the MTTR window)
    _REPL_RE = re.compile(r"\.r\d+$")

    def _on_decisions(self, rec) -> None:
        d = rec.value
        if not isinstance(d, dict):
            return
        kind = d.get("kind", "")
        n = int(d.get("n", 0))
        self.registry.counter(
            "wi_sched_decisions_total",
            "scheduler decision records by kind", kind=kind).inc(n)
        self._backlog.set(n)
        self.registry.gauge("wi_sched_decision_batch_n", kind=kind).set(n)
        if kind != "place" or not self._crashes:
            return
        t = float(d.get("t", 0.0))
        for dec in d.get("decisions", ()):
            if hasattr(dec, "server"):
                vid, workload, server = dec.vm_id, dec.workload, dec.server
            else:                   # row round-tripped as a plain array
                vid = dec[0] if dec else ""
                workload = dec[1] if len(dec) > 1 else ""
                server = dec[2] if len(dec) > 2 else ""
            if not server or not vid:
                continue
            base = self._REPL_RE.sub("", vid)
            crash_t = self._crashes.pop(base, None)
            if crash_t is not None:
                self._hist("wi_lifecycle_mttr_s",
                           "crash instant -> replacement placed",
                           self.classify(workload)).observe(
                               max(0.0, t - crash_t))

    # -- aggregation ---------------------------------------------------------
    def _counter_total(self, name: str, **match) -> float:
        total = 0.0
        for (kind, n, labels), inst in \
                self.registry._instruments.items():
            if kind != "Counter" or n != name:
                continue
            ld = dict(labels)
            if all(ld.get(k) == v for k, v in match.items()):
                total += inst.value
        return total

    def _hist_summary(self, name: str) -> Dict[str, float]:
        """Pooled summary across every workload-class series of ``name``
        (exact count/sum/min/max; percentiles from the merged buckets)."""
        merged = None
        for (kind, n, _labels), inst in \
                list(self.registry._instruments.items()):
            if kind != "Histogram" or n != name:
                continue
            if merged is None:
                merged = {"count": 0, "sum": 0.0, "min": float("inf"),
                          "max": float("-inf"),
                          "buckets": [0] * len(inst.bucket_counts),
                          "edges": inst.buckets}
            merged["count"] += inst.count
            merged["sum"] += inst.sum
            merged["min"] = min(merged["min"], inst.min)
            merged["max"] = max(merged["max"], inst.max)
            for i, c in enumerate(inst.bucket_counts):
                merged["buckets"][i] += c
        if merged is None or merged["count"] == 0:
            return {"count": 0}

        def pct(q: float) -> float:
            target = q / 100.0 * merged["count"]
            seen, lo = 0, merged["min"]
            for i, c in enumerate(merged["buckets"]):
                if c == 0:
                    continue
                hi = (merged["edges"][i] if i < len(merged["edges"])
                      else merged["max"])
                hi = min(hi, merged["max"])
                if seen + c >= target:
                    frac = (target - seen) / c
                    return max(merged["min"],
                               min(merged["max"], lo + frac * (hi - lo)))
                seen += c
                lo = hi
            return merged["max"]

        return {"count": merged["count"], "sum": merged["sum"],
                "min": merged["min"], "max": merged["max"],
                "p50": pct(50), "p95": pct(95), "p99": pct(99),
                "p100": merged["max"]}

    def summary(self) -> Dict[str, Any]:
        """Plain-dict rollup for scenario reports and BENCH_sched.json."""
        return {
            "notices": self._counter_total("wi_lifecycle_events_total",
                                           event="notice"),
            "killed": self._counter_total("wi_lifecycle_events_total",
                                          event="evicted"),
            "early_released": self._counter_total(
                "wi_lifecycle_events_total", event="early_released"),
            "cancelled": self._counter_total("wi_lifecycle_events_total",
                                             event="cancelled"),
            "already_gone": self._counter_total("wi_lifecycle_events_total",
                                                event="already_gone"),
            "crashed": self._counter_total("wi_lifecycle_events_total",
                                           event="crashed"),
            "crashed_vms": self._counter_total("wi_lifecycle_events_total",
                                               event="crashed_vm"),
            "violations": self._counter_total(
                "wi_lifecycle_violations_total"),
            "late_acks": self._counter_total("wi_lifecycle_late_acks_total"),
            "outstanding": self._outstanding.value,
            "max_notice_s": self.max_notice_s,
            "min_ack_margin_s": (None if self.min_ack_margin_s == float(
                "inf") else self.min_ack_margin_s),
            "notice_to_ack_s": self._hist_summary(
                "wi_lifecycle_notice_to_ack_s"),
            "ack_to_release_s": self._hist_summary(
                "wi_lifecycle_ack_to_release_s"),
            "kill_lead_s": self._hist_summary("wi_lifecycle_kill_lead_s"),
            "crash_detect_s": self._hist_summary(
                "wi_lifecycle_crash_detect_s"),
            "mttr_s": self._hist_summary("wi_lifecycle_mttr_s"),
        }

    def reconcile(self, pipeline) -> Dict[str, Any]:
        """Diff the bus-derived books against the ``EvictionPipeline``'s
        own.  ``ok`` must be True — the histograms above are only trusted
        because this holds."""
        s = self.summary()
        truth = {
            "notices": pipeline.stats.get("notices", 0),
            "killed": pipeline.stats.get("kills", 0),
            "early_released": pipeline.stats.get("early_releases", 0),
            "cancelled": pipeline.stats.get("cancellations", 0),
            "already_gone": pipeline.stats.get("already_gone", 0),
            "crashed": pipeline.stats.get("crashed", 0),
            "violations": len(pipeline.violations()),
        }
        diffs = {k: (s[k], truth[k]) for k in truth if s[k] != truth[k]}
        outstanding_truth = len(pipeline.tickets)
        if s["outstanding"] != outstanding_truth:
            diffs["outstanding"] = (s["outstanding"], outstanding_truth)
        return {"ok": not diffs, "diffs": diffs}
