"""Tick-phase tracing: a ring-buffer flight recorder with Perfetto export.

Observability pillar 2 (see docs/OBSERVABILITY.md).  A ``Tracer`` records
nested spans — ``with tracer.span("sched.placement_drain"): ...`` — into a
fixed-capacity ring buffer of plain tuples, so a 100k-VM eviction storm
can run with the recorder armed and only ever hold the last N spans (the
flight-recorder property: overflow overwrites the oldest spans, and the
``dropped`` counter says how many).

Exports:

  * ``to_chrome_trace()`` — the Chrome/Perfetto ``trace_event`` JSON object
    format (``"X"`` complete events, microsecond ``ts``/``dur``), openable
    directly at https://ui.perfetto.dev or chrome://tracing;
  * ``phase_breakdown()`` — per-span-name wall-clock totals
    (count/total/mean/max), the per-phase profile ``benchmarks/run.py
    --profile`` commits into BENCH_sched.json.

A disabled tracer's ``span()`` returns one shared no-op context manager
(no allocation), and ``begin``/``end`` return immediately — the scheduler
instruments unconditionally against the process-wide default tracer, which
starts disabled, so the hot path pays a handful of attribute checks per
tick and nothing per VM.

Span timestamps are wall-clock (``time.perf_counter``) because the point
is profiling real cost; pass the sim clock via span args when the sim
instant matters (``tracer.span("x", t_sim=engine.clock.t)``).
"""
from __future__ import annotations

import json
import time
from typing import Any, Callable, Dict, List, Optional


class _NullSpan:
    """Shared no-op span for disabled tracers (identity == proof of cost)."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> bool:
        return False

    def set(self, **args) -> "_NullSpan":
        return self


NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tr", "name", "cat", "args", "_t0", "_depth")

    def __init__(self, tracer: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tr = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def set(self, **args) -> "_Span":
        """Attach/merge args after the span opened (e.g. batch sizes that
        are only known mid-phase)."""
        if self.args is None:
            self.args = args
        else:
            self.args.update(args)
        return self

    def __enter__(self) -> "_Span":
        tr = self._tr
        self._depth = len(tr._stack)
        tr._stack.append(self.name)
        self._t0 = tr._clock()
        return self

    def __exit__(self, *exc) -> bool:
        tr = self._tr
        t1 = tr._clock()
        tr._stack.pop()
        tr._record(self.name, self.cat, self._t0, t1 - self._t0,
                   self._depth, self.args)
        return False


class Tracer:
    """Ring-buffer flight recorder; see the module docstring."""

    def __init__(self, capacity: int = 65536, enabled: bool = True,
                 clock: Callable[[], float] = time.perf_counter):
        if capacity <= 0:
            raise ValueError("capacity must be positive")
        self.enabled = enabled
        self.capacity = capacity
        self._clock = clock
        self._ring: List[Optional[tuple]] = [None] * capacity
        self._n = 0                     # spans ever recorded
        self._stack: List[str] = []     # active span names (nesting depth)
        self._begin_stack: List[tuple] = []     # open begin()/end() spans
        self._t0 = clock()              # trace epoch

    # -- recording -----------------------------------------------------------
    def span(self, name: str, cat: str = "sched", **args):
        """Context manager recording one span on exit.  ``args`` land in
        the trace event's ``args`` payload."""
        if not self.enabled:
            return NULL_SPAN
        return _Span(self, name, cat, args or None)

    def begin(self, name: str, cat: str = "sched") -> None:
        """Imperative open (for spans that cannot wrap a ``with`` block)."""
        if not self.enabled:
            return
        self._stack.append(name)
        self._begin_stack.append((name, cat, self._clock(),
                                  len(self._stack) - 1))

    def end(self) -> None:
        if not self.enabled or not self._begin_stack:
            return
        name, cat, t0, depth = self._begin_stack.pop()
        self._stack.pop()
        self._record(name, cat, t0, self._clock() - t0, depth, None)

    def instant(self, name: str, cat: str = "sched", **args) -> None:
        """Zero-duration marker event."""
        if not self.enabled:
            return
        self._record(name, cat, self._clock(), 0.0, len(self._stack),
                     args or None)

    def _record(self, name: str, cat: str, t0: float, dur: float,
                depth: int, args: Optional[Dict[str, Any]]) -> None:
        self._ring[self._n % self.capacity] = (name, cat, t0, dur, depth,
                                               args)
        self._n += 1

    # -- introspection -------------------------------------------------------
    @property
    def recorded(self) -> int:
        """Spans currently held in the ring."""
        return min(self._n, self.capacity)

    @property
    def dropped(self) -> int:
        """Spans overwritten by ring wraparound."""
        return max(0, self._n - self.capacity)

    def events(self) -> List[tuple]:
        """Held spans, oldest first: (name, cat, t0, dur, depth, args)."""
        if self._n <= self.capacity:
            return [e for e in self._ring[: self._n]]
        head = self._n % self.capacity
        return self._ring[head:] + self._ring[:head]

    def clear(self) -> None:
        self._ring = [None] * self.capacity
        self._n = 0
        self._stack.clear()
        self._begin_stack.clear()
        self._t0 = self._clock()

    # -- export --------------------------------------------------------------
    def to_chrome_trace(self, process_name: str = "wi-sched") -> Dict:
        """Chrome/Perfetto ``trace_event`` JSON object format: complete
        (``"X"``) events with microsecond timestamps relative to the trace
        epoch, sorted by start time so wrapped rings still load."""
        events: List[Dict[str, Any]] = [{
            "name": "process_name", "ph": "M", "pid": 1, "tid": 1,
            "args": {"name": process_name}}]
        rows = sorted(self.events(), key=lambda r: r[2])
        for name, cat, t0, dur, depth, args in rows:
            ev: Dict[str, Any] = {
                "name": name, "cat": cat or "sched", "ph": "X",
                "ts": (t0 - self._t0) * 1e6, "dur": dur * 1e6,
                "pid": 1, "tid": 1}
            if args:
                ev["args"] = dict(args)
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"recorded": self.recorded,
                              "dropped": self.dropped}}

    def write(self, path: str, process_name: str = "wi-sched") -> str:
        with open(path, "w") as fh:
            json.dump(self.to_chrome_trace(process_name), fh)
            fh.write("\n")
        return path

    def phase_breakdown(self) -> Dict[str, Dict[str, float]]:
        """Per-span-name wall-clock profile over the held spans.

        Nested spans each report their own wall time, so a parent phase's
        total includes its children's (self time = parent - sum(children)
        is left to the trace viewer, which computes it exactly).
        """
        out: Dict[str, Dict[str, float]] = {}
        for name, _cat, _t0, dur, _depth, _args in self.events():
            row = out.get(name)
            if row is None:
                row = out[name] = {"count": 0, "total_s": 0.0, "max_s": 0.0}
            row["count"] += 1
            row["total_s"] += dur
            if dur > row["max_s"]:
                row["max_s"] = dur
        for row in out.values():
            row["mean_s"] = row["total_s"] / row["count"]
        return out
