"""Metrics registry: counters, gauges, fixed-bucket latency histograms.

Zero-dependency observability pillar 1 (see docs/OBSERVABILITY.md).  A
``MetricsRegistry`` hands out instruments keyed by ``(name, labels)``:

  * ``Counter`` — monotonically increasing totals (``inc``);
  * ``Gauge`` — point-in-time values that move both ways (``set``/``inc``);
  * ``Histogram`` — fixed-bucket latency distributions with exact min/max
    and bucket-interpolated p50/p95/p99 (``observe``/``percentile``).

Labels are plain dicts (``region``, ``workload_class``, ``policy``, ...);
``instrument.labels(region="r0")`` returns the sibling series.  The whole
registry exports two ways: ``snapshot()`` — a plain nested dict — and
``render_prometheus()`` — Prometheus text exposition.

**Disabled registries are provably near-zero-cost**: every instrument
request returns the *same* shared ``NULL_INSTRUMENT`` singleton whose
methods are empty one-liners (no allocation, no dict lookup beyond the
early return), collectors never register, and snapshots are empty.  The
scheduler hot path instruments against the process-wide default registry,
which starts disabled, so ``sched_scale`` placement throughput does not
regress unless a scenario opts in (``set_default_registry`` or explicit
``metrics=`` arguments).

Increments are not atomic across threads (the sim is single-threaded per
engine); instrument *creation* is lock-protected so concurrent scenarios
sharing a registry stay safe.
"""
from __future__ import annotations

import threading
from bisect import bisect_left
from typing import Any, Callable, Dict, Iterable, List, Optional, Tuple

# Default latency buckets (seconds): sub-ms scheduler phases up through the
# multi-minute notice windows the eviction ladder hands out.
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5,
    1.0, 2.5, 5.0, 10.0, 30.0, 60.0, 120.0, 300.0, 600.0)


def _series_key(name: str, labels: Optional[Dict[str, Any]]) -> str:
    if not labels:
        return name
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return f"{name}{{{inner}}}"


class _NullInstrument:
    """Shared no-op stand-in for every instrument of a disabled registry.

    One singleton serves every name/label combination — identity is the
    proof that the disabled path allocates nothing per call site.
    """
    __slots__ = ()

    def inc(self, amount: float = 1.0) -> None:
        pass

    def dec(self, amount: float = 1.0) -> None:
        pass

    def set(self, value: float) -> None:
        pass

    def observe(self, value: float) -> None:
        pass

    def labels(self, **labels) -> "_NullInstrument":
        return self

    @property
    def value(self) -> float:
        return 0.0

    @property
    def count(self) -> int:
        return 0


NULL_INSTRUMENT = _NullInstrument()


class _Instrument:
    __slots__ = ("name", "help", "label_values", "_registry")

    def __init__(self, registry: "MetricsRegistry", name: str, help: str,
                 label_values: Optional[Dict[str, Any]]):
        self._registry = registry
        self.name = name
        self.help = help
        self.label_values = dict(label_values or {})

    def labels(self, **labels):
        """The sibling series with ``labels`` merged in (cached by the
        registry, so repeated lookups return the same object)."""
        merged = dict(self.label_values)
        merged.update(labels)
        return self._registry._get(type(self), self.name, self.help, merged)

    @property
    def key(self) -> str:
        return _series_key(self.name, self.label_values)


class Counter(_Instrument):
    __slots__ = ("value",)

    def __init__(self, registry, name, help, label_values):
        super().__init__(registry, name, help, label_values)
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        if amount < 0:
            raise ValueError("counters only go up; use a Gauge")
        self.value += amount


class Gauge(_Instrument):
    __slots__ = ("value",)

    def __init__(self, registry, name, help, label_values):
        super().__init__(registry, name, help, label_values)
        self.value = 0.0

    def set(self, value: float) -> None:
        self.value = value

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def dec(self, amount: float = 1.0) -> None:
        self.value -= amount


class Histogram(_Instrument):
    """Fixed-bucket histogram with exact count/sum/min/max.

    ``percentile(q)`` interpolates linearly inside the bucket holding the
    q-quantile observation, clamped to the exact observed [min, max] — so
    ``percentile(100) == max`` and ``percentile(0) == min`` exactly.
    """
    __slots__ = ("buckets", "bucket_counts", "count", "sum", "min", "max")

    def __init__(self, registry, name, help, label_values,
                 buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        super().__init__(registry, name, help, label_values)
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * (len(self.buckets) + 1)  # last = +Inf
        self.count = 0
        self.sum = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def observe(self, value: float) -> None:
        self.bucket_counts[bisect_left(self.buckets, value)] += 1
        self.count += 1
        self.sum += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value

    def percentile(self, q: float) -> float:
        """The q-th percentile (0..100) estimated from the buckets."""
        if self.count == 0:
            return float("nan")
        target = q / 100.0 * self.count
        seen = 0
        lo = self.min
        for i, n in enumerate(self.bucket_counts):
            if n == 0:
                continue
            hi = self.buckets[i] if i < len(self.buckets) else self.max
            hi = min(hi, self.max)
            if seen + n >= target:
                frac = (target - seen) / n
                return max(self.min, min(self.max, lo + frac * (hi - lo)))
            seen += n
            lo = hi
        return self.max

    def summary(self) -> Dict[str, float]:
        if self.count == 0:
            return {"count": 0, "sum": 0.0}
        return {"count": self.count, "sum": self.sum,
                "min": self.min, "max": self.max,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Process-local instrument store; see the module docstring."""

    def __init__(self, enabled: bool = True,
                 default_buckets: Tuple[float, ...] = DEFAULT_BUCKETS):
        self.enabled = enabled
        self.default_buckets = tuple(default_buckets)
        self._lock = threading.Lock()
        # (cls, name, frozenset(label items)) -> instrument
        self._instruments: Dict[Tuple, _Instrument] = {}
        self._buckets_by_name: Dict[str, Tuple[float, ...]] = {}
        self._collectors: Dict[str, Callable[[], Dict]] = {}

    # -- instrument handout --------------------------------------------------
    def _get(self, cls, name: str, help: str,
             labels: Optional[Dict[str, Any]]):
        if not self.enabled:
            return NULL_INSTRUMENT
        key = (cls.__name__, name,
               frozenset((labels or {}).items()))
        inst = self._instruments.get(key)
        if inst is None:
            with self._lock:
                inst = self._instruments.get(key)
                if inst is None:
                    if cls is Histogram:
                        buckets = self._buckets_by_name.get(
                            name, self.default_buckets)
                        inst = Histogram(self, name, help, labels, buckets)
                    else:
                        inst = cls(self, name, help, labels)
                    self._instruments[key] = inst
        return inst

    def counter(self, name: str, help: str = "", **labels) -> Counter:
        return self._get(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", **labels) -> Gauge:
        return self._get(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  buckets: Optional[Iterable[float]] = None,
                  **labels) -> Histogram:
        if buckets is not None and self.enabled:
            self._buckets_by_name.setdefault(name, tuple(sorted(buckets)))
        return self._get(Histogram, name, help, labels)

    # -- pull-based collectors ----------------------------------------------
    def add_collector(self, name: str, fn: Callable[[], Dict]) -> None:
        """Register a zero-hot-path-cost stats source: ``fn`` is only
        called at ``snapshot()`` time (e.g. an ``AdmissionController``'s
        stats dict, bus topic depths).  No-op when disabled, so default
        scheduler construction never accumulates collector references."""
        if self.enabled:
            self._collectors[name] = fn

    # -- export --------------------------------------------------------------
    def _by_kind(self):
        out: Dict[str, List[_Instrument]] = {
            "Counter": [], "Gauge": [], "Histogram": []}
        for (kind, _n, _l), inst in sorted(self._instruments.items(),
                                           key=lambda kv: kv[1].key):
            out[kind].append(inst)
        return out

    def snapshot(self) -> Dict[str, Any]:
        """Plain-dict export of every series plus collector pulls."""
        kinds = self._by_kind()
        out: Dict[str, Any] = {
            "counters": {i.key: i.value for i in kinds["Counter"]},
            "gauges": {i.key: i.value for i in kinds["Gauge"]},
            "histograms": {i.key: i.summary() for i in kinds["Histogram"]},
        }
        if self._collectors:
            out["collected"] = {name: dict(fn())
                                for name, fn in sorted(
                                    self._collectors.items())}
        return out

    def render_prometheus(self) -> str:
        """Prometheus text exposition (0.0.4 format)."""
        lines: List[str] = []
        kinds = self._by_kind()
        seen_header = set()

        def header(inst, typ):
            if inst.name in seen_header:
                return
            seen_header.add(inst.name)
            if inst.help:
                lines.append(f"# HELP {inst.name} {inst.help}")
            lines.append(f"# TYPE {inst.name} {typ}")

        for inst in kinds["Counter"]:
            header(inst, "counter")
            lines.append(f"{inst.key} {inst.value}")
        for inst in kinds["Gauge"]:
            header(inst, "gauge")
            lines.append(f"{inst.key} {inst.value}")
        for inst in kinds["Histogram"]:
            header(inst, "histogram")
            cum = 0
            for i, edge in enumerate(inst.buckets):
                cum += inst.bucket_counts[i]
                labels = dict(inst.label_values, le=repr(edge))
                lines.append(
                    f"{_series_key(inst.name + '_bucket', labels)} {cum}")
            labels = dict(inst.label_values, le="+Inf")
            lines.append(
                f"{_series_key(inst.name + '_bucket', labels)} {inst.count}")
            lines.append(
                f"{_series_key(inst.name + '_sum', inst.label_values)} "
                f"{inst.sum}")
            lines.append(
                f"{_series_key(inst.name + '_count', inst.label_values)} "
                f"{inst.count}")
        return "\n".join(lines) + ("\n" if lines else "")


class MetricDict:
    """A ``defaultdict(float)``-shaped counter bag backed by a registry.

    Drop-in migration target for the hand-rolled ``metrics = defaultdict``
    dicts (``AgentRuntime``, case studies): reads, ``+=`` and assignment
    keep exactly their old semantics against an internal float dict (the
    reported numbers cannot change), while every entry is mirrored into a
    registry gauge — one series per key, visible in ``snapshot()`` and the
    Prometheus exposition.  With a disabled registry the mirror is the
    shared null instrument and only the plain dict remains.
    """

    def __init__(self, registry: Optional[MetricsRegistry] = None,
                 prefix: str = "", **labels):
        self._vals: Dict[str, float] = {}
        self._reg = registry if registry is not None \
            else MetricsRegistry(enabled=False)
        self._prefix = prefix
        self._labels = labels
        self._gauges: Dict[str, Any] = {}

    def _gauge(self, key: str):
        g = self._gauges.get(key)
        if g is None:
            g = self._gauges[key] = self._reg.gauge(
                self._prefix + key, **self._labels)
        return g

    def __getitem__(self, key: str) -> float:
        return self._vals.setdefault(key, 0.0)

    def __setitem__(self, key: str, value: float) -> None:
        self._vals[key] = value
        self._gauge(key).set(value)

    def get(self, key: str, default: float = 0.0) -> float:
        return self._vals.get(key, default)

    def __contains__(self, key: str) -> bool:
        return key in self._vals

    def __iter__(self):
        return iter(self._vals)

    def __len__(self) -> int:
        return len(self._vals)

    def __repr__(self) -> str:
        return repr(self._vals)

    def keys(self):
        return self._vals.keys()

    def items(self):
        return self._vals.items()

    def values(self):
        return self._vals.values()
