"""Eviction pipeline: from optimization-manager actions to honored notices.

``SpotManager.reclaim`` and ``MADatacenterManager.power_event`` emit
``Action("evict", ...)`` lists but nothing in the seed repo ever killed a VM
— or guaranteed the workload its promised warning.  The pipeline closes the
loop:

  1. for each evict action, the notice window is the *maximum* of what the
     issuing manager promised (``payload["after_s"]``) and the workload's
     hinted minimum (extension hint ``x-eviction-notice-s``, defaulting to
     the paper's 30 s Spot notice) — a workload can buy itself more warning
     but the platform never gives less than promised;
  2. the notice is published immediately: a platform hint
     (EVICTION_NOTICE, delivered to VM endpoints via local managers) plus an
     authoritative record on ``wi.sched.evictions``;
  3. a deadline ladder runs on the sim ``Engine``: a reminder at half the
     window, the kill exactly at the deadline.  Cancellation (capacity
     recovered) any time before the kill leaves the VM running.

Every completed eviction is logged with its achieved lead time so scenarios
and tests can assert the invariant *lead_time >= notice window* exactly.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

from repro import obs
from repro.core import hints as H
from repro.sim.cluster import Cluster
from repro.sim.engine import Engine

DEFAULT_NOTICE_S = 30.0             # paper §2.2: Spot eviction notice
# Notice redelivery (lossy guest channels): capped exponential backoff
# until the guest acks, goes silent (lease expired), or the deadline
# arrives.  The first redelivery comes at notice/8 clamped to this band,
# then doubles up to the cap — a dropped first notice is retried quickly
# without spamming slow-but-honest guests.
REMIND_BASE_S = 2.0
REMIND_CAP_S = 16.0
# Ack dedup window: (vm, seq) pairs already honored.  Bounds memory under
# duplicate-heavy chaos runs; 4096 outstanding acks is far beyond any wave.
_ACK_SEEN_MAX = 4096


def notice_window_s(eff_hints: Dict[str, Any],
                    default: float = DEFAULT_NOTICE_S) -> float:
    """The workload's hinted minimum eviction notice, in seconds."""
    v = eff_hints.get("x-eviction-notice-s", default)
    try:
        return max(0.0, float(v))
    except (TypeError, ValueError):
        return default


@dataclass
class EvictionTicket:
    vm_id: str
    workload: str
    resource: str               # "server/vm"
    notice_s: float
    issued_t: float
    kill_t: float
    source: str = ""            # which manager asked (spot / ma_datacenters)
    cancelled: bool = False
    killed: bool = False
    killed_t: float = -1.0
    # how the ticket resolved: pending | killed | early_released |
    # cancelled | already_gone | crashed.  ``killed``/``cancelled`` stay in
    # sync for existing callers; ``already_gone``/``crashed`` tickets never
    # count as kills (the pipeline did not perform them).
    outcome: str = "pending"

    @property
    def lead_time_s(self) -> float:
        return (self.killed_t - self.issued_t) if self.killed else -1.0


class EvictionPipeline:
    def __init__(self, gm, cluster: Cluster, engine: Engine,
                 release_cb: Optional[Callable] = None,
                 default_notice_s: float = DEFAULT_NOTICE_S,
                 tracer=None):
        self.gm = gm
        self.cluster = cluster
        self.engine = engine
        self.tracer = tracer if tracer is not None else obs.default_tracer()
        self.release_cb = release_cb        # e.g. Placer.unplace
        self.default_notice_s = default_notice_s
        self.tickets: Dict[str, EvictionTicket] = {}
        self.log: List[EvictionTicket] = []
        self.stats: Dict[str, int] = defaultdict(int)
        # acks that raced ahead of their ticket: an optimization manager's
        # *advisory* eviction notice reaches the guest before the pipeline
        # books the authoritative ticket, and an eager (stateless) agent
        # acks synchronously.  vm_id -> ack time; entries are only honored
        # for a ticket issued at that same instant and purged otherwise.
        self._acked_ahead: Dict[str, float] = {}
        self._in_submit = False         # defer in-wave acks (see on_ack)
        # dedup-by-seq: ack records already honored, so a duplicated or
        # re-delivered bus record can never double-release (insertion
        # order doubles as the eviction queue for bounding)
        self._acks_seen: Dict[tuple, None] = {}
        # guests whose local-manager lease expired: stop redelivering
        # notices to them; the ladder kill at the deadline stands
        self._silent: set = set()

    # -- intake -------------------------------------------------------------
    def submit(self, actions: List, source: str = "sched"
               ) -> List[EvictionTicket]:
        """Schedule every evict action; other action kinds pass through.
        Notice records for the whole wave go out as one bus batch (an
        eviction storm submits hundreds of actions at once)."""
        out = []
        notices: List[tuple] = []
        with self.tracer.span("evict.submit_wave", cat="evict",
                              source=source, actions=len(actions)) as sp:
            self._in_submit = True      # guest acks during the wave defer
            try:
                for a in actions:
                    if getattr(a, "kind", None) != "evict":
                        continue
                    t = self._schedule(a, source, notices)
                    if t is not None:
                        out.append(t)
            finally:
                self._in_submit = False
            if notices:
                self.gm.bus.publish_batch(H.TOPIC_EVICTIONS, notices)
            # only now honor acks that arrived during the wave (racing the
            # managers' advisory notices or this pipeline's own), so
            # release records never precede their notice records on the bus
            for vm_id, t_ack in list(self._acked_ahead.items()):
                ticket = self.tickets.get(vm_id)
                if ticket is not None and t_ack >= ticket.issued_t - 1e-9:
                    del self._acked_ahead[vm_id]
                    self.early_release(vm_id)
            sp.set(tickets=len(out))
        return out

    def _schedule(self, action, source: str,
                  notice_sink: Optional[List] = None
                  ) -> Optional[EvictionTicket]:
        vm = self.cluster.vms.get(action.vm)
        if vm is None or not vm.alive:
            self.stats["skipped_gone"] += 1
            return None
        if action.vm in self.tickets:
            self.stats["skipped_already_pending"] += 1
            return None
        resource = f"{vm.server}/{vm.vm_id}"
        eff = self.gm.effective_hints(vm.workload, resource)
        notice = max(float(action.payload.get("after_s", 0.0)),
                     notice_window_s(eff, self.default_notice_s))
        now = self.engine.clock.t
        ticket = EvictionTicket(vm.vm_id, vm.workload, resource, notice,
                                issued_t=now, kill_t=now + notice,
                                source=source)
        self.tickets[vm.vm_id] = ticket
        self.gm.checker.note_eviction_pending(resource)
        # kill_t / notice_s are guest-visible: a trainer agent uses the
        # absolute deadline to judge whether its emergency checkpoint can
        # finish (and the ack still count) before the ladder kill
        self.gm.publish_platform_hint(H.PlatformHint(
            event=H.PlatformEvent.EVICTION_NOTICE.value, workload=vm.workload,
            resource=resource, deadline_s=notice,
            payload={"cores": vm.cores, "source": source,
                     "notice_s": notice, "kill_t": ticket.kill_t},
            source_opt="evictor"))
        notice_rec = {
            "event": "notice", "vm": vm.vm_id, "workload": vm.workload,
            "resource": resource, "notice_s": notice, "t": now,
            "kill_t": ticket.kill_t, "source": source}
        if notice_sink is not None:
            notice_sink.append((vm.vm_id, notice_rec))
        else:
            self.gm.bus.publish(H.TOPIC_EVICTIONS, notice_rec, key=vm.vm_id)
        # deadline ladder: redeliveries on capped exponential backoff until
        # the guest acks (ticket resolves) or the deadline; the kill is
        # armed exactly at the deadline
        if notice > 0:
            d0 = min(max(notice / 8.0, REMIND_BASE_S), REMIND_CAP_S)
            self.engine.at(now + d0,
                           lambda t=ticket, d=d0: self._remind(t, d))
        self.engine.at(ticket.kill_t, lambda t=ticket: self._kill(t))
        self.stats["notices"] += 1
        return ticket

    # -- ladder -------------------------------------------------------------
    def _remind(self, ticket: EvictionTicket, delay: float = 0.0):
        """Redeliver a pending notice.  The payload repeats everything the
        original carried (notice_s / kill_t) because on a lossy channel the
        redelivery may be the first copy the guest ever sees."""
        if ticket.outcome != "pending":
            return
        if ticket.vm_id in self._silent:
            return      # lease expired: nobody is listening; ladder stands
        remaining = ticket.kill_t - self.engine.clock.t
        self.gm.publish_platform_hint(H.PlatformHint(
            event=H.PlatformEvent.EVICTION_NOTICE.value,
            workload=ticket.workload, resource=ticket.resource,
            deadline_s=remaining,
            payload={"reminder": True, "notice_s": ticket.notice_s,
                     "kill_t": ticket.kill_t, "source": ticket.source},
            source_opt="evictor"))
        self.stats["reminders"] += 1
        next_d = min(max(delay, REMIND_BASE_S) * 2.0, REMIND_CAP_S)
        if self.engine.clock.t + next_d < ticket.kill_t - 1e-9:
            self.engine.after(next_d,
                              lambda t=ticket, d=next_d: self._remind(t, d))

    def note_silent(self, vm_id: str):
        """The guest's lease expired: suppress further redeliveries (a
        later ack — the guest came back — re-enables them implicitly by
        releasing the ticket)."""
        self._silent.add(vm_id)
        self.stats["silent_guests"] += 1

    def _kill(self, ticket: EvictionTicket):
        if ticket.outcome != "pending":
            return
        with self.tracer.span("evict.kill", cat="evict", vm=ticket.vm_id):
            self._kill_live(ticket)

    def _kill_live(self, ticket: EvictionTicket):
        vm = self.cluster.vms.get(ticket.vm_id)
        if (vm is not None and vm.alive
                and f"{vm.server}/{vm.vm_id}" != ticket.resource):
            # the VM moved since the notice (migration / failover): the
            # capacity the eviction was meant to free is already free
            self.cancel(ticket.vm_id)
            return
        if vm is None or not vm.alive:
            # the VM died between notice and deadline (churn, a scenario
            # kill, region failure).  Recording this as a kill would feed a
            # bogus lead time into min_lead_time_s()/violations(); it is a
            # distinct outcome, not an eviction the pipeline performed.
            ticket.outcome = "already_gone"
            ticket.killed_t = self.engine.clock.t
            self.tickets.pop(ticket.vm_id, None)
            self._silent.discard(ticket.vm_id)
            self.gm.checker.note_eviction_done(ticket.resource)
            self.gm.purge_resource_hints(ticket.workload, ticket.resource)
            self.gm.bus.publish(H.TOPIC_EVICTIONS, {
                "event": "already_gone", "vm": ticket.vm_id,
                "workload": ticket.workload, "resource": ticket.resource,
                "t": ticket.killed_t, "source": ticket.source},
                key=ticket.vm_id)
            self.log.append(ticket)
            self.stats["already_gone"] += 1
            return
        if self.release_cb is not None:
            self.release_cb(vm)
        self.cluster.kill_vm(ticket.vm_id)
        ticket.killed = True
        ticket.outcome = "killed"
        ticket.killed_t = self.engine.clock.t
        self.tickets.pop(ticket.vm_id, None)
        self._silent.discard(ticket.vm_id)
        self.gm.checker.note_eviction_done(ticket.resource)
        # the resource is gone: per-VM hint state must not outlive it
        self.gm.purge_resource_hints(ticket.workload, ticket.resource)
        self.gm.bus.publish(H.TOPIC_EVICTIONS, {
            "event": "evicted", "vm": ticket.vm_id,
            "workload": ticket.workload, "resource": ticket.resource,
            "lead_time_s": ticket.lead_time_s, "notice_s": ticket.notice_s,
            "t": ticket.killed_t, "source": ticket.source}, key=ticket.vm_id)
        self.log.append(ticket)
        self.stats["kills"] += 1

    # -- guest acks: release before the deadline ----------------------------
    def on_ack(self, vm_id: str, t: float, seq=None, kill_t=None) -> bool:
        """A guest acknowledged an eviction notice.  Release its ticket if
        one is booked; otherwise remember the ack — the authoritative
        ticket may be created later in the same synchronous wave (managers
        pre-notify before the pipeline books).  Acks arriving mid-wave are
        always deferred to ``submit``'s epilogue so the release record
        never beats the wave's batched notice records onto the bus.

        Lossy-channel discipline: ``seq`` (the notice's event sequence)
        dedups duplicated/re-delivered ack records — each honored at most
        once; ``kill_t`` (the deadline the guest was acking) pins the ack
        to its ticket generation, so a delayed ack from a long-dead notice
        can never release a *later* ticket booked for the same VM id."""
        if seq is not None:
            k = (vm_id, seq)
            if k in self._acks_seen:
                self.stats["acks_deduped"] += 1
                return False
            self._acks_seen[k] = None
            if len(self._acks_seen) > _ACK_SEEN_MAX:
                # evict the oldest entries (dict preserves insertion order)
                for old in list(self._acks_seen)[:_ACK_SEEN_MAX // 4]:
                    del self._acks_seen[old]
        self._silent.discard(vm_id)     # the guest is evidently alive
        ticket = self.tickets.get(vm_id)
        if (ticket is not None and kill_t is not None
                and abs(float(kill_t) - ticket.kill_t) > 1e-6):
            # an ack for a different (older) generation of this VM id
            self.stats["acks_stale_generation"] += 1
            return False
        if not self._in_submit and ticket is not None:
            return self.early_release(vm_id)
        now = self.engine.clock.t
        # acks from earlier instants can never match a future ticket:
        # purge them so the map only ever holds the current wave
        if self._acked_ahead:
            stale = [v for v, ts in self._acked_ahead.items() if ts < now]
            for v in stale:
                del self._acked_ahead[v]
        self._acked_ahead[vm_id] = t if t >= now else now
        return False

    def early_release(self, vm_id: str) -> bool:
        """The workload acknowledged the notice (checkpointed / drained /
        replacement up): take the VM *now* and free its capacity instead of
        idling until the deadline.  The pending ladder kill becomes a no-op.
        Consented releases are not notice-window violations."""
        ticket = self.tickets.get(vm_id)
        if ticket is None or ticket.outcome != "pending":
            return False
        with self.tracer.span("evict.early_release", cat="evict", vm=vm_id):
            return self._early_release(ticket)

    def _early_release(self, ticket: EvictionTicket) -> bool:
        vm_id = ticket.vm_id
        vm = self.cluster.vms.get(vm_id)
        if vm is None or not vm.alive:
            return False                # the deadline kill will classify it
        if f"{vm.server}/{vm.vm_id}" != ticket.resource:
            return self.cancel(vm_id)   # moved away: capacity already free
        if self.release_cb is not None:
            self.release_cb(vm)
        self.cluster.kill_vm(vm_id)
        ticket.killed = True
        ticket.outcome = "early_released"
        ticket.killed_t = self.engine.clock.t
        self.tickets.pop(vm_id, None)
        self._silent.discard(vm_id)
        self.gm.checker.note_eviction_done(ticket.resource)
        self.gm.purge_resource_hints(ticket.workload, ticket.resource)
        self.gm.bus.publish(H.TOPIC_EVICTIONS, {
            "event": "early_released", "vm": vm_id,
            "workload": ticket.workload, "resource": ticket.resource,
            "lead_time_s": ticket.lead_time_s, "notice_s": ticket.notice_s,
            "t": ticket.killed_t, "source": ticket.source}, key=vm_id)
        self.log.append(ticket)
        self.stats["early_releases"] += 1
        return True

    def cancel(self, vm_id: str) -> bool:
        """Capacity recovered before the deadline: the VM keeps running."""
        ticket = self.tickets.pop(vm_id, None)
        if ticket is None or ticket.killed:
            return False
        ticket.cancelled = True
        ticket.outcome = "cancelled"
        self._silent.discard(vm_id)
        self.gm.checker.note_eviction_done(ticket.resource)
        self.gm.bus.publish(H.TOPIC_EVICTIONS, {
            "event": "cancelled", "vm": vm_id, "workload": ticket.workload,
            "resource": ticket.resource, "t": self.engine.clock.t},
            key=vm_id)
        self.stats["cancellations"] += 1
        return True

    # -- unannounced failures (scheduler repair loop) ------------------------
    def on_crashed(self, vm_id: str, t: float) -> bool:
        """The VM hardware-crashed while under notice: close the ticket as
        ``crashed`` (not a kill the pipeline performed — it never feeds
        lead-time/violation stats).  Called by the repair loop with the
        actual crash time, so the recorded instant matches the billing
        close."""
        ticket = self.tickets.pop(vm_id, None)
        if ticket is None or ticket.outcome != "pending":
            return False
        ticket.outcome = "crashed"
        ticket.killed_t = t
        self._silent.discard(vm_id)
        self.gm.checker.note_eviction_done(ticket.resource)
        self.gm.purge_resource_hints(ticket.workload, ticket.resource)
        self.gm.bus.publish(H.TOPIC_EVICTIONS, {
            "event": "crashed", "vm": vm_id, "workload": ticket.workload,
            "resource": ticket.resource, "t": t, "source": ticket.source},
            key=vm_id)
        self.log.append(ticket)
        self.stats["crashed"] += 1
        return True

    # -- invariants ---------------------------------------------------------
    def violations(self) -> List[EvictionTicket]:
        """Completed evictions whose achieved lead time undercut the hinted
        notice window (must be empty — the acceptance invariant).  Early
        releases are excluded: the workload *asked* to go before the
        deadline, so a short lead is consent, not a broken promise."""
        return [t for t in self.log
                if t.outcome == "killed"
                and t.lead_time_s < t.notice_s - 1e-9]

    def min_lead_time_s(self) -> float:
        leads = [t.lead_time_s for t in self.log if t.outcome == "killed"]
        return min(leads) if leads else float("inf")
