"""The hint-aware platform scheduler: the platform half of WI.

Wires the pieces together and runs the main loop:

  * a pending-VM queue (on the cluster) drained first-fit-decreasing
    through the hint-aware ``Placer`` + ``AdmissionController``;
  * bus subscriptions on the deployment- and runtime-hint topics: a hint
    change marks the workload dirty, invalidates the placer's hint cache,
    and the next tick re-evaluates region placement (e.g. a workload that
    just became region-independent migrates to the cheaper region);
  * capacity crunch handling: defragment by migrating region-agnostic VMs
    out of the crunched region, then reclaim spot capacity through the
    ``EvictionPipeline`` (notices honored, kills on the engine's clock);
  * maintenance-aware power events routed through ``MADatacenterPolicy``;
  * a periodic optimization pass (``run_policies``, gated by
    ``policy_period_s``) driving the tick-driven ``OptimizationPolicy``
    hooks — rightsizing, oversubscription pressure, auto-scaling,
    under/overclocking, harvest rebalancing — in Table-4 priority order
    against the incremental cluster (the dict-of-dicts view path is
    retired);
  * region failover: displaced VMs are re-queued and re-placed on
    surviving regions;
  * decision telemetry on ``wi.sched.decisions`` (batched records: one
    publish per scheduler entry point and kind, carrying the Decision
    tuples themselves, rows ordered per ``Decision._fields``) plus
    aggregate stats.
"""
from __future__ import annotations

from collections import defaultdict, deque
from typing import Deque, Dict, List, Optional

from repro import obs
from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.optimizations import ALL_POLICIES, MADatacenterPolicy, \
    SpotPolicy
from repro.core.pricing import PRIORITY, applicable
from repro.sim.cluster import VM, Cluster
from repro.sim.engine import Engine

from repro.sched.admission import AdmissionController
from repro.sched.evictor import EvictionPipeline
from repro.sched.placement import Decision, Placer


class Scheduler:
    def __init__(self, gm: Optional[GlobalManager] = None,
                 cluster: Optional[Cluster] = None,
                 engine: Optional[Engine] = None,
                 default_region: str = "region-0",
                 objective: str = "price",
                 oversub_ratio: float = 1.25,
                 default_notice_s: float = 30.0,
                 max_migrations_per_tick: int = 64,
                 max_defrag_migrations: int = 256,
                 decision_log_cap: int = 10_000,
                 publish_decisions: bool = True,
                 policy_period_s: float = 0.0,
                 apply_rightsizing: bool = False,
                 tracer=None, metrics=None):
        # observability: spans go to the flight recorder, counters to the
        # registry.  Both default to the process-wide instances, which are
        # disabled (shared no-op instruments) unless a scenario or
        # --profile run opted in — the hot path pays per tick-phase, never
        # per VM.
        self.tracer = tracer if tracer is not None else obs.default_tracer()
        self.metrics = metrics if metrics is not None \
            else obs.default_registry()
        self.engine = engine or Engine()
        self.gm = gm or GlobalManager(clock=self.engine.clock,
                                      hint_rate_per_s=1e6, hint_burst=1e6)
        self.cluster = cluster or Cluster()
        if self.cluster.clock is None:      # start the core-hour integral
            self.cluster.attach_clock(self.engine.clock)
        self.admission = AdmissionController(self.cluster, oversub_ratio)
        self.placer = Placer(self.gm, self.cluster, self.admission,
                             default_region, objective)
        self.evictor = EvictionPipeline(self.gm, self.cluster, self.engine,
                                        release_cb=self.placer.unplace,
                                        default_notice_s=default_notice_s,
                                        tracer=self.tracer)
        # the ten Table-2 optimizations, bound to this scheduler's loops
        # (Table-4 priority order — higher-priority optimizations act first
        # on each policy pass)
        self.policies = {
            cls.name: (cls(self.gm, eviction_notice_s=default_notice_s)
                       if cls is SpotPolicy else cls(self.gm)).bind(self)
            for cls in sorted(ALL_POLICIES, key=lambda c: PRIORITY[c.name])}
        self.spot: SpotPolicy = self.policies["spot"]
        self.madc: MADatacenterPolicy = self.policies["ma_datacenters"]
        # which policies run on the periodic pass, in Table-4 priority
        # order (the rest are event-driven: spot/ma_datacenters from
        # crunches and power events, region_agnostic enacted continuously
        # by the placer + defrag loop, non_preprovision at submit)
        self.tick_policies = ("rightsizing", "oversubscription",
                              "auto_scaling", "underclocking",
                              "overclocking", "harvest")
        self.policy_period_s = policy_period_s
        self.apply_rightsizing = apply_rightsizing
        self._next_policy_t = 0.0
        self._pass_vms: Optional[List] = None
        self._seen_workloads: set = set()
        self.max_migrations_per_tick = max_migrations_per_tick
        self.max_defrag_migrations = max_defrag_migrations
        self.publish_decisions = publish_decisions
        self.decisions: Deque[Decision] = deque(maxlen=decision_log_cap)
        self.stats: Dict[str, int] = defaultdict(int)
        self._dirty: set = set()
        # decision telemetry is buffered per scheduler entry point and
        # flushed as one batched record per kind (see
        # _publish_decision_batch) instead of one publish per decision
        self._record_buf: List[tuple] = []
        self.gm.bus.subscribe(H.TOPIC_DEPLOY_HINTS, self._on_hint_change)
        self.gm.bus.subscribe(H.TOPIC_RUNTIME_HINTS, self._on_hint_change)
        # guest acks close the bidirectional loop: a VM that acknowledges
        # its eviction notice is released (and its capacity freed) before
        # the deadline instead of idling until the ladder kill
        self.gm.bus.subscribe(H.TOPIC_EVENT_ACKS, self._on_event_ack)
        # silent-guest declarations from local managers (lease expiries)
        self.gm.bus.subscribe(H.TOPIC_LEASES, self._on_lease)
        # direct-store hint path (set_hints with runtime scope never hits
        # the bus) — without this the placer would keep serving stale hints
        self.gm.hint_listeners.append(self._mark_dirty)
        # pull-based exposition: stats dicts and queue depths are read at
        # snapshot() time only, so the hot path never touches them (on the
        # default disabled registry these calls are no-ops)
        self.metrics.add_collector("sched", self.telemetry)
        self.metrics.add_collector("bus", self._bus_depths)
        self.metrics.add_collector("engine", lambda: {
            "qsize": self.engine.qsize(),
            "dispatched": self.engine.dispatched,
            "t_sim": self.engine.clock.t})

    def _bus_depths(self) -> Dict:
        bus = self.gm.bus
        return {"published": bus.published,
                "topic_depths": {t: sum(bus.end_offsets(t).values())
                                 for t in bus.topics()}}

    def _mark_dirty(self, workload: str):
        self._dirty.add(workload)
        self.placer.invalidate(workload)

    # -- intake -------------------------------------------------------------
    def submit(self, vm: VM):
        if vm.workload not in self._seen_workloads:
            # consult the non-preprovision policy once per workload: a
            # deploy-time-tolerant workload skips the pre-provisioned pool
            self._seen_workloads.add(vm.workload)
            if not self.policies["non_preprovision"].should_preprovision(
                    vm.workload):
                self.stats["non_preprovisioned_workloads"] += 1
        self.cluster.enqueue(vm)
        self.stats["submitted"] += 1

    # -- hint reactions -----------------------------------------------------
    def _on_hint_change(self, rec):
        d = rec.value
        if isinstance(d, dict) and "workload" in d:
            self._mark_dirty(d["workload"])

    def _on_event_ack(self, rec):
        """Guest acknowledged a scheduled event (fanned in by its local
        manager).  An acked eviction notice means the workload is done
        (checkpointed / drained / replacement running): release the VM now
        — or, when the ack raced ahead of the pipeline's ticket, as soon
        as the ticket is booked (``EvictionPipeline.on_ack``)."""
        d = rec.value
        if not isinstance(d, dict):
            return
        if d.get("event") == H.PlatformEvent.EVICTION_NOTICE.value:
            # the authoritative resolution count lives in
            # evictor.stats["early_releases"] (acks that resolve during a
            # wave are deferred to submit's epilogue and would be missed
            # by any counting done here).  seq + kill_t ride along so the
            # pipeline can dedup duplicated ack records and refuse acks
            # aimed at an older ticket generation (lossy channels).
            self.evictor.on_ack(d.get("vm", ""),
                                float(d.get("t", self.engine.clock.t)),
                                seq=d.get("seq"), kill_t=d.get("kill_t"))

    def _on_lease(self, rec):
        """A guest stopped heartbeating: its local manager published a
        lease expiry.  Mark it silent so the evictor stops redelivering
        notices; the ladder kill at the deadline still stands."""
        d = rec.value
        if isinstance(d, dict) and d.get("event") == "lease_expired":
            self.evictor.note_silent(d.get("vm", ""))
            self.stats["silent_guests"] += 1

    def react_to_hints(self) -> List[Decision]:
        """Re-place VMs of workloads whose hints changed: a workload that is
        (now) region-independent and sits in a worse region migrates."""
        if not self._dirty:
            return []
        with self.tracer.span("sched.react_to_hints",
                              dirty=len(self._dirty)):
            return self._react_to_hints()

    def _react_to_hints(self) -> List[Decision]:
        dirty, self._dirty = self._dirty, set()
        moved: List[Decision] = []
        budget = self.max_migrations_per_tick
        for vm in list(self.cluster.vms.values()):
            if budget <= 0:
                # out of budget: keep the marks so later ticks finish the job
                self._dirty |= dirty
                break
            if not vm.alive or not vm.server or vm.workload not in dirty:
                continue
            eff = self.placer.effective(vm.workload)
            if not applicable("region_agnostic", eff):
                continue
            want = self.placer.target_region(vm.workload)
            here = self.cluster.servers[vm.server].region
            if want == here:
                continue
            d = self.placer.migrate(vm, self.engine.clock.t)
            if d.placed and d.region != here:
                self.gm.publish_platform_hint(H.PlatformHint(
                    event=H.PlatformEvent.MIGRATION_NOTICE.value,
                    workload=vm.workload, resource=f"{d.server}/{vm.vm_id}",
                    payload={"from_region": here, "to_region": d.region},
                    source_opt="sched"))
                moved.append(d)
                self._record(d, kind="migrate")
                budget -= 1
        self.stats["hint_migrations"] += len(moved)
        self._flush_records()
        return moved

    # -- the main loop ------------------------------------------------------
    def schedule_pending(self, max_batch: Optional[int] = None
                         ) -> List[Decision]:
        """Drain the pending queue first-fit-decreasing.  Unplaceable VMs
        return to the queue (they retry next tick / after a crunch)."""
        if not self.cluster.pending:
            return []
        with self.tracer.span("sched.placement_drain") as sp:
            out, n_unplaced = self._drain_pending(max_batch)
            sp.set(placed=len(out) - n_unplaced, unplaced=n_unplaced)
        self.metrics.counter(
            "wi_sched_placed_total",
            "VMs placed by the pending-queue drain").inc(
                len(out) - n_unplaced)
        if n_unplaced:
            self.metrics.counter(
                "wi_sched_unplaced_total",
                "drain attempts returned to the pending queue").inc(
                    n_unplaced)
        return out

    def _drain_pending(self, max_batch: Optional[int]):
        if max_batch is None:           # full drain: one pass, no poplefts
            batch = [vm for vm in self.cluster.pending if vm.alive]
            dropped = len(self.cluster.pending) - len(batch)
            if dropped:
                self.stats["dropped_dead"] += dropped
            self.cluster.pending.clear()
        else:
            batch = []
            while self.cluster.pending and len(batch) < max_batch:
                vm = self.cluster.pending.popleft()
                if not vm.alive:    # killed while queued (e.g. eviction)
                    self.stats["dropped_dead"] += 1
                    continue
                batch.append(vm)
        batch.sort(key=lambda v: v.cores, reverse=True)
        now = self.engine.clock.t
        unplaced: List[VM] = []
        out = self.placer.place_batch(batch, now, unplaced_out=unplaced)
        self.cluster.pending.extend(unplaced)   # they retry next tick
        self.decisions.extend(out)
        if self.publish_decisions and out:
            # zero-copy telemetry: the Decision tuples ARE the payload
            self._publish_decision_batch("place", out)
        self.stats["placed"] += len(out) - len(unplaced)
        self.stats["unplaced"] += len(unplaced)
        return out, len(unplaced)

    def tick(self):
        with self.tracer.span("sched.tick", t_sim=self.engine.clock.t):
            self.repair_failures()
            self.react_to_hints()
            if self.policy_period_s > 0 and \
                    self.engine.clock.t >= self._next_policy_t:
                self._next_policy_t = \
                    self.engine.clock.t + self.policy_period_s
                self.run_policies(self.engine.clock.t)
            self.schedule_pending()

    # -- crash repair loop ---------------------------------------------------
    def repair_failures(self) -> int:
        """Close the books on unannounced hardware crashes the cluster
        queued since the last tick: release placement + admission state,
        resolve any in-flight eviction ticket as ``crashed``, purge the
        dead resource's hints and safety history, and publish the failure
        on ``wi.sched.failures`` (detection latency = crash -> this tick).
        Agents react to the failure record by requesting replacements with
        backoff; billing already closed at crash time via the cluster's
        kill listeners."""
        crashed = self.cluster.drain_crashed()
        if not crashed:
            return 0
        now = self.engine.clock.t
        with self.tracer.span("sched.repair_failures", cat="evict",
                              n=len(crashed)):
            for vm, crash_t in crashed:
                # resource identity BEFORE unplace wipes vm.server
                resource = f"{vm.server}/{vm.vm_id}"
                self.placer.unplace(vm)
                if not self.evictor.on_crashed(vm.vm_id, crash_t):
                    # no ticket was in flight: the evictor's terminal path
                    # did not run, so close safety/hint state here
                    self.gm.checker.forget(vm.workload, resource)
                    self.gm.purge_resource_hints(vm.workload, resource)
                self.gm.bus.publish(H.TOPIC_FAILURES, {
                    "event": "crashed", "vm": vm.vm_id,
                    "workload": vm.workload, "resource": resource,
                    "server": resource.rsplit("/", 1)[0],
                    "crash_t": crash_t, "t": now}, key=vm.vm_id)
                self.stats["crashed_vms"] += 1
        self.metrics.counter(
            "wi_sched_crashed_vms_total",
            "unannounced VM crashes repaired").inc(len(crashed))
        return len(crashed)

    # -- the periodic optimization pass -------------------------------------
    def run_policies(self, now: Optional[float] = None):
        """Drive every tick-driven optimization policy once, in Table-4
        priority order.  Gated by ``policy_period_s`` from ``tick`` so the
        steady-state scheduling hot path pays nothing when disabled."""
        now = self.engine.clock.t if now is None else now
        self._pass_vms = None       # fresh snapshot for this pass
        with self.tracer.span("sched.policy_pass", t_sim=now):
            for name in self.tick_policies:
                with self.tracer.span(f"sched.policy.{name}", cat="policy"):
                    self.policies[name].on_tick(now)
        self.stats["policy_passes"] += 1
        self._flush_records()

    def alive_placed_vms(self) -> List:
        """Alive placed VMs in deterministic vm-id order, snapshotted once
        per policy pass (one sort instead of one per policy).  Policies
        re-check liveness per VM: an in-pass guest ack can early-release
        a VM after the snapshot was taken."""
        if self._pass_vms is None:
            vms = self.cluster.vms
            self._pass_vms = [vms[vid] for vid in sorted(vms)
                              if vms[vid].alive and vms[vid].server]
        return self._pass_vms

    def note_policy_actions(self, policy: str, actions) -> None:
        """Telemetry hook for policy hooks: count per-kind stats and record
        state-changing actions (resize / grow / shrink) as decision records
        so downstream consumers (billing meters, agent runtimes) see them
        on ``wi.sched.decisions``."""
        now = self.engine.clock.t
        for a in actions:
            self.stats[f"policy_{policy}_{a.kind}"] += 1
            if a.kind in ("resize", "grow", "shrink"):
                vm = self.cluster.vms.get(a.vm)
                if vm is None or not vm.server:
                    continue
                region = self.cluster.servers[vm.server].region
                self._record(Decision(a.vm, a.workload, vm.server, region,
                                      vm.oversubscribed, a.kind, now),
                             kind="resize")

    def start(self, period_s: float, until: float):
        """Run the scheduling loop on the engine clock."""
        self.engine.every(period_s, self.tick, until)

    def run_until(self, t: float):
        self.engine.run(until=t)

    # -- capacity crunch ----------------------------------------------------
    def defragment(self, region: str, cores_needed: float) -> float:
        """Migrate region-agnostic VMs out of a crunched region (walked via
        the cluster's per-server vm index, O(region VMs) not O(all VMs)).
        Bounded by ``max_defrag_migrations`` per call — live migration
        bandwidth is finite, so a crunch can never stall the platform by
        migrating half a region; the remaining shortfall is covered by
        spot reclaim.  Returns the nominal cores freed."""
        with self.tracer.span("sched.defrag", region=region,
                              cores_needed=cores_needed):
            return self._defragment(region, cores_needed)

    def _defragment(self, region: str, cores_needed: float) -> float:
        freed = 0.0
        moved = 0
        budget = self.max_defrag_migrations
        for sid in list(self.cluster.servers_in_region(region)):
            if freed >= cores_needed or moved >= budget:
                break
            # sorted: vm_ids_on returns a set, and victim choice under the
            # migration budget must not depend on PYTHONHASHSEED (seeded
            # benchmark runs must reproduce exactly)
            for vid in sorted(self.cluster.vm_ids_on(sid)):
                if freed >= cores_needed or moved >= budget:
                    break
                vm = self.cluster.vms.get(vid)
                if vm is None or not vm.alive or not vm.server:
                    continue
                eff = self.placer.effective(vm.workload)
                if not applicable("region_agnostic", eff):
                    continue
                here = vm.server
                d = self.placer.migrate(vm, self.engine.clock.t,
                                        exclude_region=region)
                if d.placed and d.server != here:
                    freed += vm.cores
                    moved += 1
                    self._record(d, kind="defrag")
        self.stats["defrag_migrations"] += moved
        self._flush_records()
        return freed

    def capacity_crunch(self, region: str, cores_needed: float) -> Dict:
        """Free `cores_needed` nominal cores in `region`: first defragment
        (migrate flexible VMs out), then reclaim spot capacity with honored
        eviction notices."""
        with self.tracer.span("sched.capacity_crunch", region=region,
                              cores_needed=cores_needed) as sp:
            freed = self.defragment(region, cores_needed)
            tickets = []
            if freed < cores_needed:
                # spot reclaim straight off the cluster's per-server vm
                # index (O(region VMs)); VMs already mid-eviction are
                # excluded — their cores are spoken for
                acts = self.spot.reclaim_cores(self.cluster,
                                               cores_needed - freed,
                                               region=region,
                                               exclude=self.evictor.tickets)
                tickets = self.evictor.submit(acts, source="spot")
                freed += sum(self.cluster.vms[t.vm_id].cores
                             for t in tickets)
            sp.set(freed_cores=freed, evictions=len(tickets))
        self.stats["capacity_crunches"] += 1
        self.metrics.counter(
            "wi_sched_capacity_crunches_total",
            "capacity-crunch events handled").inc()
        return {"freed_cores": freed, "evictions": len(tickets),
                "tickets": tickets}

    # -- infrastructure events ---------------------------------------------
    def power_event(self, server: str, shed_frac: float) -> Dict:
        """MA-datacenter power event: throttle low-availability VMs, evict
        preemptible ones (through the notice pipeline)."""
        # walked via the cluster's per-server vm index; VMs already
        # mid-eviction are excluded (their cores would double-count toward
        # the shed target and then be dropped)
        with self.tracer.span("sched.power_event", cat="policy",
                              server=server, shed_frac=shed_frac):
            acts = self.madc.power_event_cluster(
                self.cluster, server, shed_frac,
                exclude=self.evictor.tickets)
            tickets = self.evictor.submit(acts, source="ma_datacenters")
        throttles = [a for a in acts if a.kind == "throttle"]
        self.stats["power_events"] += 1
        self.stats["power_throttles"] += len(throttles)
        return {"throttles": len(throttles), "evictions": len(tickets),
                "tickets": tickets}

    def region_failover(self, region: str) -> List[Decision]:
        """Region outage: displaced VMs re-queue (front) and re-place on
        surviving regions; region-fixed workloads stay pending."""
        displaced = self.cluster.fail_region(region)
        for vm in displaced:
            self.placer.unplace(vm)
            self.cluster.requeue(vm)
        self.stats["failover_displaced"] += len(displaced)
        return self.schedule_pending()

    # -- telemetry ----------------------------------------------------------
    def _record(self, d: Decision, kind: str):
        self.decisions.append(d)
        if self.publish_decisions:
            self._record_buf.append((kind, d))

    def _publish_decision_batch(self, kind: str, ds: List[Decision]):
        """One batched record per (entry point, kind): {"kind", "n", "t",
        "fields", "decisions": [Decision tuples]} with rows ordered per
        ``Decision._fields`` — per-decision publishes (and per-decision
        dicts) cost more than the placements they report at 100k-VM
        scale.  Decisions are NamedTuples, so rows JSON-serialize as
        plain arrays on durable buses."""
        with self.tracer.span("sched.bus_publish", cat="bus",
                              kind=kind, n=len(ds)):
            self.gm.bus.publish(H.TOPIC_SCHED_DECISIONS, {
                "kind": kind, "n": len(ds), "t": self.engine.clock.t,
                "fields": Decision._fields, "decisions": ds})

    def _flush_records(self):
        if not self._record_buf:
            return
        buf, self._record_buf = self._record_buf, []
        by_kind: Dict[str, List[Decision]] = {}
        for kind, d in buf:
            by_kind.setdefault(kind, []).append(d)
        for kind, ds in by_kind.items():
            self._publish_decision_batch(kind, ds)

    def telemetry(self) -> Dict:
        self._flush_records()        # decisions buffered mid-entry-point
        alive = [v for v in self.cluster.vms.values() if v.alive and v.server]
        return {
            "sched": dict(self.stats),
            "placer": dict(self.placer.stats),
            "admission": dict(self.admission.stats),
            "evictor": dict(self.evictor.stats),
            "alive_vms": len(alive),
            "pending_vms": len(self.cluster.pending),
            "eviction_violations": len(self.evictor.violations()),
        }
