"""Admission control for the hint-aware platform scheduler.

Keeps incremental per-server commitment accounting (O(1) per decision, so
the `sched_scale` benchmark can admit tens of thousands of VMs) and decides
whether a VM may land on a server:

  * regular VMs reserve their nominal cores against physical capacity;
  * oversubscription-eligible VMs reserve only their p95 demand
    (``cores * util_p95``) against the p95 headroom, but their *nominal*
    cores still count against the server's oversubscription commitment cap
    (``cores * oversub_ratio``) so a single server can never promise more
    than the configured overcommit;
  * down servers admit nothing.

Every decision is counted; rejections carry a reason the scheduler surfaces
in its telemetry stream.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict, Tuple

from repro.sim.cluster import VM, Cluster

EPS = 1e-9


class AdmissionController:
    def __init__(self, cluster: Cluster, oversub_ratio: float = 1.25):
        self.cluster = cluster
        self.oversub_ratio = oversub_ratio
        # per-server reserved capacity, maintained incrementally
        self.reserved: Dict[str, float] = defaultdict(float)   # p95-aware
        self.nominal: Dict[str, float] = defaultdict(float)    # sum of cores
        self.stats: Dict[str, int] = defaultdict(int)
        self.sync()

    # -- accounting ---------------------------------------------------------
    def _demand(self, vm: VM, oversubscribed: bool) -> float:
        if oversubscribed:
            return vm.cores * vm.util_p95
        return vm.cores + vm.harvested

    def sync(self):
        """Rebuild accounting from cluster ground truth (init / after any
        mutation that bypassed the controller)."""
        self.reserved.clear()
        self.nominal.clear()
        for vm in self.cluster.vms.values():
            if vm.alive and vm.server:
                self.reserved[vm.server] += self._demand(vm, vm.oversubscribed)
                self.nominal[vm.server] += vm.cores

    # -- decisions ----------------------------------------------------------
    def check(self, vm: VM, server_id: str,
              oversubscribed: bool = False) -> Tuple[bool, str]:
        """Would `vm` be admitted on `server_id`? No state change."""
        srv = self.cluster.servers.get(server_id)
        if srv is None:
            return False, "no_such_server"
        if not srv.up:
            return False, "server_down"
        if self.nominal[server_id] + vm.cores > \
                srv.cores * self.oversub_ratio + EPS:
            return False, "oversub_commit_cap"
        demand = self._demand(vm, oversubscribed)
        if self.reserved[server_id] + demand > srv.cores + EPS:
            return False, "p95_headroom" if oversubscribed else "capacity"
        return True, "ok"

    def admit(self, vm: VM, server_id: str,
              oversubscribed: bool = False) -> Tuple[bool, str]:
        """Admit and reserve, or reject with a reason."""
        ok, reason = self.check(vm, server_id, oversubscribed)
        if not ok:
            self.stats["rejected_" + reason] += 1
            return ok, reason
        self.reserved[server_id] += self._demand(vm, oversubscribed)
        self.nominal[server_id] += vm.cores
        self.stats["admitted"] += 1
        return True, "ok"

    def shift_demand(self, server_id: str, delta: float):
        """Move a server's reserved demand by ``delta`` for a demand-model
        change outside admit/release (harvest grow/shrink, load shed).
        The controller has no per-VM records, so callers that mutate a
        placed VM's demand route the books change through here."""
        self.reserved[server_id] = max(0.0, self.reserved[server_id] + delta)

    def set_util_p95(self, vm: VM, new_util: float):
        """Change a placed VM's p95 utilization with the reservation books
        following: oversubscribed VMs reserve ``cores * util_p95``, so the
        delta moves with the utilization (load shed, demand-conserving
        rescale/resize).  The cluster's own counters follow through field
        interception."""
        old = vm.util_p95
        vm.util_p95 = new_util
        if vm.alive and vm.server and vm.oversubscribed:
            self.shift_demand(vm.server, vm.cores * (new_util - old))

    def resize(self, vm: VM, new_cores: float) -> Tuple[bool, str]:
        """Resize a VM in place (rightsizing / auto-scaling decisions).
        Shrinks always succeed; growth must clear the same commitment cap
        and headroom checks as admission.  The cores change goes through
        the VM's field interception, so the cluster books follow."""
        if new_cores <= 0:
            return False, "bad_size"
        delta = new_cores - vm.cores
        if not vm.server:
            vm.cores = new_cores
            return True, "unplaced"
        srv = self.cluster.servers.get(vm.server)
        if srv is None:
            return False, "no_such_server"
        demand_delta = delta * (vm.util_p95 if vm.oversubscribed else 1.0)
        if delta > 0:
            if self.nominal[vm.server] + delta > \
                    srv.cores * self.oversub_ratio + EPS:
                self.stats["resize_rejected_oversub_commit_cap"] += 1
                return False, "oversub_commit_cap"
            if self.reserved[vm.server] + demand_delta > srv.cores + EPS:
                self.stats["resize_rejected_capacity"] += 1
                return False, "capacity"
        self.nominal[vm.server] = max(0.0, self.nominal[vm.server] + delta)
        self.reserved[vm.server] = max(0.0,
                                       self.reserved[vm.server] + demand_delta)
        vm.cores = new_cores
        self.stats["resized"] += 1
        return True, "ok"

    def release(self, vm: VM):
        """Return a placed VM's reservation (eviction, migration, kill)."""
        if not vm.server:
            return
        self.reserved[vm.server] = max(
            0.0, self.reserved[vm.server] - self._demand(vm, vm.oversubscribed))
        self.nominal[vm.server] = max(0.0, self.nominal[vm.server] - vm.cores)
        self.stats["released"] += 1

    # -- introspection ------------------------------------------------------
    def commit_frac(self, server_id: str) -> float:
        srv = self.cluster.servers[server_id]
        return self.nominal[server_id] / srv.cores if srv.cores else 0.0

    def headroom(self, server_id: str) -> float:
        return self.cluster.servers[server_id].cores - self.reserved[server_id]
