"""Hint-aware platform scheduler: placement, admission control, and the
eviction pipeline that enact WI hints at cluster scale (the platform half
the paper's §2 optimizations assume exists)."""
from repro.sched.admission import AdmissionController
from repro.sched.evictor import (DEFAULT_NOTICE_S, EvictionPipeline,
                                 EvictionTicket, notice_window_s)
from repro.sched.placement import Decision, Placer, spread_limit
from repro.sched.scheduler import Scheduler

__all__ = [
    "AdmissionController", "DEFAULT_NOTICE_S", "Decision", "EvictionPipeline",
    "EvictionTicket", "Placer", "Scheduler", "notice_window_s",
    "spread_limit",
]
