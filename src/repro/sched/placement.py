"""Hint-aware VM placement (bin-packing) for the platform scheduler.

Effective WI hints (conservative defaults merged with deployment + runtime
hints, via the global manager) drive every decision:

  * ``availability_nines`` → anti-affinity spread: the higher the required
    availability class, the fewer replicas of one workload may share a
    server (five/four nines: hard anti-affinity, one per server);
  * ``region_independent`` → the VM goes to the cheapest (or greenest)
    region, the ``RegionAgnosticManager`` objective;
  * oversubscription-eligible VMs (Table 3 requirements + low p95
    utilization) are packed against p95 headroom instead of nominal cores,
    through the admission controller.

Two packing paths share the same admission books:

  * ``place`` — sticky first-fit with a per-region rotating cursor, the
    exact per-VM path (migrations, fallback);
  * ``place_batch`` — the scheduler's hot path: pending VMs are grouped by
    workload (one hint/profile lookup per group, not per VM) and matched
    against numpy arrays of per-server admission headroom with **one
    vectorized candidate filter per batch group** (sort-free: no global
    server ordering is ever built).  Candidates are consumed through a
    monotone cursor — O(1) amortized per VM — with scalar re-verification
    against the live counters before each commit, and an exhaustive
    ``place`` fallback when the filtered candidates run dry, so batch
    placement never rejects a VM the per-VM path could place.
"""
from __future__ import annotations

from bisect import bisect_left
from collections import defaultdict
from typing import (Any, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.core.optimizations import (OversubscriptionManager,
                                      RegionAgnosticManager)
from repro.core.pricing import applicable
from repro.sim.cluster import VM, Cluster

from repro.sched.admission import AdmissionController

EPS = 1e-9
_DOWN = -1e30       # candidate-filter sentinel for down servers


class Decision(NamedTuple):
    """One placement outcome.  A NamedTuple (not a dataclass): the batch
    placer materializes one per VM, and tuple construction is measurably
    cheaper at 100k-VM scale."""
    vm_id: str
    workload: str
    server: str                 # "" when rejected
    region: str = ""
    oversubscribed: bool = False
    reason: str = ""
    t: float = 0.0

    @property
    def placed(self) -> bool:
        return bool(self.server)


def spread_limit(availability_nines: float) -> int:
    """Max replicas of one workload per server for an availability class."""
    if availability_nines >= 4.0:
        return 1                    # hard anti-affinity
    if availability_nines >= 3.0:
        return 2
    return 1 << 30                  # best-effort: pack freely


class _WorkloadProfile:
    """Per-workload placement facts, computed once per batch group instead
    of once per VM: spread limit, oversubscription applicability, and the
    (regions-version-keyed) preferred region order."""
    __slots__ = ("limit", "oversub_applicable", "orders")

    def __init__(self, limit: int, oversub_applicable: bool):
        self.limit = limit
        self.oversub_applicable = oversub_applicable
        self.orders: Dict[Optional[str], List[str]] = {}


class _RegionState:
    """Live per-region admission headroom for one ``place_batch`` call.

    Built with one vectorized numpy pass over the admission counters, then
    kept as plain Python lists: the drain loop's single-element reads and
    read-modify-writes are 2-3x cheaper on lists than on numpy scalars,
    while the (rare) refilters convert back for the vectorized compare.
    ``cursor`` is the shared rotating fill position — batch groups continue
    packing where the previous group stopped, exactly like the per-VM
    sticky cursor, so both paths produce the same front-to-back layout."""
    __slots__ = ("ids", "cursor", "nom_free", "p95_free", "cand_cache",
                 "_index")

    def __init__(self, cluster: Cluster, admission: AdmissionController,
                 region: str, cursor: int = 0):
        self.cursor = cursor
        self.cand_cache: Dict[Tuple[float, bool], List[int]] = {}
        self._index: Optional[Dict[str, int]] = None
        ids = cluster.servers_in_region(region)
        self.ids = ids
        n = len(ids)
        servers = cluster.servers
        nominal = admission.nominal
        reserved = admission.reserved
        ratio = admission.oversub_ratio
        cores = np.fromiter((servers[s].cores for s in ids),
                            dtype=np.float64, count=n)
        up = np.fromiter((servers[s].up for s in ids), dtype=bool, count=n)
        nom = np.fromiter((nominal.get(s, 0.0) for s in ids),
                          dtype=np.float64, count=n)
        res = np.fromiter((reserved.get(s, 0.0) for s in ids),
                          dtype=np.float64, count=n)
        nom_free = cores * ratio - nom
        nom_free[~up] = _DOWN           # down servers never become candidates
        p95_free = cores - res
        self.nom_free: List[float] = nom_free.tolist()
        self.p95_free: List[float] = p95_free.tolist()

    def candidates(self, min_nominal: float, min_p95: float) -> List[int]:
        """Vectorized (re)filter: indices of servers that could admit a VM
        needing ``min_nominal`` commit room and ``min_p95`` headroom."""
        nom = np.asarray(self.nom_free)
        p95 = np.asarray(self.p95_free)
        return np.flatnonzero((nom >= min_nominal - EPS)
                              & (p95 >= min_p95 - EPS)).tolist()

    def server_index(self, sid: str) -> int:
        """Index of a server id in ``ids`` (lazily built map; the fallback
        path must not pay an O(n) list scan per placed VM)."""
        if self._index is None:
            self._index = {s: i for i, s in enumerate(self.ids)}
        return self._index.get(sid, -1)

    def cached_candidates(self, cores: float, oversub: bool) -> List[int]:
        """Candidate list shared by every subgroup with the same (cores,
        oversub) key: one vectorized filter per key per batch.  Entries go
        stale as capacity shrinks (the walk's exact per-VM checks skip
        them); ``refresh_candidates`` drops the filled servers for all
        later subgroups, which keeps high-utilization batches from
        re-walking thousands of full servers per subgroup."""
        key = (cores, oversub)
        c = self.cand_cache.get(key)
        if c is None:
            # oversub packs against p95 demand < cores, so its p95 floor
            # is ~0; non-oversub needs the full nominal in p95 headroom
            c = self.cand_cache[key] = self.candidates(
                cores, 0.0 if oversub else cores)
        return c

    def refresh_candidates(self, cores: float, oversub: bool) -> List[int]:
        c = self.cand_cache[(cores, oversub)] = self.candidates(
            cores, 0.0 if oversub else cores)
        return c


class Placer:
    def __init__(self, gm, cluster: Cluster, admission: AdmissionController,
                 default_region: str = "region-0", objective: str = "price"):
        self.gm = gm
        self.cluster = cluster
        self.admission = admission
        self.default_region = default_region
        self.objective = objective
        self.region_mgr = RegionAgnosticManager(gm)
        self.oversub_mgr = OversubscriptionManager(gm)
        self._eff: Dict[str, Dict[str, Any]] = {}       # workload -> hints
        self._profiles: Dict[str, _WorkloadProfile] = {}
        self._profiles_regions_version = -1
        self._cursor: Dict[str, int] = {}               # region -> index
        # (server, workload) -> replica count, for anti-affinity spread
        self._colocated: Dict[tuple, int] = defaultdict(int)
        self.stats: Dict[str, int] = defaultdict(int)
        self.sync()

    def sync(self):
        """Rebuild anti-affinity counts from cluster ground truth, so a
        scheduler attached to a pre-populated cluster sees existing
        replicas (mirrors AdmissionController.sync)."""
        self._colocated.clear()
        for vm in self.cluster.vms.values():
            if vm.alive and vm.server:
                self._colocated[(vm.server, vm.workload)] += 1

    # -- hint cache (invalidated by the scheduler on hint-change topics) ----
    def effective(self, workload: str) -> Dict[str, Any]:
        eff = self._eff.get(workload)
        if eff is None:
            eff = self._eff[workload] = self.gm.effective_hints(workload)
        return eff

    def invalidate(self, workload: Optional[str] = None):
        if workload is None:
            self._eff.clear()
            self._profiles.clear()
        else:
            self._eff.pop(workload, None)
            self._profiles.pop(workload, None)

    def _profile(self, workload: str) -> _WorkloadProfile:
        if self._profiles_regions_version != self.cluster.regions_version:
            # region prices / topology changed: cached orders are stale
            self._profiles.clear()
            self._profiles_regions_version = self.cluster.regions_version
        prof = self._profiles.get(workload)
        if prof is None:
            eff = self.effective(workload)
            prof = self._profiles[workload] = _WorkloadProfile(
                spread_limit(eff["availability_nines"]),
                applicable(self.oversub_mgr.name, eff))
        return prof

    def _oversub_eligible(self, prof: _WorkloadProfile, vm: VM) -> bool:
        """Profile-cached equivalent of ``OversubscriptionManager.eligible``
        (one hint resolution per workload, not per VM)."""
        if vm.spot or vm.harvest or not prof.oversub_applicable:
            return False
        if vm.util_p95 >= OversubscriptionManager.UTIL_P95_MAX:
            return False
        self.oversub_mgr.stats["eligible"] += 1
        return True

    # -- region choice ------------------------------------------------------
    def target_region(self, workload: str) -> str:
        eff = self.effective(workload)
        if applicable("region_agnostic", eff):
            regs = self.cluster.regions
            key = ((lambda r: regs[r].price) if self.objective == "price"
                   else (lambda r: regs[r].carbon_g_kwh))
            return min(regs, key=key)
        return self.default_region

    def _region_order(self, workload: str,
                      exclude_region: Optional[str] = None) -> List[str]:
        """Regions to try, preferred first.  Region-fixed workloads may only
        use their default region; agnostic ones fail over anywhere.
        ``exclude_region`` drops one region (defragmentation: move *out*).
        Cached per workload until hints or regions change."""
        prof = self._profile(workload)
        order = prof.orders.get(exclude_region)
        if order is not None:
            return order
        eff = self.effective(workload)
        first = self.target_region(workload)
        if not applicable("region_agnostic", eff):
            order = [] if first == exclude_region else [first]
        else:
            regs = self.cluster.regions
            key = ((lambda r: regs[r].price) if self.objective == "price"
                   else (lambda r: regs[r].carbon_g_kwh))
            order = [first] + sorted((r for r in regs if r != first), key=key)
            order = [r for r in order if r != exclude_region]
        prof.orders[exclude_region] = order
        return order

    # -- per-VM placement ---------------------------------------------------
    def place(self, vm: VM, now: float = 0.0,
              exclude_region: Optional[str] = None,
              oversub: Optional[bool] = None) -> Decision:
        """Place one VM: pick region, scan servers from the rotating cursor,
        admit on the first server satisfying spread + admission control.
        ``oversub`` may carry a precomputed eligibility (the batch fallback
        passes it so the eligibility stat is not counted twice)."""
        if not vm.alive:
            self.stats["unplaced"] += 1
            return Decision(vm.vm_id, vm.workload, "", "", False, "dead", now)
        prof = self._profile(vm.workload)
        limit = prof.limit
        if oversub is None:
            oversub = self._oversub_eligible(prof, vm)
        last_reason = "no_capacity"
        for region in self._region_order(vm.workload, exclude_region):
            servers = self.cluster.servers_in_region(region)
            if not servers:
                continue
            start = self._cursor.get(region, 0) % len(servers)
            for i in range(len(servers)):
                sid = servers[(start + i) % len(servers)]
                # .get: a probe must not materialize dict entries
                if self._colocated.get((sid, vm.workload), 0) >= limit:
                    last_reason = "anti_affinity"
                    continue
                ok, reason = self.admission.admit(vm, sid, oversub)
                if ok:
                    # sticky cursor: keep filling this server next time
                    self._cursor[region] = (start + i) % len(servers)
                    vm.server = sid
                    vm.oversubscribed = oversub
                    self.cluster.add_vm(vm)
                    self._colocated[(sid, vm.workload)] += 1
                    self.stats["placed"] += 1
                    return Decision(vm.vm_id, vm.workload, sid, region,
                                    oversub, "ok", now)
                last_reason = reason
        self.stats["unplaced"] += 1
        return Decision(vm.vm_id, vm.workload, "", "", False, last_reason, now)

    # -- batch placement (the scheduler's hot path) -------------------------
    def place_batch(self, vms: Sequence[VM], now: float = 0.0,
                    exclude_region: Optional[str] = None,
                    unplaced_out: Optional[List[VM]] = None
                    ) -> List[Decision]:
        """Place a batch of VMs, preserving input order in the returned
        decisions.  VMs are grouped by workload so hints/profiles resolve
        once per group, and each (workload, cores, oversub) run is drained
        through one vectorized candidate filter per region.  VMs that do
        not fit are appended to ``unplaced_out`` when given (saves the
        caller a full decisions pass)."""
        if len(vms) < 32:
            # tiny batches (steady-state ticks): building per-region numpy
            # state would cost more than the sticky per-VM scan it replaces
            out: List[Decision] = []
            for vm in vms:
                d = self.place(vm, now, exclude_region)
                if not d.placed and unplaced_out is not None and vm.alive:
                    unplaced_out.append(vm)
                out.append(d)
            return out
        decisions: List[Optional[Decision]] = [None] * len(vms)
        # one grouping pass: (workload, cores, oversub) runs, in first-seen
        # order (input is FFD-sorted, so runs of equal cores stay together)
        groups: Dict[Tuple[str, float, bool], List[int]] = {}
        profs: Dict[str, _WorkloadProfile] = {}
        util_max = OversubscriptionManager.UTIL_P95_MAX
        eligible_n = 0
        for i, vm in enumerate(vms):
            w = vm.workload
            prof = profs.get(w)
            if prof is None:
                prof = profs[w] = self._profile(w)
            if not vm.alive:
                # "dead" decision only — never offered back for requeue
                decisions[i] = self.place(vm, now, exclude_region)
                continue
            # inlined _oversub_eligible (one call per VM is measurable here)
            oversub = (prof.oversub_applicable and not vm.spot
                       and not vm.harvest and vm.util_p95 < util_max)
            eligible_n += oversub
            groups.setdefault((w, vm.cores, oversub), []).append(i)
        if eligible_n:
            self.oversub_mgr.stats["eligible"] += eligible_n
        states: Dict[str, _RegionState] = {}
        for (workload, cores, oversub), sub in groups.items():
            self._place_group(workload, profs[workload].limit, cores,
                              oversub, vms, sub, states, now,
                              exclude_region, decisions, unplaced_out)
        for region, st in states.items():   # keep stickiness across batches
            self._cursor[region] = st.cursor
        return decisions            # type: ignore[return-value]

    def _place_group(self, workload: str, limit: int, cores: float,
                     oversub: bool, vms: Sequence[VM], sub: List[int],
                     states: Dict[str, _RegionState], now: float,
                     exclude_region: Optional[str],
                     decisions: List[Optional[Decision]],
                     unplaced_out: Optional[List[VM]] = None):
        remaining = sub
        for region in self._region_order(workload, exclude_region):
            if not remaining:
                break
            st = states.get(region)
            if st is None:
                st = states[region] = _RegionState(
                    self.cluster, self.admission, region,
                    self._cursor.get(region, 0))
            remaining = self._drain_region(
                st, region, workload, limit, cores, oversub,
                vms, remaining, now, decisions)
        for i in remaining:
            # exhaustive parity fallback: the per-VM path scans every
            # server and records the authoritative rejection reason
            vm = vms[i]
            d = self.place(vm, now, exclude_region, oversub=oversub)
            if d.placed:
                # keep the batch state honest for later VMs
                st = states.get(d.region)
                if st is not None:
                    si = st.server_index(d.server)
                    if si >= 0:
                        st.nom_free[si] -= vm.cores
                        st.p95_free[si] -= (
                            vm.cores * vm.util_p95 if d.oversubscribed
                            else vm.cores + vm.harvested)
            elif unplaced_out is not None:
                unplaced_out.append(vm)
            decisions[i] = d

    def _drain_region(self, st: _RegionState, region: str, workload: str,
                      limit: int, cores: float, oversub: bool,
                      vms: Sequence[VM], sub: List[int], now: float,
                      decisions: List[Optional[Decision]]) -> List[int]:
        """Drain one (cores, oversub) subgroup into one region through a
        circular candidate walk rotated around the region's sticky cursor.
        The walk only moves forward (O(1) amortized per VM); every commit
        re-verifies the live scalar counters first.  Returns the indices
        that did not fit."""
        rc = st.cached_candidates(cores, oversub)   # shared per-key list;
        n = len(rc)                     # never copied: the walk wraps via
        if not n:                       # an index instead of rotating
            return sub
        # start the walk at the cursor; the advance step (which runs before
        # the first visit) increments j, so begin one slot earlier
        j = bisect_left(rc, st.cursor) - 1
        if j < 0:
            j = n - 1
        p, refilters = -1, 0        # visited count; advances before use
        nom_free = st.nom_free
        p95_free = st.p95_free
        ids = st.ids
        colocated = self._colocated
        cget = colocated.get
        adm = self.admission
        reserved = adm.reserved
        nominal = adm.nominal
        adm_stats = adm.stats
        placer_stats = self.stats
        cluster = self.cluster
        vms_reg = cluster.vms
        used_c = cluster._used
        p95_c = cluster._p95
        on_server = cluster._on_server
        dirty_s = cluster._dirty_servers
        dirty_v = cluster._dirty_vms
        cores_eps = cores - EPS
        limited = limit < (1 << 30)
        min_p95 = (cores * min(vms[i].util_p95 for i in sub) if oversub
                   else cores)
        tuple_new = tuple.__new__      # Decision is a NamedTuple; calling
        ok = "ok"                      # tuple.__new__ directly skips the
        leftover: List[int] = []       # generated __new__'s call layer
        # The walk caches the *current server* entirely in locals: free
        # capacity as plain floats plus accumulated admission/cluster
        # deltas.  The sticky fast path therefore costs a handful of local
        # float ops; all dict/array traffic happens when the cursor
        # advances (amortized O(1) per VM).
        si = -1
        sid = None
        cur_nom = cur_p95 = _DOWN
        colo_room = 0
        pend_res = pend_nom = pend_used = pend_p95 = 0.0
        pend_colo = 0
        cur_set = None
        placed_n = 0
        unlimited_room = 1 << 30
        for i in sub:
            vm = vms[i]
            nominal_delta = cores + vm.harvested
            demand = cores * vm.util_p95 if oversub else nominal_delta
            placed = False
            while True:
                if colo_room > 0 and cur_nom >= cores_eps and \
                        cur_p95 >= demand - EPS:
                    # commit (sticky: the walk stays on this server);
                    # bookkeeping == AdmissionController.commit +
                    # Cluster.place_fresh, accumulated into locals and
                    # flushed when the walk advances
                    cur_nom -= cores
                    cur_p95 -= demand
                    colo_room -= 1
                    pend_res += demand
                    pend_nom += cores
                    pend_colo += 1
                    vd = vm.__dict__
                    vid = vm.vm_id
                    if vd.get("_cluster") is not None:
                        # registered (e.g. requeued): the slow, fully
                        # intercepted path keeps the cluster books
                        cluster.place_fresh(vm, sid, oversub, demand)
                    else:
                        if vms_reg.setdefault(vid, vm) is not vm:
                            cluster.remove_vm(vid)      # id reuse: unbook
                            vms_reg[vid] = vm
                        vd["server"] = sid
                        vd["oversubscribed"] = oversub
                        vd["_cluster"] = cluster
                        pend_used += nominal_delta
                        pend_p95 += demand
                        cur_set.add(vid)
                        dirty_v.add(vid)
                    placed_n += 1
                    decisions[i] = tuple_new(Decision, (
                        vid, workload, sid, region, oversub, ok, now))
                    placed = True
                    break
                # advance the walk: flush the cached server state first
                if si >= 0:
                    nom_free[si] = cur_nom
                    p95_free[si] = cur_p95
                    if pend_nom:
                        reserved[sid] += pend_res
                        nominal[sid] += pend_nom
                        used_c[sid] += pend_used
                        cluster._bump_used_total(pend_used)
                        p95_c[sid] += pend_p95
                        # counts kept even for unlimited workloads: a later
                        # hint change may lower the spread limit
                        colocated[(sid, workload)] += pend_colo
                        dirty_s.add(sid)
                        pend_res = pend_nom = pend_used = pend_p95 = 0.0
                        pend_colo = 0
                    si = -1
                    cur_nom = cur_p95 = _DOWN   # no stale commits if the
                    colo_room = 0               # walk breaks before reload
                p += 1
                if p >= n:
                    # walk ran dry: refilter — re-admits servers skipped
                    # on exact (per-VM) checks, and compacts the shared
                    # cache so later subgroups skip the filled servers
                    if refilters >= 2:
                        break
                    refilters += 1
                    if oversub:
                        rc = st.candidates(cores, min_p95)
                    else:
                        rc = st.refresh_candidates(cores, oversub)
                    n = len(rc)
                    if not n:
                        refilters = 2
                        break
                    j = bisect_left(rc, st.cursor)
                    if j >= n:
                        j = 0
                    p = 0
                else:
                    j += 1
                    if j >= n:
                        j = 0
                si = rc[j]
                sid = ids[si]
                cur_set = on_server[sid]
                st.cursor = si
                cur_nom = nom_free[si]
                cur_p95 = p95_free[si]
                colo_room = (limit - cget((sid, workload), 0) if limited
                             else unlimited_room)
            if not placed:
                leftover.append(i)
        if si >= 0:                     # final flush of the cached server
            nom_free[si] = cur_nom
            p95_free[si] = cur_p95
            if pend_nom:
                reserved[sid] += pend_res
                nominal[sid] += pend_nom
                used_c[sid] += pend_used
                cluster._bump_used_total(pend_used)
                p95_c[sid] += pend_p95
                colocated[(sid, workload)] += pend_colo
                dirty_s.add(sid)
        if placed_n:
            adm_stats["admitted"] += placed_n
            placer_stats["placed"] += placed_n
        return leftover

    def unplace(self, vm: VM):
        """Release a placed VM (kill, eviction, or pre-migration)."""
        if not vm.server:
            return
        self.admission.release(vm)
        n = self._colocated.get((vm.server, vm.workload), 0)
        if n > 0:
            self._colocated[(vm.server, vm.workload)] = n - 1
        vm.server = ""

    def migrate(self, vm: VM, now: float = 0.0,
                exclude_region: Optional[str] = None) -> Decision:
        """Re-place an already-placed VM (defragmentation / better region).
        On failure the VM is restored to its original server."""
        old_server = vm.server
        old_oversub = vm.oversubscribed
        self.unplace(vm)
        d = self.place(vm, now, exclude_region)
        if not d.placed:
            # put it back — migration must never lose a running VM; restore
            # only if the old slot still admits (it normally must, we just
            # released it), otherwise the VM goes back to the pending queue
            ok, _ = self.admission.admit(vm, old_server, old_oversub)
            if ok:
                vm.oversubscribed = old_oversub
                vm.server = old_server
                self._colocated[(old_server, vm.workload)] += 1
                self.stats["migration_failed"] += 1
            else:               # old server gone (e.g. died mid-migration)
                self.cluster.requeue(vm)
                self.stats["migration_displaced"] += 1
        elif d.server != old_server:
            self.stats["migrations"] += 1
        return d
