"""Hint-aware VM placement (bin-packing) for the platform scheduler.

Effective WI hints (conservative defaults merged with deployment + runtime
hints, via the global manager) drive every decision:

  * ``availability_nines`` → anti-affinity spread: the higher the required
    availability class, the fewer replicas of one workload may share a
    server (five/four nines: hard anti-affinity, one per server);
  * ``region_independent`` → the VM goes to the cheapest (or greenest)
    region, the ``RegionAgnosticManager`` objective;
  * oversubscription-eligible VMs (Table 3 requirements + low p95
    utilization) are packed against p95 headroom instead of nominal cores,
    through the admission controller.

Packing is sticky first-fit with a per-region rotating cursor: the placer
keeps filling the current server until it rejects, then moves on — O(1)
amortized per VM, which is what lets the ``sched_scale`` benchmark place
10k+ VMs on 2k+ servers in seconds.  Callers wanting first-fit-*decreasing*
quality sort the batch by cores descending first (the scheduler does).
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass
from typing import Any, Dict, List, Optional

from repro.core.optimizations import (OversubscriptionManager,
                                      RegionAgnosticManager)
from repro.core.pricing import applicable
from repro.sim.cluster import VM, Cluster

from repro.sched.admission import AdmissionController


@dataclass
class Decision:
    vm_id: str
    workload: str
    server: str                 # "" when rejected
    region: str = ""
    oversubscribed: bool = False
    reason: str = ""
    t: float = 0.0

    @property
    def placed(self) -> bool:
        return bool(self.server)


def spread_limit(availability_nines: float) -> int:
    """Max replicas of one workload per server for an availability class."""
    if availability_nines >= 4.0:
        return 1                    # hard anti-affinity
    if availability_nines >= 3.0:
        return 2
    return 1 << 30                  # best-effort: pack freely


class Placer:
    def __init__(self, gm, cluster: Cluster, admission: AdmissionController,
                 default_region: str = "region-0", objective: str = "price"):
        self.gm = gm
        self.cluster = cluster
        self.admission = admission
        self.default_region = default_region
        self.objective = objective
        self.region_mgr = RegionAgnosticManager(gm)
        self.oversub_mgr = OversubscriptionManager(gm)
        self._eff: Dict[str, Dict[str, Any]] = {}       # workload -> hints
        self._cursor: Dict[str, int] = {}               # region -> index
        # (server, workload) -> replica count, for anti-affinity spread
        self._colocated: Dict[tuple, int] = defaultdict(int)
        self.stats: Dict[str, int] = defaultdict(int)
        self.sync()

    def sync(self):
        """Rebuild anti-affinity counts from cluster ground truth, so a
        scheduler attached to a pre-populated cluster sees existing
        replicas (mirrors AdmissionController.sync)."""
        self._colocated.clear()
        for vm in self.cluster.vms.values():
            if vm.alive and vm.server:
                self._colocated[(vm.server, vm.workload)] += 1

    # -- hint cache (invalidated by the scheduler on hint-change topics) ----
    def effective(self, workload: str) -> Dict[str, Any]:
        eff = self._eff.get(workload)
        if eff is None:
            eff = self._eff[workload] = self.gm.effective_hints(workload)
        return eff

    def invalidate(self, workload: Optional[str] = None):
        if workload is None:
            self._eff.clear()
        else:
            self._eff.pop(workload, None)

    # -- region choice ------------------------------------------------------
    def target_region(self, workload: str) -> str:
        eff = self.effective(workload)
        if applicable("region_agnostic", eff):
            regs = self.cluster.regions
            key = ((lambda r: regs[r].price) if self.objective == "price"
                   else (lambda r: regs[r].carbon_g_kwh))
            return min(regs, key=key)
        return self.default_region

    def _region_order(self, workload: str,
                      exclude_region: Optional[str] = None) -> List[str]:
        """Regions to try, preferred first.  Region-fixed workloads may only
        use their default region; agnostic ones fail over anywhere.
        ``exclude_region`` drops one region (defragmentation: move *out*)."""
        eff = self.effective(workload)
        first = self.target_region(workload)
        if not applicable("region_agnostic", eff):
            return [] if first == exclude_region else [first]
        regs = self.cluster.regions
        key = ((lambda r: regs[r].price) if self.objective == "price"
               else (lambda r: regs[r].carbon_g_kwh))
        order = [first] + sorted((r for r in regs if r != first), key=key)
        return [r for r in order if r != exclude_region]

    # -- placement ----------------------------------------------------------
    def place(self, vm: VM, now: float = 0.0,
              exclude_region: Optional[str] = None) -> Decision:
        """Place one VM: pick region, scan servers from the rotating cursor,
        admit on the first server satisfying spread + admission control."""
        if not vm.alive:
            self.stats["unplaced"] += 1
            return Decision(vm.vm_id, vm.workload, "", "", False, "dead", now)
        eff = self.effective(vm.workload)
        limit = spread_limit(eff["availability_nines"])
        oversub = (not vm.spot and not vm.harvest
                   and self.oversub_mgr.eligible(vm.workload, vm.util_p95))
        last_reason = "no_capacity"
        for region in self._region_order(vm.workload, exclude_region):
            servers = self.cluster.servers_in_region(region)
            if not servers:
                continue
            start = self._cursor.get(region, 0) % len(servers)
            for i in range(len(servers)):
                sid = servers[(start + i) % len(servers)]
                # .get: a probe must not materialize dict entries
                if self._colocated.get((sid, vm.workload), 0) >= limit:
                    last_reason = "anti_affinity"
                    continue
                ok, reason = self.admission.admit(vm, sid, oversub)
                if ok:
                    # sticky cursor: keep filling this server next time
                    self._cursor[region] = (start + i) % len(servers)
                    vm.server = sid
                    vm.oversubscribed = oversub
                    self.cluster.add_vm(vm)
                    self._colocated[(sid, vm.workload)] += 1
                    self.stats["placed"] += 1
                    return Decision(vm.vm_id, vm.workload, sid, region,
                                    oversub, "ok", now)
                last_reason = reason
        self.stats["unplaced"] += 1
        return Decision(vm.vm_id, vm.workload, "", "", False, last_reason, now)

    def unplace(self, vm: VM):
        """Release a placed VM (kill, eviction, or pre-migration)."""
        if not vm.server:
            return
        self.admission.release(vm)
        n = self._colocated.get((vm.server, vm.workload), 0)
        if n > 0:
            self._colocated[(vm.server, vm.workload)] = n - 1
        vm.server = ""

    def migrate(self, vm: VM, now: float = 0.0,
                exclude_region: Optional[str] = None) -> Decision:
        """Re-place an already-placed VM (defragmentation / better region).
        On failure the VM is restored to its original server."""
        old_server = vm.server
        old_oversub = vm.oversubscribed
        self.unplace(vm)
        d = self.place(vm, now, exclude_region)
        if not d.placed:
            # put it back — migration must never lose a running VM; restore
            # only if the old slot still admits (it normally must, we just
            # released it), otherwise the VM goes back to the pending queue
            ok, _ = self.admission.admit(vm, old_server, old_oversub)
            if ok:
                vm.server = old_server
                vm.oversubscribed = old_oversub
                self._colocated[(old_server, vm.workload)] += 1
                self.stats["migration_failed"] += 1
            else:               # old server gone (e.g. died mid-migration)
                self.cluster.requeue(vm)
                self.stats["migration_displaced"] += 1
        elif d.server != old_server:
            self.stats["migrations"] += 1
        return d
