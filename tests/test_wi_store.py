"""Property tests for the CloudDB stand-in (core/store.py).

The durability contract §4.2 needs: state after a crash + restart equals a
*prefix* of the committed write sequence — a crash at any WAL byte offset
must never recover out-of-order or corrupted state, only (possibly) fewer
trailing writes.
"""
import tempfile
from pathlib import Path

import pytest

from repro.core.store import Store

hypothesis = pytest.importorskip(
    "hypothesis")   # property tests need it; skip cleanly if absent
from hypothesis import given, settings, strategies as st   # noqa: E402

KEYS = "abcd"

_ops = st.lists(
    st.tuples(st.sampled_from(("put", "del")),
              st.sampled_from(KEYS),
              st.integers(min_value=0, max_value=999)),
    min_size=1, max_size=40)


def _apply(ops):
    """Reference semantics: the expected kv dict after each op prefix."""
    mem = {}
    states = [dict(mem)]
    for op, k, v in ops:
        if op == "put":
            mem[k] = v
        else:
            mem.pop(k, None)
        states.append(dict(mem))
    return states


@settings(max_examples=30, deadline=None)
@given(ops=_ops, data=st.data())
def test_wal_crash_at_any_byte_prefix_recovers_a_prefix(ops, data):
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        with Store(root=d1, snapshot_every=10_000) as store:
            for op, k, v in ops:
                if op == "put":
                    store.put(k, v)
                else:
                    store.delete(k)
        wal = (Path(d1) / "wal.log").read_bytes()
        cut = data.draw(st.integers(min_value=0, max_value=len(wal)),
                        label="crash_byte")
        (Path(d2) / "wal.log").write_bytes(wal[:cut])
        with Store(root=d2, snapshot_every=10_000) as recovered:
            got = {k: recovered.get(k) for k in KEYS
                   if recovered.get(k) is not None}
        # crash-consistency: the recovered state must equal the state after
        # SOME prefix of the committed writes (never a reordering, never a
        # torn value)
        assert got in _apply(ops), (got, ops, cut)


@settings(max_examples=30, deadline=None)
@given(ops=_ops, snap_every=st.integers(min_value=1, max_value=6),
       data=st.data())
def test_crash_at_any_wal_prefix_with_snapshots_recovers_a_prefix(
        ops, snap_every, data):
    """Same prefix contract, but with the snapshot path engaged: a small
    ``snapshot_every`` forces snapshot.json rewrites + WAL truncations
    mid-sequence, and the crash leaves torn ``snapshot.json.tmp`` debris
    behind.  Recovery = snapshot + replayed WAL prefix must still be a
    prefix of the committed writes — never a reordering, never a hole."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        with Store(root=d1, snapshot_every=snap_every) as store:
            for op, k, v in ops:
                if op == "put":
                    store.put(k, v)
                else:
                    store.delete(k)
        snap = Path(d1) / "snapshot.json"
        if snap.exists():
            (Path(d2) / "snapshot.json").write_bytes(snap.read_bytes())
        wal = (Path(d1) / "wal.log").read_bytes()
        cut = data.draw(st.integers(min_value=0, max_value=len(wal)),
                        label="crash_byte")
        (Path(d2) / "wal.log").write_bytes(wal[:cut])
        # a crash mid-_snapshot leaves the staged tmp file behind; it must
        # be ignored by recovery (only the atomic rename publishes it)
        (Path(d2) / "snapshot.json.tmp").write_bytes(b'{"seq": 9999, "kv"')
        with Store(root=d2, snapshot_every=10_000) as recovered:
            got = {k: recovered.get(k) for k in KEYS
                   if recovered.get(k) is not None}
        assert got in _apply(ops), (got, ops, cut, snap_every)


@settings(max_examples=30, deadline=None)
@given(ops=_ops, snap_every=st.integers(min_value=1, max_value=6))
def test_crash_between_snapshot_publish_and_wal_truncate_loses_nothing(
        ops, snap_every):
    """The other half of the snapshot durability ordering: if the crash
    lands AFTER the snapshot rename but BEFORE the WAL truncate, recovery
    sees the new snapshot plus a stale WAL holding records the snapshot
    already contains.  Seq-gated replay must skip them and land exactly on
    the final committed state."""
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2, \
            tempfile.TemporaryDirectory() as d3:
        # d1: snapshotting store -> provides the published snapshot.json
        with Store(root=d1, snapshot_every=snap_every) as store:
            for op, k, v in ops:
                if op == "put":
                    store.put(k, v)
                else:
                    store.delete(k)
        # d3: same ops, snapshots disabled -> provides the full stale WAL
        with Store(root=d3, snapshot_every=10_000) as shadow:
            for op, k, v in ops:
                if op == "put":
                    shadow.put(k, v)
                else:
                    shadow.delete(k)
        snap = Path(d1) / "snapshot.json"
        if snap.exists():
            (Path(d2) / "snapshot.json").write_bytes(snap.read_bytes())
        (Path(d2) / "wal.log").write_bytes(
            (Path(d3) / "wal.log").read_bytes())
        with Store(root=d2, snapshot_every=10_000) as recovered:
            got = {k: recovered.get(k) for k in KEYS
                   if recovered.get(k) is not None}
        assert got == _apply(ops)[-1], (got, ops, snap_every)


def test_store_close_releases_wal_handle():
    with tempfile.TemporaryDirectory() as d:
        s = Store(root=d)
        s.put("k", 1)
        wal = s._wal
        assert wal is not None and not wal.closed
        s.close()
        assert s._wal is None and wal.closed
        s.close()                           # idempotent
        # context-manager form
        with Store(root=d) as s2:
            s2.put("k", 2)
            wal2 = s2._wal
        assert s2._wal is None and wal2.closed
        assert Store(root=d).get("k") == 2   # durable across reopen


def test_global_manager_close_closes_store_and_bus():
    from repro.core.global_manager import GlobalManager
    with tempfile.TemporaryDirectory() as d:
        gm = GlobalManager(store=Store(root=d))
        gm.register_workload("w", {"preemptibility_pct": 50.0})
        gm.close()
        assert gm.store._wal is None
        gm.close()                          # idempotent
