"""Tests for the cluster simulator + paper-evaluation reproductions.

Tolerance bands are generous where the paper's inputs are non-public
(production traces); exact where the math is deterministic (pricing,
provider-scale model).
"""
import pytest

from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.optimizations import (HarvestManager, MADatacenterManager,
                                      SpotManager)
from repro.sim.cluster import VM, Cluster
from repro.sim.engine import Engine
from repro.sim.provider_scale import (FIGURE5_CONTRIB, PAPER_CARBON_SAVING,
                                      PAPER_TOTAL_SAVING, TABLE3_CORE_FRAC,
                                      evaluate, fit_rho, waterfall)
from repro.sim.workload import (TABLE1_TARGETS, core_weighted_marginals,
                                sample_population)


def test_engine_orders_events():
    e = Engine()
    seen = []
    e.at(2.0, lambda: seen.append("b"))
    e.at(1.0, lambda: seen.append("a"))
    e.after(0.5, lambda: seen.append("first"))
    e.run(until=10.0)
    assert seen == ["first", "a", "b"]


def test_table1_marginals_reproduced():
    pop = sample_population(20_000, seed=3)
    marg = core_weighted_marginals(pop)
    for attr, target in TABLE1_TARGETS.items():
        tot = sum(target.values())
        for k, frac in target.items():
            got = marg[attr].get(k, 0.0)
            assert got == pytest.approx(frac / tot, abs=0.04), (attr, k)


def test_provider_scale_reproduces_paper():
    r = evaluate()
    # independence baseline within 2pp of the paper's totals
    assert r.saving_independence == pytest.approx(PAPER_TOTAL_SAVING, abs=0.02)
    assert r.carbon_independence == pytest.approx(PAPER_CARBON_SAVING,
                                                  abs=0.02)
    # calibrated hits the total by construction
    assert r.saving_calibrated == pytest.approx(PAPER_TOTAL_SAVING, abs=0.002)
    # per-opt Figure-5 contributions within 1pp each (independence case)
    for opt, tgt in FIGURE5_CONTRIB.items():
        assert r.contrib_independence[opt] == pytest.approx(tgt, abs=0.011), \
            opt
    # waterfall identity: contributions sum to the total saving
    assert sum(r.contrib_independence.values()) == pytest.approx(
        r.saving_independence, rel=1e-9)


def test_fit_rho_bisection_converges_to_reference():
    """Regression for the duplicated bisection-update lines: ``fit_rho``
    must converge to the same rho as a clean reference bisection, and the
    fitted rho must reproduce the paper total by construction."""
    def reference_fit(target):
        lo, hi = -0.5, 0.9
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if 1.0 - waterfall(TABLE3_CORE_FRAC, rho=mid)[0] > target:
                lo = mid
            else:
                hi = mid
        return 0.5 * (lo + hi)

    rho = fit_rho()
    assert rho == pytest.approx(reference_fit(PAPER_TOTAL_SAVING), abs=1e-12)
    assert 1.0 - waterfall(TABLE3_CORE_FRAC, rho=rho)[0] == pytest.approx(
        PAPER_TOTAL_SAVING, abs=1e-9)
    # monotonicity sanity: saving strictly decreases in rho around the fit
    assert (1.0 - waterfall(TABLE3_CORE_FRAC, rho=rho - 0.05)[0]
            > 1.0 - waterfall(TABLE3_CORE_FRAC, rho=rho + 0.05)[0])


def test_bigdata_case_study_figure4():
    from repro.sim.casestudies.bigdata import run_all
    r = run_all(seed=0)
    assert r["regular"]["slowdown_x"] == 1.0
    assert r["wi_deploy"]["slowdown_x"] == pytest.approx(2.1, abs=0.25)
    assert r["wi_full"]["slowdown_x"] == pytest.approx(1.7, abs=0.2)
    # runtime hints reduce the slowdown (paper: by ~21%)
    rel = 1 - r["wi_full"]["slowdown_x"] / r["wi_deploy"]["slowdown_x"]
    assert 0.1 < rel < 0.3
    assert r["wi_deploy"]["cost_saving"] == pytest.approx(0.926, abs=0.02)
    assert r["wi_full"]["cost_saving"] == pytest.approx(0.935, abs=0.02)
    assert r["wi_full"]["cost_saving"] > r["wi_deploy"]["cost_saving"]
    assert r["wi_full"]["jobs_done"] == 100


def test_microservices_case_study():
    from repro.sim.casestudies.microservices import run
    r = run()
    assert r["baseline"]["p99_ms"] == pytest.approx(376, abs=25)
    assert r["summary"]["latency_improvement"] == pytest.approx(0.133,
                                                                abs=0.04)
    assert r["summary"]["cost_saving"] == pytest.approx(0.44, abs=0.03)


def test_videoconf_case_study():
    from repro.sim.casestudies.videoconf import run
    r = run()
    s = r["summary"]
    assert s["cost_saving"] == pytest.approx(0.263, abs=0.03)
    assert s["carbon_saving"] == pytest.approx(0.51, abs=0.01)
    assert s["rate_improvement"] == pytest.approx(0.354, abs=0.06)
    assert s["spike_rate_improvement"] == pytest.approx(0.22, abs=0.05)
    assert s["wi_delayed_events"] == 0
    assert s["region"] == "region-green"


def test_spot_manager_prefers_preemptible_victims():
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("a", {"preemptibility_pct": 90.0})
    gm.register_workload("b", {"preemptibility_pct": 25.0})
    cl = Cluster()
    cl.add_server("s0", 64)
    cl.add_vm(VM("vm-a", "a", "s0", 8, spot=True))
    cl.add_vm(VM("vm-b", "b", "s0", 8, spot=True))
    spot = SpotManager(gm)
    acts = spot.reclaim(cl.view(), cores_needed=8)
    assert len(acts) == 1 and acts[0].vm == "vm-a"
    evs = gm.events_for("a")
    assert evs and evs[0]["event"] == "eviction_notice"
    assert evs[0]["deadline_s"] == 30.0


def test_madc_power_event_prefers_low_availability():
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("lowav", {"availability_nines": 2.0,
                                   "scale_up_down": True})
    gm.register_workload("highav", {"availability_nines": 5.0})
    cl = Cluster()
    cl.add_server("s0", 32)
    cl.add_vm(VM("vm-l", "lowav", "s0", 16))
    cl.add_vm(VM("vm-h", "highav", "s0", 16))
    ma = MADatacenterManager(gm)
    acts = ma.power_event(cl.view(), "s0", shed_frac=0.25)
    assert acts and acts[0].vm == "vm-l" and acts[0].kind == "throttle"
    assert not any(a.vm == "vm-h" for a in acts)


def test_harvest_rebalance_grow_and_shrink():
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("h", {"preemptibility_pct": 60.0,
                               "scale_up_down": True,
                               "delay_tolerance_ms": 100.0})
    cl = Cluster()
    cl.add_server("s0", 64)
    cl.add_vm(VM("vm-h", "h", "s0", 8, harvest=True))
    hm = HarvestManager(gm)
    acts = hm.rebalance(cl.view())
    assert acts and acts[0].kind == "grow"
    # now oversubscribe the server: shrink expected
    cl.add_vm(VM("vm-big", "x", "s0", 60))
    cl.vms["vm-h"].harvested = 20.0
    acts = hm.rebalance(cl.view())
    assert acts and acts[0].kind == "shrink"
