"""Tests for the observability layer (src/repro/obs/): metrics registry,
tick-phase tracer with Perfetto export, and the bus-fed lifecycle
observer — including the proof that default (disabled) instrumentation
stays far under the 2% placement-throughput budget."""
import json
import time

from repro import obs
from repro.agents import STATEFUL, STATELESS, AgentPolicy, AgentRuntime
from repro.core import hints as H
from repro.core.bus import Bus
from repro.sched import Scheduler
from repro.sim.cluster import VM


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counters_gauges_and_label_series():
    reg = obs.MetricsRegistry(enabled=True)
    c = reg.counter("ev_total", "events by kind", event="notice")
    c.inc(3)
    c.labels(event="evicted").inc()
    # repeated lookups return the same cached series
    assert reg.counter("ev_total", event="notice") is c
    assert reg.counter("ev_total", event="notice").value == 3.0
    assert reg.counter("ev_total", event="evicted").value == 1.0
    g = reg.gauge("depth")
    g.set(4)
    g.dec()
    assert g.value == 3.0


def test_histogram_percentiles_are_clamped_to_observed_extrema():
    reg = obs.MetricsRegistry(enabled=True)
    h = reg.histogram("lat_s", buckets=(1.0, 2.0, 5.0, 10.0))
    for v in (0.4, 1.5, 1.6, 3.0, 7.0):
        h.observe(v)
    assert h.count == 5 and h.sum == 13.5
    assert h.percentile(0) == 0.4          # exact min
    assert h.percentile(100) == 7.0        # exact max
    assert 0.4 <= h.percentile(50) <= h.percentile(95) <= 7.0
    s = h.summary()
    assert s["count"] == 5 and s["min"] == 0.4 and s["max"] == 7.0


def test_prometheus_exposition_has_buckets_sum_and_count():
    reg = obs.MetricsRegistry(enabled=True)
    reg.counter("ev_total", "events", event="notice").inc(3)
    reg.gauge("depth").set(4)
    reg.histogram("lat_s", buckets=(1.0, 10.0)).observe(0.5)
    text = reg.render_prometheus()
    assert "# TYPE ev_total counter" in text
    assert 'ev_total{event="notice"} 3.0' in text
    assert "# TYPE lat_s histogram" in text
    assert 'lat_s_bucket{le="1.0"} 1' in text
    assert 'lat_s_bucket{le="+Inf"} 1' in text
    assert "lat_s_sum 0.5" in text and "lat_s_count 1" in text


def test_collectors_are_pulled_only_at_snapshot_time():
    reg = obs.MetricsRegistry(enabled=True)
    calls = []
    reg.add_collector("sched", lambda: (calls.append(1), {"placed": 7})[1])
    assert calls == []                     # registration costs nothing
    snap = reg.snapshot()
    assert calls == [1]
    assert snap["collected"]["sched"] == {"placed": 7}


def test_disabled_registry_hands_out_one_shared_null_instrument():
    reg = obs.MetricsRegistry(enabled=False)
    # identity is the proof: no allocation per call site
    assert reg.counter("a") is obs.NULL_INSTRUMENT
    assert reg.gauge("b") is reg.histogram("c", buckets=(1.0,))
    obs.NULL_INSTRUMENT.inc()
    obs.NULL_INSTRUMENT.observe(1.0)
    assert obs.NULL_INSTRUMENT.labels(x=1) is obs.NULL_INSTRUMENT
    reg.add_collector("x", lambda: {"never": "called"})
    assert reg.snapshot() == {"counters": {}, "gauges": {},
                              "histograms": {}}


def test_metricdict_keeps_defaultdict_semantics_and_mirrors_gauges():
    reg = obs.MetricsRegistry(enabled=True)
    m = obs.MetricDict(reg, prefix="wi_agents_")
    m["acks"] += 1
    m["acks"] += 2
    m["lost_s"] = 4.5
    assert m["acks"] == 3.0
    assert m.get("missing") == 0.0 and "missing" not in m
    assert dict(m) == {"acks": 3.0, "lost_s": 4.5}
    assert reg.snapshot()["gauges"]["wi_agents_acks"] == 3.0


def test_process_defaults_start_disabled_and_swap_cleanly():
    assert not obs.default_registry().enabled
    assert not obs.default_tracer().enabled
    reg = obs.MetricsRegistry(enabled=True)
    prev = obs.set_default_registry(reg)
    try:
        assert obs.default_registry() is reg
    finally:
        assert obs.set_default_registry(prev) is reg
    assert obs.default_registry() is prev


# ---------------------------------------------------------------------------
# tick-phase tracer
# ---------------------------------------------------------------------------


def test_tracer_records_nested_spans_with_depths_and_args():
    tr = obs.Tracer(capacity=16)
    with tr.span("sched.tick", t_sim=5.0):
        with tr.span("sched.placement_drain") as sp:
            sp.set(placed=12, unplaced=0)
    inner, outer = tr.events()             # inner exits (records) first
    assert inner[0] == "sched.placement_drain" and inner[4] == 1
    assert inner[5] == {"placed": 12, "unplaced": 0}
    assert outer[0] == "sched.tick" and outer[4] == 0
    assert outer[5] == {"t_sim": 5.0}
    bd = tr.phase_breakdown()
    assert bd["sched.tick"]["count"] == 1
    assert bd["sched.tick"]["total_s"] >= bd["sched.placement_drain"][
        "total_s"]


def test_tracer_ring_wraparound_keeps_newest_and_counts_dropped():
    tr = obs.Tracer(capacity=8)
    for i in range(20):
        with tr.span(f"s{i}"):
            pass
    assert tr.recorded == 8 and tr.dropped == 12
    assert [e[0] for e in tr.events()] == [f"s{i}" for i in range(12, 20)]


def test_chrome_trace_export_is_valid_trace_event_json(tmp_path):
    tr = obs.Tracer(capacity=4)
    for i in range(6):                     # wraps: keeps s2..s5
        with tr.span(f"s{i}", cat="evict", v=i):
            pass
    path = tr.write(str(tmp_path / "t.trace.json"), process_name="wi-test")
    with open(path) as fh:
        doc = json.load(fh)
    evs = doc["traceEvents"]
    assert evs[0]["ph"] == "M" and evs[0]["args"]["name"] == "wi-test"
    xs = evs[1:]
    assert len(xs) == 4
    assert all(e["ph"] == "X" for e in xs)
    required = {"name", "cat", "ph", "ts", "dur", "pid", "tid"}
    assert all(required <= set(e) for e in xs)
    assert all(e["ts"] >= 0 and e["dur"] >= 0 for e in xs)
    assert [e["ts"] for e in xs] == sorted(e["ts"] for e in xs)
    assert doc["otherData"] == {"recorded": 4, "dropped": 2}


def test_disabled_tracer_returns_the_shared_null_span():
    tr = obs.Tracer(capacity=4, enabled=False)
    assert tr.span("x") is obs.NULL_SPAN
    with tr.span("x") as sp:
        sp.set(anything=1)
    tr.begin("y")
    tr.end()
    tr.instant("z")
    assert tr.recorded == 0 and tr.dropped == 0


# ---------------------------------------------------------------------------
# lifecycle observer
# ---------------------------------------------------------------------------


def _eviction(bus, **kw):
    bus.publish(H.TOPIC_EVICTIONS, kw)


def test_lifecycle_observer_derives_histograms_from_raw_records():
    bus = Bus()
    o = obs.LifecycleObserver(bus)
    _eviction(bus, event="notice", vm="v0", workload="web-3",
              t=10.0, notice_s=30.0)
    bus.publish(H.TOPIC_EVENT_ACKS, {
        "vm": "v0", "t": 12.5, "event": H.PlatformEvent.EVICTION_NOTICE.value})
    _eviction(bus, event="early_released", vm="v0", workload="web-3", t=13.0)
    _eviction(bus, event="notice", vm="v1", workload="web-7",
              t=10.0, notice_s=30.0)
    _eviction(bus, event="evicted", vm="v1", workload="web-7",
              t=40.0, notice_s=30.0, lead_time_s=30.0)
    s = o.summary()
    assert s["notices"] == 2 and s["early_released"] == 1 and s["killed"] == 1
    assert s["violations"] == 0 and s["late_acks"] == 0
    assert s["outstanding"] == 0
    assert s["notice_to_ack_s"]["count"] == 1
    assert abs(s["notice_to_ack_s"]["max"] - 2.5) < 1e-9
    assert abs(s["ack_to_release_s"]["max"] - 0.5) < 1e-9
    assert abs(s["kill_lead_s"]["min"] - 30.0) < 1e-9
    # both replicas pooled under one workload class
    snap = o.registry.snapshot()
    assert ('wi_lifecycle_events_total{event="notice",'
            'workload_class="web"}') in snap["counters"]


def test_lifecycle_observer_handles_release_record_beating_the_ack():
    # bus delivery is synchronous in subscription order: the scheduler's
    # ack handler (subscribed first) can publish the early_released record
    # before the ack record itself reaches the observer
    bus = Bus()
    o = obs.LifecycleObserver(bus)
    _eviction(bus, event="notice", vm="v0", workload="web-1",
              t=10.0, notice_s=30.0)
    _eviction(bus, event="early_released", vm="v0", workload="web-1", t=15.0)
    bus.publish(H.TOPIC_EVENT_ACKS, {
        "vm": "v0", "t": 14.0, "event": H.PlatformEvent.EVICTION_NOTICE.value})
    s = o.summary()
    assert s["notice_to_ack_s"]["count"] == 1
    assert abs(s["notice_to_ack_s"]["max"] - 4.0) < 1e-9
    assert s["ack_to_release_s"]["count"] == 1
    assert abs(s["ack_to_release_s"]["max"] - 1.0) < 1e-9


def test_lifecycle_observer_reconciles_against_a_live_storm():
    reg = obs.MetricsRegistry(enabled=True)
    s = Scheduler(default_notice_s=30.0, metrics=reg)
    o = obs.LifecycleObserver(s.gm.bus, registry=reg)
    for i in range(2):
        s.cluster.add_server(f"region-0/s{i}", 32)
    s.gm.register_workload("web", {
        "scale_out_in": True, "preemptibility_pct": 70.0,
        "availability_nines": 2.0, "delay_tolerance_ms": 5_000.0})
    s.gm.register_workload("batch", {"preemptibility_pct": 90.0})
    for i in range(3):
        s.submit(VM(f"v{i}", "web", "", 8, spot=True))
    s.submit(VM("b0", "batch", "", 8, spot=True))
    s.schedule_pending()
    # web acks immediately and early-releases; batch's checkpoint (30 GB at
    # 0.2 GB/s, 150 s) cannot beat the 30 s window, so it rides the ladder
    # to a full-lead kill
    AgentRuntime(s, policies={
        "web": AgentPolicy(statefulness=STATELESS, scale_out_in=True),
        "batch": AgentPolicy(statefulness=STATEFUL, state_gb=30.0,
                             ckpt_gbps=0.2)})
    s.capacity_crunch("region-0", 32)
    s.run_until(200.0)
    recon = o.reconcile(s.evictor)
    assert recon["ok"], recon["diffs"]
    life = o.summary()
    assert life["notices"] >= 2
    assert life["early_released"] == s.evictor.stats["early_releases"] > 0
    assert life["killed"] == s.evictor.stats["kills"] > 0
    assert life["violations"] == 0 and life["outstanding"] == 0
    # every ladder kill honored the full hinted window
    assert life["kill_lead_s"]["min"] >= 30.0 - 1e-9
    # every web ack was observed and landed inside its window
    assert life["notice_to_ack_s"]["count"] == life["early_released"]
    assert life["min_ack_margin_s"] >= 0.0
    # decision records flowed: the placement batch was counted
    assert reg.counter("wi_sched_decisions_total", kind="place").value >= 4
    o.close()


# ---------------------------------------------------------------------------
# overhead budget
# ---------------------------------------------------------------------------


def test_disabled_instrumentation_is_under_the_two_percent_budget():
    # a real pending-queue drain with everything at defaults (disabled
    # registry + tracer) -- the configuration the sched_scale benchmark
    # times
    s = Scheduler()
    assert not s.metrics.enabled and not s.tracer.enabled
    for i in range(32):
        s.cluster.add_server(f"s{i}", 64)
    for i in range(1000):
        s.submit(VM(f"v{i}", f"w-{i % 20}", "", 2))
    t0 = time.perf_counter()
    s.schedule_pending()
    drain_s = time.perf_counter() - t0
    assert s.stats["placed"] >= 500

    # per-drain instrumentation cost: one span plus the placed/unplaced
    # counter handouts.  Measure it directly on the disabled defaults and
    # project against the measured drain -- flake-safe because the no-op
    # path is ~1e5x cheaper than the drain itself.
    tracer, reg = s.tracer, s.metrics
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        with tracer.span("sched.placement_drain") as sp:
            sp.set(placed=1, unplaced=0)
        reg.counter("wi_sched_placed_total").inc(1)
        reg.counter("wi_sched_unplaced_total").inc(1)
    per_drain_overhead = (time.perf_counter() - t0) / n
    assert per_drain_overhead < 0.02 * drain_s, (
        f"disabled instrumentation {per_drain_overhead * 1e6:.2f}us/drain "
        f"vs drain {drain_s * 1e3:.2f}ms")
