"""Docs link check: every relative link in the markdown docs resolves.

Run standalone by the CI docs-link-check step::

    PYTHONPATH=src python -m pytest tests/test_docs_links.py -q

Scope: ``*.md`` at the repo root plus ``docs/``.  External links
(``http(s)://``) and pure anchors (``#...``) are out of scope; relative
targets may carry an anchor, which is stripped before the existence check.
"""
import re
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parent.parent
_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def md_files():
    return sorted(ROOT.glob("*.md")) + sorted((ROOT / "docs").glob("*.md"))


def relative_links(path: Path):
    for m in _LINK.finditer(path.read_text()):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        yield target.split("#", 1)[0]


def test_docs_exist():
    names = {p.name for p in md_files()}
    assert "README.md" in names
    assert (ROOT / "docs" / "ARCHITECTURE.md").exists()
    assert (ROOT / "docs" / "HINTS.md").exists()


@pytest.mark.parametrize("md", md_files(), ids=lambda p: str(p.relative_to(
    ROOT)))
def test_relative_md_links_resolve(md):
    broken = []
    for target in relative_links(md):
        if not (md.parent / target).exists():
            broken.append(target)
    assert not broken, f"{md.relative_to(ROOT)} has broken links: {broken}"
