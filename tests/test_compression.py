"""int8 gradient-compression tests: quantization round-trip and the ring
all-reduce vs exact psum (4 virtual devices in a subprocess)."""
import json
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np

from repro.train.compression import dequantize_int8, quantize_int8

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_quantize_roundtrip_error_bound():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(1000).astype(np.float32)) * 3.0
    q, sc = quantize_int8(x, block=128)
    y = dequantize_int8(q, sc, x.shape)
    # blockwise symmetric int8: |err| <= scale/2 = max|block|/254
    err = np.abs(np.asarray(y - x))
    bound = np.asarray(sc).max() * 0.5 + 1e-7
    assert err.max() <= bound


def test_ring_allreduce_matches_psum():
    code = textwrap.dedent("""
        import os, json
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.train.compression import ring_allreduce_q
        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal((4, 317)).astype(np.float32))

        def body(xs):
            s, err = ring_allreduce_q(xs[0], "pod", 4, block=64)
            return s[None], err[None]

        if hasattr(jax, "shard_map"):       # jax >= 0.5
            smapped = jax.shard_map(body, mesh=mesh, in_specs=P("pod"),
                                    out_specs=P("pod"), check_vma=False)
        else:                               # jax 0.4.x
            from jax.experimental.shard_map import shard_map
            smapped = shard_map(body, mesh=mesh, in_specs=P("pod"),
                                out_specs=P("pod"), check_rep=False)
        f = jax.jit(smapped)
        s, err = f(x)
        exact = np.asarray(x).sum(0)
        got = np.asarray(s)
        # every shard within a few quantization steps of the exact sum;
        # shards may differ slightly from each other (each rank keeps its
        # own unquantized accumulation of its segment — same contract as
        # prod int8 rings; periodic param sync handles the drift)
        abs_err = np.abs(got - exact[None]).max()
        cross = max(np.abs(got[i] - got[0]).max() for i in range(1, 4))
        print("RESULT " + json.dumps({
            "abs_err": float(abs_err), "cross": float(cross),
            "err_norm": float(np.abs(np.asarray(err)).max())}))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    res = json.loads(line[len("RESULT "):])
    # int8 blockwise quantization across 2(n-1) hops of ~N(0,1) segments:
    # scale ~ 3/127 per hop, ~6 quantizations -> abs error << 0.3
    assert res["abs_err"] < 0.3, res
    assert res["cross"] < 0.2, res
    # error-feedback residual is bounded by the quantization step
    assert res["err_norm"] < 0.2, res
