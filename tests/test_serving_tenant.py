"""Serving-as-tenant tests: the replica fleet attached to VMs placed by
the REAL scheduler.

The ``ServingTenant`` is engine-agnostic, so the notice -> drain -> ack ->
early-release -> re-grow choreography is pinned here against a stub engine
(fast, no jax); one subprocess test then runs the full ``serving_fleet``
case study with synthetic-mode ``ServingEngine`` replicas under open-loop
traffic and checks the acceptance bars end to end.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.agents import AgentRuntime, ServingAgent, ServingTenant
from repro.sched import Scheduler
from repro.sim.cluster import VM

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class Req:
    """Minimal request for the stub: ``steps`` decode steps remain."""

    def __init__(self, rid, steps=4):
        self.rid = rid
        self.steps = steps


class StubEngine:
    """Implements the tenant-facing engine protocol; records calls."""

    def __init__(self, vm_id, slots):
        self.vm_id = vm_id
        self.slots = slots
        self.active = []
        self.queue = []
        self.draining = False
        self.resizes = []
        self.p99 = float("nan")

    def submit(self, req):
        if self.draining:
            return False
        if len(self.active) < self.slots:
            self.active.append(req)
        else:
            self.queue.append(req)
        return True

    def queue_depth(self):
        return len(self.queue)

    def active_count(self):
        return len(self.active)

    def drain(self):
        self.draining = True
        q, self.queue = self.queue, []
        steps = max((r.steps for r in self.active), default=0)
        return steps, q

    def resize_slots(self, n):
        self.resizes.append(n)
        self.slots = n
        return n

    def step_once(self):
        for r in self.active:
            r.steps -= 1
        self.active = [r for r in self.active if r.steps > 0]
        return 1

    def p99_token_latency(self):
        return self.p99


def make_tenant(n_vms=2, slots_per_vm=4, notice_s=60.0, token_time_s=1.0,
                n_servers=3, harvest=True, hints=None):
    s = Scheduler(default_notice_s=30.0)
    for i in range(n_servers):
        s.cluster.add_server(f"region-0/s{i}", 32, region="region-0")
    h = {"scale_out_in": True, "scale_up_down": True,
         "preemptibility_pct": 80.0, "availability_nines": 2.5,
         "delay_tolerance_ms": 1000.0, "x-eviction-notice-s": notice_s}
    h.update(hints or {})
    s.gm.register_workload("svc", h)
    engines = {}

    def factory(vm_id, slots):
        e = StubEngine(vm_id, slots)
        engines[vm_id] = e
        return e

    tenant = ServingTenant("svc", factory, slots_per_vm=slots_per_vm,
                           token_time_s=token_time_s, p99_target_s=5.0)
    for i in range(n_vms):
        s.submit(VM(f"svc{i}", "svc", "", 8, util_p95=0.5, spot=True,
                    harvest=harvest))
    s.schedule_pending()
    rt = AgentRuntime(s, policies={"svc": tenant.policy()})
    return s, rt, tenant, engines


def test_notice_drain_ack_early_release_and_regrow():
    s, rt, tenant, engines = make_tenant()
    assert all(isinstance(a, ServingAgent) for a in rt.agents.values())
    # 4 decode steps in flight everywhere, plus queued work on each replica
    for e in engines.values():
        e.active = [Req(1, 4), Req(2, 4)]
        e.queue = [Req(3), Req(4)]
    r = s.capacity_crunch("region-0", 8)
    assert r["evictions"] == 1
    ticket = next(iter(s.evictor.tickets.values()))
    assert ticket.notice_s == 60.0          # hinted window honored
    vm_id = ticket.vm_id
    victim = engines[vm_id]
    survivor = next(e for vid, e in engines.items() if vid != vm_id)
    # admission stopped NOW: the victim is draining and its queued (not
    # yet started) requests moved to the surviving replica
    assert victim.draining and not victim.queue
    assert not tenant.submit(Req(9)) == vm_id
    assert tenant.metrics["requests_rerouted"] == 2
    assert survivor.queue_depth() + survivor.active_count() >= 4
    # the ack waits for the modeled drain (4 steps x 1 s/token)...
    s.run_until(3.9)
    assert s.cluster.vms[vm_id].alive
    victim.active.clear()                   # in-flight batch finished
    # ...then lands on wi.events.acks and the pipeline early-releases
    s.run_until(4.1)
    assert not s.cluster.vms[vm_id].alive
    done = s.evictor.log[-1]
    assert done.outcome == "early_released"
    assert abs(done.lead_time_s - 4.0) < 1e-9
    assert s.evictor.violations() == []
    # the drain completed before the release: no request was lost
    assert tenant.metrics["requests_lost"] == 0.0
    assert len(tenant._order) == 1
    # the replacement VM lands on the next tick and the fleet re-grows
    s.tick()
    assert len(tenant._order) == 2
    assert rt.metrics["replacements_placed"] == 1
    # the ladder kill at the 60 s deadline is a no-op
    s.run_until(100.0)
    assert s.evictor.stats["kills"] == 0


def test_slow_drain_rides_ladder_and_loses_bounded_requests():
    # 4 decode steps x 30 s/token = 120 s drain cannot fit the 60 s
    # window: the ladder kill wins, and only the in-flight batch (bounded
    # by the replica's slots) is lost — queued requests were rerouted
    s, rt, tenant, engines = make_tenant(token_time_s=30.0)
    for e in engines.values():
        e.active = [Req(i, 4) for i in range(4)]
        e.queue = [Req(10), Req(11)]
    s.capacity_crunch("region-0", 8)
    assert tenant.metrics["ack_margin_min_s"] < 0  # agent knew it would lose
    assert tenant.metrics["requests_rerouted"] == 2
    s.run_until(200.0)
    done = s.evictor.log[-1]
    assert done.outcome == "killed"
    assert abs(done.lead_time_s - 60.0) < 1e-9     # full window honored
    assert s.evictor.violations() == []
    assert tenant.metrics["requests_lost"] == 4    # == slots, never more
    assert tenant.metrics["requests_lost"] <= 4
    assert len(tenant._order) == 1


def test_throttle_halves_slots_and_policy_pass_restores():
    s, rt, tenant, engines = make_tenant(harvest=False)
    lead = s.cluster.vms[tenant._order[0]]
    s.power_event(lead.server, shed_frac=0.9)
    assert all(e.slots == 2 for e in engines.values())  # 4 -> 2
    # serving throttles shed compute (decode slots), not p95 demand (else
    # the overclock offer that restores the slots would never re-qualify)
    assert lead.util_p95 == 0.5
    # duplicate throttle notices do not re-toggle
    s.power_event(lead.server, shed_frac=0.9)
    assert all(e.slots == 2 for e in engines.values())
    assert tenant.metrics["throttle_notices"] >= 2
    # the periodic pass's OVERCLOCK_OFFER (util 0.5 > 0.4, applicable)
    # clears it through the guest channel
    s.run_policies()
    assert all(e.slots == 4 for e in engines.values())
    assert tenant.metrics["restores"] == 1


def test_harvest_scale_up_offer_grows_decode_slots():
    s, rt, tenant, engines = make_tenant(slots_per_vm=2)
    s.run_policies()                    # HarvestPolicy offers spare cores
    # 8-core VMs, 2 slots each -> 4 cores/slot; the grow cap (50% of
    # nominal) grants exactly one extra decode slot per replica
    assert tenant.metrics["harvest_slots_granted"] == 2
    assert all(e.slots == 3 for e in engines.values())


def test_total_reclaim_parks_requests_until_replacement_lands():
    s, rt, tenant, engines = make_tenant(n_vms=1)
    s.capacity_crunch("region-0", 8)    # the only replica is reclaimed
    assert tenant.paused                # nothing is admitting
    assert tenant.submit(Req(1)) is None
    assert tenant.metrics["requests_overflowed"] == 1
    s.run_until(4.1)                    # empty batch: immediate-ish ack
    assert len(tenant._order) == 0
    s.tick()                            # replacement lands
    assert not tenant.paused
    # the parked request boarded the fresh replica
    assert tenant.metrics["overflow_replayed"] == 1
    new_eng = engines[tenant._order[0]]
    assert new_eng.active_count() == 1


def test_autoscale_pressure_hint_drives_scale_out_and_back_in():
    s, rt, tenant, engines = make_tenant()
    pol = s.policies["auto_scaling"]
    # saturated fleet: full batches plus deep queues -> pressure pins high
    for e in engines.values():
        e.active = [Req(i, 4) for i in range(4)]
        e.queue = [Req(10 + i) for i in range(6)]
    assert tenant.autoscale_pressure() > 0.6
    assert tenant.publish_autoscale_hint()
    s.run_policies()
    assert pol.stats["pressure_signals"] >= 1
    assert pol.stats["rescale"] >= 1
    s.schedule_pending()                # the clone VM lands...
    assert len(tenant._order) == 3      # ...and the tenant adopted it
    assert any(v.startswith("svc.as") for v in tenant._order)
    # demand gone: pressure collapses and the policy drains surplus
    # replicas through the eviction pipeline (consented shrink still pays
    # the hinted notice window -> the drain choreography runs)
    for e in engines.values():
        e.active.clear()
        e.queue.clear()
    assert tenant.autoscale_pressure() < 0.25
    assert tenant.publish_autoscale_hint()
    s.run_policies()
    assert len(s.evictor.tickets) >= 1
    assert tenant.metrics["drains"] >= 1


def test_latency_pressure_scales_out_without_queue():
    # tail latency alone (no backlog) must trip the scale-out trigger:
    # this is the "queue depth AND p99, not util alone" signal
    s, rt, tenant, engines = make_tenant()
    for e in engines.values():
        e.p99 = 7.5                     # 1.5x the 5 s target
    assert tenant.autoscale_pressure() > 0.6
    for e in engines.values():
        e.p99 = float("nan")            # no samples yet -> occupancy only
    assert tenant.autoscale_pressure() < 0.25


@pytest.mark.skipif(os.environ.get("CI", "") != ""
                    and os.environ.get("SERVING_FLEET_E2E", "") == "",
                    reason="CI runs this exact scenario (with the same "
                           "asserts) in the bench-smoke job; set "
                           "SERVING_FLEET_E2E=1 to force it in tier-1 too")
def test_serving_fleet_case_study_end_to_end():
    """Synthetic-mode replicas under the live scheduler and open-loop
    diurnal traffic: reclaim waves + power throttle + flash crowd, zero
    notice violations, early releases via drain acks, bounded p99, and a
    clean lifecycle reconcile (the ISSUE's acceptance bars)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-m", "repro.sim.casestudies.serving_fleet"],
        env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert r["waves"] >= 2
    assert r["violations"] == 0
    assert r["serving_early_releases"] >= 1
    assert r["obs_reconcile_ok"]
    assert r["goodput_frac"] >= 0.95
    assert r["e2e_p99_s"] <= r["p99_bound_s"]
    assert r["requests_lost"] == 0
    assert r["throttle_notices"] >= 1 and r["restores"] >= 1
    assert r["scale_outs"] >= 1
