"""The optimization policies on the scheduler substrate (PR 4 tentpole).

Covers: cluster-native policy entry points vs the legacy view adapters
(parity), the resize path through admission control, harvest grow/shrink
against the incremental books, demand-conserving auto-scaling, the
scheduler's periodic policy pass, and the e2e_savings scenario invariants
(±3pp of the analytical 48.8%, zero notice violations, meter/cluster
core-hour reconciliation).
"""
import pytest

from repro.core.global_manager import GlobalManager
from repro.core.optimizations import (HarvestManager, HarvestPolicy,
                                      MADatacenterManager, MADatacenterPolicy,
                                      OversubscriptionManager, SpotManager,
                                      SpotPolicy)
from repro.sched import Scheduler
from repro.sim.cluster import VM, Cluster


def _gm():
    return GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)


def _acts(actions):
    return [(a.kind, a.vm) for a in actions]


# -- parity: cluster-native policies == legacy view adapters ----------------

def test_spot_policy_matches_view_adapter():
    gm = _gm()
    gm.register_workload("a", {"preemptibility_pct": 90.0})
    gm.register_workload("b", {"preemptibility_pct": 25.0})
    cl = Cluster()
    cl.add_server("s0", 64)
    cl.add_vm(VM("vm-a", "a", "s0", 8, spot=True))
    cl.add_vm(VM("vm-b", "b", "s0", 8, spot=True))
    cl.add_vm(VM("vm-c", "b", "s0", 8))                 # not spot: never picked
    want = _acts(SpotManager(_gm_clone(gm)).reclaim(cl.view(), 12))
    got = _acts(SpotPolicy(gm).reclaim_cores(cl, 12))
    assert got == want == [("evict", "vm-a"), ("evict", "vm-b")]


def _gm_clone(gm):
    """Fresh GM with the same deployment hints (adapters/policies must not
    share stats for the parity comparison)."""
    g2 = _gm()
    for key, v in gm.store.scan("hints/deployment/"):
        g2.set_hints(v["workload"], v["resource"], v["hints"],
                     source="clone")
    return g2


def test_madc_policy_matches_view_adapter():
    gm = _gm()
    gm.register_workload("lowav", {"availability_nines": 2.0,
                                   "scale_up_down": True})
    gm.register_workload("preempt", {"availability_nines": 4.0,
                                     "preemptibility_pct": 60.0})
    gm.register_workload("highav", {"availability_nines": 5.0})
    cl = Cluster()
    cl.add_server("s0", 48)
    cl.add_vm(VM("vm-l", "lowav", "s0", 16))
    cl.add_vm(VM("vm-p", "preempt", "s0", 16))
    cl.add_vm(VM("vm-h", "highav", "s0", 16))
    want = _acts(MADatacenterManager(_gm_clone(gm)).power_event(
        cl.view(), "s0", shed_frac=0.5))
    got = _acts(MADatacenterPolicy(gm).power_event_cluster(
        cl, "s0", shed_frac=0.5))
    assert got == want
    assert ("throttle", "vm-l") in got and ("evict", "vm-p") in got
    assert not any(vm == "vm-h" for _, vm in got)


def test_madc_policy_excludes_mid_eviction_vms():
    gm = _gm()
    gm.register_workload("w", {"availability_nines": 2.0})
    cl = Cluster()
    cl.add_server("s0", 32)
    cl.add_vm(VM("vm-0", "w", "s0", 16))
    cl.add_vm(VM("vm-1", "w", "s0", 16))
    acts = MADatacenterPolicy(gm).power_event_cluster(
        cl, "s0", shed_frac=0.9, exclude={"vm-0"})
    assert all(a.vm != "vm-0" for a in acts) and acts


# -- harvest grow/shrink on the live books ----------------------------------

def test_harvest_policy_applies_growth_with_books():
    s = Scheduler()
    s.cluster.add_server("s0", 64)
    s.gm.register_workload("h", {"preemptibility_pct": 60.0,
                                 "scale_up_down": True,
                                 "delay_tolerance_ms": 100.0})
    s.submit(VM("vm-h", "h", "", 8, harvest=True, spot=True))
    s.schedule_pending()
    hp: HarvestPolicy = s.policies["harvest"]
    acts = hp.rebalance_cluster(s.cluster, s.admission, apply=True)
    assert acts and acts[0].kind == "grow"
    vm = s.cluster.vms["vm-h"]
    # applied growth is capped at half the nominal cores, and both the
    # cluster counters and the admission reservation follow
    assert vm.harvested == pytest.approx(4.0)
    assert s.cluster.free_cores("s0") == pytest.approx(64 - 8 - 4)
    assert s.admission.reserved["s0"] == pytest.approx(12.0)
    s.cluster.assert_consistent()
    # legacy adapter still reports the same offers from a view
    legacy = HarvestManager(_gm())
    view_acts = legacy.rebalance(s.cluster.view())
    assert view_acts and view_acts[0].kind == "grow"


def test_harvest_policy_shrinks_under_pressure():
    s = Scheduler()
    s.cluster.add_server("s0", 64)
    s.gm.register_workload("h", {"preemptibility_pct": 60.0,
                                 "scale_up_down": True,
                                 "delay_tolerance_ms": 100.0})
    s.submit(VM("vm-h", "h", "", 8, harvest=True, spot=True))
    s.schedule_pending()
    vm = s.cluster.vms["vm-h"]
    vm.harvested = 4.0
    s.admission.reserved["s0"] += 4.0
    big = VM("vm-big", "x", "s0", 58)
    s.cluster.add_vm(big)                       # free_cores now negative
    acts = s.policies["harvest"].rebalance_cluster(
        s.cluster, s.admission, apply=True)
    assert any(a.kind == "shrink" for a in acts)
    assert vm.harvested < 4.0
    s.cluster.assert_consistent()


# -- resize through admission ----------------------------------------------

def test_admission_resize_paths():
    s = Scheduler()
    s.cluster.add_server("s0", 32)
    s.gm.register_workload("w", {})
    s.submit(VM("v0", "w", "", 16.0, util_p95=0.9))
    s.schedule_pending()
    vm = s.cluster.vms["v0"]
    ok, reason = s.admission.resize(vm, 8.0)
    assert ok and vm.cores == 8.0
    assert s.admission.nominal["s0"] == pytest.approx(8.0)
    assert s.cluster.free_cores("s0") == pytest.approx(24.0)
    ok, reason = s.admission.resize(vm, 32.0)
    assert ok and vm.cores == 32.0
    # growth beyond the commitment cap is rejected, books untouched
    ok, reason = s.admission.resize(vm, 64.0)
    assert not ok and reason == "oversub_commit_cap" and vm.cores == 32.0
    s.cluster.assert_consistent()


# -- auto-scaling: demand conservation --------------------------------------

def test_autoscaling_scan_scales_out_without_runaway():
    s = Scheduler(policy_period_s=60.0)
    for i in range(8):
        s.cluster.add_server(f"s{i}", 64)
    s.gm.register_workload("web", {
        "scale_out_in": True, "scale_up_down": True,
        "delay_tolerance_ms": 1000.0, "availability_nines": 2.0})
    for i in range(4):
        s.submit(VM(f"v{i}", "web", "", 8.0, util_p95=0.8))
    s.schedule_pending()
    asp = s.policies["auto_scaling"]
    acts = asp.scan(s)
    assert acts and all(a.kind == "scale_out" for a in acts)
    s.schedule_pending()                    # place the clones
    alive = [v for v in s.cluster.vms.values() if v.alive and v.server]
    # demand conserved: total p95 demand unchanged by the rescale
    assert sum(v.cores * v.util_p95 for v in alive) == pytest.approx(
        4 * 8.0 * 0.8)
    n_after_first = len(alive)
    # a second pass must not keep compounding (utilization settled)
    asp.scan(s)
    s.schedule_pending()
    alive2 = [v for v in s.cluster.vms.values() if v.alive and v.server]
    assert len(alive2) == n_after_first


def test_autoscaling_restores_demand_when_clone_cannot_place():
    """A scale-out against a full cluster must not let the workload's
    demand silently evaporate: once the clone is given up on, its demand
    share returns to the live replicas."""
    s = Scheduler(policy_period_s=60.0)
    s.cluster.add_server("s0", 16)              # exactly full after placement
    s.gm.register_workload("web", {
        "scale_out_in": True, "scale_up_down": True,
        "delay_tolerance_ms": 1000.0, "availability_nines": 2.0})
    for i in range(2):
        s.submit(VM(f"v{i}", "web", "", 8.0, util_p95=0.8, spot=True))
    s.schedule_pending()
    demand0 = sum(v.cores * v.util_p95 for v in s.cluster.vms.values()
                  if v.alive and v.server)
    asp = s.policies["auto_scaling"]
    acts = asp.scan(s)
    assert acts and acts[0].kind == "scale_out"
    s.schedule_pending()                        # clone cannot place (full)
    assert asp._pending_clones
    # the clone waits a few passes, then is given up on and demand restored
    for _ in range(asp.MAX_CLONE_WAIT_PASSES + 1):
        asp.scan(s)
        s.schedule_pending()
    assert not asp._pending_clones
    assert asp.stats["clones_unplaceable"] == 1
    demand1 = sum(v.cores * v.util_p95 for v in s.cluster.vms.values()
                  if v.alive and v.server)
    assert demand1 == pytest.approx(demand0)
    # and the workload backs off instead of churning a fresh clone per pass
    asp.scan(s)
    assert not asp._pending_clones
    s.cluster.assert_consistent()


def test_harvest_offer_advertises_capped_grant():
    """The SCALE_UP_OFFER must promise exactly what apply-mode grants."""
    s = Scheduler()
    s.cluster.add_server("s0", 64)
    s.gm.register_workload("h", {"preemptibility_pct": 60.0,
                                 "scale_up_down": True,
                                 "delay_tolerance_ms": 100.0})
    s.submit(VM("vm-h", "h", "", 8, harvest=True, spot=True))
    s.schedule_pending()
    acts = s.policies["harvest"].rebalance_cluster(
        s.cluster, s.admission, apply=True)
    assert acts and acts[0].kind == "grow"
    # offer == grant == the 50%-of-nominal cap, not the 56 spare cores
    assert acts[0].payload["cores"] == pytest.approx(4.0)
    assert s.cluster.vms["vm-h"].harvested == pytest.approx(4.0)


def test_autoscaling_scale_in_goes_through_notice_pipeline():
    s = Scheduler(policy_period_s=60.0)
    for i in range(8):
        s.cluster.add_server(f"s{i}", 64)
    s.gm.register_workload("idle", {
        "scale_out_in": True, "delay_tolerance_ms": 1000.0,
        "availability_nines": 2.0, "x-eviction-notice-s": 45.0})
    for i in range(6):
        s.submit(VM(f"v{i}", "idle", "", 8.0, util_p95=0.05))
    s.schedule_pending()
    acts = s.policies["auto_scaling"].scan(s)
    assert any(a.kind == "evict" for a in acts)
    assert s.evictor.tickets                # booked, not instantly killed
    for t in s.evictor.tickets.values():
        assert t.source == "auto_scaling" and t.notice_s == 45.0
    s.run_until(120.0)
    assert s.evictor.stats["kills"] >= 1
    assert len(s.evictor.violations()) == 0


def test_rightsizing_apply_does_not_oscillate():
    """A VM with util in (0.9, 1.0) grows once and then holds: the shrink
    rule must not undo a grow whose post-resize utilization sits just
    under 0.5 (that flap would churn books + billing every pass)."""
    s = Scheduler(apply_rightsizing=True)
    s.cluster.add_server("s0", 64)
    s.gm.register_workload("hot", {
        "scale_up_down": True, "availability_nines": 4.0,
        "delay_tolerance_ms": 1000.0})
    s.submit(VM("v0", "hot", "", 4.0, util_p95=0.92))
    s.schedule_pending()
    rp = s.policies["rightsizing"]
    rp.scan_cluster(s.cluster, s.admission, apply=True)
    vm = s.cluster.vms["v0"]
    assert vm.cores == 8.0 and vm.util_p95 == pytest.approx(0.46)
    for _ in range(3):                      # further passes: stable
        rp.scan_cluster(s.cluster, s.admission, apply=True)
    assert vm.cores == 8.0 and vm.util_p95 == pytest.approx(0.46)
    assert rp.stats["resize_skipped_unstable"] >= 1
    assert s.admission.stats["resized"] == 1
    s.cluster.assert_consistent()


def test_autoscaling_ignores_vms_mid_eviction():
    """Replicas with a booked eviction ticket are leaving: they must not
    count toward the replica target nor receive redistributed demand."""
    from repro.core.optimizations import Action
    s = Scheduler(default_notice_s=60.0)
    for i in range(4):
        s.cluster.add_server(f"s{i}", 64)
    s.gm.register_workload("web", {
        "scale_out_in": True, "scale_up_down": True,
        "delay_tolerance_ms": 1000.0, "availability_nines": 2.0})
    for i in range(4):
        s.submit(VM(f"v{i}", "web", "", 8.0, util_p95=0.1, spot=True))
    s.schedule_pending()
    s.evictor.submit([Action("evict", vm="v0", workload="web")],
                     source="spot")
    util_before = s.cluster.vms["v0"].util_p95
    acts = s.policies["auto_scaling"].scan(s)
    # scale-in considered only the 3 live replicas, never the ticketed one
    assert all(a.vm != "v0" for a in acts)
    assert s.cluster.vms["v0"].util_p95 == util_before
    s.run_until(120.0)
    assert len(s.evictor.violations()) == 0


# -- the periodic policy pass ----------------------------------------------

def test_scheduler_policy_pass_runs_in_priority_order_and_is_gated():
    s = Scheduler()                         # policy_period_s=0: disabled
    s.cluster.add_server("s0", 64)
    s.start(5.0, 50.0)
    s.run_until(50.0)
    assert s.stats.get("policy_passes", 0) == 0
    s2 = Scheduler(policy_period_s=20.0)
    s2.cluster.add_server("s0", 64)
    s2.start(5.0, 100.0)
    s2.run_until(100.0)
    assert s2.stats["policy_passes"] == 5
    # ten policies live on the scheduler, keyed by Table-4 name
    assert len(s2.policies) == 10
    from repro.core.pricing import PRIORITY
    names = list(s2.policies)
    assert names == sorted(names, key=PRIORITY.get)


def test_oversub_pressure_throttles_via_policy():
    s = Scheduler(oversub_ratio=2.0)
    s.cluster.add_server("s0", 16)
    s.gm.register_workload("svc", {
        "scale_up_down": True, "delay_tolerance_ms": 1000.0,
        "availability_nines": 2.0})
    for i in range(4):
        s.submit(VM(f"v{i}", "svc", "", 8.0, util_p95=0.3))
    s.schedule_pending()
    placed = [v for v in s.cluster.vms.values() if v.server]
    assert len(placed) >= 2 and all(v.oversubscribed for v in placed)
    # correlated spike: everyone's p95 jumps, server demand exceeds cores
    for v in placed:
        v.util_p95 = 0.9
    acts = s.policies["oversubscription"].on_tick(s.engine.clock.t)
    assert acts and all(a.kind == "throttle" for a in acts)
    assert s.stats["policy_oversubscription_throttle"] == len(acts)


# -- the headline scenario --------------------------------------------------

def test_e2e_savings_recovers_paper_total():
    from repro.sim.casestudies.e2e_savings import run
    r = run(seed=0, n_workloads=150, n_servers_per_region=30,
            horizon_s=1800.0)
    # the acceptance bar: live metered saving within ±3pp of the paper's
    # 48.8%, zero notice violations, meters reconcile with the cluster's
    # core-hour integral
    assert r["abs_err_vs_paper"] <= 0.03, r["saving"]
    assert r["violations"] == 0
    assert r["early_releases"] > 0
    assert r["evictions_killed"] > 0        # some rode the ladder
    assert r["min_lead_s"] >= 30.0          # ...with the window honored
    assert r["reconcile_abs_diff"] <= 1e-6 * r["cluster_core_hours"]
    assert r["migration_displaced"] == 0
    assert r["placed"] == 450               # full fleet admitted
    # the model cross-check: the sampled fleet's closed-form expectation
    # is itself within the band (the live number tracks it)
    assert abs(r["expected_sampled"] - 0.488) <= 0.02
    assert abs(r["saving"] - r["expected_sampled"]) <= 0.02


def test_e2e_savings_expectation_model():
    from repro.sim.provider_scale import (enablement_probs,
                                          expected_fleet_saving,
                                          fit_enablement_shrink)
    shrink = fit_enablement_shrink()
    assert expected_fleet_saving(enablement_probs(shrink=shrink)) == \
        pytest.approx(0.488, abs=1e-6)
    # conflict-exclusive probabilities stay a valid sub-probability vector
    from repro.core.pricing import CONFLICT_SETS
    probs = enablement_probs(shrink=shrink)
    for cs in CONFLICT_SETS:
        assert sum(probs[o] for o in cs) <= 1.0
    assert all(0.0 <= p <= 1.0 for p in probs.values())
