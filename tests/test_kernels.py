"""Pallas kernel validation (interpret=True on CPU) against ref.py oracles.

Shape/dtype sweeps via hypothesis; gradients of the flash kernel wrapper
checked against the dense oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis")   # property tests need it; skip cleanly if absent
from hypothesis import given, settings, strategies as st

from repro.configs.base import AttnConfig
from repro.kernels.flash_attention import ops as fa_ops
from repro.kernels.flash_attention import ref as fa_ref
from repro.kernels.rglru import ops as lru_ops
from repro.kernels.rglru import ref as lru_ref
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([64, 128, 256]),
    kh=st.sampled_from([1, 2, 4]),
    rep=st.sampled_from([1, 2, 4]),
    hd=st.sampled_from([16, 32]),
    causal=st.booleans(),
    window=st.sampled_from([None, 32, 64]),
    softcap=st.sampled_from([None, 30.0]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_flash_kernel_sweep(b, s, kh, rep, hd, causal, window, softcap,
                            dtype):
    cfg = AttnConfig(causal=causal, window=window, logit_softcap=softcap)
    H = kh * rep
    ks = jax.random.split(jax.random.PRNGKey(b * s + H), 3)
    q = rand(ks[0], (b, s, H, hd), dtype)
    k = rand(ks[1], (b, s, kh, hd), dtype)
    v = rand(ks[2], (b, s, kh, hd), dtype)
    ref = fa_ref.reference(q.astype(jnp.float32), k.astype(jnp.float32),
                           v.astype(jnp.float32), cfg)
    out = fa_ops.attention(q, k, v, cfg, q_chunk=32, kv_chunk=32,
                           interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=TOL[dtype], rtol=TOL[dtype] * 10)


def test_flash_kernel_grad_matches_dense():
    cfg = AttnConfig(causal=True, window=64, logit_softcap=50.0)
    B, S, H, K, hd = 2, 128, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = rand(ks[0], (B, S, H, hd), jnp.float32)
    k = rand(ks[1], (B, S, K, hd), jnp.float32)
    v = rand(ks[2], (B, S, K, hd), jnp.float32)
    f_k = lambda *a: (fa_ops.attention(*a, cfg, 32, 32, True) ** 2).sum()
    f_r = lambda *a: (fa_ref.reference(*a, cfg) ** 2).sum()
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-3)


# ---------------------------------------------------------------------------
# SSD
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([32, 64, 128]),
    h=st.sampled_from([2, 4]),
    p=st.sampled_from([8, 16]),
    g=st.sampled_from([1, 2]),
    n=st.sampled_from([8, 16]),
    chunk=st.sampled_from([16, 32]),
    dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
)
def test_ssd_kernel_sweep(b, s, h, p, g, n, chunk, dtype):
    if h % g:
        g = 1
    ks = jax.random.split(jax.random.PRNGKey(s + h + p), 5)
    x = rand(ks[0], (b, s, h, p), dtype)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a_log = rand(ks[2], (h,), jnp.float32) * 0.5
    Bm = rand(ks[3], (b, s, g, n), dtype) * 0.3
    Cm = rand(ks[4], (b, s, g, n), dtype) * 0.3
    ref = ssd_ref.reference(x.astype(jnp.float32), dt, a_log,
                            Bm.astype(jnp.float32),
                            Cm.astype(jnp.float32), chunk=chunk)
    out = ssd_ops.ssd_mixer(x, dt, a_log, Bm, Cm, chunk=chunk,
                            interpret=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32),
                               atol=max(TOL[dtype], 1e-4),
                               rtol=TOL[dtype] * 10)


def test_ssd_kernel_state_continuity_across_chunks():
    """Different chunk sizes must give identical results (state handoff)."""
    ks = jax.random.split(jax.random.PRNGKey(11), 5)
    b, s, h, p, g, n = 1, 128, 2, 8, 1, 16
    x = rand(ks[0], (b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(rand(ks[1], (b, s, h), jnp.float32))
    a_log = rand(ks[2], (h,), jnp.float32) * 0.5
    Bm = rand(ks[3], (b, s, g, n), jnp.float32) * 0.3
    Cm = rand(ks[4], (b, s, g, n), jnp.float32) * 0.3
    o16 = ssd_ops.ssd_mixer(x, dt, a_log, Bm, Cm, chunk=16, interpret=True)
    o64 = ssd_ops.ssd_mixer(x, dt, a_log, Bm, Cm, chunk=64, interpret=True)
    np.testing.assert_allclose(np.asarray(o16), np.asarray(o64), atol=2e-5,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# RG-LRU
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(
    b=st.sampled_from([1, 2]),
    s=st.sampled_from([32, 96, 256]),
    w=st.sampled_from([8, 16, 64]),
    chunk=st.sampled_from([16, 32]),
    steep=st.floats(0.5, 8.0),
)
def test_rglru_kernel_sweep(b, s, w, chunk, steep):
    if s % chunk:
        chunk = 16
    ks = jax.random.split(jax.random.PRNGKey(s + w), 2)
    x = rand(ks[0], (b, s, w), jnp.float32)
    log_a = -jax.nn.softplus(rand(ks[1], (b, s, w), jnp.float32) * steep)
    ref = lru_ref.reference(x, log_a)
    out = lru_ops.rglru_mixer(x, log_a, chunk=chunk, interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-4,
                               rtol=1e-3)


def test_rglru_kernel_steep_decay_no_overflow():
    """Steep decays overflowed the rejected closed-form variant; the
    sequential kernel must stay finite and exact."""
    b, s, w = 1, 512, 8
    x = jnp.ones((b, s, w))
    log_a = jnp.full((b, s, w), -8.0)       # decay ~ e^-8 per step
    out = lru_ops.rglru_mixer(x, log_a, chunk=256, interpret=True)
    assert np.isfinite(np.asarray(out)).all()
    ref = lru_ref.reference(x, log_a)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
