"""Table-4 priority ordering, §6.4 conflict sets, and the metering layer.

The billing invariant under test: two optimizations that contend for the
same resource (one §6.4 conflict set) are never co-billed on one VM, no
matter what a workload enrolls in — and the per-VM meters reconcile exactly
with the cluster's own core-hour integral.
"""
from itertools import combinations

import pytest

from repro.core.pricing import (CONFLICT_SETS, ENROLLED_HINT_KEY, PRICING,
                                PRIORITY, BillingMeter, applicable,
                                applicable_set, billed_set, combined_price)
from repro.sched import Scheduler
from repro.sim.cluster import VM


# -- Table 4 ----------------------------------------------------------------

def test_table4_priority_ordering():
    # exact Table-4 ranks: 0 = highest (on-demand), spare-compute tiers last
    want = ["on_demand", "ma_datacenters", "rightsizing", "oversubscription",
            "auto_scaling", "non_preprovision", "region_agnostic",
            "underclocking", "overclocking", "spot", "harvest"]
    assert sorted(PRIORITY, key=PRIORITY.get) == want
    assert PRIORITY["on_demand"] == 0
    assert [PRIORITY[o] for o in want] == list(range(len(want)))
    # every priced optimization has a priority (the manager base asserts it)
    assert set(PRICING) <= set(PRIORITY)


def test_spot_reclaim_respects_harvest_tier_priority():
    """Table 4: harvest (lowest priority) is reclaimed before spot when
    keep-priorities tie."""
    from repro.core.optimizations import SpotPolicy
    from repro.core.global_manager import GlobalManager
    from repro.sim.cluster import Cluster
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    gm.register_workload("w", {"preemptibility_pct": 50.0})
    cl = Cluster()
    cl.add_server("s0", 64)
    cl.add_vm(VM("vm-a", "w", "s0", 8, spot=True))             # plain spot
    cl.add_vm(VM("vm-b", "w", "s0", 8, spot=True, harvest=True))
    acts = SpotPolicy(gm).reclaim_cores(cl, cores_needed=8)
    assert [a.vm for a in acts] == ["vm-b"]


# -- §6.4 conflict sets -----------------------------------------------------

def test_conflict_sets_cover_shared_resources():
    spare, freq = CONFLICT_SETS
    assert spare == frozenset({"spot", "harvest", "non_preprovision"})
    assert freq == frozenset({"overclocking", "underclocking",
                              "ma_datacenters"})
    for cs in CONFLICT_SETS:
        resources = {PRICING[o].resource for o in cs}
        # members of one set contend for one resource class
        assert len(resources) == 1, resources


def test_applicable_drives_billed_set():
    # hints that make every spare-compute optimization applicable at once
    eff = {"scale_up_down": True, "scale_out_in": True,
           "preemptibility_pct": 80.0, "delay_tolerance_ms": 1000.0,
           "deploy_time_ms": 120_000.0, "availability_nines": 3.0,
           "region_independent": True}
    apps = applicable_set(eff)
    assert {"spot", "harvest", "non_preprovision"} <= set(apps)
    billed = billed_set(apps, eff)
    # cheapest member of each conflict set survives, nothing else from it
    assert "harvest" in billed and "spot" not in billed \
        and "non_preprovision" not in billed
    assert "ma_datacenters" in billed and "overclocking" not in billed
    # applicability filter: an optimization the hints exclude never bills
    assert "rightsizing" not in billed_set(PRICING, {**eff,
                                                     "scale_up_down": False})


def test_billed_set_never_co_bills_a_conflict_set():
    opts = sorted(PRICING)
    for r in (1, 2, 3):
        for subset in combinations(opts, r):
            billed = billed_set(subset)
            for cs in CONFLICT_SETS:
                assert len(set(billed) & cs) <= 1, (subset, billed)
            # collapsing never changes the price the user pays
            assert combined_price(billed) == pytest.approx(
                combined_price(subset))


# -- the metering layer -----------------------------------------------------

def _fleet_sched(**kw):
    s = Scheduler(default_notice_s=30.0, **kw)
    for i in range(4):
        s.cluster.add_server(f"s{i}", 64.0)
    return s


def test_meter_bills_conflict_free_and_reconciles():
    s = _fleet_sched()
    # adversarial enrollment: all three spare-compute optimizations at once
    s.gm.register_workload("spare-heavy", {
        "scale_up_down": True, "scale_out_in": True,
        "preemptibility_pct": 80.0, "delay_tolerance_ms": 1000.0,
        "deploy_time_ms": 120_000.0, "availability_nines": 1.0,
        ENROLLED_HINT_KEY: ["spot", "harvest", "non_preprovision"]})
    s.gm.register_workload("plain", {})
    meter = BillingMeter(s.gm, s.cluster)
    s.submit(VM("v0", "spare-heavy", "", 8.0, spot=True, harvest=True))
    s.submit(VM("v1", "plain", "", 4.0))
    s.schedule_pending()
    s.run_until(3600.0)

    m0, m1 = meter.meters["v0"], meter.meters["v1"]
    assert m0.opts == ("harvest",)          # never co-billed with spot/nonpre
    assert m0.rate == PRICING["harvest"].price_multiplier
    assert m1.opts == () and m1.rate == 1.0
    summary = meter.summary(3600.0)
    assert summary["core_hours"] == pytest.approx(12.0)
    assert summary["cost"] == pytest.approx(8.0 * 0.09 + 4.0 * 1.0)
    rec = meter.reconcile(3600.0)
    assert rec["abs_diff"] < 1e-9
    for m in meter.meters.values():
        for cs in CONFLICT_SETS:
            assert len(set(m.opts) & cs) <= 1


def test_meter_closes_on_eviction_and_survives_pipeline_kill():
    s = _fleet_sched()
    s.gm.register_workload("spotty", {
        "preemptibility_pct": 90.0, "delay_tolerance_ms": 1000.0,
        ENROLLED_HINT_KEY: ["spot"]})
    meter = BillingMeter(s.gm, s.cluster)
    for i in range(4):
        s.submit(VM(f"v{i}", "spotty", "", 8.0, spot=True))
    s.schedule_pending()
    s.engine.at(1800.0, lambda: s.capacity_crunch("region-0", 8.0))
    s.run_until(3600.0)
    killed = [t for t in s.evictor.log if t.outcome == "killed"]
    assert len(killed) == 1
    m = meter.meters[killed[0].vm_id]
    assert not m.open
    # billed exactly up to the kill: notice issued at 1800 + 30 s window
    assert m.core_hours == pytest.approx(8.0 * 1830.0 / 3600.0)
    assert m.cost == pytest.approx(m.core_hours * 0.15)
    assert meter.reconcile(3600.0)["abs_diff"] < 1e-9
    assert len(s.evictor.violations()) == 0


def test_meter_rerates_on_hint_change():
    s = _fleet_sched()
    s.gm.register_workload("w", {"preemptibility_pct": 60.0,
                                 ENROLLED_HINT_KEY: ["spot"]})
    meter = BillingMeter(s.gm, s.cluster)
    s.submit(VM("v0", "w", "", 8.0, spot=True))
    s.schedule_pending()
    s.engine.run(until=1800.0)
    # mid-run the workload drops preemptibility: spot no longer applicable,
    # the meter re-rates to Regular from the change instant
    from repro.core import hints as H
    s.gm.set_hints("w", "*", {"preemptibility_pct": 0.0},
                   scope=H.Scope.DEPLOYMENT, source="deploy-api")
    s.engine.run(until=3600.0)
    m = meter.meters["v0"]
    meter.accrue_all(3600.0)
    assert m.opts == ()
    assert m.cost == pytest.approx(
        8.0 * 0.5 * 0.15 + 8.0 * 0.5 * 1.0)     # half spot, half regular
    assert meter.reconcile(3600.0)["abs_diff"] < 1e-9


def test_meter_tracks_resize_decisions():
    s = _fleet_sched(policy_period_s=60.0, apply_rightsizing=True)
    s.gm.register_workload("sizable", {
        "scale_up_down": True, "availability_nines": 4.0,
        "delay_tolerance_ms": 1000.0, ENROLLED_HINT_KEY: ["rightsizing"]})
    meter = BillingMeter(s.gm, s.cluster)
    s.submit(VM("v0", "sizable", "", 8.0, util_p95=0.3))
    s.schedule_pending()
    s.start(60.0, 3600.0)
    s.run_until(3600.0)
    vm = s.cluster.vms["v0"]
    assert vm.cores == 4.0                  # halved by the rightsizing pass
    assert s.admission.stats["resized"] >= 1
    m = meter.meters["v0"]
    meter.accrue_all(3600.0)
    # meter accrued at 8 cores until the resize decision, 4 after
    assert meter.reconcile(3600.0)["abs_diff"] < 1e-9
    assert m.cores == 4.0
    assert m.rate == PRICING["rightsizing"].price_multiplier
