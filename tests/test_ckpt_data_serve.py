"""Checkpointer (atomicity/async/retention), data pipeline determinism,
serving engine behaviour."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import Checkpointer
from repro.configs.archs import smoke_config
from repro.configs.base import ParallelConfig
from repro.data.pipeline import DataConfig, FileLM, SyntheticLM, make_dataset
from repro.models import model as M
from repro.serve.engine import Request, ServingEngine

PCFG = ParallelConfig(data=1, model=1, attn_impl="dense", fsdp=False,
                      seq_shard_acts=False)


def tree(v=0.0):
    return {"a": jnp.full((4, 3), v), "b": [jnp.arange(5.0) + v,
                                            jnp.zeros((2, 2)) + v]}


def test_checkpoint_roundtrip_and_retention(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3):
        ck.save(s, tree(float(s)), {"step": s})
    assert ck.committed_steps() == [2, 3]      # retention
    got = ck.restore(3, tree())
    np.testing.assert_array_equal(np.asarray(got["a"]),
                                  np.full((4, 3), 3.0))
    assert ck.metadata(3)["step"] == 3


def test_checkpoint_async_and_crash_debris(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save_async(5, tree(5.0))
    ck.wait()
    assert ck.latest_step() == 5
    # uncommitted debris (simulated crash mid-write) is ignored + GC'd
    (tmp_path / "step_9").mkdir()
    (tmp_path / "step_9" / "junk.npy").write_bytes(b"x")
    ck2 = Checkpointer(str(tmp_path))
    assert ck2.latest_step() == 5
    ck2.save(6, tree(6.0))
    assert not (tmp_path / "step_9").exists()


def test_checkpoint_elastic_reshard(tmp_path):
    """Restore with different shardings (device_put path)."""
    ck = Checkpointer(str(tmp_path))
    ck.save(1, tree(2.0))
    shard = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]),
        tree())
    got = ck.restore(1, tree(), shard)
    assert got["a"].sharding == jax.sharding.SingleDeviceSharding(
        jax.devices()[0])


def test_data_determinism_and_elasticity():
    cfg = smoke_config("minitron-8b")
    d1 = SyntheticLM(cfg, batch=8, seq=32, dcfg=DataConfig(seed=7))
    d2 = SyntheticLM(cfg, batch=8, seq=32, dcfg=DataConfig(seed=7))
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"],
                                  d2.batch_at(5)["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"],
                              d1.batch_at(6)["tokens"])
    assert d1.batch_at(0)["tokens"].shape == (8, 33)
    assert d1.batch_at(0)["tokens"].max() < cfg.vocab_size


def test_file_dataset(tmp_path):
    cfg = smoke_config("minitron-8b")
    toks = np.random.default_rng(0).integers(0, 250, size=10_000,
                                             dtype=np.uint16)
    f = tmp_path / "tokens.bin"
    toks.tofile(f)
    ds = make_dataset(cfg, batch=4, seq=16,
                      dcfg=DataConfig(kind="file", path=str(f)))
    b0, b1 = ds.batch_at(0), ds.batch_at(1)
    assert b0["tokens"].shape == (4, 17)
    assert not np.array_equal(b0["tokens"], b1["tokens"])
    np.testing.assert_array_equal(ds.batch_at(0)["tokens"], b0["tokens"])


def test_engine_continuous_batching_and_determinism():
    cfg = smoke_config("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, PCFG, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(1)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size,
                                    size=rng.integers(2, 8)).astype(np.int32),
                    max_new=5) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_drained()
    assert all(r.done and len(r.out_tokens) == 5 for r in reqs)
    # greedy determinism independent of co-scheduled slots
    p = np.arange(6, dtype=np.int32)
    solo = Request(90, p, max_new=4)
    eng.submit(solo)
    eng.run_until_drained()
    e2 = ServingEngine(cfg, PCFG, params, batch_slots=2, max_len=64)
    busy = Request(91, rng.integers(0, cfg.vocab_size, size=7)
                   .astype(np.int32), max_new=12)
    mirrored = Request(92, p, max_new=4)
    e2.submit(busy)
    e2.submit(mirrored)
    e2.run_until_drained()
    assert solo.out_tokens == mirrored.out_tokens


def test_engine_respects_max_len():
    cfg = smoke_config("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, PCFG, params, batch_slots=1, max_len=12)
    r = Request(0, np.arange(6, dtype=np.int32), max_new=50)
    eng.submit(r)
    eng.run_until_drained()
    assert r.done and len(r.out_tokens) <= 12


def test_engine_drain_completes_inflight_and_rejects_new():
    cfg = smoke_config("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, PCFG, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(3)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32), max_new=6) for i in range(3)]
    for r in reqs:
        assert eng.submit(r)
    eng.step_once()                     # 2 in flight, 1 queued
    steps, requeued = eng.drain()
    # queued-but-unstarted work is handed back for rerouting; the modeled
    # drain latency covers the worst in-flight sequence
    assert [r.rid for r in requeued] == [2]
    assert steps > 0
    assert not eng.admitting
    late = Request(9, np.arange(4, dtype=np.int32), max_new=2)
    assert not eng.submit(late)
    assert eng.stats["rejected"] == 1
    eng.run_until_drained()
    assert reqs[0].done and reqs[1].done        # in-flight completed
    assert not reqs[2].done and not late.done   # never admitted here
    assert eng.active_count() == 0 and eng.queue_depth() == 0


def test_engine_resize_preserves_active_sequences():
    """Greedy output must be identical to a solo run across a harvest grow
    and a deferred shrink mid-decode."""
    cfg = smoke_config("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    p = np.arange(6, dtype=np.int32)
    solo = Request(0, p, max_new=8)
    ref = ServingEngine(cfg, PCFG, params, batch_slots=1, max_len=64)
    ref.submit(solo)
    ref.run_until_drained()

    eng = ServingEngine(cfg, PCFG, params, batch_slots=2, max_len=64)
    rng = np.random.default_rng(5)
    tracked = Request(1, p, max_new=8)
    busy = Request(2, rng.integers(0, cfg.vocab_size, size=5)
                   .astype(np.int32), max_new=10)
    eng.submit(tracked)
    eng.submit(busy)
    eng.step_once()
    eng.step_once()
    assert eng.resize_slots(4) == 4             # grow applies immediately
    filler = Request(3, rng.integers(0, cfg.vocab_size, size=3)
                     .astype(np.int32), max_new=4)
    eng.submit(filler)
    eng.step_once()
    eng.resize_slots(1)                         # shrink defers: 2 active
    assert eng.active_count() >= 2
    eng.run_until_drained()
    assert eng.slots == 1                       # shrink landed once free
    assert tracked.out_tokens == solo.out_tokens


def test_engine_freed_slots_refill_fifo():
    cfg = smoke_config("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    eng = ServingEngine(cfg, PCFG, params, batch_slots=1, max_len=64)
    rng = np.random.default_rng(7)
    reqs = [Request(i, rng.integers(0, cfg.vocab_size, size=3)
                    .astype(np.int32), max_new=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    order = []
    for _ in range(200):
        eng.step_once()
        cur = eng._active[0]
        if cur is not None and (not order or cur.rid != order[-1]):
            order.append(cur.rid)
        if all(r.done for r in reqs):
            break
    assert order == [0, 1, 2]           # submit order == service order
    assert all(r.done for r in reqs)
