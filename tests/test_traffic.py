"""Open-loop traffic generator tests: seeded determinism, the
coordinated-omission guard (arrivals never slow down with the server),
profile shape, and latency-percentile parity with ``obs.metrics``.

All jax-free: the generator emits ``serve.engine.Request`` objects but
never touches an engine here.
"""
import numpy as np

from repro import obs
from repro.sim.engine import Engine
from repro.sim.traffic import (OpenLoopTraffic, constant_rate, diurnal_rate,
                               with_spike)


def _run(rate_fn, horizon, seed=0, submit=None, **kw):
    eng = Engine()
    got = []
    t = OpenLoopTraffic(eng, submit or got.append, rate_fn, horizon,
                        seed=seed, **kw)
    t.start()
    eng.run(horizon)
    return t, got


def test_seeded_determinism():
    t1, got1 = _run(constant_rate(5.0), 10.0, seed=42)
    t2, got2 = _run(constant_rate(5.0), 10.0, seed=42)
    assert t1.arrivals == t2.arrivals
    assert [r.max_new for r in got1] == [r.max_new for r in got2]
    assert all(np.array_equal(a.prompt, b.prompt)
               for a, b in zip(got1, got2))
    t3, got3 = _run(constant_rate(5.0), 10.0, seed=43)
    assert [r.max_new for r in got3] != [r.max_new for r in got1] or \
        not all(np.array_equal(a.prompt, b.prompt)
                for a, b in zip(got1, got3))


def test_constant_rate_arrivals_ignore_service_time():
    """Coordinated-omission guard: a stalled server (submit accepted but
    nothing ever completes) must not slow the arrival schedule."""
    t_stalled, _ = _run(constant_rate(10.0), 5.0)       # nothing completes
    eng = Engine()
    done = []

    def fast_server(req):
        # completes instantly and reports back — a closed-loop client
        # would speed up; the open-loop schedule must not care
        req.t_done = eng.clock.t
        done.append(req)

    t_fast = OpenLoopTraffic(eng, fast_server, constant_rate(10.0), 5.0,
                             seed=0)
    t_fast.start()
    eng.run(5.0)
    assert t_fast.arrivals == t_stalled.arrivals
    gaps = np.diff(t_stalled.arrivals)
    assert np.allclose(gaps, 0.1)


def test_diurnal_profile_shape():
    period = 100.0
    rate = diurnal_rate(2.0, 20.0, period)
    assert abs(rate(0.0) - 2.0) < 1e-9          # trough
    assert abs(rate(period / 2) - 20.0) < 1e-9  # peak
    assert abs(rate(period) - 2.0) < 1e-9       # periodic
    t, _ = _run(rate, period, seed=1)
    trough_n = sum(1 for a in t.arrivals if a < period / 4)
    peak_n = sum(1 for a in t.arrivals
                 if period * 3 / 8 <= a < period * 5 / 8)
    assert peak_n > 2 * trough_n


def test_spike_overlay_multiplies_inside_window_only():
    base = constant_rate(5.0)
    rate = with_spike(base, at_s=10.0, dur_s=5.0, mult=4.0)
    assert rate(9.99) == 5.0 and rate(15.0) == 5.0
    assert rate(10.0) == 20.0 and rate(14.99) == 20.0
    t, _ = _run(rate, 30.0, seed=2)
    in_spike = sum(1 for a in t.arrivals if 10.0 <= a < 15.0)
    before = sum(1 for a in t.arrivals if 5.0 <= a < 10.0)
    # ~4x arrivals in-window (edge arrivals sample the pre-spike rate, so
    # the ratio is a touch under the multiplier)
    assert 3 * before <= in_spike <= 4.5 * before


def test_zero_rate_window_pauses_and_recovers():
    rate = lambda t: 0.0 if 2.0 <= t < 6.0 else 10.0
    t, _ = _run(rate, 10.0, idle_step_s=0.5)
    assert not any(2.6 <= a < 6.0 for a in t.arrivals)
    assert any(a >= 6.0 for a in t.arrivals)


def test_latency_percentiles_match_obs_histogram_buckets():
    eng = Engine()
    t = OpenLoopTraffic(eng, lambda r: None, constant_rate(1.0), 1.0)
    ref = obs.MetricsRegistry(enabled=True).histogram("ref")
    lat = np.linspace(0.01, 2.0, 200)
    for i, l in enumerate(lat):
        r = t._make_request(now=0.0)
        r.t_first_token = l / 2
        r.t_done = l
        t.observe_completion(r)
        ref.observe(l)
    s = t.summary()
    assert s["completed"] == 200
    for q, key in ((50, "e2e_p50_s"), (99, "e2e_p99_s")):
        assert abs(s[key] - ref.percentile(q)) < 1e-9
    # same bucket math as the rest of the fleet: monotone and bounded
    assert s["e2e_p50_s"] <= s["e2e_p99_s"] <= 2.0 + 1e-9
    assert s["ttft_p99_s"] <= s["e2e_p99_s"]
