"""Tests for the hint-aware platform scheduler (src/repro/sched/) plus the
bus/engine fixes it depends on (multi-partition poll offsets, bounded-run
clock advance)."""
import random

import pytest

from repro.core import hints as H
from repro.core.bus import Bus
from repro.sched import (AdmissionController, Scheduler, notice_window_s,
                         spread_limit)
from repro.sim.cluster import VM, Cluster
from repro.sim.engine import Engine


def make_scheduler(n_servers=4, cores=32, regions=("region-0",)):
    s = Scheduler()
    for r in regions:
        for i in range(n_servers):
            s.cluster.add_server(f"{r}/s{i}", cores, region=r)
    return s


# ---------------------------------------------------------------------------
# engine + bus satellites
# ---------------------------------------------------------------------------


def test_engine_run_until_advances_clock_when_queue_drains_early():
    e = Engine()
    e.at(3.0, lambda: None)
    e.run(until=100.0)
    assert e.clock.t == 100.0


def test_engine_run_unbounded_stops_at_last_event():
    e = Engine()
    e.at(3.0, lambda: None)
    e.run()
    assert e.clock.t == 3.0


def test_engine_run_leaves_future_events_queued():
    e = Engine()
    seen = []
    e.at(5.0, lambda: seen.append("early"))
    e.at(50.0, lambda: seen.append("late"))
    e.run(until=10.0)
    assert seen == ["early"] and e.clock.t == 10.0
    e.run(until=60.0)
    assert seen == ["early", "late"]


def test_bus_poll_multi_partition_exactly_once():
    bus = Bus(n_partitions=4)
    sent = []
    for i in range(37):
        # keys chosen to hit several partitions; None pins partition 0
        bus.publish("t", i, key=["a", "b", "c", "d", None][i % 5])
        sent.append(i)
    got = []
    while True:
        recs = bus.poll("t", "g", max_records=5)
        if not recs:
            break
        got.extend(r.value for r in recs)
    assert sorted(got) == sent            # no duplicates, no losses
    assert bus.lag("t", "g") == 0


def test_bus_poll_advances_every_partition_in_one_big_poll():
    bus = Bus(n_partitions=4)
    for i in range(20):
        bus.publish("t", i, key=str(i))
    first = bus.poll("t", "g", max_records=100)
    assert len(first) == 20
    assert bus.poll("t", "g", max_records=100) == []


# ---------------------------------------------------------------------------
# placement
# ---------------------------------------------------------------------------


def test_placement_respects_availability_spread():
    s = make_scheduler(n_servers=6)
    s.gm.register_workload("fe", {"availability_nines": 4.0})
    for i in range(5):
        s.submit(VM(f"fe-{i}", "fe", "", 4))
    ds = s.schedule_pending()
    assert all(d.placed for d in ds)
    servers = [d.server for d in ds]
    assert len(set(servers)) == 5         # hard anti-affinity: all distinct


def test_spread_limit_ladder():
    assert spread_limit(5.0) == 1
    assert spread_limit(4.0) == 1
    assert spread_limit(3.0) == 2
    assert spread_limit(2.0) > 1000       # pack freely


def test_placement_region_agnostic_goes_to_cheapest_region():
    s = make_scheduler(n_servers=2, regions=("region-0", "region-green"))
    s.gm.register_workload("flex", {
        "region_independent": True, "availability_nines": 2.0})
    s.gm.register_workload("fixed", {"availability_nines": 2.0})
    s.submit(VM("v-flex", "flex", "", 4))
    s.submit(VM("v-fixed", "fixed", "", 4))
    by_vm = {d.vm_id: d for d in s.schedule_pending()}
    assert by_vm["v-flex"].region == "region-green"    # price 0.78 < 1.0
    assert by_vm["v-fixed"].region == "region-0"       # conservative default


def test_oversubscription_packs_against_p95_headroom():
    s = make_scheduler(n_servers=1, cores=32)
    s.gm.register_workload("burst", {
        "delay_tolerance_ms": 1000.0, "availability_nines": 2.0})
    for i in range(12):                    # 48 nominal cores on a 32-core box
        s.submit(VM(f"b-{i}", "burst", "", 4, util_p95=0.25))
    ds = s.schedule_pending()
    placed = [d for d in ds if d.placed]
    assert len(placed) == 10               # commit cap 1.25x: 40/32 nominal
    assert all(d.oversubscribed for d in placed)
    sid = placed[0].server
    assert s.admission.nominal[sid] > s.cluster.servers[sid].cores
    assert s.cluster.p95_used(sid) <= s.cluster.servers[sid].cores + 1e-9


def test_delay_sensitive_vms_reserve_nominal_cores():
    s = make_scheduler(n_servers=1, cores=32)
    s.gm.register_workload("strict", {"availability_nines": 2.0})
    for i in range(10):
        s.submit(VM(f"s-{i}", "strict", "", 4, util_p95=0.2))
    ds = s.schedule_pending()
    assert sum(d.placed for d in ds) == 8  # 32/4, no oversubscription
    assert not any(d.oversubscribed for d in ds)


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------


def test_admission_rejects_overcommitted_server():
    cl = Cluster()
    cl.add_server("s0", 16)
    adm = AdmissionController(cl, oversub_ratio=1.25)
    big = VM("big", "w", "", 16)
    ok, _ = adm.admit(big, "s0")
    assert ok
    big.server = "s0"
    cl.add_vm(big)
    ok, reason = adm.admit(VM("one-more", "w", "", 1.0), "s0")
    assert not ok and reason == "capacity"
    ok, reason = adm.check(VM("os", "w", "", 8, util_p95=0.1), "s0", True)
    assert not ok and reason == "oversub_commit_cap"


def test_admission_rejects_down_server_and_releases():
    cl = Cluster()
    cl.add_server("s0", 16)
    adm = AdmissionController(cl)
    vm = VM("v", "w", "s0", 8)
    assert adm.admit(vm, "s0")[0]
    cl.servers["s0"].up = False
    assert adm.admit(VM("v2", "w", "", 8), "s0") == (False, "server_down")
    adm.release(vm)
    assert adm.reserved["s0"] == 0.0 and adm.nominal["s0"] == 0.0


# ---------------------------------------------------------------------------
# eviction pipeline
# ---------------------------------------------------------------------------


def test_notice_window_helper():
    assert notice_window_s({}) == 30.0
    assert notice_window_s({"x-eviction-notice-s": 120.0}) == 120.0
    assert notice_window_s({"x-eviction-notice-s": "bogus"}) == 30.0


def test_eviction_notice_honors_hinted_window():
    s = make_scheduler(n_servers=2)
    s.gm.register_workload("sp", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "x-eviction-notice-s": 120.0})
    for i in range(4):
        s.submit(VM(f"sp-{i}", "sp", "", 8, spot=True))
    s.schedule_pending()
    r = s.capacity_crunch("region-0", cores_needed=16)
    assert r["evictions"] == 2
    # notice is on the bus immediately, kill only after the hinted window
    notices = [rec.value for rec in s.gm.bus.poll(H.TOPIC_EVICTIONS, "t", 50)]
    assert [n["event"] for n in notices] == ["notice", "notice"]
    assert all(n["notice_s"] == 120.0 for n in notices)  # > manager's 30s
    s.run_until(119.0)
    assert sum(v.alive for v in s.cluster.vms.values()) == 4   # not yet
    s.run_until(121.0)
    assert sum(v.alive for v in s.cluster.vms.values()) == 2
    assert s.evictor.violations() == []
    assert s.evictor.min_lead_time_s() >= 120.0


def test_eviction_cancel_keeps_vm_alive():
    s = make_scheduler(n_servers=1)
    s.gm.register_workload("sp", {"preemptibility_pct": 80.0,
                                  "availability_nines": 1.0})
    s.submit(VM("sp-0", "sp", "", 8, spot=True))
    s.schedule_pending()
    tickets = s.capacity_crunch("region-0", cores_needed=8)["tickets"]
    assert len(tickets) == 1
    assert s.evictor.cancel("sp-0")
    s.run_until(100.0)
    assert s.cluster.vms["sp-0"].alive
    assert s.evictor.stats["cancellations"] == 1
    assert s.evictor.violations() == []


def test_vm_dead_before_deadline_is_already_gone_not_a_kill():
    s = make_scheduler(n_servers=1)
    s.gm.register_workload("sp", {"preemptibility_pct": 80.0,
                                  "availability_nines": 1.0,
                                  "x-eviction-notice-s": 120.0})
    s.submit(VM("sp-0", "sp", "", 8, spot=True))
    s.schedule_pending()
    assert s.capacity_crunch("region-0", cores_needed=8)["evictions"] == 1
    # the VM dies for unrelated reasons (churn) before the deadline
    s.run_until(10.0)
    s.placer.unplace(s.cluster.vms["sp-0"])
    s.cluster.kill_vm("sp-0")
    s.run_until(200.0)
    # the ladder must not count this as a pipeline kill: no bogus lead time
    # in the violation/min-lead books, a distinct outcome in the log
    assert s.evictor.stats.get("kills", 0) == 0
    assert s.evictor.stats["already_gone"] == 1
    assert s.evictor.log[0].outcome == "already_gone"
    assert not s.evictor.log[0].killed
    assert s.evictor.min_lead_time_s() == float("inf")
    assert s.evictor.violations() == []
    notices = [r.value for r in s.gm.bus.poll(H.TOPIC_EVICTIONS, "t", 50)]
    assert [n["event"] for n in notices] == ["notice", "already_gone"]


def test_power_event_routes_evictions_through_pipeline():
    s = make_scheduler(n_servers=1)
    s.gm.register_workload("pre", {
        "preemptibility_pct": 50.0, "availability_nines": 3.5,
        "x-eviction-notice-s": 60.0})
    s.submit(VM("p-0", "pre", "", 16))
    s.schedule_pending()
    r = s.power_event("region-0/s0", shed_frac=0.9)
    assert r["evictions"] == 1
    s.run_until(61.0)
    assert not s.cluster.vms["p-0"].alive
    # manager promised only 10s; the pipeline stretched it to the hint
    assert s.evictor.log[0].notice_s == 60.0
    assert s.evictor.violations() == []


# ---------------------------------------------------------------------------
# hint reactions, failover, scenarios
# ---------------------------------------------------------------------------


def test_runtime_hint_change_triggers_region_migration():
    s = make_scheduler(n_servers=2, regions=("region-0", "region-green"))
    s.gm.register_workload("w", {"availability_nines": 2.0})
    s.submit(VM("v0", "w", "", 8))
    ds = s.schedule_pending()
    assert ds[0].region == "region-0"      # conservative: region-fixed
    assert s.gm.set_hints("w", "*", {"region_independent": True},
                          scope=H.Scope.DEPLOYMENT, source="owner")
    s.tick()
    assert s.cluster.servers[s.cluster.vms["v0"].server].region == \
        "region-green"
    assert s.stats["hint_migrations"] == 1


def test_region_failover_replaces_flexible_vms():
    s = make_scheduler(n_servers=2, regions=("region-0", "region-green"))
    s.gm.register_workload("flex", {"region_independent": True,
                                    "availability_nines": 2.0})
    s.gm.register_workload("fixed", {"availability_nines": 2.0})
    s.submit(VM("fx", "flex", "", 8))
    s.submit(VM("fd", "fixed", "", 8))
    s.schedule_pending()
    # flex went to region-green; kill that region
    assert s.cluster.servers[s.cluster.vms["fx"].server].region == \
        "region-green"
    s.region_failover("region-green")
    assert s.cluster.servers[s.cluster.vms["fx"].server].region == "region-0"
    assert s.cluster.vms["fx"].alive


def test_eviction_storm_scenario_has_zero_violations():
    from repro.sim.casestudies.eviction_storm import run
    r = run(seed=0)
    assert r["evictions"] > 50
    assert r["violations"] == 0
    assert r["min_lead_s"] >= 30.0
    assert len(r["evictions_by_window"]) >= 2   # heterogeneous windows hit


def test_capacity_crunch_scenario_admits_surge():
    from repro.sim.casestudies.capacity_crunch import run
    r = run(seed=0)
    assert r["placed_before_crunch"] < r["surge_vms"]
    assert r["placed_after_crunch"] == r["surge_vms"]
    assert r["defrag_migrations"] > 0
    assert r["evictions"] > 0
    assert r["eviction_violations"] == 0
    assert r["overcommitted_servers"] == 0


def test_overlapping_crunches_pick_fresh_victims():
    s = make_scheduler(n_servers=2)
    s.gm.register_workload("sp", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "x-eviction-notice-s": 300.0})
    for i in range(8):
        s.submit(VM(f"sp-{i}", "sp", "", 8, spot=True))
    s.schedule_pending()
    r1 = s.capacity_crunch("region-0", cores_needed=16)
    assert r1["evictions"] == 2
    # second wave before the 300s notices mature: must not re-select the
    # already-ticketed VMs (and claim their cores again) — fresh victims
    r2 = s.capacity_crunch("region-0", cores_needed=16)
    assert r2["evictions"] == 2
    assert len(s.evictor.tickets) == 4
    assert s.evictor.stats.get("skipped_already_pending", 0) == 0


def test_hint_migrations_resume_across_ticks_when_over_budget():
    s = make_scheduler(n_servers=8, regions=("region-0", "region-green"))
    s.max_migrations_per_tick = 3
    s.gm.register_workload("w", {"availability_nines": 2.0})
    for i in range(8):
        s.submit(VM(f"v{i}", "w", "", 2))
    s.schedule_pending()
    s.gm.set_hints("w", "*", {"region_independent": True},
                   scope=H.Scope.DEPLOYMENT, source="owner")
    for _ in range(4):      # 8 migrations at 3/tick need 3 ticks
        s.tick()
    regions = {s.cluster.servers[v.server].region
               for v in s.cluster.vms.values() if v.alive}
    assert regions == {"region-green"}


def test_runtime_scope_hint_update_invalidates_placer_cache():
    s = make_scheduler(n_servers=2, regions=("region-0", "region-green"))
    s.gm.register_workload("w", {"availability_nines": 2.0})
    s.submit(VM("v0", "w", "", 8))
    assert s.schedule_pending()[0].region == "region-0"
    # direct-store runtime path: never touches the bus, must still be seen
    assert s.gm.set_hints("w", "*", {"region_independent": True},
                          source="owner")       # default scope = RUNTIME
    s.tick()
    assert s.cluster.servers[s.cluster.vms["v0"].server].region == \
        "region-green"
    s.submit(VM("v1", "w", "", 8))
    assert s.schedule_pending()[0].region == "region-green"


def test_power_event_skips_vms_already_mid_eviction():
    s = make_scheduler(n_servers=1)
    # two workloads so four VMs share the server despite the spread limit
    # (3.5 nines -> max two replicas per workload per server)
    for w in ("sp-a", "sp-b"):
        s.gm.register_workload(w, {
            "preemptibility_pct": 80.0, "availability_nines": 3.5,
            "x-eviction-notice-s": 300.0})
        for i in range(2):
            s.submit(VM(f"{w}-{i}", w, "", 8, spot=True))
    s.schedule_pending()
    assert s.capacity_crunch("region-0", cores_needed=8)["evictions"] == 1
    # power event before the 300s notice matures: must shed its 16 cores
    # from the three *other* VMs, not re-select (and double-count) the
    # already-ticketed one
    r = s.power_event("region-0/s0", shed_frac=0.5)
    assert r["evictions"] == 2
    assert s.evictor.stats.get("skipped_already_pending", 0) == 0


def test_migrate_displaces_to_pending_when_old_server_died():
    s = make_scheduler(n_servers=1, regions=("region-0",))
    s.gm.register_workload("flex", {"region_independent": True,
                                    "availability_nines": 2.0})
    s.submit(VM("fx", "flex", "", 8))
    s.schedule_pending()
    vm = s.cluster.vms["fx"]
    old = vm.server
    s.cluster.servers[old].up = False
    d = s.placer.migrate(vm, exclude_region="region-0")
    # nowhere to go and the old slot is down: VM must not ghost-occupy it
    assert not d.placed and vm.server == ""
    assert vm in s.cluster.pending
    assert s.admission.nominal[old] == 0.0
    assert s.placer.stats["migration_displaced"] == 1


def test_eviction_moots_itself_when_vm_migrates_away():
    s = make_scheduler(n_servers=1, regions=("region-0", "region-green"))
    s.gm.register_workload("sp", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "delay_tolerance_ms": 60_000.0, "x-eviction-notice-s": 300.0})
    s.submit(VM("sp-0", "sp", "", 8, spot=True))
    s.schedule_pending()
    assert s.capacity_crunch("region-0", 8)["evictions"] == 1
    # the workload becomes region-independent and migrates before the kill:
    # the crunched cores are freed already, the eviction must cancel itself
    assert s.gm.set_hints("sp", "*", {"region_independent": True},
                          scope=H.Scope.DEPLOYMENT, source="owner")
    s.tick()
    vm = s.cluster.vms["sp-0"]
    assert s.cluster.servers[vm.server].region == "region-green"
    s.run_until(400.0)
    assert vm.alive                     # not killed on its new server
    assert s.evictor.stats["cancellations"] == 1
    assert s.evictor.violations() == []


def test_dead_vm_in_pending_queue_is_never_placed():
    s = make_scheduler(n_servers=2)
    s.gm.register_workload("w", {"availability_nines": 2.0})
    vm = VM("v0", "w", "", 8)
    s.submit(vm)
    vm.alive = False                    # dies while still queued
    assert s.schedule_pending() == []
    assert s.stats["dropped_dead"] == 1
    assert all(n == 0.0 for n in s.admission.nominal.values())


def test_placer_sees_replicas_of_a_prepopulated_cluster():
    from repro.sim.cluster import Cluster
    cl = Cluster()
    for i in range(3):
        cl.add_server(f"s{i}", 32)
    # two four-nines replicas already running, placed by someone else
    cl.add_vm(VM("old-0", "fe", "s0", 4))
    cl.add_vm(VM("old-1", "fe", "s1", 4))
    s = Scheduler(cluster=cl)
    s.gm.register_workload("fe", {"availability_nines": 4.0})
    s.submit(VM("new-0", "fe", "", 4))
    d = s.schedule_pending()[0]
    assert d.server == "s2"     # anti-affinity vs the pre-existing replicas


# ---------------------------------------------------------------------------
# churn soak
# ---------------------------------------------------------------------------


def _check_invariants(s: Scheduler):
    ratio = s.admission.oversub_ratio
    nominal = {}
    reserved = {}
    for vm in s.cluster.vms.values():
        if not vm.alive or not vm.server:
            continue
        srv = s.cluster.servers[vm.server]
        assert srv.up, f"{vm.vm_id} on down server"
        nominal[vm.server] = nominal.get(vm.server, 0.0) + vm.cores
        reserved[vm.server] = reserved.get(vm.server, 0.0) + (
            vm.cores * vm.util_p95 if vm.oversubscribed
            else vm.cores + vm.harvested)
    for sid, n in nominal.items():
        cores = s.cluster.servers[sid].cores
        assert n <= cores * ratio + 1e-6, f"{sid} over commit cap"
        assert reserved[sid] <= cores + 1e-6, f"{sid} over p95 capacity"
        # admission books match cluster ground truth
        assert abs(s.admission.nominal[sid] - n) < 1e-6
        assert abs(s.admission.reserved[sid] - reserved[sid]) < 1e-6


def test_churn_soak_1k_vms_stays_invariant_clean():
    rng = random.Random(7)
    s = Scheduler()
    for i in range(64):
        s.cluster.add_server(f"s{i}", 64,
                             region="region-0" if i % 2 else "region-green")
    profiles = {
        "fe": {"availability_nines": 4.0},
        "svc": {"availability_nines": 3.0, "delay_tolerance_ms": 1000.0},
        "flex": {"region_independent": True, "availability_nines": 2.0,
                 "scale_out_in": True, "scale_up_down": True,
                 "delay_tolerance_ms": 5000.0},
        "sp": {"preemptibility_pct": 80.0, "availability_nines": 1.0,
               "delay_tolerance_ms": 60_000.0},
    }
    for name, hints in profiles.items():
        for i in range(4):
            s.gm.register_workload(f"{name}-{i}", hints)
    names = [f"{n}-{i}" for n in profiles for i in range(4)]
    total = 0
    for i in range(1000):
        w = names[i % len(names)]
        s.submit(VM(f"vm{i}", w, "", rng.choice((2.0, 4.0, 8.0)),
                    util_p95=rng.uniform(0.1, 0.9),
                    spot=w.startswith("sp")))
        total += 1
    s.schedule_pending()
    _check_invariants(s)
    # churn: waves of kills, crunches, and re-submissions
    for wave in range(5):
        alive = [v for v in s.cluster.vms.values() if v.alive and v.server]
        for vm in rng.sample(alive, 60):
            s.placer.unplace(vm)
            s.cluster.kill_vm(vm.vm_id)
        region = "region-0" if wave % 2 else "region-green"
        s.capacity_crunch(region, cores_needed=100.0)
        for j in range(40):
            w = names[(wave * 40 + j) % len(names)]
            s.submit(VM(f"vm{total}", w, "", rng.choice((2.0, 4.0, 8.0)),
                        util_p95=rng.uniform(0.1, 0.9),
                        spot=w.startswith("sp")))
            total += 1
        s.run_until(s.engine.clock.t + 60.0)
        s.schedule_pending()
        _check_invariants(s)
    assert s.evictor.violations() == []
    t = s.telemetry()
    assert t["eviction_violations"] == 0
    assert t["alive_vms"] + t["pending_vms"] <= total
