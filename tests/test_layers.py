"""Layer-level oracles: pure-JAX flash attention vs dense (fwd+grad, all
variants), SSD chunked vs sequential recurrence, RG-LRU assoc-scan vs
sequential step, MoE dispatch vs dense oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, MoEConfig
from repro.models.layers.attention import dense_attention
from repro.models.layers.flash import flash_attention
from repro.models.layers.moe import moe, moe_dense_oracle, moe_params
from repro.models.layers.rglru import rglru_scan, rglru_step
from repro.models.layers.ssd import ssd_chunked, ssd_recurrent_step


@pytest.mark.parametrize("causal,window,cap,H,K,skip", [
    (True, None, None, 8, 4, False),
    (True, None, None, 8, 4, True),
    (True, 256, None, 8, 8, False),
    (True, None, 50.0, 4, 2, False),
    (False, None, None, 4, 4, False),
    (True, 128, 30.0, 8, 2, False),
])
def test_flash_vs_dense_fwd_and_grad(causal, window, cap, H, K, skip):
    cfg = AttnConfig(causal=causal, window=window, logit_softcap=cap)
    B, S, hd = 2, 512, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    ref = dense_attention(q, k, v, cfg)
    out = flash_attention(q, k, v, cfg, 128, 128, skip)
    assert float(jnp.abs(ref - out).max()) < 2e-5
    gr = jax.grad(lambda *a: (dense_attention(*a, cfg) ** 2).sum(),
                  argnums=(0, 1, 2))(q, k, v)
    gf = jax.grad(lambda *a: (flash_attention(*a, cfg, 128, 128, skip) ** 2)
                  .sum(), argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gr, gf):
        rel = float(jnp.abs(a - b).max() / (jnp.abs(a).max() + 1e-9))
        assert rel < 2e-4


def test_ssd_chunked_vs_sequential():
    B, S, H, P, G, N = 2, 64, 4, 8, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    a_log = jax.random.normal(ks[2], (H,)) * 0.5
    Bm = jax.random.normal(ks[3], (B, S, G, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, S, G, N)) * 0.3
    st = jnp.zeros((B, H, P, N))
    ys = []
    for t in range(S):
        y, st = ssd_recurrent_step(st, x[:, t], dt[:, t], a_log, Bm[:, t],
                                   Cm[:, t])
        ys.append(y)
    yref = jnp.stack(ys, 1)
    for chunk in (8, 16, 64):
        y, fin = ssd_chunked(x, dt, a_log, Bm, Cm, chunk)
        assert float(jnp.abs(y - yref).max()) < 1e-3, chunk
        assert float(jnp.abs(fin.reshape(B, H, P, N) - st).max()) < 1e-4


def test_rglru_scan_vs_step_with_init():
    B, S, W = 2, 33, 16
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    xg = jax.random.normal(ks[0], (B, S, W))
    log_a = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, W)))
    h0 = jax.random.normal(ks[2], (B, W))
    _, fin = rglru_scan(xg, log_a, init_h=h0)
    st = h0
    for t in range(S):
        st, _ = rglru_step(st, xg[:, t], log_a[:, t])
    assert float(jnp.abs(fin - st).max()) < 1e-4


def test_moe_matches_oracle_and_subsets():
    mcfg = MoEConfig(n_experts=4, top_k=2, expert_d_ff=32,
                     capacity_factor=2.0)
    p = moe_params(64, mcfg, jnp.float32, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 16, 64))
    out, aux = moe(p, x, mcfg)
    ref = moe_dense_oracle(p, x, mcfg)
    assert float(jnp.abs(out - ref).max()) < 1e-5
    assert float(aux) > 0
    out2, _ = moe(p, x[:, :8], mcfg)
    assert float(jnp.abs(out2 - out[:, :8]).max()) < 1e-5


def test_moe_capacity_drops_tokens():
    mcfg = MoEConfig(n_experts=4, top_k=2, expert_d_ff=32,
                     capacity_factor=0.05)  # tiny capacity forces drops
    p = moe_params(32, mcfg, jnp.float32, jax.random.PRNGKey(1))
    x = jax.random.normal(jax.random.PRNGKey(6), (4, 64, 32))
    out, _ = moe(p, x, mcfg)
    ref = moe_dense_oracle(p, x, mcfg)
    assert jnp.isfinite(out).all()
    # dropped tokens produce zero output -> must differ from the oracle
    assert float(jnp.abs(out - ref).max()) > 1e-3
