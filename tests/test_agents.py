"""Tests for the workload-side agent runtime (src/repro/agents/) and the
ack -> early-release -> cancel path it drives through the eviction
pipeline, plus the local-manager churn-hygiene fixes that ride along."""
import random

from repro.agents import (PARTIAL, STATEFUL, STATELESS, AgentPolicy,
                          AgentRuntime, DiurnalProfile)
from repro.core import hints as H
from repro.core.bus import Bus
from repro.core.local_manager import LocalManager
from repro.sched import Scheduler
from repro.sim.cluster import VM


def make_sched(n_servers=2, cores=32, regions=("region-0",)):
    s = Scheduler(default_notice_s=30.0)
    for r in regions:
        for i in range(n_servers):
            s.cluster.add_server(f"{r}/s{i}", cores, region=r)
    return s


def submit_and_place(s, vm):
    s.submit(vm)
    s.schedule_pending()


# ---------------------------------------------------------------------------
# ack -> early release -> cancel (the platform half of the loop)
# ---------------------------------------------------------------------------


def test_stateless_agent_acks_and_vm_is_released_before_deadline():
    s = make_sched()
    s.gm.register_workload("web", {
        "scale_out_in": True, "preemptibility_pct": 70.0,
        "availability_nines": 2.0, "delay_tolerance_ms": 5_000.0})
    submit_and_place(s, VM("v0", "web", "", 8, spot=True))
    rt = AgentRuntime(s, policies={
        "web": AgentPolicy(statefulness=STATELESS, scale_out_in=True)})
    r = s.capacity_crunch("region-0", 8)
    assert r["evictions"] == 1
    # the ack raced the ticket (manager pre-notice) and was still honored:
    # the VM is gone immediately, long before the 30 s deadline
    assert not s.cluster.vms["v0"].alive
    assert s.evictor.stats["early_releases"] == 1
    assert s.evictor.log[0].outcome == "early_released"
    assert s.evictor.violations() == []         # consent, not a violation
    # its capacity is actually free again
    sid = s.evictor.log[0].resource.rsplit("/", 1)[0]
    assert s.admission.nominal[sid] == 0.0
    # the ladder kill at the deadline is a no-op
    s.run_until(100.0)
    assert s.evictor.stats["kills"] == 0
    # a replacement VM was requested and lands on the next tick
    assert rt.metrics["replacements_requested"] == 1
    s.tick()
    assert rt.metrics["replacements_placed"] == 1
    assert sum(1 for v in s.cluster.vms.values()
               if v.alive and v.workload == "web") == 1


def test_stateful_agent_checkpoints_then_drains_with_zero_lost_work():
    s = make_sched()
    s.gm.register_workload("batch", {
        "preemptibility_pct": 60.0, "availability_nines": 2.0,
        "delay_tolerance_ms": 30_000.0, "x-eviction-notice-s": 120.0})
    submit_and_place(s, VM("b0", "batch", "", 8, spot=True))
    # 8 GB at 0.2 GB/s -> 40 s checkpoint, well inside the 120 s window
    rt = AgentRuntime(s, policies={
        "batch": AgentPolicy(statefulness=STATEFUL, state_gb=8.0,
                             ckpt_gbps=0.2)})
    s.capacity_crunch("region-0", 8)
    s.run_until(39.0)
    assert s.cluster.vms["b0"].alive            # still checkpointing
    s.run_until(41.0)
    assert not s.cluster.vms["b0"].alive        # drained right after
    t = s.evictor.log[0]
    assert t.outcome == "early_released"
    assert abs(t.lead_time_s - 40.0) < 1e-6     # released at ckpt completion
    assert rt.metrics["checkpoints_completed"] == 1
    assert rt.metrics["lost_work_s"] == 0.0     # checkpoint was durable
    assert s.evictor.violations() == []


def test_stateful_agent_slow_checkpoint_rides_ladder_and_loses_work():
    s = make_sched()
    s.gm.register_workload("batch", {
        "preemptibility_pct": 60.0, "availability_nines": 2.0,
        "delay_tolerance_ms": 30_000.0, "x-eviction-notice-s": 60.0})
    submit_and_place(s, VM("b0", "batch", "", 8, spot=True))
    # 30 GB at 0.2 GB/s -> 150 s checkpoint, longer than the 60 s window
    rt = AgentRuntime(s, policies={
        "batch": AgentPolicy(statefulness=STATEFUL, state_gb=30.0,
                             ckpt_gbps=0.2)})
    s.run_until(10.0)                           # accrue some work first
    s.capacity_crunch("region-0", 8)
    s.run_until(200.0)
    t = s.evictor.log[0]
    assert t.outcome == "killed"                # deadline won
    assert abs(t.lead_time_s - 60.0) < 1e-6     # full hinted window honored
    assert s.evictor.violations() == []
    # everything since attach (t=0) was lost at the t=70 kill
    assert abs(rt.metrics["lost_work_s"] - 70.0) < 1e-6


def test_agent_sheds_load_on_throttle_notice():
    s = make_sched(n_servers=1)
    s.gm.register_workload("vc", {
        "scale_up_down": True, "availability_nines": 3.0,
        "delay_tolerance_ms": 1_000.0})
    submit_and_place(s, VM("v0", "vc", "", 8, util_p95=0.8))
    rt = AgentRuntime(s, policies={
        "vc": AgentPolicy(statefulness=PARTIAL, state_gb=1.0)})
    r = s.power_event("region-0/s0", shed_frac=0.9)
    assert r["throttles"] == 1
    assert rt.metrics["shed_reactions"] == 1
    vm = s.cluster.vms["v0"]
    assert vm.util_p95 < 0.8                    # demand actually dropped
    # and the cluster's incremental books followed the shed
    s.cluster.assert_consistent()
    # the low keep-priority runtime hint reached the store
    eff = s.gm.effective_hints("vc", "region-0/s0/v0")
    assert eff["x-preemption-priority"] == 5.0


def test_shed_on_oversubscribed_vm_keeps_admission_books_exact():
    s = make_sched(n_servers=1)
    s.gm.register_workload("vc", {
        "scale_up_down": True, "availability_nines": 2.0,
        "delay_tolerance_ms": 1_000.0})
    submit_and_place(s, VM("v0", "vc", "", 8, util_p95=0.5))
    assert s.cluster.vms["v0"].oversubscribed
    rt = AgentRuntime(s, policies={"vc": AgentPolicy(statefulness=PARTIAL)})
    sid = s.cluster.vms["v0"].server
    s.power_event(sid, shed_frac=0.9)
    vm = s.cluster.vms["v0"]
    assert vm.util_p95 < 0.5
    # the admission reservation followed the shed: no phantom capacity
    assert abs(s.admission.reserved[sid] - vm.cores * vm.util_p95) < 1e-9
    # ...so a later release returns the books exactly to zero
    s.placer.unplace(vm)
    s.cluster.kill_vm("v0")
    assert s.admission.reserved[sid] == 0.0
    assert s.admission.nominal[sid] == 0.0


def test_diurnal_leader_adapts_hints_and_scheduler_replaces():
    s = make_sched(n_servers=2, regions=("region-0", "region-green"))
    s.gm.register_workload("bd", {
        "scale_out_in": True, "availability_nines": 2.0,
        "delay_tolerance_ms": 30_000.0})
    submit_and_place(s, VM("v0", "bd", "", 8))
    assert s.cluster.servers[s.cluster.vms["v0"].server].region == "region-0"
    rt = AgentRuntime(s, policies={"bd": AgentPolicy(
        statefulness=STATEFUL, state_gb=1.0,
        diurnal=DiurnalProfile(
            peak_hints={"region_independent": False},
            offpeak_hints={"region_independent": True,
                           "preemptibility_pct": 80.0}))})
    rt.set_phase("offpeak")
    assert rt.metrics["hint_adaptations"] >= 1
    # the workload-wide runtime hint is visible at workload granularity
    assert s.gm.effective_hints("bd")["region_independent"] is True
    s.tick()            # dirty workload -> re-placement to the cheap region
    assert s.cluster.servers[s.cluster.vms["v0"].server].region == \
        "region-green"
    assert s.stats["hint_migrations"] == 1


def test_agent_rebinds_endpoint_after_migration():
    s = make_sched(n_servers=1, regions=("region-0", "region-green"))
    s.gm.register_workload("flex", {
        "region_independent": True, "availability_nines": 2.0})
    submit_and_place(s, VM("v0", "flex", "", 8))
    rt = AgentRuntime(s, policies={"flex": AgentPolicy()})
    agent = rt.agents["v0"]
    old_server = agent.server_id
    assert s.cluster.servers[old_server].region == "region-green"
    s.region_failover("region-green")
    assert rt.agents["v0"] is agent             # same agent, new endpoint
    assert agent.server_id != old_server
    assert s.cluster.servers[agent.server_id].region == "region-0"
    # the old server's local manager no longer routes to the stale endpoint
    assert "v0" not in rt.local(old_server)._vms
    assert rt.metrics["agents_rebound"] == 1


def test_stale_checkpoint_timer_cannot_ack_a_later_ticket():
    s = make_sched(n_servers=1)
    s.gm.register_workload("bd", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "x-eviction-notice-s": 200.0})
    submit_and_place(s, VM("v0", "bd", "", 8, spot=True))
    rt = AgentRuntime(s, policies={"bd": AgentPolicy(
        statefulness=STATEFUL, state_gb=16.0, ckpt_gbps=0.2)})  # 80 s ckpt
    s.capacity_crunch("region-0", 8)    # ckpt #1 timer fires at t=80
    s.run_until(10.0)
    assert s.evictor.cancel("v0")       # capacity recovered, agent re-arms
    s.run_until(20.0)
    s.capacity_crunch("region-0", 8)    # ckpt #2 runs t=20..100
    s.run_until(99.0)
    # the stale t=80 timer must NOT have acked ticket #2: checkpoint #2 is
    # not durable yet, so the VM must still be running
    assert s.cluster.vms["v0"].alive
    s.run_until(101.0)
    assert not s.cluster.vms["v0"].alive
    t = s.evictor.log[-1]
    assert t.outcome == "early_released"
    assert abs(t.killed_t - 100.0) < 1e-6   # released at ckpt #2 completion
    assert rt.metrics["lost_work_s"] == 0.0
    assert s.evictor.violations() == []


def test_cancelled_eviction_rearms_agent_for_the_next_notice():
    s = make_sched(n_servers=1)
    s.gm.register_workload("bd", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0,
        "x-eviction-notice-s": 100.0})
    submit_and_place(s, VM("v0", "bd", "", 8, spot=True))
    rt = AgentRuntime(s, policies={"bd": AgentPolicy(
        statefulness=STATEFUL, state_gb=30.0, ckpt_gbps=0.1)})  # 300 s ckpt
    s.capacity_crunch("region-0", 8)
    agent = rt.agents["v0"]
    assert agent.draining
    assert s.evictor.cancel("v0")               # capacity recovered
    assert not agent.draining                   # re-armed
    s.capacity_crunch("region-0", 8)            # a fresh wave
    assert agent.draining
    assert rt.metrics["eviction_notices_seen"] == 2


def test_only_the_designated_workload_manager_may_set_workload_wide_hints():
    bus = Bus()
    lm = LocalManager("s0", bus, vm_hint_rate_per_s=100, vm_hint_burst=100)
    mgr = lm.attach_vm("v0", "w", workload_manager=True)
    peer = lm.attach_vm("v1", "w")
    assert mgr.set_runtime_hints({"preemptibility_pct": 80.0},
                                 workload_wide=True)
    assert not peer.set_runtime_hints({"preemptibility_pct": 100.0},
                                      workload_wide=True)
    assert lm.stats["vm_hint_unauthorized"] == 1
    assert peer.set_runtime_hints({"preemptibility_pct": 10.0})  # own VM ok
    # host-side promotion (leader re-election) unlocks the channel
    lm.authorize_workload_manager("v1")
    assert peer.set_runtime_hints({"preemptibility_pct": 50.0},
                                  workload_wide=True)


def test_leader_reelection_promotes_next_agents_endpoint():
    s = make_sched(n_servers=1)
    s.gm.register_workload("bd", {
        "preemptibility_pct": 80.0, "availability_nines": 1.0})
    prof = DiurnalProfile(peak_hints={"preemptibility_pct": 20.0},
                          offpeak_hints={"preemptibility_pct": 80.0})
    pol = AgentPolicy(statefulness=STATELESS, scale_out_in=False,
                      diurnal=prof)
    s.submit(VM("v0", "bd", "", 4, spot=True))
    s.submit(VM("v1", "bd", "", 4, spot=True))
    s.schedule_pending()
    rt = AgentRuntime(s, policies={"bd": pol})
    assert rt.is_leader(rt.agents["v0"])
    s.placer.unplace(s.cluster.vms["v0"])
    s.cluster.kill_vm("v0")                     # leader dies
    assert rt.is_leader(rt.agents["v1"])
    rt.set_phase("offpeak")                     # new leader can adapt hints
    assert rt.metrics["hint_adaptations"] >= 1
    assert s.gm.effective_hints("bd")["preemptibility_pct"] == 80.0


def test_dead_vm_hint_state_is_purged_from_spot_manager_and_store():
    s = make_sched(n_servers=1)
    s.gm.register_workload("web", {
        "scale_out_in": True, "preemptibility_pct": 70.0,
        "availability_nines": 2.0, "delay_tolerance_ms": 5_000.0})
    submit_and_place(s, VM("v0", "web", "", 8, spot=True))
    AgentRuntime(s, policies={
        "web": AgentPolicy(statefulness=STATELESS, scale_out_in=True)})
    sid = s.cluster.vms["v0"].server
    resource = f"{sid}/v0"
    # a runtime hint lands per-resource in the spot manager and the store
    s.power_event(sid, shed_frac=0.1)           # no evictions, one throttle
    assert resource in s.spot.priority_hint
    assert s.gm.store.get(f"hints/runtime/web/{resource}") is not None
    s.capacity_crunch("region-0", 8)            # agent acks -> early release
    assert not s.cluster.vms["v0"].alive
    # per-resource state died with the VM
    assert resource not in s.spot.priority_hint
    assert s.gm.store.get(f"hints/runtime/web/{resource}") is None


# ---------------------------------------------------------------------------
# local-manager churn hygiene (the leak fixes)
# ---------------------------------------------------------------------------


def test_detach_vm_purges_limiter_and_ack_state():
    bus = Bus()
    lm = LocalManager("s0", bus, vm_hint_rate_per_s=100, vm_hint_burst=100)
    ep = lm.attach_vm("v0", "w")
    assert ep.set_runtime_hints({"scale_out_in": True})
    ep._deliver({"event": "eviction_notice", "seq": 7})
    ep.ack_event(7)
    assert ("v0",) in lm._limiter._state
    assert lm.acked(7) == {"v0"}
    lm.detach_vm("v0")
    assert ("v0",) not in lm._limiter._state
    assert lm.acked(7) == set()
    assert 7 not in lm._acks and "v0" not in lm._vm_acks


def test_local_manager_churn_soak_state_stays_bounded():
    bus = Bus()
    lm = LocalManager("s0", bus, vm_hint_rate_per_s=1e6, vm_hint_burst=1e6)
    rng = random.Random(3)
    for i in range(2000):
        vm_id = f"v{i}"
        ep = lm.attach_vm(vm_id, f"w{i % 7}")
        ep.set_runtime_hints({"preemptibility_pct": float(i % 100)})
        for j in range(rng.randrange(0, 4)):
            seq = i * 10 + j
            ep._deliver({"event": "eviction_notice", "seq": seq})
            ep.ack_event(seq)
        lm.detach_vm(vm_id)
    # after full churn NOTHING per-VM may survive
    assert len(lm._vms) == 0
    assert len(lm._limiter._state) == 0
    assert len(lm._acks) == 0
    assert len(lm._vm_acks) == 0


def test_endpoint_acked_set_is_bounded_by_the_event_buffer():
    bus = Bus()
    lm = LocalManager("s0", bus)
    ep = lm.attach_vm("v0", "w")
    for seq in range(1000):                     # 4x the 256-deep ring
        ep._deliver({"event": "eviction_notice", "seq": seq})
        ep.ack_event(seq)
    assert len(ep._events) == 256
    assert len(ep._acked) <= 256                # old seqs fell off with ring
    assert ep.scheduled_events() == []          # everything visible is acked
    # acks for seqs the ring never held (or that expired) are ignored, so
    # they cannot grow _acked either
    acked_before = len(ep._acked)
    ep.ack_event(10_000)
    ep.ack_event(3)                             # long expired
    assert len(ep._acked) == acked_before
    assert lm.stats["events_acked"] == 1000


def test_ack_event_is_idempotent():
    bus = Bus()
    lm = LocalManager("s0", bus)
    ep = lm.attach_vm("v0", "w")
    ep._deliver({"event": "eviction_notice", "seq": 1})
    ep.ack_event(1)
    ep.ack_event(1)
    assert lm.stats["events_acked"] == 1


# ---------------------------------------------------------------------------
# the full scenario
# ---------------------------------------------------------------------------


def test_diurnal_agents_scenario_meets_acceptance_bars():
    from repro.sim.casestudies.diurnal_agents import run
    r = run(seed=0, n_servers_per_region=20, vm_scale=0.6)
    assert r["violations"] == 0
    resolved = r["evictions_killed"] + r["early_releases"]
    assert resolved > 20
    assert r["early_release_frac"] >= 0.3
    assert r["lost_work_s_stateless"] == 0.0
    assert r["stateless_killed_without_ack"] == 0
    assert r["replacements_placed"] > 0
    assert r["replacement_lead_s_mean"] > 0.0   # replacements beat the kill
    assert r["hint_adaptations"] > 0
    assert r["hint_migrations"] > 0             # diurnal hints moved VMs
