"""Incremental cluster accounting + batched placement + bus batching.

The core invariant of the PR-2 perf work: after ANY sequence of cluster
mutations (enqueue/place/kill/harvest/fail_server/...), the incremental
``free_cores`` / ``p95_used`` counters and the cached ``view()`` equal the
from-scratch recompute.  Exercised three ways: a deterministic random-ops
soak (always runs), a hypothesis property test (skips without hypothesis),
and a batch-vs-per-VM placement parity check.
"""
import random

import pytest

from repro.core.bus import Bus
from repro.sched import Scheduler
from repro.sim.cluster import VM, Cluster, Region


def assert_books_match(cl: Cluster):
    truth = cl.recompute()
    for sid in cl.servers:
        assert cl.free_cores(sid) == pytest.approx(
            cl.servers[sid].cores - truth["used"][sid], abs=1e-6), sid
        assert cl.p95_used(sid) == pytest.approx(
            truth["p95_used"][sid], abs=1e-6), sid
    cl.assert_consistent()


def build_cluster(n_servers=8, cores=32.0):
    cl = Cluster()
    for i in range(n_servers):
        cl.add_server(f"s{i}", cores,
                      region="region-0" if i % 2 else "region-green")
    return cl


# ---------------------------------------------------------------------------
# incremental counters == recompute
# ---------------------------------------------------------------------------


def _apply_random_ops(cl: Cluster, rng: random.Random, n_ops: int):
    vm_seq = [0]
    def op_place():
        vm = VM(f"v{vm_seq[0]}", f"w{rng.randrange(4)}",
                rng.choice(list(cl.servers)), rng.choice((2.0, 4.0, 8.0)),
                util_p95=rng.uniform(0.05, 0.95),
                oversubscribed=rng.random() < 0.3)
        vm_seq[0] += 1
        cl.add_vm(vm)
    def op_enqueue():
        vm = VM(f"v{vm_seq[0]}", "wq", "", 4.0)
        vm_seq[0] += 1
        cl.enqueue(vm)
    def op_kill():
        if cl.vms:
            cl.kill_vm(rng.choice(list(cl.vms)))
    def op_remove():
        if cl.vms:
            cl.remove_vm(rng.choice(list(cl.vms)))
    def op_harvest():
        alive = [v for v in cl.vms.values() if v.alive and v.server]
        if alive:
            rng.choice(alive).harvested = rng.uniform(0.0, 4.0)
    def op_util():
        alive = [v for v in cl.vms.values() if v.alive and v.server]
        if alive:
            rng.choice(alive).util_p95 = rng.uniform(0.05, 0.95)
    def op_oversub_flip():
        alive = [v for v in cl.vms.values() if v.alive and v.server]
        if alive:
            vm = rng.choice(alive)
            vm.oversubscribed = not vm.oversubscribed
    def op_move():
        alive = [v for v in cl.vms.values() if v.alive and v.server]
        if alive:
            rng.choice(alive).server = rng.choice(list(cl.servers))
    def op_unplace():
        alive = [v for v in cl.vms.values() if v.alive and v.server]
        if alive:
            rng.choice(alive).server = ""
    def op_fail_server():
        cl.fail_server(rng.choice(list(cl.servers)))
    ops = (op_place, op_place, op_enqueue, op_kill, op_remove, op_harvest,
           op_util, op_oversub_flip, op_move, op_unplace, op_fail_server)
    for _ in range(n_ops):
        rng.choice(ops)()


def test_incremental_counters_survive_random_ops_soak():
    for seed in range(8):
        rng = random.Random(seed)
        cl = build_cluster()
        _apply_random_ops(cl, rng, 300)
        assert_books_match(cl)
        # the cached view agrees with a fresh cluster's full rebuild
        view = cl.view()
        for sid in cl.servers:
            assert view["servers"][sid]["free_cores"] == pytest.approx(
                cl.free_cores(sid), abs=1e-6)
        alive = {v.vm_id for v in cl.vms.values() if v.alive}
        assert set(view["vms"]) == alive


def test_incremental_counters_random_ops_property():
    """Hypothesis variant of the soak (skips cleanly without hypothesis)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(min_value=0, max_value=2**32 - 1),
               n_ops=st.integers(min_value=1, max_value=120))
    @hyp.settings(max_examples=40, deadline=None)
    def run(seed, n_ops):
        cl = build_cluster(n_servers=4)
        _apply_random_ops(cl, random.Random(seed), n_ops)
        assert_books_match(cl)
        view = cl.view()
        assert set(view["vms"]) == {v.vm_id for v in cl.vms.values()
                                    if v.alive}

    run()


def test_view_delta_patch_tracks_mutations():
    cl = build_cluster(n_servers=2)
    vm = VM("a", "w", "s0", 8.0, util_p95=0.5)
    cl.add_vm(vm)
    v1 = cl.view()
    assert v1["servers"]["s0"]["free_cores"] == 24.0
    assert v1["vms"]["a"]["harvested"] == 0.0
    # direct field mutation must invalidate the cached entries
    vm.harvested = 4.0
    v2 = cl.view()
    assert v2 is v1                     # same cached snapshot object
    assert v2["servers"]["s0"]["free_cores"] == 20.0
    assert v2["vms"]["a"]["harvested"] == 4.0
    cl.kill_vm("a")
    assert "a" not in cl.view()["vms"]
    assert cl.view()["servers"]["s0"]["free_cores"] == 32.0
    cl.servers["s1"].up = False
    assert cl.view()["servers"]["s1"]["up"] is False
    # region price changes re-render the regions block
    cl.regions["region-0"].price = 0.5
    assert cl.view()["regions"]["region-0"]["price"] == 0.5
    cl.add_region(Region("region-x", 0.1, 10.0))
    assert "region-x" in cl.view()["regions"]


def test_vms_on_uses_index_and_matches_scan():
    cl = build_cluster(n_servers=3)
    for i in range(9):
        cl.add_vm(VM(f"v{i}", "w", f"s{i % 3}", 2.0))
    cl.kill_vm("v3")
    got = sorted(v.vm_id for v in cl.vms_on("s0"))
    want = sorted(v.vm_id for v in cl.vms.values()
                  if v.alive and v.server == "s0")
    assert got == want
    assert cl.vm_ids_on("s1") == {"v1", "v4", "v7"}


# ---------------------------------------------------------------------------
# batched placement parity
# ---------------------------------------------------------------------------


def _mixed_scheduler_and_vms(n_vms=400, seed=5):
    s = Scheduler(publish_decisions=False)
    for i in range(48):
        s.cluster.add_server(
            f"s{i}", 32, region="region-0" if i % 2 else "region-green")
    profiles = {
        "fe": {"availability_nines": 4.0},
        "svc": {"availability_nines": 3.0, "delay_tolerance_ms": 1000.0},
        "flex": {"region_independent": True, "availability_nines": 2.0,
                 "scale_out_in": True, "scale_up_down": True,
                 "delay_tolerance_ms": 5000.0},
        "sp": {"preemptibility_pct": 80.0, "availability_nines": 1.0,
               "delay_tolerance_ms": 60_000.0},
    }
    for name, hints in profiles.items():
        s.gm.register_workload(name, hints)
    rng = random.Random(seed)
    names = list(profiles)
    vms = [VM(f"vm{i}", names[i % len(names)], "",
              rng.choice((2.0, 4.0, 8.0)), util_p95=rng.uniform(0.1, 0.9),
              spot=rng.random() < 0.2)
           for i in range(n_vms)]
    return s, vms


def test_place_batch_matches_per_vm_placement_when_capacity_suffices():
    """With room for everyone, batch and per-VM placement both place all
    VMs (exact parity; under saturation, packing order may change *which*
    VMs win, so exact counts are only comparable below saturation).  88
    VMs -> 22 per workload, within fe's 24 hard-anti-affinity slots."""
    s1, vms1 = _mixed_scheduler_and_vms(n_vms=88)
    for vm in vms1:
        s1.submit(vm)
    batch_ds = s1.schedule_pending()

    s2, vms2 = _mixed_scheduler_and_vms(n_vms=88)
    order = sorted(range(len(vms2)), key=lambda i: vms2[i].cores,
                   reverse=True)
    per_vm_ds = [s2.placer.place(vms2[i]) for i in order]
    assert sum(d.placed for d in batch_ds) == len(vms1)
    assert sum(d.placed for d in per_vm_ds) == len(vms2)
    for s in (s1, s2):
        s.cluster.assert_consistent()


def test_place_batch_counts_and_invariants_under_saturation():
    s1, vms1 = _mixed_scheduler_and_vms()
    for vm in vms1:
        s1.submit(vm)
    batch_ds = s1.schedule_pending()        # batch path

    s2, vms2 = _mixed_scheduler_and_vms()
    order = sorted(range(len(vms2)), key=lambda i: vms2[i].cores,
                   reverse=True)
    per_vm_ds = [s2.placer.place(vms2[i]) for i in order]

    # saturation: packing order shifts who wins, but the batch path must
    # not pack materially worse than sticky first-fit
    assert sum(d.placed for d in batch_ds) >= \
        0.9 * sum(d.placed for d in per_vm_ds)
    for s in (s1, s2):
        s.cluster.assert_consistent()
        ratio = s.admission.oversub_ratio
        for sid, srv in s.cluster.servers.items():
            assert s.admission.nominal[sid] <= srv.cores * ratio + 1e-6
            assert s.admission.reserved[sid] <= srv.cores + 1e-6
            assert s.cluster.p95_used(sid) <= srv.cores + 1e-6
    # admission books equal cluster ground truth on the batch path
    truth = s1.cluster.recompute()
    for sid in s1.cluster.servers:
        assert s1.admission.reserved[sid] <= s1.cluster.servers[sid].cores \
            + 1e-6
        nominal_truth = sum(v.cores for v in s1.cluster.vms.values()
                            if v.alive and v.server == sid)
        assert s1.admission.nominal[sid] == pytest.approx(nominal_truth,
                                                          abs=1e-6)
    assert truth is not None


def test_place_batch_respects_anti_affinity_and_oversubscription():
    s = Scheduler(publish_decisions=False)
    for i in range(8):
        s.cluster.add_server(f"s{i}", 32)
    s.gm.register_workload("fe", {"availability_nines": 4.0})
    s.gm.register_workload("burst", {"delay_tolerance_ms": 1000.0,
                                     "availability_nines": 2.0})
    for i in range(6):
        s.submit(VM(f"fe-{i}", "fe", "", 4))
    for i in range(12):
        s.submit(VM(f"b-{i}", "burst", "", 4, util_p95=0.25))
    ds = s.schedule_pending()
    fe = [d for d in ds if d.workload == "fe"]
    assert all(d.placed for d in fe)
    assert len({d.server for d in fe}) == 6      # hard spread
    burst = [d for d in ds if d.workload == "burst" and d.placed]
    assert burst and all(d.oversubscribed for d in burst)
    s.cluster.assert_consistent()


# ---------------------------------------------------------------------------
# bus: publish_batch, poll fast path, durable handles
# ---------------------------------------------------------------------------


def test_publish_batch_matches_sequential_publish_semantics():
    b1, b2 = Bus(n_partitions=4), Bus(n_partitions=4)
    items = [(f"k{i % 5}", {"i": i}) for i in range(40)]
    acks1 = [b1.publish("t", v, key=k) for k, v in items]
    acks2 = b2.publish_batch("t", items)
    assert acks1 == acks2
    assert b1.end_offsets("t") == b2.end_offsets("t")
    r1 = [(r.partition, r.offset, r.key, r.value)
          for r in b1.poll("t", "g", 1000)]
    r2 = [(r.partition, r.offset, r.key, r.value)
          for r in b2.poll("t", "g", 1000)]
    assert r1 == r2


def test_publish_batch_delivers_to_push_subscribers_in_order():
    bus = Bus(n_partitions=2)
    seen = []
    bus.subscribe("t", lambda rec: seen.append(rec.value))
    bus.publish_batch("t", [(str(i), i) for i in range(10)])
    assert seen == list(range(10))


def test_poll_fast_path_drains_huge_backlog_exactly_once():
    bus = Bus(n_partitions=4)
    n = 5000
    for i in range(n):
        bus.publish("t", i, key=str(i % 7))
    got = []
    while True:
        recs = bus.poll("t", "g", max_records=1999)
        if not recs:
            break
        got.extend(r.value for r in recs)
    assert sorted(got) == list(range(n))
    assert bus.lag("t", "g") == 0
    # offsets advance exactly per partition, so a replay poll gets nothing
    assert bus.poll("t", "g", max_records=10) == []


def test_durable_segments_stay_open_and_survive_restart(tmp_path):
    d = str(tmp_path / "bus")
    bus = Bus(n_partitions=2, durable_dir=d)
    bus.publish("t", {"a": 1}, key="x")
    bus.publish_batch("t", [("x", {"a": 2}), ("y", {"a": 3})])
    assert bus._handles                 # handles held open across publishes
    bus.close()
    bus2 = Bus(n_partitions=2, durable_dir=d)
    vals = sorted(r.value["a"] for r in bus2.poll("t", "g", 100))
    assert vals == [1, 2, 3]


def test_scheduler_decision_telemetry_is_batched():
    s = Scheduler(publish_decisions=True)
    for i in range(4):
        s.cluster.add_server(f"s{i}", 32)
    s.gm.register_workload("w", {"availability_nines": 2.0})
    for i in range(10):
        s.submit(VM(f"v{i}", "w", "", 4))
    s.schedule_pending()
    from repro.core import hints as H
    recs = s.gm.bus.poll(H.TOPIC_SCHED_DECISIONS, "t", 100)
    assert len(recs) == 1               # one batched record per entry point
    batch = recs[0].value
    assert batch["kind"] == "place"
    assert batch["n"] == 10 and len(batch["decisions"]) == 10
    row = dict(zip(batch["fields"], batch["decisions"][0]))
    assert row["workload"] == "w" and row["server"]
