"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
asserting output shapes + no NaNs; prefill+decode vs full-forward consistency.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.archs import ARCHS, smoke_config
from repro.configs.base import ParallelConfig
from repro.models import model as M

PCFG = ParallelConfig(data=1, model=1, attn_impl="dense",
                      seq_shard_acts=False, fsdp=False)
KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg, key, batch=B, seq=S, train=True):
    kt, kf = jax.random.split(key)
    extra = 1 if train else 0
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(kf, (batch, seq, cfg.d_model),
                                            jnp.bfloat16),
                "tokens": jax.random.randint(kt, (batch, seq // 4 + extra), 0,
                                             cfg.vocab_size)}
    if cfg.family == "vlm":
        nv = cfg.n_vision_tokens
        return {"patches": jax.random.normal(kf, (batch, nv, M.VIS_EMBED_DIM),
                                             jnp.bfloat16),
                "tokens": jax.random.randint(kt, (batch, seq - nv + extra), 0,
                                             cfg.vocab_size)}
    return {"tokens": jax.random.randint(kt, (batch, seq + extra), 0,
                                         cfg.vocab_size)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_forward_and_grad_step(name):
    cfg = smoke_config(name)
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, KEY)

    def loss(p):
        l, _ = M.loss_and_aux(cfg, PCFG, p, batch)
        return l

    l0, grads = jax.jit(jax.value_and_grad(loss))(params)
    assert np.isfinite(float(l0)), name
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, name
    # one SGD step lowers the loss on the same batch
    lr = 2e-2
    p2 = jax.tree.map(lambda p, g: (p.astype(jnp.float32)
                                    - lr * g.astype(jnp.float32)).astype(p.dtype),
                      params, grads)
    l1 = jax.jit(loss)(p2)
    assert float(l1) < float(l0), (name, float(l0), float(l1))


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_prefill_decode_matches_forward(name):
    """Greedy decode continuation must match teacher-forced full forward."""
    cfg = smoke_config(name)
    params = M.init_params(cfg, KEY)
    batch = make_batch(cfg, KEY, train=False)
    n_prompt = 8 if cfg.family not in ("vlm",) else 4
    toks = batch["tokens"]

    # full forward logits at each position (teacher forcing)
    full_batch = dict(batch, tokens=toks)
    enc_out = None
    if cfg.family == "encdec":
        enc_out = M.encode(cfg, PCFG, params, batch["frames"])
    x, positions, _, _ = M._embed_inputs(cfg, params, full_batch,
                                         for_decode=True)
    x, _, _ = M._run_groups(cfg, PCFG, params["groups"], M.stack_groups(cfg),
                            x, positions, enc_out=enc_out)
    from repro.models.layers import basic
    x = basic.rmsnorm(params["final_norm"], x, cfg.rms_eps)
    ref_logits = basic.unembed_logits(params["embed"], x,
                                      cfg.final_logit_softcap)

    # prefill on the prompt prefix, then decode token by token
    max_len = toks.shape[1] + (cfg.n_vision_tokens
                               if cfg.family == "vlm" else 0)
    enc_len = batch["frames"].shape[1] if cfg.family == "encdec" else 0
    cache = M.init_cache(cfg, B, max_len, enc_len=enc_len)
    pre_batch = dict(batch, tokens=toks[:, :n_prompt])
    logits, cache = M.prefill(cfg, PCFG, params, pre_batch, cache)
    off = cfg.n_vision_tokens if cfg.family == "vlm" else 0
    np.testing.assert_allclose(
        np.asarray(logits[:, 0]),
        np.asarray(ref_logits[:, off + n_prompt - 1]), rtol=0.15, atol=0.15)

    for t in range(n_prompt, min(toks.shape[1], n_prompt + 4)):
        logits, cache = M.decode_step(cfg, PCFG, params, cache, toks[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(ref_logits[:, off + t]),
            rtol=0.15, atol=0.15)


def test_count_params_matches_tree():
    for name in ARCHS:
        cfg = smoke_config(name)
        tree = M.abstract_params(cfg)
        n_tree = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(tree))
        assert M.count_params(cfg) == n_tree
        if cfg.moe:
            assert M.count_params(cfg, active_only=True) < n_tree


def test_sub_quadratic_flags():
    assert ARCHS["mamba2-370m"].sub_quadratic
    assert ARCHS["recurrentgemma-9b"].sub_quadratic
    for n in ("gemma2-27b", "gemma2-9b", "llama3-405b", "minitron-8b",
              "granite-moe-1b-a400m", "whisper-tiny", "internvl2-26b"):
        assert not ARCHS[n].sub_quadratic, n
