"""Trainer-as-tenant tests: the elastic trainer attached to VMs placed by
the REAL scheduler (not the ``FaultInjector`` shim).

The ``TrainerTenant`` is trainer-agnostic, so the notice -> checkpoint ->
ack -> early-release -> resize choreography is pinned here against a stub
trainer (fast, no jax); one subprocess test then runs the full
``ai_training`` case study with the real ``WITrainer`` on 8 virtual host
devices and checks the acceptance bars end to end.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.agents import AgentRuntime, TrainerAgent, TrainerTenant
from repro.sched import Scheduler
from repro.sim.cluster import VM

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


class StubCkpt:
    def wait(self):
        pass


class StubTrainer:
    """Implements the tenant-facing trainer protocol; records calls."""

    def __init__(self):
        self.step = 0
        self.ckpt_every = 4
        self.resizes = []
        self.throttled = []
        self.emergencies = 0
        self.ckpt = StubCkpt()

    def step_once(self):
        self.step += 1

    def resize_to_devices(self, devs):
        if len(devs) < 1:
            return False
        self.resizes.append(list(devs))
        return True

    def set_throttled(self, on):
        self.throttled.append(bool(on))

    def emergency_checkpoint(self):
        self.emergencies += 1


def make_tenant(n_vms=2, devices=4, notice_s=60.0, emergency_ckpt_s=4.0,
                n_servers=3):
    s = Scheduler(default_notice_s=30.0)
    for i in range(n_servers):
        s.cluster.add_server(f"region-0/s{i}", 32, region="region-0")
    s.gm.register_workload("ai", {
        "scale_out_in": True, "scale_up_down": True,
        "preemptibility_pct": 80.0, "availability_nines": 2.0,
        "delay_tolerance_ms": 60_000.0, "x-eviction-notice-s": notice_s})
    tenant = TrainerTenant("ai", devices=list(range(devices)),
                           devices_per_vm=2,
                           emergency_ckpt_s=emergency_ckpt_s)
    for i in range(n_vms):
        s.submit(VM(f"ai{i}", "ai", "", 8, util_p95=0.5, spot=True,
                    harvest=True))
    s.schedule_pending()
    rt = AgentRuntime(s, policies={"ai": tenant.policy()})
    stub = StubTrainer()
    tenant.attach_trainer(stub)
    return s, rt, tenant, stub


def test_notice_checkpoint_ack_early_release_and_regrow():
    s, rt, tenant, stub = make_tenant()
    assert all(isinstance(a, TrainerAgent) for a in rt.agents.values())
    r = s.capacity_crunch("region-0", 8)
    assert r["evictions"] == 1
    # the REAL checkpoint happened at notice time, before any consent
    assert stub.emergencies == 1
    ticket = next(iter(s.evictor.tickets.values()))
    assert ticket.notice_s == 60.0          # hinted window honored
    vm_id = ticket.vm_id
    # the ack waits for the modeled durable-write latency (4 s)...
    s.run_until(3.9)
    assert s.cluster.vms[vm_id].alive
    # ...then lands on wi.events.acks and the pipeline early-releases
    s.run_until(4.1)
    assert not s.cluster.vms[vm_id].alive
    done = s.evictor.log[-1]
    assert done.outcome == "early_released"
    assert abs(done.lead_time_s - 4.0) < 1e-9
    assert s.evictor.violations() == []
    # the dead slice's devices left the mesh eagerly
    assert stub.resizes[-1] == tenant.active_devices()
    assert len(tenant.active_devices()) == 2
    # checkpoint was durable before the kill: nothing lost
    assert tenant.metrics["lost_work_s"] == 0.0
    # the replacement VM lands on the next tick and DP width re-grows
    s.tick()
    tenant.apply_pending()
    assert len(tenant.active_devices()) == 4
    assert rt.metrics["replacements_placed"] == 1
    # the ladder kill at the 60 s deadline is a no-op
    s.run_until(100.0)
    assert s.evictor.stats["kills"] == 0


def test_slow_checkpoint_rides_ladder_and_loses_bounded_work():
    # durable-write latency (120 s) cannot fit the 60 s window: the ladder
    # kill wins, the stale ack timer never fires, lost work is metered
    s, rt, tenant, stub = make_tenant(notice_s=60.0, emergency_ckpt_s=120.0)
    s.run_until(10.0)                   # accrue work since attach
    s.capacity_crunch("region-0", 8)
    assert tenant.metrics["ack_margin_min_s"] < 0  # agent knew it would lose
    s.run_until(200.0)
    done = s.evictor.log[-1]
    assert done.outcome == "killed"
    assert abs(done.lead_time_s - 60.0) < 1e-9     # full window honored
    assert s.evictor.violations() == []
    assert abs(tenant.metrics["lost_work_s"] - 70.0) < 1e-9
    # the kill still shrank the device map
    assert len(tenant.active_devices()) == 2


def test_throttle_halves_and_policy_pass_restores():
    s, rt, tenant, stub = make_tenant()
    lead = s.cluster.vms[tenant._order[0]]
    s.power_event(lead.server, shed_frac=0.9)
    assert stub.throttled == [True]     # microbatch halved
    # trainer throttles shed compute, not p95 demand (else the overclock
    # offer that restores the microbatch would never re-qualify)
    assert lead.util_p95 == 0.5
    # duplicate throttle notices do not re-toggle
    s.power_event(lead.server, shed_frac=0.9)
    assert stub.throttled == [True]
    # the periodic pass's OVERCLOCK_OFFER (util 0.5 > 0.4, applicable)
    # clears it through the guest channel
    s.run_policies()
    assert stub.throttled == [True, False]
    assert tenant.metrics["restores"] == 1


def test_oversubscription_pressure_throttles_the_trainer():
    # a correlated demand spike on an oversubscribed server: the policy's
    # spike-resolution core throttles the least-critical half, and the
    # trainer reacts to OversubscriptionPolicy's THROTTLE_NOTICE exactly
    # like it does to a power event's
    s, rt, tenant, stub = make_tenant(n_servers=1)
    sid = s.cluster.vms[tenant._order[0]].server
    for vm in s.cluster.vms.values():
        vm.oversubscribed = True
    acts = s.policies["oversubscription"].resolve_pressure_cluster(
        s.cluster, sid)
    assert any(a.kind == "throttle" for a in acts)
    assert True in stub.throttled
    assert tenant.metrics["throttle_notices"] >= 1


def test_harvest_scale_up_offer_grows_the_device_map():
    s, rt, tenant, stub = make_tenant(n_vms=2, devices=6)
    assert len(tenant.active_devices()) == 4 and len(tenant._spare) == 2
    s.run_policies()                    # HarvestPolicy offers spare cores
    tenant.apply_pending()
    # 8-core VMs, 2 devices each -> 4 cores/device; the grow cap (50% of
    # nominal) grants exactly one extra device per VM
    assert tenant.metrics["harvest_devices_granted"] == 2
    assert len(tenant.active_devices()) == 6
    assert stub.resizes[-1] == tenant.active_devices()


def test_total_reclaim_pauses_until_replacement_capacity_returns():
    s, rt, tenant, stub = make_tenant(n_vms=1, devices=2)
    s.capacity_crunch("region-0", 8)    # the only slice is reclaimed
    s.run_until(4.1)                    # ack -> early release
    assert tenant.paused                # nothing left to train on
    assert tenant.metrics["pauses"] == 1
    s.tick()                            # replacement lands
    tenant.apply_pending()
    assert not tenant.paused
    assert len(tenant.active_devices()) == 2


@pytest.mark.skipif(os.environ.get("CI", "") != ""
                    and os.environ.get("AI_TRAINING_E2E", "") == "",
                    reason="CI runs this exact scenario (with the same "
                           "asserts) in the bench-smoke job; set "
                           "AI_TRAINING_E2E=1 to force it in tier-1 too")
def test_ai_training_case_study_end_to_end():
    """The real WITrainer under the live scheduler: ≥2 reclaim waves, zero
    notice violations, ≥1 early release via a trainer ack, DP shrink +
    regrow with loss continuity, lost work ≤ one checkpoint interval per
    kill (the ISSUE's acceptance bars)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC, AI_TRAINING_STEPS="24")
    out = subprocess.run(
        [sys.executable, "-m", "repro.sim.casestudies.ai_training"],
        env=env, capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT ")][-1]
    r = json.loads(line[len("RESULT "):])
    assert r["waves"] >= 2
    assert r["violations"] == 0
    assert r["trainer_early_releases"] >= 1
    assert r["emergency_checkpoints"] >= 1
    assert r["dp_min"] < r["dp0"]                   # width shrank...
    assert r["dp_regrown"] > r["dp_min"]            # ...and re-grew
    assert r["resizes"] >= 2
    # only a ladder kill may lose work; early releases checkpoint first
    assert r["lost_work_s"] <= \
        r["trainer_ladder_kills"] * r["ckpt_interval_s"] + 1e-9
    assert r["losses_finite"]
    assert r["loss_last3"] < r["loss_first3"]       # continuity across it all
    assert r["microbatch_throttled"] >= 1           # throttle round trip...
    assert r["restores"] >= 1
    assert r["microbatch_final"] == 0               # ...fully restored
    assert r["fleet_lost_work_s_stateless"] == 0.0  # co-tenants kept whole
