"""Unit + property tests for the WI core (bus, store, safety, coordinator,
pricing, envelopes, managers, API)."""
import json
import threading

import pytest
pytest.importorskip("hypothesis")   # property tests need it; skip cleanly if absent
from hypothesis import given, settings, strategies as st

from repro.core import hints as H
from repro.core.bus import Bus
from repro.core.coordinator import Claim, Coordinator
from repro.core.envelope import KeyRegistry, seal, unseal
from repro.core.global_manager import GlobalManager
from repro.core.local_manager import LocalManager
from repro.core.pricing import (CONFLICT_SETS, PRICING, PRIORITY, CostMeter,
                                applicable_set, combined_price)
from repro.core.safety import ConsistencyChecker, FairShare, RateLimiter
from repro.core.store import Store


class Clock:
    def __init__(self):
        self.t = 0.0

    def __call__(self):
        return self.t


# ---------------------------------------------------------------------------
# hints
# ---------------------------------------------------------------------------

def test_hint_validation_and_conservative_defaults():
    H.validate_hints({"preemptibility_pct": 50.0, "scale_out_in": True})
    with pytest.raises(H.HintError):
        H.validate_hints({"preemptibility_pct": 150.0})
    with pytest.raises(H.HintError):
        H.validate_hints({"bogus": 1})
    H.validate_hints({"x-custom": 1})            # namespaced extension ok
    eff = H.effective(None)
    assert eff["availability_nines"] == 5.0      # most conservative
    assert eff["preemptibility_pct"] == 0.0
    eff = H.effective({"preemptibility_pct": 80.0})
    assert eff["preemptibility_pct"] == 80.0
    assert eff["delay_tolerance_ms"] == 0.0


# ---------------------------------------------------------------------------
# bus
# ---------------------------------------------------------------------------

def test_bus_offsets_and_groups():
    bus = Bus(n_partitions=2)
    for i in range(10):
        bus.publish("t", {"i": i}, key=f"k{i % 2}")
    r1 = bus.poll("t", "g1", max_records=4)
    assert len(r1) == 4
    r2 = bus.poll("t", "g1", max_records=100)
    assert len(r1) + len(r2) == 10
    # a different group sees everything from the start
    assert len(bus.poll("t", "g2", max_records=100)) == 10
    assert bus.lag("t", "g1") == 0
    # per-partition order preserved
    seen = {}
    for r in r1 + r2:
        seen.setdefault(r.partition, []).append(r.offset)
    for offs in seen.values():
        assert offs == sorted(offs)


def test_bus_push_subscribe():
    bus = Bus()
    got = []
    bus.subscribe("t", got.append)
    bus.publish("t", 42)
    assert got and got[0].value == 42


def test_bus_durability(tmp_path):
    b1 = Bus(durable_dir=str(tmp_path))
    for i in range(5):
        b1.publish("t", i, key="k")
    b2 = Bus(durable_dir=str(tmp_path))
    recs = b2.poll("t", "g", 100)
    assert [r.value for r in recs] == [0, 1, 2, 3, 4]


def test_bus_torn_tail_write(tmp_path):
    b1 = Bus(durable_dir=str(tmp_path), n_partitions=1)
    for i in range(5):
        b1.publish("t", i)
    seg = next(tmp_path.glob("*.log"))
    raw = seg.read_text()
    seg.write_text(raw[: len(raw) - 7])         # torn tail
    b2 = Bus(durable_dir=str(tmp_path), n_partitions=1)
    vals = [r.value for r in b2.poll("t", "g", 100)]
    assert vals == [0, 1, 2, 3]                 # prefix survives


# ---------------------------------------------------------------------------
# store: WAL + snapshot recovery (property)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["put", "del"]),
                          st.integers(0, 9), st.integers(0, 100)),
                max_size=40),
       st.integers(0, 10_000))
def test_store_crash_recovery_prefix(ops, cut):
    import tempfile, os, shutil
    d = tempfile.mkdtemp()
    try:
        s = Store(root=d, snapshot_every=7)
        applied = []
        for op, k, v in ops:
            if op == "put":
                s.put(f"k{k}", v)
            else:
                s.delete(f"k{k}")
            applied.append((op, k, v))
        s.close()
        # crash: truncate WAL at an arbitrary byte
        wal = os.path.join(d, "wal.log")
        raw = open(wal, "rb").read()
        open(wal, "wb").write(raw[: min(cut, len(raw))])
        s2 = Store(root=d)
        # recovered state must equal SOME prefix of the applied ops replayed
        # over the last snapshot: verify by replaying every prefix
        def state_after(n):
            st_ = {}
            for op, k, v in applied[:n]:
                if op == "put":
                    st_[f"k{k}"] = v
                else:
                    st_.pop(f"k{k}", None)
            return st_
        got = {k: v for k, v in s2.scan("")}
        assert any(got == state_after(n) for n in range(len(applied) + 1)), got
        s2.close()
    finally:
        shutil.rmtree(d)


def test_store_versioned_and_scan(tmp_path):
    s = Store(root=str(tmp_path))
    s.put("hints/a/1", {"x": 1})
    s.put("hints/a/2", {"x": 2})
    s.put("hints/b/1", {"x": 3})
    assert [k for k, _ in s.scan("hints/a/")] == ["hints/a/1", "hints/a/2"]
    seq1, _ = s.get_versioned("hints/a/1")
    s.put("hints/a/1", {"x": 9})
    seq2, v = s.get_versioned("hints/a/1")
    assert seq2 > seq1 and v == {"x": 9}


# ---------------------------------------------------------------------------
# safety
# ---------------------------------------------------------------------------

def test_rate_limiter():
    clk = Clock()
    rl = RateLimiter(rate_per_s=1.0, burst=3.0, clock=clk)
    assert [rl.allow("a") for _ in range(4)] == [True, True, True, False]
    clk.t += 2.0
    assert rl.allow("a") and rl.allow("a") and not rl.allow("a")
    assert rl.allow("b")                        # independent principals


def test_consistency_flipflop_and_eviction_contradiction():
    clk = Clock()
    c = ConsistencyChecker(clk, window_s=60, max_flips=3)
    for i in range(8):
        clk.t += 1
        v = c.check("w", "r", {"scale_out_in": bool(i % 2)})
        if not v.accepted:
            break
    assert not v.accepted and "flip-flop" in v.reason
    c2 = ConsistencyChecker(clk)
    assert c2.check("w", "vm1", {"preemptibility_pct": 80.0}).accepted
    c2.note_eviction_pending("vm1")
    v = c2.check("w", "vm1", {"preemptibility_pct": 90.0})
    assert not v.accepted and "eviction" in v.reason
    c2.note_eviction_done("vm1")
    assert c2.check("w", "vm1", {"preemptibility_pct": 90.0}).accepted


@given(st.dictionaries(st.text(min_size=1, max_size=3),
                       st.floats(0.01, 100.0), min_size=1, max_size=8),
       st.floats(0.1, 200.0))
@settings(max_examples=50, deadline=None)
def test_fair_share_properties(demands, capacity):
    alloc = FairShare.allocate(capacity, demands)
    assert set(alloc) == set(demands)
    for k in demands:
        assert -1e-6 <= alloc[k] <= demands[k] + 1e-6
    assert sum(alloc.values()) <= capacity + 1e-6
    # work conserving: either all demand met or capacity exhausted
    if sum(demands.values()) >= capacity:
        assert sum(alloc.values()) == pytest.approx(capacity, rel=1e-6)
    # max-min: unsatisfied claimants all get >= any satisfied one's share? No:
    # unsatisfied get the max share; check no one with leftover demand gets
    # less than someone else's allocation above their demand
    unsat = [k for k in demands if alloc[k] < demands[k] - 1e-6]
    if unsat:
        floor = min(alloc[k] for k in unsat)
        for k in demands:
            assert alloc[k] <= max(floor, demands[k]) + 1e-6


# ---------------------------------------------------------------------------
# coordinator (Table 4 / Fig 3)
# ---------------------------------------------------------------------------

def test_priority_order_and_preemption():
    co = Coordinator(seed=1)
    co.set_capacity("s1/cores", 10.0)
    g = co.submit([Claim("harvest", "w1", "s1/cores", 8, False, ts=0.0),
                   Claim("on_demand", "w2", "s1/cores", 8, False, ts=1.0)])
    by_opt = {x.claim.opt: x for x in g}
    assert by_opt["on_demand"].amount == 8.0     # priority 0 wins
    assert by_opt["harvest"].amount == 2.0


def test_fair_share_equal_priority_compressible():
    co = Coordinator()
    co.set_capacity("s1/cpu_freq", 1.0)
    g = co.submit([Claim("overclocking", "w1", "s1/cpu_freq", 0.8, True, 0.0),
                   Claim("overclocking", "w2", "s1/cpu_freq", 0.8, True, 0.0)])
    amounts = sorted(x.amount for x in g)
    assert amounts == [pytest.approx(0.5), pytest.approx(0.5)]


def test_earliest_request_noncompressible_and_random_tiebreak():
    co = Coordinator(seed=7)
    co.set_capacity("s1/slot", 1.0)
    g = co.submit([Claim("spot", "w1", "s1/slot", 1.0, False, ts=5.0),
                   Claim("spot", "w2", "s1/slot", 1.0, False, ts=2.0)])
    w = {x.claim.workload: x.amount for x in g}
    assert w["w2"] == 1.0 and w["w1"] == 0.0     # earliest wins
    # simultaneous: deterministic under a fixed seed
    co2 = Coordinator(seed=7)
    co2.set_capacity("s1/slot", 1.0)
    g2 = co2.submit([Claim("spot", "w1", "s1/slot", 1.0, False, ts=2.0),
                     Claim("spot", "w2", "s1/slot", 1.0, False, ts=2.0)])
    assert sum(x.amount for x in g2) == 1.0


def test_priority_table_matches_paper():
    order = ["on_demand", "ma_datacenters", "rightsizing", "oversubscription",
             "auto_scaling", "non_preprovision", "region_agnostic",
             "underclocking", "overclocking", "spot", "harvest"]
    assert [PRIORITY[o] for o in order] == list(range(11))


# ---------------------------------------------------------------------------
# pricing (Table 2)
# ---------------------------------------------------------------------------

def test_pricing_table_2():
    assert PRICING["spot"].user_benefit == 0.85
    assert PRICING["harvest"].user_benefit == 0.91
    assert PRICING["rightsizing"].user_benefit == 0.50
    assert PRICING["ma_datacenters"].user_benefit == 0.40
    for p in PRICING.values():
        assert 0 < p.price_multiplier <= 1.0
        assert p.price_multiplier == pytest.approx(1 - p.user_benefit)


def test_combined_price_conflict_sets():
    # spot+harvest do NOT stack: only the cheaper (harvest) applies
    assert combined_price({"spot", "harvest"}) == pytest.approx(0.09)
    # independent opts stack multiplicatively
    assert combined_price({"spot", "region_agnostic"}) == \
        pytest.approx(0.15 * 0.78)
    # oc/uc/ma conflict
    assert combined_price({"overclocking", "ma_datacenters"}) == \
        pytest.approx(0.60)
    assert combined_price(()) == 1.0


def test_applicability_matrix():
    spot_ok = H.effective({"preemptibility_pct": 50.0})
    assert "spot" in applicable_set(spot_ok)
    assert "harvest" not in applicable_set(spot_ok)    # needs scale_up_down
    rich = H.effective({"preemptibility_pct": 80.0, "scale_up_down": True,
                        "scale_out_in": True, "delay_tolerance_ms": 100.0,
                        "region_independent": True,
                        "availability_nines": 3.0,
                        "deploy_time_ms": 120_000.0})
    s = applicable_set(rich)
    assert set(s) == set(PRICING)                      # everything applies
    assert applicable_set(H.effective(None)) == ()     # conservative: nothing


def test_cost_meter():
    m = CostMeter()
    m.charge(10, 1.0, opts=("spot",))
    m.charge(10, 1.0, opts=())
    assert m.saving == pytest.approx((1 - (0.15 + 1.0) / 2))


# ---------------------------------------------------------------------------
# envelopes
# ---------------------------------------------------------------------------

def test_envelope_roundtrip_and_tamper():
    kr = KeyRegistry()
    k = kr.provision("w1")
    env = seal(k, {"preemptibility_pct": 40.0}, nonce=b"0" * 12)
    assert unseal(k, env) == {"preemptibility_pct": 40.0}
    bad = dict(env)
    bad["ct"] = ("00" + env["ct"][2:])
    assert unseal(k, bad) is None
    k2 = kr.provision("w2")
    assert unseal(k2, env) is None


# ---------------------------------------------------------------------------
# managers end-to-end
# ---------------------------------------------------------------------------

def make_gm():
    clk = Clock()
    gm = GlobalManager(clock=clk, hint_rate_per_s=100, hint_burst=100)
    return gm, clk


def test_hint_round_trip_vm_to_optimization():
    gm, clk = make_gm()
    lm = LocalManager("rack0/srv0", gm.bus, clock=clk, vm_hint_rate_per_s=100,
                      vm_hint_burst=100)
    gm.register_workload("bigdata", {"preemptibility_pct": 60.0,
                                     "scale_out_in": True,
                                     "delay_tolerance_ms": 500.0})
    ep = lm.attach_vm("vm3", "bigdata")
    assert ep.set_runtime_hints({"preemptibility_pct": 10.0})
    eff = gm.effective_hints("bigdata", "rack0/srv0/vm3")
    assert eff["preemptibility_pct"] == 10.0    # runtime overrides deployment
    assert eff["scale_out_in"] is True          # deployment hint visible
    eff_other = gm.effective_hints("bigdata", "rack0/srv0/vm9")
    assert eff_other["preemptibility_pct"] == 60.0


def test_platform_event_delivery_and_ack():
    gm, clk = make_gm()
    lm = LocalManager("rack0/srv0", gm.bus, clock=clk)
    gm.register_workload("svc")
    ep = lm.attach_vm("vm1", "svc")
    got = []
    ep.on_event(got.append)
    gm.publish_platform_hint(H.PlatformHint(
        event=H.PlatformEvent.EVICTION_NOTICE.value, workload="svc",
        resource="rack0/srv0/vm1", deadline_s=30.0))
    assert got and got[0]["event"] == "eviction_notice"
    assert ep.scheduled_events()
    ep.ack_event(got[0]["seq"])
    assert not ep.scheduled_events()
    assert lm.acked(got[0]["seq"]) == {"vm1"}


def test_workload_addressed_event_reaches_only_that_workloads_vms():
    gm, clk = make_gm()
    lm = LocalManager("rack0/srv0", gm.bus, clock=clk)
    gm.register_workload("svc")
    gm.register_workload("other")
    ep_a = lm.attach_vm("vm1", "svc")
    ep_b = lm.attach_vm("vm2", "svc")
    ep_c = lm.attach_vm("vm3", "other")
    # resource == "": workload-addressed, fans out to that workload's VMs
    gm.publish_platform_hint(H.PlatformHint(
        event=H.PlatformEvent.MAINTENANCE.value, workload="svc",
        resource="", deadline_s=60.0))
    assert len(ep_a.scheduled_events()) == 1
    assert len(ep_b.scheduled_events()) == 1
    assert ep_c.scheduled_events() == []
    assert lm.stats["events_delivered"] == 2
    # an unrelated server-qualified resource matches nobody here
    gm.publish_platform_hint(H.PlatformHint(
        event=H.PlatformEvent.MAINTENANCE.value, workload="svc",
        resource="rack9/srv9/vm1"))
    assert lm.stats["events_delivered"] == 2


def test_ack_event_fans_in_across_vms():
    gm, clk = make_gm()
    lm = LocalManager("rack0/srv0", gm.bus, clock=clk)
    gm.register_workload("svc")
    eps = [lm.attach_vm(f"vm{i}", "svc") for i in range(3)]
    gm.publish_platform_hint(H.PlatformHint(
        event=H.PlatformEvent.MAINTENANCE.value, workload="svc",
        resource="rack0/srv0", deadline_s=60.0))     # server-wide broadcast
    seq = eps[0].scheduled_events()[0]["seq"]
    for ep in eps[:2]:
        ep.ack_event(seq)
    assert lm.acked(seq) == {"vm0", "vm1"}           # fan-in, vm2 pending
    assert lm.stats["events_acked"] == 2
    # acks are forwarded onto the bus for the platform to react to
    acks = [r.value for r in gm.bus.poll(H.TOPIC_EVENT_ACKS, "t", 10)]
    assert [a["vm"] for a in acks] == ["vm0", "vm1"]
    assert all(a["seq"] == seq and a["event"] == "maintenance"
               for a in acks)
    eps[2].ack_event(seq)
    assert lm.acked(seq) == {"vm0", "vm1", "vm2"}


def test_rate_limit_rejects_hint_storm():
    clk = Clock()
    gm = GlobalManager(clock=clk, hint_rate_per_s=1.0, hint_burst=2.0)
    gm.register_workload("w")
    ok = [gm.set_hints("w", "*", {"scale_out_in": True}, source="s")
          for _ in range(5)]
    assert sum(ok) < 5 and gm.stats["rejected_rate_limit"] > 0


def test_envelope_path_through_global_manager():
    gm, clk = make_gm()
    key = gm.register_workload("sec")
    env = seal(key, {"region_independent": True})
    assert gm.set_hints("sec", "*", {}, envelope=env)
    assert gm.effective_hints("sec")["region_independent"] is True
    bad = seal(b"wrongkey" * 4, {"region_independent": True})
    assert not gm.set_hints("sec", "*", {}, envelope=bad)


def test_aggregation_levels():
    gm, clk = make_gm()
    gm.register_workload("w1")
    gm.register_workload("w2")
    gm.set_hints("w1", "rack0/srv0/vm0", {"preemptibility_pct": 40.0})
    gm.set_hints("w1", "rack0/srv1/vm0", {"preemptibility_pct": 80.0})
    gm.set_hints("w2", "rack1/srv0/vm0", {"region_independent": True})
    racks = gm.aggregate("rack")
    assert racks["rack0"]["n"] == 2
    assert racks["rack0"]["preemptibility_pct_mean"] == pytest.approx(60.0)
    assert racks["rack1"]["region_independent_frac"] == 1.0
    servers = gm.aggregate("server")
    assert "rack0/srv0" in servers and "rack0/srv1" in servers
    wl = gm.aggregate("workload")
    assert wl["w1"]["n"] == 2


def test_api_server_round_trip():
    from repro.core.api import ApiClient, ApiServer
    gm, clk = make_gm()
    srv = ApiServer(gm).start()
    try:
        cl = ApiClient(srv.address)
        r = cl.call(op="register", workload="api-wl",
                    hints={"scale_out_in": True})
        assert r["ok"]
        r = cl.call(op="set_hints", workload="api-wl",
                    hints={"preemptibility_pct": 30.0})
        assert r["ok"]
        r = cl.call(op="get_hints", workload="api-wl")
        assert r["hints"]["preemptibility_pct"] == 30.0
        assert r["hints"]["scale_out_in"] is True
        r = cl.call(op="bogus")
        assert not r["ok"]
        cl.close()
    finally:
        srv.stop()
