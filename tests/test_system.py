"""End-to-end behaviour test for the paper's system: a training job runs
under WI, publishes hints, receives an eviction notice from the Spot
manager, checkpoints, shrinks, and keeps training with the loss descending.

(The full elastic matrix is in tests/test_runtime_elastic.py; this is the
single-process integration smoke across all layers: WI core + optimization
manager + runtime + model + optimizer + checkpointing + data.)
"""
import tempfile

import numpy as np

from repro.configs.archs import smoke_config
from repro.configs.base import RunConfig
from repro.core import hints as H
from repro.core.global_manager import GlobalManager
from repro.core.optimizations import SpotManager
from repro.runtime.trainer import WITrainer
from repro.sim.cluster import VM, Cluster


def test_wi_training_system_end_to_end():
    cfg = smoke_config("minitron-8b")
    rcfg = RunConfig(model=cfg, learning_rate=2e-3, warmup_steps=5,
                     total_steps=100)
    gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
    tr = WITrainer(rcfg, gm, ckpt_dir=tempfile.mkdtemp(), model_axis=1,
                   ckpt_every=6, batch_override=8, seq_override=32)
    tr.run(8)

    # the job's runtime hints are visible to the platform
    eff = gm.effective_hints("train-job", "rack0/host0/vm0")
    assert eff["preemptibility_pct"] in (40.0, 90.0)
    assert gm.aggregate("workload")["train-job"]["n"] >= 1

    # a real optimization manager issues the eviction via the hint channel
    cl = Cluster()
    cl.add_server("rack0/host0", 64)
    cl.add_vm(VM("vm0", "train-job", "rack0/host0", 8, spot=True))
    spot = SpotManager(gm)
    acts = spot.reclaim(cl.view(), cores_needed=8)
    assert acts and acts[0].workload == "train-job"

    tr.run(16)          # trainer consumed the notice and kept going
    kinds = [e["kind"] for e in tr.events_log]
    assert "eviction_notice" in kinds
    assert "checkpoint" in kinds
    losses = [m["loss"] for m in tr.metrics_log]
    assert all(np.isfinite(l) for l in losses)
    assert np.mean(losses[-4:]) < np.mean(losses[:4])
    assert tr.ckpt.latest_step() is not None
