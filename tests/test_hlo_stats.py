"""Unit tests for the HLO analyzer: trip counts, call-graph multipliers,
dot FLOPs via symbol lookup, collective wire-byte formulas."""
import pytest

from repro.analysis import hlo_stats as H

SAMPLE = """\
HloModule jit_step

%wrapped_compare_computation.1 (p0: s32[], p1: s32[]) -> pred[] {
  %p0 = s32[] parameter(0)
  %p1 = s32[] parameter(1)
  ROOT %cmp = pred[] compare(%p0, %p1), direction=LT
}

%cond.1 (arg: (s32[], f32[8,16]{1,0})) -> pred[] {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%arg), index=0
  %c5 = s32[] constant(5)
  ROOT %wc = pred[] fusion(%gte, %c5), kind=kLoop, calls=%wrapped_compare_computation.1
}

%body.1 (arg: (s32[], f32[8,16]{1,0})) -> (s32[], f32[8,16]{1,0}) {
  %arg = (s32[], f32[8,16]{1,0}) parameter(0)
  %i = s32[] get-tuple-element(%arg), index=0
  %x = f32[8,16]{1,0} get-tuple-element(%arg), index=1
  %w = f32[16,16]{1,0} constant({...})
  %d = f32[8,16]{1,0} dot(%x, %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,16]{1,0} all-reduce(%d), replica_groups={{0,1,2,3}}, to_apply=%add_comp
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8,16]{1,0}) tuple(%ip, %ar)
}

%add_comp (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main.1 (in: f32[8,16]{1,0}) -> f32[8,16]{1,0} {
  %in = f32[8,16]{1,0} parameter(0)
  %zero = s32[] constant(0)
  %tup = (s32[], f32[8,16]{1,0}) tuple(%zero, %in)
  %wh = (s32[], f32[8,16]{1,0}) while(%tup), condition=%cond.1, body=%body.1
  %ag = f32[32,16]{1,0} all-gather(%in), replica_groups={{0,1,2,3}}, dimensions={0}
  ROOT %out = f32[8,16]{1,0} get-tuple-element(%wh), index=1
}
"""


def test_trip_count_and_flop_multiplication():
    hs = H.analyze(SAMPLE, n_devices=4)
    # dot: 2 * 8*16 * 16 = 4096 flops, inside a trip-5 while
    assert hs.dot_flops == pytest.approx(5 * 2 * 8 * 16 * 16)


def test_collective_wire_bytes():
    hs = H.analyze(SAMPLE, n_devices=4)
    # all-reduce of 8*16*4 bytes, group 4, ring: 2*(3/4)*512 = 768, x5 trips
    # all-gather out 32*16*4=2048, (3/4)*2048 = 1536, x1
    assert hs.collective_by_kind["all-reduce"] == pytest.approx(768 * 5)
    assert hs.collective_by_kind["all-gather"] == pytest.approx(1536)
    assert hs.collective_by_group[4] == pytest.approx(768 * 5 + 1536)
    assert hs.n_collectives >= 6


def test_wire_byte_formulas():
    assert H._wire_bytes("all-reduce", 100, 100, 4) == pytest.approx(150)
    assert H._wire_bytes("all-gather", 25, 100, 4) == pytest.approx(75)
    assert H._wire_bytes("reduce-scatter", 100, 25, 4) == pytest.approx(75)
    assert H._wire_bytes("collective-permute", 100, 100, 4) == 100
    assert H._wire_bytes("all-reduce", 100, 100, 1) == 0.0


def test_roofline_model_flops_sane():
    from repro.analysis.roofline import model_flops
    from repro.configs.archs import ARCHS
    from repro.models.model import count_params
    # train: >= 6*N*D matmul floor
    n = count_params(ARCHS["minitron-8b"], active_only=True)
    d = 256 * 4096
    assert model_flops("minitron-8b", "train_4k") >= 6.0 * n * d
    # MoE uses active params (much smaller than total)
    tot = count_params(ARCHS["granite-moe-3b-a800m"])
    act = count_params(ARCHS["granite-moe-3b-a800m"], active_only=True)
    assert act < 0.6 * tot
    # decode is per-token
    assert model_flops("minitron-8b", "decode_32k") < \
        model_flops("minitron-8b", "train_4k") / 1000
