"""Chaos layer tests (src/repro/chaos/): fault plans, the ChaosBus wrapper,
unannounced-crash repair, heartbeat leases, checkpoint integrity, and the
end-to-end property: under ANY random drop/duplicate/delay/reorder/crash
schedule the eviction pipeline keeps its invariants — every ticket reaches
a terminal outcome, nothing is double-released, no capacity leaks, and the
cluster's incremental books still balance.  A deterministic seeded soak
always runs; the hypothesis variant skips cleanly without hypothesis.
"""
import random

import pytest

from repro.agents import STATEFUL, STATELESS, AgentPolicy, AgentRuntime
from repro.chaos import (ChannelFaults, ChaosBus, CrashInjector, FaultPlan,
                         install_guest_modes, lossy_guest_plan)
from repro.chaos import plan as CP
from repro.core import hints as H
from repro.core.bus import Bus
from repro.core.global_manager import GlobalManager
from repro.core.pricing import BillingMeter
from repro.sched import Scheduler
from repro.sim.cluster import VM
from repro.sim.engine import Engine

TERMINAL = {"killed", "early_released", "cancelled", "already_gone",
            "crashed"}


# ---------------------------------------------------------------------------
# FaultPlan validation
# ---------------------------------------------------------------------------


def test_fault_plan_rejects_protected_topics():
    for topic in (H.TOPIC_SCHED_DECISIONS, H.TOPIC_EVICTIONS,
                  H.TOPIC_FAILURES):
        with pytest.raises(ValueError):
            FaultPlan(channels={topic: ChannelFaults(drop_p=0.1)})


def test_fault_plan_rejects_unknown_guest_mode():
    with pytest.raises(ValueError):
        FaultPlan(guest_modes={"w": "eats_homework"})


def test_lossy_guest_plan_never_touches_protected_topics():
    plan = lossy_guest_plan(seed=3, drop_p=0.5, dup_p=0.5, delay_p=0.5,
                            reorder_p=0.5)
    assert not CP.PROTECTED_TOPICS & set(plan.channels)


# ---------------------------------------------------------------------------
# ChaosBus semantics
# ---------------------------------------------------------------------------


def _collect(bus, topic):
    got = []
    bus.subscribe(topic, lambda rec: got.append(rec.value))
    return got


def test_zero_plan_chaosbus_is_pass_through():
    """An empty plan must make the wrapper behaviorally identical to the
    inner bus (the acceptance bar for reusing committed benchmark runs)."""
    plain, wrapped = Bus(), ChaosBus(Bus(), FaultPlan())
    a, b = _collect(plain, "t"), _collect(wrapped, "t")
    for i in range(50):
        plain.publish("t", i, key=str(i % 3))
        wrapped.publish("t", i, key=str(i % 3))
    assert a == b == list(range(50))
    assert all(v == 0 for v in wrapped.stats.values())


def test_chaosbus_drop_all_loses_every_record():
    bus = ChaosBus(Bus(), FaultPlan(
        channels={"t": ChannelFaults(drop_p=1.0)}))
    got = _collect(bus, "t")
    for i in range(10):
        bus.publish("t", i)
    assert got == [] and bus.stats["dropped"] == 10


def test_chaosbus_duplicate_all_delivers_twice():
    bus = ChaosBus(Bus(), FaultPlan(
        channels={"t": ChannelFaults(dup_p=1.0)}))
    got = _collect(bus, "t")
    for i in range(5):
        bus.publish("t", i)
    assert got == [0, 0, 1, 1, 2, 2, 3, 3, 4, 4]
    assert bus.stats["duplicated"] == 5


def test_chaosbus_delay_defers_until_engine_advances():
    eng = Engine()
    bus = ChaosBus(Bus(clock=eng.clock), FaultPlan(
        channels={"t": ChannelFaults(delay_p=1.0, delay_max_s=3.0)}),
        engine=eng)
    got = _collect(bus, "t")
    bus.publish("t", "x")
    assert got == [] and bus.stats["delayed"] == 1
    eng.run(until=3.0)
    assert got == ["x"]


def test_chaosbus_reorder_swaps_adjacent_records():
    eng = Engine()
    bus = ChaosBus(Bus(clock=eng.clock), FaultPlan(
        channels={"t": ChannelFaults(reorder_p=1.0)}), engine=eng)
    got = _collect(bus, "t")
    bus.publish("t", "first")       # held back
    bus.publish("t", "second")      # overtakes, flushes the held record
    assert got == ["second", "first"]
    assert bus.stats["reordered"] >= 1


def test_chaosbus_reorder_safety_timer_flushes_lone_record():
    eng = Engine()
    bus = ChaosBus(Bus(clock=eng.clock), FaultPlan(
        channels={"t": ChannelFaults(reorder_p=1.0, reorder_hold_s=2.0)}),
        engine=eng)
    got = _collect(bus, "t")
    bus.publish("t", "only")
    assert got == []
    eng.run(until=2.5)              # no successor: the timer delivers it
    assert got == ["only"]


def test_delay_plan_without_engine_raises():
    with pytest.raises(ValueError):
        ChaosBus(Bus(), FaultPlan(
            channels={"t": ChannelFaults(delay_p=0.5)}))


# ---------------------------------------------------------------------------
# unannounced crashes: repair loop closes every book
# ---------------------------------------------------------------------------


def _mini_fleet(seed=0, drop_p=0.0, dup_p=0.0, delay_p=0.0, reorder_p=0.0,
                guest_modes=None, n_servers=6, notice_s=20.0):
    eng = Engine()
    plan = lossy_guest_plan(seed=seed, drop_p=drop_p, dup_p=dup_p,
                            delay_p=delay_p, delay_max_s=3.0,
                            reorder_p=reorder_p,
                            guest_modes=guest_modes or {})
    bus = ChaosBus(Bus(clock=eng.clock), plan, eng)
    gm = GlobalManager(bus=bus, clock=eng.clock,
                       hint_rate_per_s=1e6, hint_burst=1e6)
    s = Scheduler(gm=gm, engine=eng, default_notice_s=notice_s)
    for i in range(n_servers):
        s.cluster.add_server(f"region-0/s{i}", 32.0, region="region-0")
    policies = {}
    rng = random.Random(seed)
    for w, pol in (("web", AgentPolicy(statefulness=STATELESS,
                                       scale_out_in=True)),
                   ("batch", AgentPolicy(statefulness=STATEFUL,
                                         state_gb=2.0, ckpt_gbps=0.5))):
        s.gm.register_workload(w, {"scale_out_in": True,
                                   "preemptibility_pct": 70.0})
        policies[w] = pol
    for mode_w in (guest_modes or {}):
        s.gm.register_workload(mode_w, {"preemptibility_pct": 90.0})
        policies[mode_w] = AgentPolicy(statefulness=STATEFUL, state_gb=1.0,
                                       ckpt_gbps=0.5)
    vm = 0
    for w in policies:
        for _ in range(6):
            s.submit(VM(f"vm{vm}", w, "", 4,
                        util_p95=rng.uniform(0.2, 0.8), spot=True))
            vm += 1
    s.schedule_pending()
    install_guest_modes(plan, policies)
    rt = AgentRuntime(s, policies=policies)
    return s, rt, plan, eng


def test_crash_repair_closes_books_and_publishes_failure():
    s, rt, plan, eng = _mini_fleet()
    meter = BillingMeter(s.gm, s.cluster)     # meters open on crash test VM?
    # re-place one VM so the meter (attached late) observes its decision
    records = []
    s.gm.bus.subscribe(H.TOPIC_FAILURES, lambda r: records.append(r.value))
    victim = next(v for v in s.cluster.vms.values() if v.alive and v.server)
    eng.run(until=10.0)
    assert s.cluster.crash_vm(victim.vm_id)
    eng.run(until=10.5)           # crash queued, not yet detected
    assert not records
    s.tick()                      # repair loop drains the crash queue
    assert [r["vm"] for r in records] == [victim.vm_id]
    assert records[0]["crash_t"] == pytest.approx(10.0)
    assert s.stats["crashed_vms"] == 1
    assert not victim.alive and victim.server == ""
    s.cluster.assert_consistent()
    # double delivery of the same crash is impossible: queue was drained
    s.tick()
    assert len(records) == 1


def test_crash_mid_eviction_resolves_ticket_as_crashed_not_violation():
    s, rt, plan, eng = _mini_fleet()
    # stateful guest: its ack waits on a 4 s checkpoint, so a crash at
    # t=2 lands while the ticket is still open
    victim = next(v for v in s.cluster.vms.values()
                  if v.alive and v.server and v.workload == "batch")
    from repro.core.optimizations.policies import Action
    [t] = s.evictor.submit(
        [Action("evict", vm=victim.vm_id, workload=victim.workload,
                payload={"after_s": 20.0})], source="test")
    eng.run(until=2.0)
    assert s.cluster.crash_vm(victim.vm_id)
    s.tick()
    assert t.outcome == "crashed" and not t.killed
    assert s.evictor.violations() == []
    s.cluster.assert_consistent()


def test_billing_meter_closes_at_crash_instant():
    eng = Engine()
    s = Scheduler(engine=eng)
    meter = BillingMeter(s.gm, s.cluster)
    s.cluster.add_server("region-0/s0", 32.0, region="region-0")
    s.gm.register_workload("w", {})
    s.submit(VM("a", "w", "", 8))
    s.schedule_pending()
    eng.run(until=100.0)
    assert s.cluster.crash_vm("a")
    eng.run(until=400.0)          # long dead tail: no phantom metering
    s.tick()
    rec = meter.reconcile(400.0)
    assert rec["abs_diff"] < 1e-9
    assert rec["metered_core_hours"] == pytest.approx(8 * 100.0 / 3600.0)


def test_silent_guest_lease_expires_and_ladder_kill_stands():
    s, rt, plan, eng = _mini_fleet(guest_modes={"rogue": "never_ack"},
                                   notice_s=15.0)
    rt.enable_leases(lease_s=10.0, until=200.0, check_period_s=2.0)
    rogue_vm = next(v for v in s.cluster.vms.values()
                    if v.workload == "rogue" and v.alive)
    from repro.core.optimizations.policies import Action
    [t] = s.evictor.submit(
        [Action("evict", vm=rogue_vm.vm_id, workload="rogue",
                payload={"after_s": 15.0})], source="test")
    s.start(2.0, 60.0)
    s.run_until(60.0)
    assert s.evictor.stats.get("silent_guests", 0) >= 1
    assert t.outcome == "killed" and not rogue_vm.alive
    # killed exactly at the deadline => full notice honored, no violation
    assert s.evictor.violations() == []


# ---------------------------------------------------------------------------
# the chaos property: any schedule, every invariant
# ---------------------------------------------------------------------------


def _chaos_episode(seed: int, drop_p: float, dup_p: float, delay_p: float,
                   reorder_p: float, n_crashes: int, horizon: float = 300.0):
    s, rt, plan, eng = _mini_fleet(seed=seed, drop_p=drop_p, dup_p=dup_p,
                                   delay_p=delay_p, reorder_p=reorder_p)
    rng = random.Random(seed ^ 0x5EED)
    rt.enable_leases(lease_s=30.0, until=horizon, check_period_s=5.0)
    for w in range(3):
        eng.at(20.0 + 60.0 * w,
               lambda: s.capacity_crunch("region-0", 40.0))
    crasher = CrashInjector(s.cluster, eng, plan)
    for i in range(n_crashes):
        eng.at(rng.uniform(10.0, horizon - 60.0),
               lambda: crasher.crash_vm(rng.choice(
                   sorted(s.cluster.vms))) if s.cluster.vms else None)
    s.start(5.0, horizon)
    s.run_until(horizon)

    # every ticket terminal — nothing stuck mid-ladder after the horizon
    open_tickets = [t for t in s.evictor.log
                    if t.outcome not in TERMINAL] + \
        list(s.evictor.tickets.values())
    assert not open_tickets, [vars(t) for t in open_tickets]
    # no violation among delivered notices
    assert s.evictor.violations() == []
    # no double release / capacity leak: the incremental books balance
    s.cluster.assert_consistent()
    # every crash the cluster recorded was repaired and published
    assert s.stats.get("crashed_vms", 0) == s.cluster.crashes_total
    # a dead VM never occupies a server
    for v in s.cluster.vms.values():
        if not v.alive:
            assert v.server == ""


def test_chaos_schedule_property_soak():
    """Deterministic always-run form of the property: random fault rates
    and crash schedules, seeded per episode."""
    for seed in range(6):
        rng = random.Random(seed)
        _chaos_episode(seed,
                       drop_p=rng.uniform(0.0, 0.4),
                       dup_p=rng.uniform(0.0, 0.3),
                       delay_p=rng.uniform(0.0, 0.3),
                       reorder_p=rng.uniform(0.0, 0.2),
                       n_crashes=rng.randrange(0, 5))


def test_chaos_schedule_property_hypothesis():
    """Hypothesis variant (skips cleanly without hypothesis installed)."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.given(seed=st.integers(min_value=0, max_value=2**16 - 1),
               drop_p=st.floats(min_value=0.0, max_value=0.5),
               dup_p=st.floats(min_value=0.0, max_value=0.5),
               delay_p=st.floats(min_value=0.0, max_value=0.3),
               reorder_p=st.floats(min_value=0.0, max_value=0.3),
               n_crashes=st.integers(min_value=0, max_value=6))
    @hyp.settings(max_examples=15, deadline=None)
    def run(seed, drop_p, dup_p, delay_p, reorder_p, n_crashes):
        _chaos_episode(seed, drop_p, dup_p, delay_p, reorder_p, n_crashes,
                       horizon=200.0)

    run()


# ---------------------------------------------------------------------------
# store durability: crash anywhere across the snapshot path (always-run
# deterministic form; the hypothesis properties live in test_wi_store.py)
# ---------------------------------------------------------------------------


def test_store_snapshot_crash_at_every_wal_byte_recovers_a_prefix(tmp_path):
    from pathlib import Path

    from repro.core.store import Store
    ops = [("put", "a", 1), ("put", "b", 2), ("del", "a", 0),
           ("put", "c", 3), ("put", "b", 4), ("put", "d", 5),
           ("del", "b", 0), ("put", "a", 6)]
    states = [{}]
    for op, k, v in ops:
        st = dict(states[-1])
        st[k] = v
        if op == "del":
            st.pop(k, None)
        states.append(st)
    src = tmp_path / "src"
    with Store(root=str(src), snapshot_every=3) as store:
        for op, k, v in ops:
            if op == "put":
                store.put(k, v)
            else:
                store.delete(k)
    wal = (src / "wal.log").read_bytes()
    snap = (src / "snapshot.json").read_bytes()
    for cut in range(len(wal) + 1):
        d = tmp_path / f"crash{cut}"
        d.mkdir()
        (d / "snapshot.json").write_bytes(snap)
        (d / "wal.log").write_bytes(wal[:cut])
        (d / "snapshot.json.tmp").write_bytes(b'{"torn')
        with Store(root=str(d), snapshot_every=10_000) as rec:
            got = {k: rec.get(k) for k in "abcd"
                   if rec.get(k) is not None}
        assert got in states, (got, cut)


# ---------------------------------------------------------------------------
# checkpoint integrity (crc32 + corrupt fallback)
# ---------------------------------------------------------------------------


def _ckpt(tmp_path, keep=5):
    ckpt_mod = pytest.importorskip("repro.ckpt.checkpoint")
    return ckpt_mod, ckpt_mod.Checkpointer(str(tmp_path), keep=keep)


def test_checkpoint_crc_detects_corrupt_leaf(tmp_path):
    import numpy as np
    ckpt_mod, ck = _ckpt(tmp_path)
    like = {"w": np.zeros(16)}
    ck.save(1, {"w": np.ones(16)})
    ck.save(2, {"w": np.full(16, 2.0)})
    assert ck.verify(2)
    leaf = next((ck.root / "step_2").glob("*.npy"))
    leaf.write_bytes(b"torn write")
    assert not ck.verify(2)
    assert ck.verify(1)
    assert ck.latest_good_step() == 1
    with pytest.raises(ckpt_mod.CheckpointCorruptError):
        ck.restore(2, like)
    restored = ck.restore(1, like)
    assert float(restored["w"][0]) == 1.0


def test_checkpoint_bitflip_detected_not_just_torn_file(tmp_path):
    import numpy as np
    ckpt_mod, ck = _ckpt(tmp_path)
    ck.save(1, {"w": np.arange(8.0)})
    leaf = next((ck.root / "step_1").glob("*.npy"))
    arr = np.load(leaf)
    arr[3] += 1.0                       # silent bit-level corruption
    np.save(leaf, arr)
    assert not ck.verify(1)
    with pytest.raises(ckpt_mod.CheckpointCorruptError):
        ck.restore(1, {"w": np.zeros(8)})


def test_checkpoint_legacy_manifest_without_crc_still_verifies(tmp_path):
    import json

    import numpy as np
    _, ck = _ckpt(tmp_path)
    ck.save(1, {"w": np.ones(4)})
    mf = ck.root / "step_1" / "manifest.json"
    manifest = json.loads(mf.read_text())
    del manifest["crc32"]
    mf.write_text(json.dumps(manifest))
    assert ck.verify(1)                 # nothing to check against
    ck.restore(1, {"w": np.zeros(4)})   # and restore keeps working


def test_sim_trainer_recovers_past_corrupt_checkpoint(tmp_path):
    chaos_soak = pytest.importorskip("repro.sim.casestudies.chaos_soak")
    tr = chaos_soak.SimCkptTrainer(str(tmp_path), ckpt_every=10)
    for _ in range(25):
        tr.step_once()                  # checkpoints at 10 and 20
    corrupted = tr.corrupt_newest()
    assert corrupted == 20
    fresh = chaos_soak.SimCkptTrainer(str(tmp_path), ckpt_every=10)
    assert fresh.step == 10             # fell back past the corrupt one
    assert any(e["kind"] == "corrupt_checkpoint_skipped"
               for e in fresh.events_log)
    assert tr.step - fresh.step <= 10 + 5   # bounded by interval + tail
