"""Elastic-runtime tests: checkpoint/restart equivalence, WI-driven elastic
resize under eviction, harvest grow, throttle, straggler detection.

Resize tests run in a subprocess with 8 virtual host devices so the mesh can
actually change shape (the main test process keeps 1 device).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.runtime.straggler import StragglerDetector

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


COMMON = textwrap.dedent("""
    import json, os, tempfile
    import jax, numpy as np
    from repro.configs.archs import smoke_config
    from repro.configs.base import RunConfig
    from repro.core.global_manager import GlobalManager
    from repro.runtime.trainer import WITrainer
    from repro.runtime.faults import FaultInjector
    cfg = smoke_config("minitron-8b")
    rcfg = RunConfig(model=cfg, learning_rate=1e-3, warmup_steps=5,
                     total_steps=200)
""")


def test_elastic_shrink_and_grow_under_wi_events():
    res = run_sub(COMMON + textwrap.dedent("""
        gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        d = tempfile.mkdtemp()
        tr = WITrainer(rcfg, gm, ckpt_dir=d, model_axis=2, ckpt_every=5,
                       batch_override=8, seq_override=32)
        inj = FaultInjector(gm, "train-job")
        assert tr.dp == 4 and len(tr.active_devices) == 8
        tr.run(4)
        inj.evict(n_devices=4)            # lose half the fleet
        tr.run(8)
        dp_after_evict = tr.dp
        inj.offer_capacity(n_devices=4)   # harvest offer: grow back
        tr.run(12)
        dp_after_grow = tr.dp
        losses = [m["loss"] for m in tr.metrics_log]
        evs = [e["kind"] for e in tr.events_log]
        print("RESULT " + json.dumps({
            "dp_evict": dp_after_evict, "dp_grow": dp_after_grow,
            "losses": losses, "events": evs,
            "final_step": tr.step}))
    """))
    assert res["dp_evict"] == 2
    assert res["dp_grow"] == 4
    assert res["final_step"] == 12
    assert "eviction_notice" in res["events"]
    assert "resize" in res["events"]
    assert all(np.isfinite(l) for l in res["losses"])
    # loss continues to go down across the resizes
    assert np.mean(res["losses"][-3:]) < np.mean(res["losses"][:3])


def test_checkpoint_restart_equivalence():
    """Same data stream + restart from checkpoint == uninterrupted run."""
    res = run_sub(COMMON + textwrap.dedent("""
        gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        d1, d2 = tempfile.mkdtemp(), tempfile.mkdtemp()
        tr = WITrainer(rcfg, gm, ckpt_dir=d1, model_axis=2, ckpt_every=4,
                       batch_override=8, seq_override=32)
        tr.run(12)
        uninterrupted = [m["loss"] for m in tr.metrics_log]

        gm2 = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        tr2 = WITrainer(rcfg, gm2, ckpt_dir=d2, model_axis=2, ckpt_every=4,
                        batch_override=8, seq_override=32)
        tr2.run(8)                      # checkpoint lands at step 8
        tr2.ckpt.wait()
        del tr2
        gm3 = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        tr3 = WITrainer(rcfg, gm3, ckpt_dir=d2, model_axis=2, ckpt_every=4,
                        batch_override=8, seq_override=32)
        assert tr3.step == 8, tr3.step
        tr3.run(12)
        resumed = [m["loss"] for m in tr3.metrics_log]
        print("RESULT " + json.dumps({
            "uninterrupted": uninterrupted[8:], "resumed": resumed}))
    """))
    np.testing.assert_allclose(res["uninterrupted"], res["resumed"],
                               rtol=2e-4, atol=2e-4)


def test_throttle_changes_microbatching():
    res = run_sub(COMMON + textwrap.dedent("""
        gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        d = tempfile.mkdtemp()
        tr = WITrainer(rcfg, gm, ckpt_dir=d, model_axis=2, ckpt_every=50,
                       batch_override=8, seq_override=32)
        inj = FaultInjector(gm, "train-job")
        tr.run(2)
        mb0 = tr.pcfg.microbatch
        inj.throttle()
        tr.run(4)
        mb1 = tr.pcfg.microbatch
        inj.unthrottle()
        tr.run(6)
        mb2 = tr.pcfg.microbatch
        losses = [m["loss"] for m in tr.metrics_log]
        print("RESULT " + json.dumps(
            {"mb": [mb0, mb1, mb2], "losses": losses}))
    """))
    assert res["mb"] == [0, 2, 0]
    assert all(np.isfinite(l) for l in res["losses"])


def test_runtime_hints_published():
    res = run_sub(COMMON + textwrap.dedent("""
        gm = GlobalManager(hint_rate_per_s=1e6, hint_burst=1e6)
        d = tempfile.mkdtemp()
        tr = WITrainer(rcfg, gm, ckpt_dir=d, model_axis=2, ckpt_every=4,
                       batch_override=8, seq_override=32)
        tr.run(6)
        eff = gm.effective_hints("train-job", "rack0/host0/vm0")
        print("RESULT " + json.dumps({
            "preempt": eff["preemptibility_pct"],
            "fwd": tr.local.stats["vm_hints_forwarded"]}))
    """))
    assert res["fwd"] >= 6
    assert res["preempt"] in (40.0, 90.0)


def test_straggler_detector():
    det = StragglerDetector(min_samples=3, threshold=1.4)
    for i in range(10):
        for h in ("h0", "h1", "h2", "h3"):
            det.record(h, 100.0 + (i % 3))
        det.record("h4", 180.0)
    assert det.stragglers() == ["h4"]
    assert det.slowdown("h4") == pytest.approx(1.8, abs=0.1)
    assert det.slowdown("h0") == pytest.approx(1.0, abs=0.05)
